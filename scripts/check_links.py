#!/usr/bin/env python3
"""Relative-link checker for the repo docs (offline lychee substitute).

Scans the markdown set the docs CI job guards -- README.md, docs/*.md,
rust/README.md -- for inline links and fails (exit 1) on any relative
link whose target file does not exist. External (http/https/mailto)
links are skipped; pure in-page anchors (#...) are skipped; a
file#anchor link is checked for the file part only.

Usage: python3 scripts/check_links.py [repo_root]
"""

import glob
import os
import re
import sys

# [text](target) inline links; deliberately simple — the docs use no
# nested parens or reference-style targets for files.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root):
    files = [os.path.join(root, "README.md"), os.path.join(root, "rust", "README.md")]
    files += sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    return [f for f in files if os.path.isfile(f)]


def check_file(path, root):
    errors = []
    text = open(path, encoding="utf-8").read()
    # ignore fenced code blocks: links in ``` blocks are illustrative
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for match in LINK_RE.finditer(text):
        target = match.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            errors.append((os.path.relpath(path, root), match.group(1), resolved))
    return errors


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    files = doc_files(root)
    if not files:
        print("check_links: no markdown files found under", root)
        return 1
    all_errors = []
    for f in files:
        all_errors.extend(check_file(f, root))
    if all_errors:
        print(f"check_links: {len(all_errors)} broken relative link(s):")
        for src, link, resolved in all_errors:
            print(f"  {src}: ({link}) -> missing {resolved}")
        return 1
    print(f"check_links: OK — {len(files)} files, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
