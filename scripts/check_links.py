#!/usr/bin/env python3
"""Relative-link and anchor checker for the repo docs (offline lychee
substitute).

Scans the markdown set the docs CI job guards -- README.md, docs/*.md,
rust/README.md -- for inline links and fails (exit 1) on:

* any relative link whose target file does not exist;
* any `#fragment` (in-page `#section` links *and* the fragment part of
  `file.md#section` links) that does not match a heading anchor in the
  target document, using GitHub's heading-slug rules (lowercase,
  punctuation stripped, spaces to dashes, `-1`/`-2` suffixes for
  duplicate headings).

External (http/https/mailto) links are skipped.

Usage: python3 scripts/check_links.py [repo_root]
"""

import glob
import os
import re
import sys

# [text](target) inline links; deliberately simple — the docs use no
# nested parens or reference-style targets for files.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.MULTILINE)

SKIP_PREFIXES = ("http://", "https://", "mailto:")


def doc_files(root):
    files = [os.path.join(root, "README.md"), os.path.join(root, "rust", "README.md")]
    files += sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    return [f for f in files if os.path.isfile(f)]


def strip_code_blocks(text):
    # links/headings inside ``` blocks are illustrative
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def github_slug(heading):
    """GitHub's anchor slug for a heading line (good enough for our
    docs: inline code/links unwrapped, punctuation dropped, spaces to
    dashes; underscores are preserved, as GitHub does)."""
    # unwrap inline markdown: `code`, [text](target), * emphasis
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    h = h.replace("`", "").replace("*", "")
    h = h.strip().lower()
    # drop everything that is not alphanumeric, underscore, space or dash
    h = re.sub(r"[^\w\s-]", "", h, flags=re.UNICODE)
    h = re.sub(r"\s+", "-", h.strip())
    return h


def anchors_of(path, cache={}):
    """All valid heading anchors of a markdown file (with GitHub's
    duplicate -1/-2 numbering)."""
    if path in cache:
        return cache[path]
    anchors = set()
    counts = {}
    try:
        text = strip_code_blocks(open(path, encoding="utf-8").read())
    except OSError:
        cache[path] = anchors
        return anchors
    for m in HEADING_RE.finditer(text):
        slug = github_slug(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    cache[path] = anchors
    return anchors


def check_file(path, root):
    errors = []
    text = strip_code_blocks(open(path, encoding="utf-8").read())
    for match in LINK_RE.finditer(text):
        target = match.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        file_part, _, fragment = target.partition("#")
        resolved = (
            path
            if not file_part
            else os.path.normpath(os.path.join(os.path.dirname(path), file_part))
        )
        if not os.path.exists(resolved):
            errors.append((os.path.relpath(path, root), match.group(1),
                           f"missing {resolved}"))
            continue
        if fragment and resolved.endswith(".md"):
            if fragment not in anchors_of(resolved):
                errors.append((
                    os.path.relpath(path, root),
                    match.group(1),
                    f"no heading anchor #{fragment} in "
                    f"{os.path.relpath(resolved, root)}",
                ))
    return errors


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    files = doc_files(root)
    if not files:
        print("check_links: no markdown files found under", root)
        return 1
    all_errors = []
    for f in files:
        all_errors.extend(check_file(f, root))
    if all_errors:
        print(f"check_links: {len(all_errors)} broken link(s)/anchor(s):")
        for src, link, why in all_errors:
            print(f"  {src}: ({link}) -> {why}")
        return 1
    print(f"check_links: OK — {len(files)} files, all relative links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
