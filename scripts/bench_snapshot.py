#!/usr/bin/env python3
"""Run the throughput sweeps and snapshot Mb/s per backend/shard count.

Runs `cargo bench --bench table1_throughput` and `--bench batching`
(which write `bench_results/*.json`), plus a loopback `tcvd serve` +
`loadgen` sweep over session counts (docs/NETWORKING.md), then
aggregates the CPU-backend rows into one trajectory document,
`BENCH_PR7.json`, so successive PRs can compare like-for-like numbers:

  {
    "mode": "smoke" | "default" | "full",
    "table1_workload": {"info_bits": ..., "backends": {
        "scalar": {"mbps": ..., "speedup_vs_scalar": 1.0}, ...,
        "degraded": {...}}},   # scalar fallback = worst-case degraded shard
    "shard_scaling": {"info_bits": ..., "rows": [
        {"backend": "simd" | "simd-r2" | ..., "radix": 1 | 2,
         "shards": 2, "mbps": ...}, ...]},
    "survivor": {"rows": [...]},
    "termination": {"blocks": ..., "rows": [
        {"mode": "flushed" | "tail-biting", "block_stages": ...,
         "data_bits_per_block": ..., "info_mbps": ...,
         "rate_efficiency": ...}, ...]},
    "net": {"transport": "tcp", "backend": "simd", "rows": [
        {"sessions": 1, "aggregate_mbps": ..., "p50_ms": ...,
         "p99_ms": ..., "blocks": ..., "shed_retries": ...}, ...]},
    "summary": {"scalar_mbps": ..., "simd_mbps": ..., "simd_vs_scalar": ...,
                "degraded_mbps": ...,
                "radix2_vs_radix1": ...,
                "tail_biting_vs_flushed_info": ...,
                "net_sessions_256_vs_1": ...,
                "net_sessions_4096_vs_256": ...}
  }

`summary.radix2_vs_radix1` compares the simd backend's per-rho shard
rows (`simd-r2` vs `simd`): at every shard count measured for both, it
takes mbps(rho=2) / mbps(rho=1), and reports the best ratio. Taking the
max over shard counts keeps one noisy point from failing a floor check
while a genuine regression (rho=2 slower at *every* shard count) still
trips it.

The `termination` rows come from the batching bench's flushed vs
tail-biting short-block sweep (info Mb/s counts *data* bits, so the
flushed rows pay the k-1 flush-bit rate loss; `docs/DECODING-MODES.md`
explains the model). `summary.tail_biting_vs_flushed_info` is the
info-throughput ratio at the shortest measured block length.

CI runs `scripts/bench_snapshot.py --smoke` (tiny frame budgets via
TCVD_BENCH_SMOKE=1) on every push to keep the sweeps from rotting;
numbers meant for reading (docs/PERFORMANCE.md) come from a default or
`--full` run on a quiet machine.

The `net` rows come from real loopback sockets: the script builds the
`tcvd` and `loadgen` binaries, starts `tcvd serve --listen 127.0.0.1:0`
on the simd backend, parses the announced address, and runs the
bit-verifying loadgen soak at each session count (1 to 4096 concurrent
sessions on the readiness-driven reactor; on Linux the auto-selected
epoll backend carries the top of the curve). Read the rows as a
scaling curve — aggregate Mb/s should grow with sessions until the
shards saturate while p99 stays bounded. `summary.net_sessions_256_vs_1`
is the 256-session / 1-session aggregate-throughput ratio; its
committed floor of 1.0 (bench_floors.json) is the "high session counts
must not collapse the reactor" tripwire. `summary.net_sessions_4096_vs_256`
is the 4096-session / 256-session ratio; its committed floor of 0.9 is
the epoll-scale tripwire — a 16x jump in polled fds may flatten the
curve but must not collapse it (an O(fds)-per-tick regression, e.g. the
kernel backend silently degrading to poll(2), shows up here first).

Usage:
  python3 scripts/bench_snapshot.py [--smoke | --full] [--out PATH]
      [--skip-run] [--no-net] [--min-simd-ratio R]
      [--enforce-floors FLOORS.json]

`--skip-run` aggregates existing bench_results/ JSON without invoking
cargo (it also skips the net sweep, which needs live binaries);
`--no-net` skips only the net sweep.
`--min-simd-ratio R` exits 1 if simd/scalar single-shard
throughput on the table-1 workload is below R (the PR-4 acceptance
floor is 3.0; leave it off in CI smoke runs, where container noise
makes absolute ratios unreliable).
`--enforce-floors FLOORS.json` exits 1 if any summary ratio named in
the floors file (committed as `bench_floors.json`; keys are summary
ratio names, values are minimum acceptable ratios) regresses below its
floor, or is missing from the run. CI runs this in smoke mode, so the
committed floors are deliberately *loose* lower bounds — tripwires for
"the fast path stopped being fast" (a silently-disabled AVX2 dispatch,
a fallback to scalar, a radix-2 kernel slower than radix-1 everywhere),
not headline performance claims. Quotable numbers still come from a
default or --full run on a quiet machine.
"""

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "bench_results")


def run_benches(mode):
    env = dict(os.environ)
    env.pop("TCVD_BENCH_SMOKE", None)
    env.pop("TCVD_BENCH_FULL", None)
    if mode == "smoke":
        env["TCVD_BENCH_SMOKE"] = "1"
    elif mode == "full":
        env["TCVD_BENCH_FULL"] = "1"
    for bench in ("table1_throughput", "batching"):
        cmd = ["cargo", "bench", "--bench", bench]
        print(f"bench_snapshot: running {' '.join(cmd)} (mode={mode})", flush=True)
        proc = subprocess.run(cmd, cwd=REPO, env=env)
        if proc.returncode != 0:
            sys.exit(f"bench_snapshot: {' '.join(cmd)} failed "
                     f"(rc={proc.returncode})")


NET_SESSIONS = [1, 8, 32, 256, 4096]
# Must match the loadgen binary's pipeline defaults (simd backend on the
# 64+32/32 CPU tile) so the HELLO handshake and the oracle line up.
# --max-sessions lifts the admission cap above the largest sweep point
# (the default cap of 1024 would load-shed most of the 4096-session
# row into retry churn).
NET_SERVE_FLAGS = ["--backend", "simd", "--payload", "64",
                   "--head", "32", "--tail", "32",
                   "--max-sessions", str(max(NET_SESSIONS))]


def net_sweep(mode):
    """Loopback serving sweep: tcvd serve + loadgen at each session count."""
    cmd = ["cargo", "build", "--release", "--bin", "tcvd", "--bin", "loadgen"]
    print(f"bench_snapshot: running {' '.join(cmd)}", flush=True)
    if subprocess.run(cmd, cwd=REPO).returncode != 0:
        sys.exit("bench_snapshot: cargo build failed")
    release = os.path.join(REPO, "target", "release")

    serve = subprocess.Popen(
        [os.path.join(release, "tcvd"), "serve", "--listen", "127.0.0.1:0"]
        + NET_SERVE_FLAGS,
        cwd=REPO, stdout=subprocess.PIPE, text=True)
    try:
        addr = None
        for line in serve.stdout:
            if "listening tcp=" in line:
                addr = line.rsplit("tcp=", 1)[1].strip()
                break
        if not addr:
            sys.exit("bench_snapshot: tcvd serve never announced its address")

        rows = []
        for sessions in NET_SESSIONS:
            lg = [os.path.join(release, "loadgen"),
                  "--connect", addr, "--sessions", str(sessions), "--json"]
            if mode == "smoke":
                lg.append("--smoke")
            elif mode == "full":
                lg += ["--blocks", "8", "--block-stages", "512"]
            print(f"bench_snapshot: running {' '.join(lg[1:])}", flush=True)
            proc = subprocess.run(lg, cwd=REPO, stdout=subprocess.PIPE,
                                  text=True)
            out = proc.stdout
            if proc.returncode != 0:
                sys.exit(f"bench_snapshot: loadgen soak failed "
                         f"(rc={proc.returncode}):\n{out}")
            brace = out.find("{")
            if brace < 0:
                sys.exit(f"bench_snapshot: loadgen emitted no JSON:\n{out}")
            report = json.loads(out[brace:])
            rows.append({k: report[k] for k in
                         ("sessions", "aggregate_mbps", "p50_ms", "p99_ms",
                          "blocks", "shed_retries")})
    finally:
        serve.terminate()
        serve.wait()
    return {"transport": "tcp", "backend": "simd", "rows": rows}


def load(name):
    path = os.path.join(RESULTS, name)
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except FileNotFoundError:
        sys.exit(f"bench_snapshot: {path} missing — did the bench run? "
                 "(drop --skip-run, or check the bench output for SKIPs)")


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true", help="tiny CI budgets")
    ap.add_argument("--full", action="store_true", help="full-rigor budgets")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_PR7.json"))
    ap.add_argument("--skip-run", action="store_true",
                    help="aggregate existing bench_results/ without cargo")
    ap.add_argument("--no-net", action="store_true",
                    help="skip the loopback serve + loadgen sweep")
    ap.add_argument("--min-simd-ratio", type=float, default=None,
                    help="fail below this simd/scalar table-1 ratio")
    ap.add_argument("--enforce-floors", metavar="FLOORS.json", default=None,
                    help="fail if any summary ratio named in this file "
                         "regresses below its committed floor")
    args = ap.parse_args()
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    mode = "smoke" if args.smoke else "full" if args.full else "default"

    if not args.skip_run:
        run_benches(mode)

    table1 = load("table1_throughput.json")
    batching = load("batching.json")

    backends = {}
    for row in table1.get("cpu_rows", []):
        backends[row["backend"]] = {
            "mbps": row["mbps"],
            "speedup_vs_scalar": row.get("speedup_vs_scalar"),
        }
    if not backends:
        sys.exit("bench_snapshot: table1_throughput.json has no cpu_rows — "
                 "re-run the bench (old results file?)")
    if "scalar" in backends:
        # a fully-degraded shard runs the scalar reference backend
        # (docs/RELIABILITY.md degradation chain), so the scalar row
        # doubles as the worst-case degraded-pipeline throughput floor;
        # tracked as its own row so the trajectory stays comparable if
        # the chain's terminal backend ever changes
        backends["degraded"] = dict(backends["scalar"])

    doc = {
        "mode": mode,
        "table1_workload": {
            "info_bits": table1.get("info_bits"),
            "backends": backends,
        },
        "shard_scaling": {
            "info_bits": batching.get("shard_info_bits"),
            "rows": batching.get("shard_rows", []),
        },
        "survivor": {
            "info_bits": batching.get("survivor_info_bits"),
            "rows": batching.get("survivor_rows", []),
        },
        "termination": {
            "blocks": batching.get("termination_blocks"),
            "rows": batching.get("termination_rows", []),
        },
    }
    if not doc["termination"]["rows"]:
        sys.exit("bench_snapshot: batching.json has no termination_rows — "
                 "re-run the bench (old results file?)")
    if not (args.skip_run or args.no_net):
        doc["net"] = net_sweep(mode)
    scalar = backends.get("scalar", {}).get("mbps")
    simd = backends.get("simd", {}).get("mbps")
    if scalar and simd:
        doc["summary"] = {
            "scalar_mbps": scalar,
            "simd_mbps": simd,
            "simd_vs_scalar": simd / scalar,
            # Mb/s of a shard degraded all the way down the chain
            # (scalar fallback; docs/RELIABILITY.md)
            "degraded_mbps": scalar,
        }
        # radix-2 vs radix-1 simd: best per-shard-count ratio from the
        # shard-scaling sweep (see the module docstring for why max)
        r1 = {r["shards"]: r["mbps"] for r in doc["shard_scaling"]["rows"]
              if r["backend"] == "simd"}
        r2 = {r["shards"]: r["mbps"] for r in doc["shard_scaling"]["rows"]
              if r["backend"] == "simd-r2"}
        ratios = [r2[s] / r1[s] for s in sorted(r1) if s in r2 and r1[s]]
        if ratios:
            doc["summary"]["radix2_vs_radix1"] = max(ratios)
        # tail-biting vs flushed info throughput at the shortest block
        term = doc["termination"]["rows"]
        shortest = min((r["block_stages"] for r in term), default=None)
        by_mode = {r["mode"]: r["info_mbps"] for r in term
                   if r["block_stages"] == shortest}
        if by_mode.get("flushed") and by_mode.get("tail-biting"):
            doc["summary"]["tail_biting_vs_flushed_info"] = (
                by_mode["tail-biting"] / by_mode["flushed"])
    if "net" in doc:
        # reactor scaling tripwires. Both ratios are pinned to explicit
        # session counts (not min/max of the sweep) so extending
        # NET_SESSIONS never silently changes what a committed floor
        # measures: 256-vs-1 is the "high session counts must not
        # collapse the reactor" check, 4096-vs-256 is the epoll-scale
        # check (the kernel backend must hold aggregate throughput
        # through a 16x jump in polled fds).
        by_sessions = {r["sessions"]: r["aggregate_mbps"]
                       for r in doc["net"]["rows"]}
        lo, mid, hi = (by_sessions.get(1), by_sessions.get(256),
                       by_sessions.get(4096))
        if lo and mid:
            doc.setdefault("summary", {})["net_sessions_256_vs_1"] = mid / lo
        if mid and hi:
            doc.setdefault("summary", {})["net_sessions_4096_vs_256"] = hi / mid

    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench_snapshot: wrote {args.out}")
    if "net" in doc and doc["net"]["rows"]:
        top = doc["net"]["rows"][-1]
        print(f"bench_snapshot: net {top['sessions']} sessions -> "
              f"{top['aggregate_mbps']:.2f} Mb/s aggregate, "
              f"p99 {top['p99_ms']:.2f} ms")
    if "summary" in doc and "simd_vs_scalar" in doc["summary"]:
        s = doc["summary"]
        print(f"bench_snapshot: scalar {s['scalar_mbps']:.2f} Mb/s, "
              f"simd {s['simd_mbps']:.2f} Mb/s "
              f"({s['simd_vs_scalar']:.2f}x)")
        if "radix2_vs_radix1" in s:
            print(f"bench_snapshot: simd radix-2 vs radix-1 "
                  f"{s['radix2_vs_radix1']:.2f}x (best shard point)")
        if "net_sessions_256_vs_1" in s:
            print(f"bench_snapshot: net 256-session vs 1-session aggregate "
                  f"{s['net_sessions_256_vs_1']:.2f}x")
        if "net_sessions_4096_vs_256" in s:
            print(f"bench_snapshot: net 4096-session vs 256-session aggregate "
                  f"{s['net_sessions_4096_vs_256']:.2f}x")
        if args.min_simd_ratio is not None and s["simd_vs_scalar"] < args.min_simd_ratio:
            sys.exit(f"bench_snapshot: simd/scalar ratio "
                     f"{s['simd_vs_scalar']:.2f} below floor {args.min_simd_ratio}")
    elif args.min_simd_ratio is not None:
        sys.exit("bench_snapshot: --min-simd-ratio given but scalar/simd "
                 "rows are missing from the bench output")

    if args.enforce_floors is not None:
        with open(args.enforce_floors, encoding="utf-8") as f:
            floors = json.load(f)
        summary = doc.get("summary", {})
        failures = []
        for name, floor in sorted(floors.items()):
            if name.startswith("_"):
                continue  # schema/comment keys
            got = summary.get(name)
            if got is None:
                failures.append(f"{name}: missing from summary "
                                f"(floor {floor})")
            elif got < floor:
                failures.append(f"{name}: {got:.3f} below floor {floor}")
            else:
                print(f"bench_snapshot: floor ok — {name} "
                      f"{got:.3f} >= {floor}")
        if failures:
            sys.exit("bench_snapshot: performance floor regression:\n  "
                     + "\n  ".join(failures))


if __name__ == "__main__":
    main()
