"""Pytest bootstrap: make the ``compile`` package importable when the
suite is launched from the repo root (``python -m pytest python/tests``)."""

import pathlib
import sys

_HERE = pathlib.Path(__file__).resolve().parent
if str(_HERE) not in sys.path:
    sys.path.insert(0, str(_HERE))
