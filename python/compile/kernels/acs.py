"""L1 — the tensor-formulated ACS step and its Pallas kernel.

The paper's §V/§VIII mapping: one decoder step (rho trellis stages) is
``n_ops`` dense 16x16 multiply-accumulates ``D = A@B + C`` (tensor-core /
MXU primitive) followed by a max/argmax epilogue (Eq 22) and a fixed
permutation/gather that re-arranges the 2^{k-1} path metrics for the next
step. Batched frames extend the matmul column dimension: on the MXU the
effective shape is ``[16,16] @ [16, 16*F]``, so the systolic array fills
with the frame batch.

Two implementations share `make_step_fn`:

* `pallas_acs_call` — a Pallas kernel with a sequential stage grid and the
  path metrics carried in VMEM scratch (the paper keeps Lambda in
  registers/smem across iterations). `interpret=True` on CPU.
* the `jnp` variant in `model.py` — identical math under `lax.scan`, used
  for the CPU-throughput artifacts.

Precision (paper §IX-B): A and B are always "half" (bf16 here — tensor
cores only offer fp16 A/B); the accumulator C/D and the stored path
metrics follow `acc_dtype`; the LLR array follows `chan_dtype` before it
is loaded into B.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU scratch shapes work under interpret mode on CPU too
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from ..packing import Packing

NEG = -1.0e9


@dataclasses.dataclass(frozen=True)
class StepConsts:
    """Packing spec tensors baked as jnp constants (static per code).

    The data-independent gathers of the step (lambda gather by CG, the
    state permutation by SRC) are ALSO provided as one-hot matrices:
    XLA-CPU executes a small dense matmul an order of magnitude faster
    than the equivalent gather, and `x @ onehot` is numerically exact
    (each output is a single product 1.0 * x). See §Perf in DESIGN.md.
    """

    A: jnp.ndarray          # [O,16,16] bf16 (+-1/0)
    E: jnp.ndarray          # [O,16,16,W] bf16 (0/1)
    CG: jnp.ndarray         # [O,16,16] i32 (clipped), -1 flagged via CGM
    CGM: jnp.ndarray        # [O,16,16] bool (True = valid lambda slot)
    CG_OH: jnp.ndarray      # [S, O*256] f32 one-hot: lam -> C layout
    CG_NEG: jnp.ndarray     # [O,16,16] f32: NEG where no lambda source
    SRC_FLAT: jnp.ndarray   # [S] i32 flat (o*G+g)*16+c index per state
    SRC_OH: jnp.ndarray     # [O*G*16, S] f32 one-hot: val -> state order
    PINV_S: jnp.ndarray     # [S, gamma] i32 argmax -> left local state
    gamma: int
    n_ops: int
    width: int
    n_states: int

    @staticmethod
    def from_packing(pk: Packing, n_states: int) -> "StepConsts":
        O, G, C = pk.OS.shape
        src_flat = (pk.SRC[:, 0] * G + pk.SRC[:, 1]) * 16 + pk.SRC[:, 2]
        pinv_s = pk.PINV[pk.SRC[:, 0], pk.SRC[:, 2], :]
        cg_flat = pk.CG.reshape(-1)
        cg_oh = np.zeros((n_states, cg_flat.size), dtype=np.float32)
        for i, s in enumerate(cg_flat):
            if s >= 0:
                cg_oh[s, i] = 1.0
        src_oh = np.zeros((O * G * 16, n_states), dtype=np.float32)
        for s, k in enumerate(src_flat):
            src_oh[k, s] = 1.0
        return StepConsts(
            A=jnp.asarray(pk.A, dtype=jnp.bfloat16),
            E=jnp.asarray(pk.E, dtype=jnp.bfloat16),
            CG=jnp.asarray(np.maximum(pk.CG, 0), dtype=jnp.int32),
            CGM=jnp.asarray(pk.CG >= 0),
            CG_OH=jnp.asarray(cg_oh),
            CG_NEG=jnp.asarray(np.where(pk.CG < 0, NEG, 0.0).astype(np.float32)),
            SRC_FLAT=jnp.asarray(src_flat.astype(np.int32)),
            SRC_OH=jnp.asarray(src_oh),
            PINV_S=jnp.asarray(pinv_s.astype(np.int32)),
            gamma=pk.gamma,
            n_ops=O,
            width=pk.width,
            n_states=n_states,
        )


#: the spec arrays a step consumes, in the order they are passed to the
#: Pallas kernel as inputs (Pallas forbids captured array constants).
CONST_FIELDS = ("A", "E", "CG_OH", "CG_NEG", "SRC_OH", "PINV_S")


def const_arrays(c: StepConsts) -> Tuple[jnp.ndarray, ...]:
    return tuple(getattr(c, f) for f in CONST_FIELDS)


def make_step_fn(c: StepConsts, acc_dtype):
    """Returns step(consts, lam [F,S] acc, llr [F,W]) -> (lam' [F,S] acc,
    phi [F,S] i32) where consts = const_arrays(c) (possibly read from
    kernel refs). All paper equations referenced inline."""

    O, W, S, gamma = c.n_ops, c.width, c.n_states, c.gamma
    G = 16 // gamma

    def step(consts, lam: jnp.ndarray, llr: jnp.ndarray):
        A, E, CG_OH, CG_NEG, SRC_OH, PINV_S = consts
        F = lam.shape[0]
        llr_h = llr.astype(jnp.bfloat16)            # B is always half
        # B[f,o,r,col] = sum_e E[o,r,col,e] * llr[f,e]      (Eq 19 layout)
        B = jnp.einsum("orce,fe->forc", E, llr_h)
        # C[f,o,r,col] = lambda of the gathered left state   (Eq 21/37).
        # Expressed as a one-hot matmul (exact: one product per output) —
        # far faster than a gather on XLA-CPU, free on the MXU.
        lam_g = (jnp.dot(lam.astype(jnp.float32), CG_OH)
                 .reshape(F, O, 16, 16) + CG_NEG[None])
        # D = A @ B + C  — the tensor-core / MXU op          (Eq 20)
        # fold the frame batch into matmul columns: [O, r, F*16] with the
        # frame index major in the column dimension
        Bm = jnp.transpose(B, (1, 2, 0, 3)).reshape(O, 16, F * 16)
        prod = jax.lax.dot_general(
            A, Bm, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                                     # [O,16,F*16]
        prod = jnp.swapaxes(prod.reshape(O, 16, F, 16), 1, 2)  # [O,F,16,16]
        D = (jnp.swapaxes(prod, 0, 1) + lam_g).astype(acc_dtype)
        # epilogue: max/argmax within each gamma-row group    (Eq 22)
        Dg = D.reshape(F, O, G, gamma, 16)
        val = Dg.max(axis=3)                                  # [F,O,G,16]
        sel = Dg.argmax(axis=3).astype(jnp.int32)
        # fixed permutation back to global-state order (Thm 4), again as
        # exact one-hot matmuls (sel values 0..3 are exact in f32)
        lam_new = jnp.dot(val.reshape(F, O * G * 16), SRC_OH)
        sel_s = jnp.dot(sel.reshape(F, O * G * 16).astype(jnp.float32), SRC_OH)
        sel_s = sel_s.astype(jnp.int32)
        # undo the dragonfly-group permutation                (§VIII-D)
        phi = jnp.take_along_axis(
            jnp.broadcast_to(PINV_S[None], (F, S, gamma)), sel_s[..., None], axis=2
        )[..., 0]
        return lam_new.astype(acc_dtype), phi

    return step


def renorm(lam: jnp.ndarray) -> jnp.ndarray:
    """Subtract the per-frame max so path metrics stay bounded (required
    for half-precision accumulate; free-ish on the VPU)."""
    return lam - lam.max(axis=1, keepdims=True)


def pallas_acs_call(c: StepConsts, acc_dtype, n_steps: int, batch: int,
                    renorm_every: int = 16, interpret: bool = True):
    """Build the Pallas forward kernel: grid over decoder steps (sequential
    'arbitrary' dimension), path metrics in VMEM scratch.

    Returns fn(llr [B, n_steps, W] f32/bf16, lam0 [B, S] f32)
            -> (phi [n_steps, B, S] i32, lam_final [B, S] f32).
    """
    S, W = c.n_states, c.width
    step = make_step_fn(c, acc_dtype)
    consts = const_arrays(c)

    def kernel(*refs):
        const_refs = refs[:len(consts)]
        llr_ref, lam0_ref, phi_ref, lamout_ref, lam_scr = refs[len(consts):]
        t = pl.program_id(0)

        @pl.when(t == 0)
        def _init():
            lam_scr[...] = lam0_ref[...].astype(acc_dtype)

        lam = lam_scr[...]
        if renorm_every:
            lam = jnp.where((t % renorm_every) == 0, renorm(lam), lam)
        llr_t = llr_ref[...].reshape(batch, W)
        cvals = tuple(r[...] for r in const_refs)
        lam_new, phi = step(cvals, lam, llr_t)
        phi_ref[...] = phi.reshape(1, batch, S)
        lam_scr[...] = lam_new

        @pl.when(t == n_steps - 1)
        def _fini():
            lamout_ref[...] = lam_new.astype(jnp.float32)

    scratch = [pltpu.VMEM((batch, S), acc_dtype)] if pltpu is not None else []

    def full_block(a):
        nd = a.ndim
        return pl.BlockSpec(a.shape, lambda t, _nd=nd: (0,) * _nd)

    inner = pl.pallas_call(
        kernel,
        grid=(n_steps,),
        in_specs=[full_block(a) for a in consts] + [
            pl.BlockSpec((batch, 1, W), lambda t: (0, t, 0)),
            pl.BlockSpec((batch, S), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, batch, S), lambda t: (t, 0, 0)),
            pl.BlockSpec((batch, S), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_steps, batch, S), jnp.int32),
            jax.ShapeDtypeStruct((batch, S), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )

    def call(llr, lam0):
        return inner(*consts, llr, lam0)

    return call
