"""Pure-numpy correctness oracle for the Viterbi decoder (Alg 1 + Alg 2).

This is the straight transcription of the paper's Algorithms 1 and 2 with
no tensor reformulation. Every tensor-formulated path (jnp scan, Pallas
kernel, AOT artifact, and the Rust radix-2/radix-4 mirrors) is validated
against it.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..trellis import Code

NEG = -1.0e9  # "minus infinity" that stays finite in bf16


def forward(code: Code, llr: np.ndarray, lam0: Optional[np.ndarray] = None
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Alg 1: forward ACS over n stages.

    llr: [n, beta] float; positive = bit 0 likely.
    lam0: [S] initial path metrics (None = all-zero, unknown start).
    Returns (phi [n, S] predecessor *global state*, lam [n+1, S] metrics).
    """
    n = llr.shape[0]
    S = code.n_states
    assert llr.shape[1] == code.beta
    lam = np.zeros((n + 1, S), dtype=np.float64)
    lam[0] = lam0 if lam0 is not None else 0.0
    phi = np.zeros((n, S), dtype=np.int64)
    # branch metric table: delta[i, u] for stage t = sum_b (-1)^out_b * llr_b
    sign = np.zeros((S, 2, code.beta), dtype=np.float64)
    for i in range(S):
        for u in range(2):
            a = code.branch_output(i, u)
            for b in range(code.beta):
                sign[i, u, b] = 1.0 - 2.0 * ((a >> b) & 1)
    for t in range(n):
        delta = sign @ llr[t]            # [S, 2]
        for j in range(S):
            i0, i1 = code.prev_states(j)
            u = code.branch_input(j)
            l0 = lam[t, i0] + delta[i0, u]
            l1 = lam[t, i1] + delta[i1, u]
            if l0 >= l1:                 # ties -> lower-index predecessor
                lam[t + 1, j] = l0
                phi[t, j] = i0
            else:
                lam[t + 1, j] = l1
                phi[t, j] = i1
    return phi, lam


def traceback(code: Code, phi: np.ndarray, lam_final: np.ndarray,
              end_state: Optional[int] = None) -> np.ndarray:
    """Alg 2: trace the winning survivor path back, emitting input bits."""
    n = phi.shape[0]
    j = int(np.argmax(lam_final)) if end_state is None else end_state
    out = np.zeros(n, dtype=np.int64)
    for t in range(n - 1, -1, -1):
        out[t] = code.branch_input(j)    # alpha_in of the branch into j
        j = int(phi[t, j])
    return out


def decode(code: Code, llr: np.ndarray, lam0: Optional[np.ndarray] = None,
           end_state: Optional[int] = None) -> np.ndarray:
    """Full reference decode (forward + traceback)."""
    phi, lam = forward(code, llr, lam0)
    return traceback(code, phi, lam[-1], end_state)


# --- radix-form outputs ------------------------------------------------

def phi_to_radix(code: Code, phi: np.ndarray, rho: int) -> np.ndarray:
    """Convert Alg-1 predecessor states to the radix-2^rho selection form
    the tensor kernels emit: phi_r[tau, s] = left *local* state of the
    winning super-branch into global state s over stages
    [tau*rho, (tau+1)*rho).

    Requires n divisible by rho.
    """
    n, S = phi.shape
    assert n % rho == 0
    ndf = code.n_dragonflies(rho)
    out = np.zeros((n // rho, S), dtype=np.int64)
    for tau in range(n // rho):
        for s in range(S):
            j = s
            for x in range(rho):         # walk back rho single stages
                j = int(phi[tau * rho + rho - 1 - x, j])
            f = s % ndf
            out[tau, s] = j - (f << rho)  # left local = global - 4f (Thm 4 x=0)
            assert 0 <= out[tau, s] < (1 << rho)
    return out


def traceback_radix(code: Code, rho: int, phi_r: np.ndarray,
                    lam_final: np.ndarray, end_state: Optional[int] = None
                    ) -> np.ndarray:
    """Traceback from radix-form selections (mirror of the Rust hot-path
    traceback). Emits rho bits per step: input bit consumed at local step
    x is bit x of the right local state (Thm 4 / superbranch_inputs)."""
    n_steps, S = phi_r.shape
    ndf = code.n_dragonflies(rho)
    j = int(np.argmax(lam_final)) if end_state is None else end_state
    out = np.zeros(n_steps * rho, dtype=np.int64)
    for tau in range(n_steps - 1, -1, -1):
        f = j % ndf
        jloc = j // ndf
        for x in range(rho):
            out[tau * rho + x] = (jloc >> x) & 1
        iloc = int(phi_r[tau, j])
        j = (f << rho) + iloc            # Thm 4, x = 0
    return out
