"""AOT lowering: decoder variants -> artifacts/*.hlo.txt + manifest.json.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts`` — this is the ONLY Python step; the Rust
binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import Variant, make_decoder
from .trellis import CCSDS_K7, Code

# The default artifact set. Small-batch variants exist for fast tests;
# b64 variants feed the benches (Table I / Fig 13 / ablations).
DEFAULT_VARIANTS: List[Variant] = [
    # test/correctness artifacts (small, fast to execute)
    Variant("radix4", "jnp", "single", "single", batch=8, n_steps=32),
    Variant("radix4", "pallas", "single", "single", batch=8, n_steps=32),
    # Table I / Fig 13: the four precision combos (paper §IX-B/C)
    Variant("radix4", "jnp", "single", "single", batch=64, n_steps=48),
    Variant("radix4", "jnp", "single", "half", batch=64, n_steps=48),
    Variant("radix4", "jnp", "half", "single", batch=64, n_steps=48),
    Variant("radix4", "jnp", "half", "half", batch=64, n_steps=48),
    # ablation E4: radix-2 (Q=2) and radix-4 without the DG permutation
    Variant("radix2", "jnp", "single", "single", batch=64, n_steps=96),
    Variant("radix4_noperm", "jnp", "single", "single", batch=64, n_steps=48),
    # perf: larger batch amortizes XLA-CPU per-op dispatch (§Perf L2/L3)
    Variant("radix4", "jnp", "single", "single", batch=256, n_steps=48),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    `print_large_constants=True` is ESSENTIAL: the default elides big
    constant payloads as ``constant({...})``, which xla_extension 0.5.1's
    text parser silently accepts as garbage — the packing-spec tables
    (Theta matrices, gather maps) would arrive as zeros/NaN in Rust.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return text


def lower_variant(code: Code, v: Variant) -> str:
    decode, pk = make_decoder(code, v)
    llr_spec = jax.ShapeDtypeStruct((v.batch, v.n_steps, pk.width), jnp.float32)
    lam_spec = jax.ShapeDtypeStruct((v.batch, code.n_states), jnp.float32)
    lowered = jax.jit(decode).lower(llr_spec, lam_spec)
    return to_hlo_text(lowered)


def manifest_entry(code: Code, v: Variant, path: str, hlo_text: str) -> dict:
    from .packing import build_packing
    pk = build_packing(code, v.scheme)
    return {
        "name": v.name(),
        "path": path,
        "scheme": v.scheme,
        "impl": v.impl,
        "acc": v.acc,
        "chan": v.chan,
        "batch": v.batch,
        "n_steps": v.n_steps,
        "rho": pk.rho,
        "gamma": pk.gamma,
        "width": pk.width,
        "n_ops": pk.n_ops,
        "ops_per_stage": pk.ops_per_stage(),
        "renorm_every": v.renorm_every,
        "k": code.k,
        "polys_octal": [oct(p)[2:] for p in code.polys],
        "n_states": code.n_states,
        "stages_per_frame": v.n_steps * pk.rho,
        "sha256": hashlib.sha256(hlo_text.encode()).hexdigest()[:16],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated variant-name substrings to build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    code = CCSDS_K7
    entries = []
    for v in DEFAULT_VARIANTS:
        if args.only and not any(s in v.name() for s in args.only.split(",")):
            continue
        fname = v.name() + ".hlo.txt"
        path = os.path.join(args.out_dir, fname)
        print(f"lowering {v.name()} ...", flush=True)
        text = lower_variant(code, v)
        with open(path, "w") as f:
            f.write(text)
        entries.append(manifest_entry(code, v, fname, text))
        print(f"  wrote {len(text)} chars -> {path}", flush=True)

    manifest = {
        "code": {"k": code.k, "polys_octal": [oct(p)[2:] for p in code.polys],
                 "beta": code.beta, "n_states": code.n_states},
        "io": {
            "inputs": ["llr f32[batch, n_steps, width]", "lam0 f32[batch, n_states]"],
            "outputs": [
                "phi i32[n_steps * batch * n_states] flat, index (t*B+b)*S+s",
                "lam f32[batch * n_states] flat",
            ],
            "note": ("outputs are wrapped in a tuple (return_tuple=True); "
                     "flattened 1-D so the XLA output layout is unambiguous"),
        },
        "artifacts": entries,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} artifacts -> {mpath}")


if __name__ == "__main__":
    main()
