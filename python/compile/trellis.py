"""Convolutional-code trellis math (build-time).

Implements the encoder FSM (paper §II-A), the butterfly structure (§IV,
Thm 1-2), the radix-2^rho dragonfly generalization (§VI, Thm 3-5) and the
radix-4 super-branch structure (§VII, Thm 6-7) for an arbitrary (beta,1,k)
convolutional code.

Conventions (matching `rust/src/coding/trellis.rs` bit-for-bit):

* state ``i`` is the k-1 previous input bits, newest bit at the MSB:
  ``i = (in_{t-1} << (k-2)) | ... | in_{t-k+1}``.
* on input bit ``u`` the next state is ``(u << (k-2)) | (i >> 1)``.
* generator polynomial ``g`` is a k-bit integer whose MSB multiplies the
  *current* input bit (Eq 1); the wire register is ``(u << (k-1)) | i``.
* branch output bit b is ``parity(g[b] & register)``.
* LLR convention: positive LLR means "bit 0 more likely"; BPSK maps
  bit 0 -> +1.0, so the branch metric Eq 2 uses ``(-1)^alpha * llr``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Sequence, Tuple

import numpy as np


def parity(x: int) -> int:
    """Parity (xor-reduction) of the bits of a nonnegative int."""
    return bin(x).count("1") & 1


def bits_field(x: int, hi: int, lo: int) -> int:
    """The paper's ``x_{hi:lo}`` operator (Eq 23): bits [lo, hi) of x.

    Example from the paper: x = 39 = 0b100111, x_{4:1} = 0b011 = 3,
    x_{4:0} = 0b0111 = 7.
    """
    if hi <= lo:
        return 0
    return (x >> lo) & ((1 << (hi - lo)) - 1)


@dataclasses.dataclass(frozen=True)
class Code:
    """A rate-1/beta convolutional code (beta, 1, k)."""

    k: int                      # constraint length
    polys: Tuple[int, ...]      # beta generator polynomials, k-bit ints

    def __post_init__(self):
        if self.k < 3:
            raise ValueError(f"constraint length k={self.k} must be >= 3")
        if len(self.polys) < 2:
            raise ValueError("need beta >= 2 generator polynomials")
        for g in self.polys:
            if not (0 < g < (1 << self.k)):
                raise ValueError(f"polynomial {g:o} (octal) out of range for k={self.k}")

    @property
    def beta(self) -> int:
        return len(self.polys)

    @property
    def n_states(self) -> int:
        return 1 << (self.k - 1)

    @staticmethod
    def from_octal(k: int, octal_polys: Sequence[str]) -> "Code":
        return Code(k=k, polys=tuple(int(p, 8) for p in octal_polys))

    # --- encoder FSM -----------------------------------------------------

    def next_state(self, state: int, u: int) -> int:
        return (u << (self.k - 2)) | (state >> 1)

    def branch_output(self, state: int, u: int) -> int:
        """beta-bit branch output alpha_out for (state, input u); bit b of
        the result corresponds to polynomial b."""
        reg = (u << (self.k - 1)) | state
        out = 0
        for b, g in enumerate(self.polys):
            out |= parity(g & reg) << b
        return out

    def prev_states(self, j: int) -> Tuple[int, int]:
        """The two predecessor states of j (paper: prv(j))."""
        base = (j << 1) & (self.n_states - 1)
        return (base, base | 1)

    def branch_input(self, j: int) -> int:
        """alpha_in of any branch into state j: the MSB of j."""
        return j >> (self.k - 2)

    def encode(self, bits: Sequence[int], state: int = 0) -> Tuple[List[int], int]:
        """Encode a bit sequence; returns (flat coded bits, final state).

        Coded bits are emitted LSB-polynomial-first: beta bits per input.
        """
        out: List[int] = []
        for u in bits:
            o = self.branch_output(state, u)
            out.extend((o >> b) & 1 for b in range(self.beta))
            state = self.next_state(state, u)
        return out, state

    # --- butterflies (Thm 1) and dragonflies (Thm 4) ---------------------

    def dragonfly_state(self, rho: int, f: int, x: int, y: int) -> int:
        """Thm 4: global state index for dragonfly f, local stage x in
        [0, rho], local state y in [0, 2^rho).

        ``s = (y_{rho:rho-x} << (k-x-1)) + (f << (rho-x)) + y_{rho-x-1:0}``
        (pre-bubble + bubble + post-bubble).
        """
        k = self.k
        if not (0 <= x <= rho):
            raise ValueError(f"local stage x={x} out of [0,{rho}]")
        if not (0 <= y < (1 << rho)):
            raise ValueError(f"local state y={y} out of range")
        if not (0 <= f < (1 << (k - 1 - rho))):
            raise ValueError(f"dragonfly index f={f} out of range")
        pre = bits_field(y, rho, rho - x) << (k - x - 1)
        bub = f << (rho - x)
        post = bits_field(y, rho - x, 0)
        return pre + bub + post

    def n_dragonflies(self, rho: int) -> int:
        return 1 << (self.k - 1 - rho)

    def superbranch_path(self, rho: int, f: int, y_left: int, y_right: int
                         ) -> List[Tuple[int, int, int]]:
        """The unique path (Thm 6) from left local state y_left to right
        local state y_right of dragonfly f, as a list of rho
        (global_state, input_bit, branch_output) tuples.

        The input bit consumed at local step x is bit x of y_right
        (newest input ends at the local-state MSB after rho shifts).
        """
        steps = []
        y = y_left
        for x in range(rho):
            u = (y_right >> x) & 1
            s = self.dragonfly_state(rho, f, x, y)
            steps.append((s, u, self.branch_output(s, u)))
            y = (u << (rho - 1)) | (y >> 1)
        assert y == y_right, "local FSM did not land on y_right"
        return steps

    def superbranch_output(self, rho: int, f: int, y_left: int, y_right: int) -> int:
        """rho*beta-bit super-branch output; bits of step x occupy
        positions [x*beta, (x+1)*beta) (stage-major, matching the L vector
        layout of Eq 33)."""
        out = 0
        for x, (_, _, o) in enumerate(self.superbranch_path(rho, f, y_left, y_right)):
            out |= o << (x * self.beta)
        return out

    def superbranch_inputs(self, rho: int, y_right: int) -> List[int]:
        """The rho input bits along any super-branch ending at local state
        y_right; bit consumed at step x is bit x of y_right."""
        return [(y_right >> x) & 1 for x in range(rho)]

    # --- Theta matrices (Eq 17 / Eq 36) ----------------------------------

    def theta_rows(self, rho: int, f: int) -> np.ndarray:
        """Theta-hat_f (Eq 36): shape [2^rho * 2^rho, rho*beta] of +-1.

        Row (y_right * 2^rho + y_left) holds (-1)^alpha-hat for the
        super-branch y_left -> y_right (P_j block layout: rows grouped by
        right state j, row within group = left state i).
        """
        n = 1 << rho
        w = rho * self.beta
        m = np.zeros((n * n, w), dtype=np.int8)
        for j in range(n):
            for i in range(n):
                a = self.superbranch_output(rho, f, i, j)
                for b in range(w):
                    m[j * n + i, b] = 1 - 2 * ((a >> b) & 1)
        return m

    def theta_signature(self, rho: int, f: int) -> Tuple[int, ...]:
        """Per-(i,j) super-branch outputs of dragonfly f, flattened in
        P_j-block order. Two dragonflies with equal signatures have equal
        Theta-hat matrices."""
        n = 1 << rho
        return tuple(self.superbranch_output(rho, f, i, j)
                     for j in range(n) for i in range(n))


def find_left_permutation(code: Code, rho: int, f: int, r: int):
    """Search the permutation pi of left local states such that
    alpha-hat_f^{i,j} == alpha-hat_r^{pi(i),j} for all i,j (the paper's
    §VIII-D dragonfly-group property: the same left-state permutation for
    every right-rooted tree P_j). Returns pi as a tuple or None."""
    n = 1 << rho
    sig_f = [[code.superbranch_output(rho, f, i, j) for i in range(n)] for j in range(n)]
    sig_r = [[code.superbranch_output(rho, r, i, j) for i in range(n)] for j in range(n)]
    for pi in itertools.permutations(range(n)):
        if all(sig_f[j][i] == sig_r[j][pi[i]] for j in range(n) for i in range(n)):
            return pi
    return None


@dataclasses.dataclass
class DragonflyGroups:
    """Partition of dragonflies into groups whose Theta-hat matrices are
    left-state permutations of each other (paper Fig 10/11, Eq 39-42)."""

    rho: int
    reps: List[int]                 # group representative dragonfly index
    group_of: List[int]             # dragonfly -> group id
    perm: List[Tuple[int, ...]]     # dragonfly -> pi  (theta_f[i] == theta_rep[pi(i)])

    @property
    def n_groups(self) -> int:
        return len(self.reps)


def dragonfly_groups(code: Code, rho: int) -> DragonflyGroups:
    """Group dragonflies by left-permutation equivalence of Theta-hat."""
    nf = code.n_dragonflies(rho)
    reps: List[int] = []
    group_of = [-1] * nf
    perm: List[Tuple[int, ...]] = [None] * nf  # type: ignore
    for f in range(nf):
        for gid, r in enumerate(reps):
            pi = find_left_permutation(code, rho, f, r)
            if pi is not None:
                group_of[f] = gid
                perm[f] = pi
                break
        else:
            group_of[f] = len(reps)
            perm[f] = tuple(range(1 << rho))
            reps.append(f)
    return DragonflyGroups(rho=rho, reps=reps, group_of=group_of, perm=perm)


# Standard codes (paper §IX uses CCSDS_K7; registry mirrored in rust).
CCSDS_K7 = Code.from_octal(7, ("171", "133"))    # (2,1,7) — DVB-T/S, WiFi, CCSDS
GSM_K5 = Code.from_octal(5, ("23", "33"))        # GSM TCH full-rate
LTE_K7_R13 = Code.from_octal(7, ("133", "171", "165"))  # rate-1/3 (LTE/CDMA family)
WLAN_K7 = CCSDS_K7                                # 802.11 uses the same polys
