"""Tensor-core packing specs (paper §V-B, §VIII-C/D; Figs 5, 14, 15).

A packing spec turns one trellis step of rho stages into ``n_ops`` dense
16x16 multiply-accumulates ``D_o = A_o @ B_o + C_o`` plus a max/argmax
epilogue, exactly the tensor-core (-> MXU) primitive the paper uses.

Everything here is *static* per code: the spec tensors are baked into the
AOT HLO as constants. Batching over frames extends the column dimension
(B, C, D become 16 x 16F), which is what fills the MXU on real hardware.

Spec tensors (O = n_ops, W = rho*beta LLR entries per step, G = 16/gamma
reduce groups per column, gamma = 2^rho predecessor candidates):

* ``A    [O,16,16]`` +-1/0 Theta entries (Eq 17 / Eq 36 layout).
* ``E    [O,16,16,W]`` B-builder: ``B[o,r,c] = sum_e E[o,r,c,e]*llr[e]``.
* ``CG   [O,16,16]`` lambda gather index (global state) or -1 (unused).
* ``OS   [O,G,16]`` global right state written by (group, col) or -1.
* ``PINV [O,16,gamma]`` argmax -> true left-local-state map (undoes the
  dragonfly-group permutation of §VIII-D; identity when unused).
* ``SRC  [S,3]`` for each global state s: (op, group, col) producing it.

Schemes:
* ``radix2``        — Fig 5: 4 distinct 4x2 Theta blocks on the diagonal,
                      4 butterflies (columns) per block; Q = 2 ops/stage
                      for k=7.
* ``radix4_noperm`` — Fig 14: 4 dragonflies per op, each with its own
                      16x4 Theta-hat; Q = 2 ops/stage (but 2 stages/step).
* ``radix4``        — Fig 15: dragonfly-group permutation packs the whole
                      64-state trellis into ONE op per 2 stages (Q = 0.5).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .trellis import Code, dragonfly_groups


@dataclasses.dataclass
class Packing:
    """Static tensor packing of one decoder step (rho trellis stages)."""

    scheme: str
    rho: int                 # stages per step
    gamma: int               # predecessor candidates per state (2^rho)
    n_ops: int
    A: np.ndarray            # [O,16,16] f32
    E: np.ndarray            # [O,16,16,W] f32
    CG: np.ndarray           # [O,16,16] i32, -1 unused
    OS: np.ndarray           # [O,G,16] i32, -1 unused
    PINV: np.ndarray         # [O,16,gamma] i32
    SRC: np.ndarray          # [S,3] i32 (op, group, col) per state

    @property
    def width(self) -> int:  # LLR entries consumed per step
        return self.E.shape[-1]

    @property
    def groups_per_col(self) -> int:
        return self.OS.shape[1]

    def ops_per_stage(self) -> float:
        """The paper's Q metric: tensor ops per trellis stage."""
        return self.n_ops / self.rho

    def validate(self, code: Code) -> None:
        """Structural invariants: every state produced exactly once, all
        gathers in range, SRC consistent with OS."""
        S = code.n_states
        seen = np.zeros(S, dtype=bool)
        O, G, C = self.OS.shape
        for o in range(O):
            for g in range(G):
                for c in range(C):
                    s = int(self.OS[o, g, c])
                    if s < 0:
                        continue
                    if seen[s]:
                        raise ValueError(f"state {s} produced twice")
                    seen[s] = True
        if not seen.all():
            raise ValueError(f"states never produced: {np.flatnonzero(~seen)}")
        if self.CG.max() >= S:
            raise ValueError("CG gather out of range")
        for s in range(S):
            o, g, c = (int(v) for v in self.SRC[s])
            if int(self.OS[o, g, c]) != s:
                raise ValueError(f"SRC[{s}] inconsistent")


def _theta_butterfly(code: Code, f: int) -> np.ndarray:
    """Theta_f of a butterfly (Eq 17): [4, beta] of +-1, row order
    (i0,j0),(i1,j0),(i0,j1),(i1,j1)."""
    rows = []
    for j in range(2):
        for i in range(2):
            a = code.superbranch_output(1, f, i, j)
            rows.append([1 - 2 * ((a >> b) & 1) for b in range(code.beta)])
    return np.asarray(rows, dtype=np.int8)


def build_radix2(code: Code) -> Packing:
    """Fig 5: diagonal 4x4 blocks; butterflies sharing a Theta matrix share
    a block, one butterfly per column within the block's column group."""
    beta, S = code.beta, code.n_states
    if beta > 4:
        raise ValueError(f"radix2 packing supports beta <= 4, got {beta}")
    nf = code.n_dragonflies(1)           # butterflies per stage
    W = beta
    # bucket butterflies by identical Theta (Cor 2.1: 2^beta distinct).
    buckets: Dict[Tuple[int, ...], List[int]] = {}
    for f in range(nf):
        buckets.setdefault(code.theta_signature(1, f), []).append(f)
    # (theta, chunk-of-<=4-butterflies) units, 4 units (diag blocks) per op
    units: List[Tuple[Tuple[int, ...], List[int]]] = []
    for sig, fs in sorted(buckets.items()):
        for i in range(0, len(fs), 4):
            units.append((sig, fs[i:i + 4]))
    n_ops = (len(units) + 3) // 4

    A = np.zeros((n_ops, 16, 16), dtype=np.float32)
    E = np.zeros((n_ops, 16, 16, W), dtype=np.float32)
    CG = np.full((n_ops, 16, 16), -1, dtype=np.int32)
    OS = np.full((n_ops, 8, 16), -1, dtype=np.int32)
    PINV = np.tile(np.arange(2, dtype=np.int32), (n_ops, 16, 1))
    SRC = np.zeros((S, 3), dtype=np.int32)

    for u, (sig, fs) in enumerate(units):
        o, p = divmod(u, 4)              # op, diagonal block slot
        theta = _theta_butterfly(code, fs[0])
        A[o, 4 * p:4 * p + 4, 4 * p:4 * p + beta] = theta
        for cc, f in enumerate(fs):
            c = 4 * p + cc
            for e in range(beta):
                E[o, 4 * p + e, c, e] = 1.0
            i0, i1 = 2 * f, 2 * f + 1
            CG[o, 4 * p:4 * p + 4, c] = [i0, i1, i0, i1]
            j0 = code.dragonfly_state(1, f, 1, 0)
            j1 = code.dragonfly_state(1, f, 1, 1)
            OS[o, 2 * p, c] = j0
            OS[o, 2 * p + 1, c] = j1
            SRC[j0] = (o, 2 * p, c)
            SRC[j1] = (o, 2 * p + 1, c)

    pk = Packing("radix2", 1, 2, n_ops, A, E, CG, OS, PINV, SRC)
    pk.validate(code)
    return pk


def _build_radix4(code: Code, use_perm: bool) -> Packing:
    """Fig 14 (use_perm=False) / Fig 15 (use_perm=True)."""
    beta, S = code.beta, code.n_states
    rho, gamma = 2, 4
    W = rho * beta
    nf = code.n_dragonflies(rho)
    if use_perm:
        dg = dragonfly_groups(code, rho)
        rep_of = [dg.reps[g] for g in dg.group_of]
        perm_of = dg.perm
        group_of = dg.group_of
        n_groups = dg.n_groups
    else:
        # every dragonfly is its own group with identity permutation
        rep_of = list(range(nf))
        perm_of = [tuple(range(gamma))] * nf
        group_of = list(range(nf))
        n_groups = nf

    # Assign dragonflies to (op, col): each op holds <= 16//W Theta slots
    # (A columns W*slot .. W*slot+W) and <= 16 columns.
    slots_per_op = 16 // W
    assert slots_per_op >= 1, f"super-branch width {W} exceeds the 16x16 op"
    by_group: Dict[int, List[int]] = {}
    for f in range(nf):
        by_group.setdefault(group_of[f], []).append(f)
    ops: List[List[Tuple[int, int]]] = []   # per op: list of (slot, dragonfly)
    op_groups: List[List[int]] = []          # per op: group id per slot
    cur: List[Tuple[int, int]] = []
    cur_groups: List[int] = []
    for g in sorted(by_group):
        for f in by_group[g]:
            if g not in cur_groups:
                if len(cur_groups) == slots_per_op or len(cur) == 16:
                    ops.append(cur); op_groups.append(cur_groups)
                    cur, cur_groups = [], []
                cur_groups.append(g)
            if len(cur) == 16:
                ops.append(cur); op_groups.append(cur_groups)
                cur, cur_groups = [], [g]
            cur.append((cur_groups.index(g), f))
    if cur:
        ops.append(cur); op_groups.append(cur_groups)
    n_ops = len(ops)

    A = np.zeros((n_ops, 16, 16), dtype=np.float32)
    E = np.zeros((n_ops, 16, 16, W), dtype=np.float32)
    CG = np.full((n_ops, 16, 16), -1, dtype=np.int32)
    OS = np.full((n_ops, 4, 16), -1, dtype=np.int32)
    PINV = np.zeros((n_ops, 16, gamma), dtype=np.int32)
    PINV[:] = np.arange(gamma, dtype=np.int32)
    SRC = np.zeros((S, 3), dtype=np.int32)

    for o, (cols, groups) in enumerate(zip(ops, op_groups)):
        for slot, g in enumerate(groups):
            rep = by_group[g][0] if not use_perm else rep_of[by_group[g][0]]
            A[o, :, W * slot:W * slot + W] = code.theta_rows(rho, rep)[:, :W]
        for c, (slot, f) in enumerate(cols):
            pi = perm_of[f]
            pinv = [0] * gamma
            for i in range(gamma):
                pinv[pi[i]] = i
            for e in range(W):
                E[o, W * slot + e, c, e] = 1.0
            for j in range(4):
                for i in range(4):
                    # row 4j+i holds rep's branch pi^{-1}(i) -> j, whose
                    # lambda is dragonfly f's left state pinv[i]
                    CG[o, 4 * j + i, c] = code.dragonfly_state(rho, f, 0, pinv[i])
                s = code.dragonfly_state(rho, f, rho, j)
                OS[o, j, c] = s
                SRC[s] = (o, j, c)
            PINV[o, c, :] = pinv

    pk = Packing("radix4" if use_perm else "radix4_noperm",
                 rho, gamma, n_ops, A, E, CG, OS, PINV, SRC)
    pk.validate(code)
    return pk


def build_packing(code: Code, scheme: str) -> Packing:
    """Build the packing spec for one of the paper's three layouts."""
    if scheme == "radix2":
        return build_radix2(code)
    if scheme == "radix4":
        return _build_radix4(code, use_perm=True)
    if scheme == "radix4_noperm":
        return _build_radix4(code, use_perm=False)
    raise ValueError(f"unknown packing scheme {scheme!r}")
