"""L2 — the framed Viterbi decoder forward pass (build-time JAX).

A decoder *variant* fixes: packing scheme (radix2 / radix4 / radix4_noperm),
implementation (jnp scan vs Pallas kernel), accumulator dtype, channel
dtype, batch size and steps per frame. `make_decoder` returns the jittable
function; `aot.py` lowers each variant to HLO text for the Rust runtime.

Artifact I/O contract (mirrored by `rust/src/runtime/`):

  inputs : llr  f32[B, n_steps, W]  (W = rho*beta, stage-major chunks)
           lam0 f32[B, S]
  outputs: phi  i32[n_steps * B * S] (flat, step-major: index
           (t*B + b)*S + s; winning left-local state, 0..2^rho-1)
           lam  f32[B * S]           (flat final path metrics)

Outputs are FLATTENED to 1-D on purpose: XLA is free to pick a
non-row-major layout for a multi-dim output (it did: s32[B,T,S]{2,0,1}),
which the Rust side cannot discover through the `xla` crate's Literal
API. A 1-D array has exactly one layout. The flatten is free because it
matches the scan buffer's native [T, B, S] order.

Traceback (Alg 2) is sequential and data-dependent — it stays in Rust on
the hot path, as in the paper it stays on scalar CUDA cores.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .packing import Packing, build_packing
from .trellis import Code
from .kernels import acs
from .kernels.acs import StepConsts, make_step_fn, pallas_acs_call

DTYPES = {"single": jnp.float32, "half": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT-compilable decoder configuration."""

    scheme: str = "radix4"      # radix2 | radix4 | radix4_noperm
    impl: str = "jnp"           # jnp | pallas
    acc: str = "single"         # C/D + stored path metrics
    chan: str = "single"        # LLR storage dtype at the input boundary
    batch: int = 8              # frames per execution
    n_steps: int = 32           # decoder steps per frame (rho stages each)
    renorm_every: int = 16      # path-metric renormalization period (0=off)

    def name(self) -> str:
        return (f"{self.scheme}_{self.impl}_acc-{self.acc}_ch-{self.chan}"
                f"_b{self.batch}_s{self.n_steps}")


def make_decoder(code: Code, v: Variant) -> Tuple[Callable, Packing]:
    """Build the jittable decode(llr, lam0) -> (phi, lam) for a variant."""
    pk = build_packing(code, v.scheme)
    consts = StepConsts.from_packing(pk, code.n_states)
    acc_dtype = DTYPES[v.acc]
    chan_dtype = DTYPES[v.chan]
    W, S = pk.width, code.n_states

    if v.impl == "pallas":
        inner = pallas_acs_call(consts, acc_dtype, v.n_steps, v.batch,
                                renorm_every=v.renorm_every, interpret=True)

        def decode(llr: jnp.ndarray, lam0: jnp.ndarray):
            # channel precision applies at the input boundary (paper: the
            # received array may be stored half; B is half regardless).
            llr_c = llr.astype(chan_dtype)
            phi, lam = inner(llr_c.astype(jnp.float32), lam0)
            return phi.reshape(-1), lam.reshape(-1)

        return decode, pk

    step = make_step_fn(consts, acc_dtype)
    cvals = acs.const_arrays(consts)

    def decode(llr: jnp.ndarray, lam0: jnp.ndarray):
        llr_c = llr.astype(chan_dtype)
        lam_init = lam0.astype(acc_dtype)

        def body(carry, inp):
            lam, t = carry
            if v.renorm_every:
                lam = jnp.where((t % v.renorm_every) == 0, acs.renorm(lam), lam)
            lam_new, phi = step(cvals, lam, inp)
            return (lam_new, t + 1), phi

        (lam_fin, _), phis = jax.lax.scan(
            body, (lam_init, jnp.int32(0)), jnp.swapaxes(llr_c, 0, 1))
        # phis is [T, B, S] (scan-native): flatten without transposing
        return phis.reshape(-1), lam_fin.astype(jnp.float32).reshape(-1)

    return decode, pk


def initial_metrics(S: int, batch: int, known_state: int | None = 0) -> np.ndarray:
    """lam0 for a frame: known encoder start state (stream head / flushed)
    or all-zero (mid-stream tile, no history)."""
    lam0 = np.zeros((batch, S), dtype=np.float32)
    if known_state is not None:
        lam0[:] = acs.NEG
        lam0[:, known_state] = 0.0
    return lam0
