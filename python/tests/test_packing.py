"""Packing specs (Figs 5/14/15): structure, Q metric, generality."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional in the offline image; CI installs it
from hypothesis import given, settings, strategies as st

from compile.packing import build_packing, build_radix2
from compile.trellis import CCSDS_K7, GSM_K5, LTE_K7_R13, Code

from .test_trellis import random_code


class TestPaperQMetric:
    def test_radix2_q2(self):
        pk = build_packing(CCSDS_K7, "radix2")
        assert pk.n_ops == 2 and pk.ops_per_stage() == 2.0  # §V-B: Q = 2^{k-6}

    def test_radix4_noperm_q2(self):
        pk = build_packing(CCSDS_K7, "radix4_noperm")
        assert pk.n_ops == 4 and pk.ops_per_stage() == 2.0  # Fig 14

    def test_radix4_perm_q_half(self):
        pk = build_packing(CCSDS_K7, "radix4")
        assert pk.n_ops == 1 and pk.ops_per_stage() == 0.5  # Fig 15

    def test_unknown_scheme(self):
        with pytest.raises(ValueError):
            build_packing(CCSDS_K7, "radix8")


class TestStructure:
    @pytest.mark.parametrize("scheme", ["radix2", "radix4", "radix4_noperm"])
    @pytest.mark.parametrize("code", [CCSDS_K7, GSM_K5, LTE_K7_R13],
                             ids=["ccsds", "gsm", "lte13"])
    def test_validates(self, scheme, code):
        build_packing(code, scheme).validate(code)

    @pytest.mark.parametrize("scheme", ["radix2", "radix4", "radix4_noperm"])
    def test_a_entries_are_signs(self, scheme):
        pk = build_packing(CCSDS_K7, scheme)
        assert set(np.unique(pk.A)).issubset({-1.0, 0.0, 1.0})

    def test_radix2_diagonal_blocks(self):
        pk = build_radix2(CCSDS_K7)
        # A must be zero outside the 4x4 diagonal blocks (Fig 5)
        for o in range(pk.n_ops):
            for r in range(16):
                for c in range(16):
                    if r // 4 != c // 4:
                        assert pk.A[o, r, c] == 0.0

    def test_cg_rows_reference_left_states(self):
        code = CCSDS_K7
        pk = build_packing(code, "radix4")
        # every valid CG entry must be a left state of the dragonfly the
        # column's OS states belong to
        for o in range(pk.n_ops):
            for c in range(16):
                states = [pk.OS[o, g, c] for g in range(4) if pk.OS[o, g, c] >= 0]
                if not states:
                    continue
                f = states[0] % 16
                left = {code.dragonfly_state(2, f, 0, y) for y in range(4)}
                for r in range(16):
                    v = pk.CG[o, r, c]
                    if v >= 0:
                        assert v in left

    @given(st.integers(4, 9), st.integers(0, 2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_random_codes_pack(self, k, seed):
        code = random_code(k, 2, seed)
        for scheme in ["radix2", "radix4", "radix4_noperm"]:
            build_packing(code, scheme).validate(code)

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=10, deadline=None)
    def test_rate_third_codes_pack(self, seed):
        code = random_code(7, 3, seed)
        for scheme in ["radix2", "radix4", "radix4_noperm"]:
            build_packing(code, scheme).validate(code)

    def test_widths(self):
        assert build_packing(LTE_K7_R13, "radix2").width == 3
        assert build_packing(LTE_K7_R13, "radix4").width == 6
