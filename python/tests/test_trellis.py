"""Trellis math: encoder FSM, Theorems 1-7, dragonfly groups."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional in the offline image; CI installs it
from hypothesis import given, settings, strategies as st

from compile.trellis import (
    CCSDS_K7, GSM_K5, LTE_K7_R13, Code, bits_field, dragonfly_groups,
    find_left_permutation, parity,
)


def random_code(k: int, beta: int, seed: int) -> Code:
    """A code with MSB=LSB=1 polynomials (the Cor-2.1 family)."""
    rng = np.random.default_rng(seed)
    msb = 1 << (k - 1)
    polys = tuple(int(rng.integers(0, msb)) | msb | 1 for _ in range(beta))
    return Code(k=k, polys=polys)


class TestBitOps:
    def test_parity(self):
        assert parity(0) == 0
        assert parity(0b1011) == 1
        assert parity(0b1111) == 0

    def test_bits_field_paper_example(self):
        # Eq 23 example: x=39=0b100111, x_{4:1}=3, x_{4:0}=7
        assert bits_field(39, 4, 1) == 3
        assert bits_field(39, 4, 0) == 7
        assert bits_field(39, 0, 0) == 0


class TestEncoderFsm:
    def test_fig1_code(self):
        c = CCSDS_K7
        assert c.k == 7 and c.beta == 2 and c.n_states == 64
        assert c.polys == (0o171, 0o133)

    def test_prev_inverts_next(self):
        for c in [CCSDS_K7, GSM_K5, LTE_K7_R13]:
            for i in range(c.n_states):
                for u in range(2):
                    j = c.next_state(i, u)
                    assert i in c.prev_states(j)
                    assert c.branch_input(j) == u

    @given(st.integers(0, 63), st.integers(0, 1))
    def test_branch_output_matches_eq1(self, state, u):
        c = CCSDS_K7
        reg = (u << 6) | state
        expect = 0
        for b, g in enumerate(c.polys):
            expect |= parity(g & reg) << b
        assert c.branch_output(state, u) == expect

    @given(st.lists(st.integers(0, 1), min_size=1, max_size=200))
    @settings(max_examples=50)
    def test_encode_length_and_determinism(self, bits):
        c = CCSDS_K7
        out1, s1 = c.encode(bits)
        out2, s2 = c.encode(bits)
        assert out1 == out2 and s1 == s2
        assert len(out1) == c.beta * len(bits)

    def test_rejects_bad_codes(self):
        with pytest.raises(ValueError):
            Code(k=2, polys=(1, 2))
        with pytest.raises(ValueError):
            Code(k=7, polys=(0o171,))
        with pytest.raises(ValueError):
            Code(k=7, polys=(0, 0o133))


class TestDragonflies:
    def test_thm1_butterfly_indices(self):
        c = CCSDS_K7
        for f in range(32):
            assert c.dragonfly_state(1, f, 0, 0) == 2 * f
            assert c.dragonfly_state(1, f, 0, 1) == 2 * f + 1
            assert c.dragonfly_state(1, f, 1, 0) == f
            assert c.dragonfly_state(1, f, 1, 1) == f + 32

    def test_eq28_radix4_indices(self):
        c = CCSDS_K7
        for f in range(16):
            for y in range(4):
                assert c.dragonfly_state(2, f, 0, y) == 4 * f + y
                assert c.dragonfly_state(2, f, 2, y) == f + y * 16
            assert c.dragonfly_state(2, f, 1, 2) == 2 * f + 32

    @given(st.integers(1, 3), st.integers(0, 10**9), st.integers(0, 10**9))
    @settings(max_examples=100)
    def test_thm3_isolation(self, rho, fr, yr):
        """Branches from dragonfly-f states land inside dragonfly f."""
        c = CCSDS_K7
        f = fr % c.n_dragonflies(rho) if hasattr(c, "n_dragonflies") else 0
        f = fr % (1 << (c.k - 1 - rho))
        y = yr % (1 << rho)
        for x in range(rho):
            s = c.dragonfly_state(rho, f, x, y)
            for u in range(2):
                nxt = c.next_state(s, u)
                members = {c.dragonfly_state(rho, f, x + 1, y2) for y2 in range(1 << rho)}
                assert nxt in members

    def test_thm6_superbranch_paths_consistent(self):
        c = CCSDS_K7
        for f in range(16):
            for i in range(4):
                for j in range(4):
                    path = c.superbranch_path(2, f, i, j)
                    assert len(path) == 2
                    s0, u0, _ = path[0]
                    assert c.next_state(s0, u0) == path[1][0]

    def test_cor21_butterfly_output_symmetry(self):
        c = CCSDS_K7  # MSB=LSB=1 polys
        for f in range(32):
            o00 = c.superbranch_output(1, f, 0, 0)
            o11 = c.superbranch_output(1, f, 1, 1)
            o01 = c.superbranch_output(1, f, 0, 1)
            o10 = c.superbranch_output(1, f, 1, 0)
            assert o00 == o11 and o01 == o10 and o00 ^ 0b11 == o01

    @given(st.integers(4, 9), st.integers(0, 2**32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_cor21_for_random_codes(self, k, seed):
        c = random_code(k, 2, seed)
        for f in range(min(8, c.n_states // 2)):
            assert c.superbranch_output(1, f, 0, 0) == c.superbranch_output(1, f, 1, 1)


class TestDragonflyGroups:
    def test_fig10_paper_groups(self):
        g = dragonfly_groups(CCSDS_K7, 2)
        assert g.n_groups == 4
        assert g.reps == [0, 1, 4, 5]
        # Eq 39-42
        assert g.group_of == [0, 1, 0, 1, 2, 3, 2, 3, 0, 1, 0, 1, 2, 3, 2, 3]

    def test_permutation_property(self):
        c = CCSDS_K7
        g = dragonfly_groups(c, 2)
        for f in range(16):
            r = g.reps[g.group_of[f]]
            pi = g.perm[f]
            for j in range(4):
                for i in range(4):
                    assert (c.superbranch_output(2, f, i, j)
                            == c.superbranch_output(2, r, pi[i], j))

    def test_rep_has_identity_perm(self):
        g = dragonfly_groups(CCSDS_K7, 2)
        for r in g.reps:
            assert g.perm[r] == (0, 1, 2, 3)

    @given(st.integers(5, 8), st.integers(0, 2**32 - 1))
    @settings(max_examples=15, deadline=None)
    def test_groups_partition_random_codes(self, k, seed):
        c = random_code(k, 2, seed)
        g = dragonfly_groups(c, 2)
        assert len(g.group_of) == c.n_dragonflies(2)
        assert max(g.group_of) + 1 == g.n_groups

    def test_no_cross_group_permutation(self):
        c = CCSDS_K7
        g = dragonfly_groups(c, 2)
        # dragonflies in different groups must have NO left permutation
        assert find_left_permutation(c, 2, 0, 1) is None
