"""L2 model + AOT export: shapes, dtypes, variant naming, HLO emission."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_variant, manifest_entry, to_hlo_text
from compile.model import DTYPES, Variant, initial_metrics, make_decoder
from compile.trellis import CCSDS_K7


class TestVariant:
    def test_name_is_stable(self):
        v = Variant("radix4", "jnp", "single", "half", batch=8, n_steps=32)
        assert v.name() == "radix4_jnp_acc-single_ch-half_b8_s32"

    def test_dtype_table(self):
        assert DTYPES["single"] == jnp.float32
        assert DTYPES["half"] == jnp.bfloat16


class TestDecoderContract:
    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_output_shapes_and_dtypes(self, impl):
        v = Variant("radix4", impl, batch=2, n_steps=8)
        dec, pk = make_decoder(CCSDS_K7, v)
        llr = jnp.zeros((2, 8, pk.width), jnp.float32)
        lam0 = jnp.zeros((2, 64), jnp.float32)
        phi, lam = jax.jit(dec)(llr, lam0)
        assert phi.shape == (8 * 2 * 64,) and phi.dtype == jnp.int32
        assert lam.shape == (2 * 64,) and lam.dtype == jnp.float32

    def test_phi_values_in_range(self):
        v = Variant("radix4", "jnp", batch=2, n_steps=8)
        dec, pk = make_decoder(CCSDS_K7, v)
        llr = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 8, 4)),
                          jnp.float32)
        lam0 = jnp.zeros((2, 64), jnp.float32)
        phi, _ = jax.jit(dec)(llr, lam0)
        assert int(phi.min()) >= 0 and int(phi.max()) < pk.gamma

    def test_initial_metrics(self):
        m = initial_metrics(64, 3, known_state=5)
        assert m.shape == (3, 64)
        assert (m[:, 5] == 0).all() and (m[:, 0] < -1e8).all()
        m2 = initial_metrics(64, 2, known_state=None)
        assert (m2 == 0).all()


class TestAotExport:
    def test_hlo_text_has_full_constants(self):
        v = Variant("radix4", "jnp", batch=2, n_steps=4)
        text = lower_variant(CCSDS_K7, v)
        assert "HloModule" in text
        assert "{...}" not in text, "constants must not be elided"
        # entry signature matches the contract
        assert "f32[2,4,4]" in text and "f32[2,64]" in text
        assert "s32[512]" in text  # 4*2*64 flat phi

    def test_manifest_entry_fields(self):
        v = Variant("radix4", "jnp", batch=2, n_steps=4)
        text = lower_variant(CCSDS_K7, v)
        e = manifest_entry(CCSDS_K7, v, "x.hlo.txt", text)
        assert e["rho"] == 2 and e["gamma"] == 4 and e["width"] == 4
        assert e["ops_per_stage"] == 0.5
        assert e["stages_per_frame"] == 8
        assert e["polys_octal"] == ["171", "133"]
        assert len(e["sha256"]) == 16

    def test_pallas_variant_lowers(self):
        v = Variant("radix4", "pallas", batch=2, n_steps=4)
        text = lower_variant(CCSDS_K7, v)
        assert "HloModule" in text and "{...}" not in text
