"""L1 kernel correctness: tensor-formulated ACS vs the pure-numpy oracle
(Alg 1 + Alg 2), across schemes, implementations, dtypes and shapes.

This is the CORE correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional in the offline image; CI installs it
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import Variant, initial_metrics, make_decoder
from compile.trellis import CCSDS_K7, GSM_K5

CODE = CCSDS_K7


def bf16(x):
    return np.asarray(x).astype(ml_dtypes.bfloat16).astype(np.float64)


def run_variant(v: Variant, llr: np.ndarray, lam0: np.ndarray, code=CODE):
    dec, pk = make_decoder(code, v)
    phi, lam = jax.jit(dec)(
        llr.reshape(v.batch, v.n_steps, pk.width).astype(np.float32), lam0)
    S = code.n_states
    return (np.asarray(phi).reshape(v.n_steps, v.batch, S),
            np.asarray(lam).reshape(v.batch, S), pk)


def check_against_ref(v: Variant, seed: int, rho: int, atol=1e-4):
    rng = np.random.default_rng(seed)
    n = v.n_steps * rho
    llr = rng.normal(0, 1.2, (v.batch, n, CODE.beta))
    lam0 = np.zeros((v.batch, CODE.n_states), np.float32)
    phi, lam, _ = run_variant(v, llr, lam0)
    for b in range(v.batch):
        _, lam_r = ref.forward(CODE, bf16(llr[b]), lam0[b].astype(np.float64))
        np.testing.assert_allclose(lam[b], lam_r[-1], atol=atol,
                                   err_msg=f"frame {b} metrics")
        bits_k = ref.traceback_radix(CODE, rho, phi[:, b].astype(np.int64), lam[b])
        bits_r = ref.traceback(CODE, *ref.forward(CODE, bf16(llr[b]),
                                                  lam0[b].astype(np.float64))[:1],
                               lam_r[-1])
        assert (bits_k == bits_r).all(), f"frame {b} decoded bits differ"


@pytest.mark.parametrize("scheme,rho", [("radix2", 1), ("radix4", 2),
                                        ("radix4_noperm", 2)])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_all_paths_match_oracle(scheme, impl, rho):
    v = Variant(scheme=scheme, impl=impl, batch=4, n_steps=16, renorm_every=0)
    check_against_ref(v, seed=1, rho=rho)


@pytest.mark.parametrize("batch", [1, 2, 8])
def test_batch_sizes(batch):
    v = Variant("radix4", "jnp", batch=batch, n_steps=16, renorm_every=0)
    check_against_ref(v, seed=2, rho=2)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_renorm_preserves_decisions(impl):
    """Renormalization subtracts a per-frame constant: decoded bits and
    metric *differences* are invariant."""
    rng = np.random.default_rng(3)
    llr = rng.normal(0, 1.0, (2, 32, 2)).astype(np.float32)  # 32 stages
    lam0 = np.zeros((2, 64), np.float32)
    outs = []
    for renorm in [0, 4]:
        v = Variant("radix4", impl, batch=2, n_steps=16, renorm_every=renorm)
        phi, lam, _ = run_variant(v, llr, lam0)
        outs.append((phi, lam))
    (phi_a, lam_a), (phi_b, lam_b) = outs
    np.testing.assert_array_equal(phi_a, phi_b)
    diff = lam_a - lam_b
    np.testing.assert_allclose(diff - diff[:, :1], 0.0, atol=1e-3)


def test_half_accumulator_rounds_metrics():
    rng = np.random.default_rng(4)
    llr = rng.normal(0, 1.0, (2, 32, 2)).astype(np.float32)  # 32 stages
    lam0 = np.zeros((2, 64), np.float32)
    v32 = Variant("radix4", "jnp", acc="single", batch=2, n_steps=16, renorm_every=4)
    v16 = Variant("radix4", "jnp", acc="half", batch=2, n_steps=16, renorm_every=4)
    _, lam32, _ = run_variant(v32, llr, lam0)
    _, lam16, _ = run_variant(v16, llr, lam0)
    # half metrics are bf16-representable and close-but-not-equal
    assert np.all(lam16 == bf16(lam16).astype(np.float32))
    assert not np.array_equal(lam16, lam32)
    np.testing.assert_allclose(lam16, lam32, atol=2.0)


def test_known_start_state_decodes_noiseless():
    bits = np.concatenate([np.random.default_rng(5).integers(0, 2, 26),
                           np.zeros(6, np.int64)])
    coded, _ = CODE.encode(list(bits))
    llr = (1.0 - 2.0 * np.asarray(coded)).reshape(1, 32, 2).astype(np.float32)
    v = Variant("radix4", "jnp", batch=1, n_steps=16, renorm_every=0)
    lam0 = initial_metrics(64, 1, known_state=0)
    phi, lam, _ = run_variant(v, llr.reshape(1, 32, 2), lam0)
    out = ref.traceback_radix(CODE, 2, phi[:, 0].astype(np.int64), lam[0], end_state=0)
    assert (out == bits).all()


@given(st.integers(0, 2**31 - 1), st.floats(0.3, 3.0))
@settings(max_examples=10, deadline=None)
def test_hypothesis_sweep_noise_levels(seed, sigma):
    """Property: radix-4 tensor decode equals the oracle for any noise
    level (generic continuous LLRs)."""
    rng = np.random.default_rng(seed)
    llr = rng.normal(0, sigma, (2, 24, 2))
    lam0 = np.zeros((2, 64), np.float32)
    v = Variant("radix4", "jnp", batch=2, n_steps=12, renorm_every=0)
    phi, lam, _ = run_variant(v, llr, lam0)
    for b in range(2):
        _, lam_r = ref.forward(CODE, bf16(llr[b]), lam0[b].astype(np.float64))
        np.testing.assert_allclose(lam[b], lam_r[-1], atol=1e-3)


@given(st.sampled_from([8, 12, 16, 24]), st.sampled_from([1, 3]))
@settings(max_examples=8, deadline=None)
def test_hypothesis_shapes(n_steps, batch):
    v = Variant("radix4", "jnp", batch=batch, n_steps=n_steps, renorm_every=0)
    check_against_ref(v, seed=n_steps * 31 + batch, rho=2)


def test_gsm_code_also_decodes():
    """Generality: the 16-state GSM code through the same machinery."""
    code = GSM_K5
    rng = np.random.default_rng(7)
    v = Variant("radix4", "jnp", batch=2, n_steps=12, renorm_every=0)
    dec, pk = make_decoder(code, v)
    llr = rng.normal(0, 1.0, (2, 12, pk.width)).astype(np.float32)
    lam0 = np.zeros((2, 16), np.float32)
    phi, lam = jax.jit(dec)(llr, lam0)
    phi = np.asarray(phi).reshape(12, 2, 16)
    lam = np.asarray(lam).reshape(2, 16)
    for b in range(2):
        _, lam_r = ref.forward(code, bf16(llr[b].reshape(24, 2)),
                               np.zeros(16))
        np.testing.assert_allclose(lam[b], lam_r[-1], atol=1e-3)
