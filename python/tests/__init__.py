"""Test package marker: lets pytest import these modules as
``tests.*`` with ``python/`` on ``sys.path``, so both the
``from compile...`` absolute imports and the ``from .test_trellis``
relative import resolve."""
