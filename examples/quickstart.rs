//! Quickstart: encode a payload, push it through a noisy channel, decode
//! it with the full three-layer stack (PJRT artifact if built, CPU
//! tensor-emulation otherwise) and verify the round trip — everything
//! constructed through the `tcvd::api` builder facade.
//!
//! Run: `cargo run --release --example quickstart`

use tcvd::api::DecoderBuilder;
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{registry, Encoder};
use tcvd::defaults;
use tcvd::util::rng::Rng;

fn main() -> tcvd::Result<()> {
    // 1. the paper's code: (2,1,7), polynomials 171/133 octal
    let code = registry::paper_code();
    println!("code: (2,1,{}) polys octal {:o}/{:o}", code.k(), code.polys()[0], code.polys()[1]);

    // 2. transmitter: random payload -> convolutional encoder -> BPSK
    let mut payload = Rng::new(42).bits(16384 - 6);
    payload.extend_from_slice(&[0; 6]); // flush to state 0
    let mut enc = Encoder::new(code.clone());
    let coded = enc.encode(&payload);
    let tx = bpsk::modulate(&coded);

    // 3. AWGN channel at 4 dB Eb/N0
    let mut ch = AwgnChannel::new(4.0, code.rate(), 7);
    let rx = ch.transmit(&tx);
    let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();

    // 4. receiver: the serving pipeline over the best available backend.
    //    The default builder targets the AOT artifact (the b64_s48
    //    variant decodes 96-stage frames); if that is not built, fall
    //    back to the CPU tensor emulation of the same arithmetic.
    let coord = match DecoderBuilder::new().batch_deadline_us(500).queue_depth(512).serve() {
        Ok(c) => {
            println!("backend: PJRT artifact");
            c
        }
        Err(e) => {
            println!("backend: CPU tensor emulation (artifact unavailable: {e})");
            DecoderBuilder::new()
                .backend_name("cpu-radix4")?
                .tile(defaults::CPU_TILE)
                .max_batch(16)
                .batch_deadline_us(200)
                .queue_depth(256)
                .serve()?
        }
    };

    let decoded = coord.decode_stream_blocking(&llr)?;
    let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
    let snap = coord.metrics();
    println!(
        "decoded {} bits, {} errors (BER {:.1e}) — {:.2} Mb/s through the pipeline",
        decoded.len(),
        errors,
        errors as f64 / decoded.len() as f64,
        snap.throughput_bps / 1e6
    );
    println!(
        "frames={} mean_batch={:.1} latency p50={:.0}us p99={:.0}us",
        snap.frames_out, snap.mean_batch, snap.latency_p50_us, snap.latency_p99_us
    );
    coord.shutdown()?;
    // 4 dB soft-decision BER is ~1e-4; a handful of errors is nominal
    assert!(errors < 20, "BER far above the 4 dB operating point");
    println!("quickstart OK");
    Ok(())
}
