//! Regenerate a quick Fig-13-style BER curve: soft-decision radix-4
//! tensor decode vs hard-decision vs theory references. Decoders are
//! built through the `tcvd::api` facade.
//!
//! Run: `cargo run --release --example ber_curve [max_bits_per_point]`
//! (full-rigor curves live in `cargo bench --bench fig13_ber`)

use tcvd::api::DecoderBuilder;
use tcvd::ber::{measure_ber, sweep, theory, BerSetup};
use tcvd::defaults;

fn main() -> tcvd::Result<()> {
    let max_bits: usize = std::env::args().nth(1).map_or(200_000, |s| s.parse().unwrap());
    let tile = defaults::CPU_TILE;
    let builder = DecoderBuilder::new().backend_name("cpu-radix4")?.tile(tile);

    let snrs = sweep::parse_range("0:6:1")?;
    println!(
        "{:>6} | {:>10} {:>10} | {:>12} {:>12} {:>12}",
        "dB", "soft BER", "hard BER", "theory soft", "theory hard", "uncoded"
    );
    for &db in &snrs {
        let mut soft_dec = builder.clone().build()?;
        let trellis = soft_dec.trellis().clone();
        let soft = measure_ber(
            soft_dec.as_frame_decoder(),
            &trellis,
            db,
            &BerSetup { tile, max_bits, target_errors: 200, ..Default::default() },
        )?;
        let mut hard_dec = builder.clone().build()?;
        let hard = measure_ber(
            hard_dec.as_frame_decoder(),
            &trellis,
            db,
            &BerSetup {
                tile,
                max_bits,
                target_errors: 200,
                hard_decision: true,
                ..Default::default()
            },
        )?;
        println!(
            "{:6.1} | {:10.2e} {:10.2e} | {:12.2e} {:12.2e} {:12.2e}",
            db,
            soft.ber(),
            hard.ber(),
            theory::coded_union_bound(db),
            theory::coded_union_bound_hard(db),
            theory::uncoded_bpsk(db),
        );
    }
    println!("\n(soft-decision gains ~2 dB over hard — paper §II-C)");
    Ok(())
}
