//! End-to-end SDR serving driver (the EXPERIMENTS.md §E2E run): a fleet
//! of concurrent radio sessions stream chunked LLRs through the
//! coordinator backed by the AOT PJRT artifact; reports aggregate
//! throughput, latency percentiles, batching occupancy and BER. The
//! pipeline comes from `tcvd::api::DecoderBuilder`; each session uses
//! `Session::split` for its producer/consumer thread pair.
//!
//! Run: `cargo run --release --example sdr_stream [sessions] [bits/session] [snr_db]`

use std::sync::Arc;
use std::time::Instant;

use tcvd::api::DecoderBuilder;
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{registry, Encoder};
use tcvd::util::rng::Rng;

fn main() -> tcvd::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let sessions: usize = args.get(1).map_or(8, |s| s.parse().unwrap());
    let bits_per_session: usize = args.get(2).map_or(262_144, |s| s.parse().unwrap());
    let snr: f64 = args.get(3).map_or(5.0, |s| s.parse().unwrap());

    // default backend/tile/variant: the radix-4 + DG-permutation
    // artifact at 64+16/16 tiling (defaults module)
    let coord = Arc::new(DecoderBuilder::new().workers(3).queue_depth(2048).serve()?);
    println!(
        "sdr_stream: {sessions} sessions x {bits_per_session} bits at {snr} dB \
         (radix-4 + DG-permutation artifact, Q=0.5 ops/stage)"
    );

    let code = registry::paper_code();
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for s in 0..sessions {
        let coord = coord.clone();
        let code = code.clone();
        joins.push(std::thread::spawn(move || -> tcvd::Result<(usize, usize)> {
            let mut rng = Rng::new(1000 + s as u64);
            let mut payload = rng.bits(bits_per_session - 6);
            payload.extend_from_slice(&[0; 6]);
            let mut enc = Encoder::new(code.clone());
            let coded = enc.encode(&payload);
            let tx = bpsk::modulate(&coded);
            let mut ch = AwgnChannel::new(snr, code.rate(), 5000 + s as u64);

            let (mut handle, out) = coord.open_session()?.split();
            // consumer drains in-order decoded chunks as they arrive
            let consumer = std::thread::spawn(move || {
                let mut bits = Vec::new();
                for c in out {
                    bits.extend_from_slice(&c);
                }
                bits
            });
            // producer: stream SDR-sized chunks (1024 stages) as they "arrive"
            let mut noisy = vec![0.0f64; 2048];
            for chunk in tx.chunks(2048) {
                ch.transmit_into(chunk, &mut noisy[..chunk.len()]);
                let llr: Vec<f32> = noisy[..chunk.len()].iter().map(|&x| x as f32).collect();
                handle.push(&llr)?;
            }
            handle.finish()?;
            let decoded = consumer.join().expect("consumer panicked");
            let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
            Ok((decoded.len(), errors))
        }));
    }

    let mut total_bits = 0usize;
    let mut total_errors = 0usize;
    for j in joins {
        let (b, e) = j.join().expect("session panicked")?;
        total_bits += b;
        total_errors += e;
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics();
    println!("\n== results ==");
    println!("info bits decoded : {total_bits}");
    println!("bit errors        : {total_errors} (BER {:.2e})",
             total_errors as f64 / total_bits as f64);
    println!("wall time         : {wall:.3} s");
    println!("info throughput   : {:.3} Mb/s", total_bits as f64 / wall / 1e6);
    println!("coded throughput  : {:.3} Mb/s (2x info, rate 1/2)",
             2.0 * total_bits as f64 / wall / 1e6);
    println!("PJRT executions   : {} (mean batch {:.1}/64)", snap.execs, snap.mean_batch);
    println!("frame latency     : p50 {:.0} us, p99 {:.0} us",
             snap.latency_p50_us, snap.latency_p99_us);
    println!("forward/traceback : {:.1} ms / {:.1} ms total",
             snap.forward_ns_total as f64 / 1e6, snap.traceback_ns_total as f64 / 1e6);
    println!("engine shards     : {} (total steals {})", snap.shards.len(), snap.steals_total());
    for (i, sh) in snap.shards.iter().enumerate() {
        println!("  shard {i}: frames={} execs={} steals={}", sh.frames, sh.execs, sh.steals);
    }
    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    coord.shutdown()?;
    Ok(())
}
