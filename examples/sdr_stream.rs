//! End-to-end SDR serving driver (the EXPERIMENTS.md §E2E run), in two
//! samples:
//!
//! 1. **Socket transport (primary)** — a `tcvd::net::Server` on a
//!    loopback TCP port, with a fleet of concurrent radio sessions
//!    streaming chunked LLRs through `TcpClient` (the same wire path
//!    `tcvd serve --listen` exposes; see `docs/NETWORKING.md`). Runs on
//!    the artifact-free SIMD backend.
//! 2. **In-process** — the same fleet pushed straight into the
//!    coordinator via `Session::split`, backed by the AOT PJRT
//!    artifact (skipped with a note when no artifacts are built).
//!
//! Both report aggregate throughput, latency percentiles and BER.
//!
//! Run: `cargo run --release --example sdr_stream [sessions] [bits/session] [snr_db]`

use std::sync::Arc;
use std::time::Instant;

use tcvd::api::DecoderBuilder;
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{poly::Code, registry, Encoder};
use tcvd::defaults;
use tcvd::net::{NetConfig, Server, TcpClient};
use tcvd::util::rng::Rng;

/// One session's radio workload: flush-terminated payload, BPSK + AWGN.
/// Returns (payload bits, noisy LLR stream).
fn session_workload(code: &Code, bits: usize, snr: f64, s: usize) -> (Vec<u8>, Vec<f32>) {
    let mut payload = Rng::new(1000 + s as u64).bits(bits - 6);
    payload.extend_from_slice(&[0; 6]);
    let mut enc = Encoder::new(code.clone());
    let tx = bpsk::modulate(&enc.encode(&payload));
    let mut ch = AwgnChannel::new(snr, code.rate(), 5000 + s as u64);
    let llr: Vec<f32> = ch.transmit(&tx).iter().map(|&x| x as f32).collect();
    (payload, llr)
}

fn print_results(label: &str, total_bits: usize, total_errors: usize, wall: f64) {
    println!("\n== {label} results ==");
    println!("info bits decoded : {total_bits}");
    println!("bit errors        : {total_errors} (BER {:.2e})",
             total_errors as f64 / total_bits as f64);
    println!("wall time         : {wall:.3} s");
    println!("info throughput   : {:.3} Mb/s", total_bits as f64 / wall / 1e6);
    println!("coded throughput  : {:.3} Mb/s (2x info, rate 1/2)",
             2.0 * total_bits as f64 / wall / 1e6);
}

/// Sample 1: the socket front-end on loopback TCP — every session is a
/// real connection through the HELLO/ACK handshake and framed wire
/// protocol.
fn tcp_transport_sample(sessions: usize, bits_per_session: usize, snr: f64) -> tcvd::Result<()> {
    let tile = defaults::CPU_TILE;
    let builder = DecoderBuilder::new()
        .backend_name("simd")?
        .tile_dims(tile.payload, tile.head, tile.tail)
        .workers(3)
        .queue_depth(2048);
    let server = Server::start(builder.clone(), Some("127.0.0.1:0"), None, NetConfig::default())?;
    let addr = server.tcp_addr().expect("tcp serving enabled");
    println!(
        "sdr_stream[tcp]: {sessions} sessions x {bits_per_session} bits at {snr} dB \
         over {addr} (simd backend, {}+{}/{} tile)",
        tile.payload, tile.head, tile.tail
    );

    let code = registry::paper_code();
    let chunk_llrs = tile.payload * code.beta() * 16; // SDR-sized bursts
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for s in 0..sessions {
        let code = code.clone();
        let builder = builder.clone();
        joins.push(std::thread::spawn(move || -> tcvd::Result<(usize, usize)> {
            let (payload, llr) = session_workload(&code, bits_per_session, snr, s);
            let mut client = TcpClient::connect(addr, &builder)?;
            for part in llr.chunks(chunk_llrs) {
                client.push(part)?;
            }
            let decoded = client.finish()?;
            let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
            Ok((decoded.len(), errors))
        }));
    }
    let mut total_bits = 0usize;
    let mut total_errors = 0usize;
    for j in joins {
        let (b, e) = j.join().expect("session panicked")?;
        total_bits += b;
        total_errors += e;
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    print_results("tcp transport", total_bits, total_errors, wall);
    println!("net sessions      : {} accepted, {} evicted, {} shed",
             snap.net.sessions_accepted, snap.net.sessions_evicted, snap.net.sessions_shed);
    println!("wire traffic      : {} bytes in, {} bytes out",
             snap.net.bytes_in, snap.net.bytes_out);
    println!("block latency     : p50 {:.0} us, p99 {:.0} us (finish -> last byte)",
             snap.net.block_p50_us, snap.net.block_p99_us);
    server.shutdown()
}

/// Sample 2: the original in-process fleet against the AOT artifact
/// pipeline (radix-4 + DG-permutation at the default 64+16/16 tiling).
fn in_process_sample(sessions: usize, bits_per_session: usize, snr: f64) -> tcvd::Result<()> {
    let coord = match DecoderBuilder::new().workers(3).queue_depth(2048).serve() {
        Ok(c) => Arc::new(c),
        Err(e) => {
            println!("\nsdr_stream[in-process]: skipped ({e}); run `make artifacts` first");
            return Ok(());
        }
    };
    println!(
        "\nsdr_stream[in-process]: {sessions} sessions x {bits_per_session} bits at {snr} dB \
         (radix-4 + DG-permutation artifact, Q=0.5 ops/stage)"
    );

    let code = registry::paper_code();
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for s in 0..sessions {
        let coord = coord.clone();
        let code = code.clone();
        joins.push(std::thread::spawn(move || -> tcvd::Result<(usize, usize)> {
            let (payload, llr) = session_workload(&code, bits_per_session, snr, s);
            let (mut handle, out) = coord.open_session()?.split();
            // consumer drains in-order decoded chunks as they arrive
            // (an Err chunk = the session was poisoned by a shard fault)
            let consumer = std::thread::spawn(move || {
                let mut bits = Vec::new();
                for c in out {
                    bits.extend_from_slice(&c.expect("session poisoned"));
                }
                bits
            });
            // producer: stream SDR-sized chunks (1024 stages) as they "arrive"
            for chunk in llr.chunks(2048) {
                handle.push(chunk)?;
            }
            handle.finish()?;
            let decoded = consumer.join().expect("consumer panicked");
            let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
            Ok((decoded.len(), errors))
        }));
    }
    let mut total_bits = 0usize;
    let mut total_errors = 0usize;
    for j in joins {
        let (b, e) = j.join().expect("session panicked")?;
        total_bits += b;
        total_errors += e;
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics();
    print_results("in-process", total_bits, total_errors, wall);
    println!("PJRT executions   : {} (mean batch {:.1}/64)", snap.execs, snap.mean_batch);
    println!("frame latency     : p50 {:.0} us, p99 {:.0} us",
             snap.latency_p50_us, snap.latency_p99_us);
    println!("forward/traceback : {:.1} ms / {:.1} ms total",
             snap.forward_ns_total as f64 / 1e6, snap.traceback_ns_total as f64 / 1e6);
    println!("engine shards     : {} (total steals {})", snap.shards.len(), snap.steals_total());
    for (i, sh) in snap.shards.iter().enumerate() {
        println!("  shard {i}: frames={} execs={} steals={}", sh.frames, sh.execs, sh.steals);
    }
    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    coord.shutdown()
}

fn main() -> tcvd::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let sessions: usize = args.get(1).map_or(8, |s| s.parse().unwrap());
    let bits_per_session: usize = args.get(2).map_or(262_144, |s| s.parse().unwrap());
    let snr: f64 = args.get(3).map_or(5.0, |s| s.parse().unwrap());

    tcp_transport_sample(sessions, bits_per_session, snr)?;
    in_process_sample(sessions, bits_per_session, snr)
}
