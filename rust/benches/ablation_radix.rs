//! E4 — radix/packing ablation: scalar baseline vs radix-2 (Fig 5,
//! Q=2 ops/stage) vs radix-4 without permutation (Fig 14, Q=2) vs
//! radix-4 + dragonfly-group permutation (Fig 15, Q=0.5), plus the
//! quantized SIMD fast path at radix-2^rho (rho 1 vs 2).
//!
//! Reports the paper's Q metric (tensor ops per stage — the hardware-
//! independent claim) and **info-bit Mb/s** for every row, measured the
//! same way `table1_throughput.rs` measures its rows: `llr.len() / 2`
//! info bits over wall time, `Truncated` termination (the mid-stream
//! workload has no flushed end), one shard / one engine so rows compare
//! per-executable work, not fleet size.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use tcvd::api::{Decoder, DecoderBuilder, TerminationMode};
use tcvd::coding::packing::build_packing;
use tcvd::coding::{registry, trellis::Trellis};
use tcvd::defaults;
use tcvd::util::json::{self, Json};

fn main() -> tcvd::Result<()> {
    let trellis = Arc::new(Trellis::new(registry::paper_code()));
    let requested = if common::full_rigor() { 262_144 } else { 65_536 };
    let (_, llr) = common::workload(99, requested, 5.0);
    // info-bit accounting identical to table1_throughput.rs: the stream
    // carries one info bit per trellis stage (rate-1/2, beta = 2)
    let info_bits = llr.len() / 2;
    let tile = defaults::CPU_TILE;

    println!("E4 — packing ablation on (2,1,7) 171/133\n");
    println!(
        "{:>16} | {:>12} | {:>12} | {:>14}",
        "decoder", "Q ops/stage", "matmul ops", "info Mb/s"
    );

    let mut rows = Vec::new();
    let mut bench_cpu = |name: &str, dec: &mut Decoder, q: f64| {
        let d = common::time_median(3, || {
            dec.decode_stream(&llr).unwrap();
        });
        let mbps = common::mbps(info_bits, d);
        let total_ops = q * (info_bits as f64);
        println!("{name:>16} | {q:12.2} | {total_ops:12.0} | {mbps:14.3}");
        rows.push(json::obj(vec![
            ("decoder", json::s(name)),
            ("q_ops_per_stage", json::num(q)),
            ("cpu_mbps", json::num(mbps)),
        ]));
    };

    // one-shot CPU rows: Truncated + single shard, matching the table-1
    // CPU methodology (same workload family, same accounting)
    let cpu_builder = |backend: &str| -> tcvd::Result<DecoderBuilder> {
        Ok(DecoderBuilder::new()
            .backend_name(backend)?
            .tile(tile)
            .termination(TerminationMode::Truncated)
            .shards(1))
    };
    let mut scalar = cpu_builder("scalar")?.build()?;
    bench_cpu("scalar", &mut scalar, f64::NAN);
    for (backend, scheme) in [
        ("cpu-radix2", "radix2"),
        ("cpu-radix4-noperm", "radix4_noperm"),
        ("cpu-radix4", "radix4"),
    ] {
        let pk = build_packing(&trellis, scheme).expect("known scheme");
        let q = pk.ops_per_stage();
        let mut dec = cpu_builder(backend)?.build()?;
        bench_cpu(scheme, &mut dec, q);
    }
    // the quantized SIMD fast path at both radixes: rho = 2 folds stage
    // pairs into radix-4 super-branch tournaments (no tensor ops, so no
    // Q — the comparison axis is the serial trip count)
    for (name, rho) in [("simd-r1", 1usize), ("simd-r2", 2)] {
        let mut dec = cpu_builder("simd")?.radix(rho).build()?;
        bench_cpu(name, &mut dec, f64::NAN);
    }

    // PJRT artifacts: radix2 (b64_s96) vs radix4+perm (b64_s48)
    println!("\nPJRT artifacts (XLA-CPU; compare ratio radix4/radix2):");
    let mut pjrt_rows = Vec::new();
    for (name, variant, tile) in [
        ("radix2", defaults::VARIANT_RADIX2, defaults::TILE),
        ("radix4_noperm", defaults::VARIANT_RADIX4_NOPERM, defaults::TILE),
        ("radix4+perm", defaults::VARIANT, defaults::TILE),
    ] {
        let builder = DecoderBuilder::new()
            .variant(variant)
            .tile(tile)
            .termination(TerminationMode::Truncated) // mid-stream quarter slices
            .workers(3)
            .queue_depth(2048)
            .shards(1); // per-executable ablation: keep one engine
        let coord = match builder.serve() {
            Ok(c) => c,
            Err(e) => {
                println!("{name:>16} | SKIP ({e})");
                continue;
            }
        };
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for q in llr.chunks(llr.len() / 4) {
                let coord = &coord;
                s.spawn(move || coord.decode_stream_blocking(q).unwrap());
            }
        });
        let mbps = common::mbps(info_bits, t0.elapsed());
        println!("{name:>16} | {mbps:14.3} Mb/s");
        pjrt_rows.push(json::obj(vec![
            ("decoder", json::s(name)),
            ("pjrt_mbps", json::num(mbps)),
        ]));
        coord.shutdown()?;
    }

    common::write_json("ablation_radix", &json::obj(vec![
        ("experiment", json::s("E4/radix-ablation")),
        ("info_bits", json::num(info_bits as f64)),
        ("cpu", Json::Arr(rows)),
        ("pjrt", Json::Arr(pjrt_rows)),
    ]));
    Ok(())
}
