//! E4 — radix/packing ablation: scalar baseline vs radix-2 (Fig 5,
//! Q=2 ops/stage) vs radix-4 without permutation (Fig 14, Q=2) vs
//! radix-4 + dragonfly-group permutation (Fig 15, Q=0.5).
//!
//! Reports the paper's Q metric (tensor ops per stage — the hardware-
//! independent claim), CPU wall time per decoded bit for the emulation
//! backends, and PJRT throughput for the AOT variants where present.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Duration;

use tcvd::coding::packing::build_packing;
use tcvd::coding::{registry, trellis::Trellis};
use tcvd::coordinator::server::CoordinatorConfig;
use tcvd::coordinator::{BackendSpec, Coordinator};
use tcvd::util::json::{self, Json};
use tcvd::viterbi::packed::presets;
use tcvd::viterbi::scalar::ScalarDecoder;
use tcvd::viterbi::tiled::{decode_stream, TileConfig};
use tcvd::viterbi::types::FrameDecoder;

fn main() -> anyhow::Result<()> {
    let trellis = Arc::new(Trellis::new(registry::paper_code()));
    let info_bits = if common::full_rigor() { 262_144 } else { 65_536 };
    let (_, llr) = common::workload(99, info_bits, 5.0);
    let tile = TileConfig { payload: 64, head: 32, tail: 32 };
    let stages = tile.frame_stages();

    println!("E4 — packing ablation on (2,1,7) 171/133\n");
    println!("{:>16} | {:>12} | {:>12} | {:>14}", "decoder", "Q ops/stage", "matmul ops", "cpu Mb/s");

    let mut rows = Vec::new();
    let mut bench_cpu = |name: &str, dec: &mut dyn FrameDecoder, q: f64| {
        let d = common::time_median(3, || {
            decode_stream(dec, &llr, 2, &tile, true).unwrap();
        });
        let mbps = common::mbps(info_bits, d);
        let total_ops = q * (info_bits as f64);
        println!("{name:>16} | {q:12.2} | {total_ops:12.0} | {mbps:14.3}");
        rows.push(json::obj(vec![
            ("decoder", json::s(name)),
            ("q_ops_per_stage", json::num(q)),
            ("cpu_mbps", json::num(mbps)),
        ]));
    };

    bench_cpu("scalar", &mut ScalarDecoder::new(trellis.clone(), stages), f64::NAN);
    for scheme in ["radix2", "radix4_noperm", "radix4"] {
        let pk = build_packing(&trellis, scheme)?;
        let q = pk.ops_per_stage();
        let mut dec = match scheme {
            "radix2" => presets::radix2(trellis.clone(), stages),
            "radix4_noperm" => presets::radix4_noperm(trellis.clone(), stages),
            _ => presets::radix4(trellis.clone(), stages),
        };
        bench_cpu(scheme, &mut dec, q);
    }

    // PJRT artifacts: radix2 (b64_s96) vs radix4+perm (b64_s48)
    println!("\nPJRT artifacts (XLA-CPU; compare ratio radix4/radix2):");
    let mut pjrt_rows = Vec::new();
    for (name, variant, tile) in [
        ("radix2", "radix2_jnp_acc-single_ch-single_b64_s96",
         TileConfig { payload: 64, head: 16, tail: 16 }),
        ("radix4_noperm", "radix4_noperm_jnp_acc-single_ch-single_b64_s48",
         TileConfig { payload: 64, head: 16, tail: 16 }),
        ("radix4+perm", "radix4_jnp_acc-single_ch-single_b64_s48",
         TileConfig { payload: 64, head: 16, tail: 16 }),
    ] {
        let coord = match Coordinator::start(CoordinatorConfig {
            backend: BackendSpec::artifact("artifacts", variant),
            tile,
            max_batch: 64,
            batch_deadline: Duration::from_micros(2000),
            workers: 3,
            queue_depth: 2048,
        }) {
            Ok(c) => c,
            Err(e) => {
                println!("{name:>16} | SKIP ({e})");
                continue;
            }
        };
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for q in llr.chunks(llr.len() / 4) {
                let coord = &coord;
                s.spawn(move || coord.decode_stream_blocking(q, false).unwrap());
            }
        });
        let mbps = common::mbps(info_bits, t0.elapsed());
        println!("{name:>16} | {mbps:14.3} Mb/s");
        pjrt_rows.push(json::obj(vec![
            ("decoder", json::s(name)),
            ("pjrt_mbps", json::num(mbps)),
        ]));
        coord.shutdown()?;
    }

    common::write_json("ablation_radix", &json::obj(vec![
        ("experiment", json::s("E4/radix-ablation")),
        ("cpu", Json::Arr(rows)),
        ("pjrt", Json::Arr(pjrt_rows)),
    ]));
    Ok(())
}
