//! E4 — radix/packing ablation: scalar baseline vs radix-2 (Fig 5,
//! Q=2 ops/stage) vs radix-4 without permutation (Fig 14, Q=2) vs
//! radix-4 + dragonfly-group permutation (Fig 15, Q=0.5).
//!
//! Reports the paper's Q metric (tensor ops per stage — the hardware-
//! independent claim), CPU wall time per decoded bit for the emulation
//! backends, and PJRT throughput for the AOT variants where present.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use tcvd::api::{DecoderBuilder, TerminationMode};
use tcvd::coding::packing::build_packing;
use tcvd::coding::{registry, trellis::Trellis};
use tcvd::defaults;
use tcvd::util::json::{self, Json};
use tcvd::viterbi::types::FrameDecoder;

fn main() -> tcvd::Result<()> {
    let trellis = Arc::new(Trellis::new(registry::paper_code()));
    let info_bits = if common::full_rigor() { 262_144 } else { 65_536 };
    let (_, llr) = common::workload(99, info_bits, 5.0);
    let tile = defaults::CPU_TILE;

    println!("E4 — packing ablation on (2,1,7) 171/133\n");
    println!("{:>16} | {:>12} | {:>12} | {:>14}", "decoder", "Q ops/stage", "matmul ops", "cpu Mb/s");

    let mut rows = Vec::new();
    let mut bench_cpu = |name: &str, dec: &mut dyn FrameDecoder, q: f64| {
        let d = common::time_median(3, || {
            tcvd::viterbi::tiled::decode_stream(dec, &llr, 2, &tile, TerminationMode::Flushed)
                .unwrap();
        });
        let mbps = common::mbps(info_bits, d);
        let total_ops = q * (info_bits as f64);
        println!("{name:>16} | {q:12.2} | {total_ops:12.0} | {mbps:14.3}");
        rows.push(json::obj(vec![
            ("decoder", json::s(name)),
            ("q_ops_per_stage", json::num(q)),
            ("cpu_mbps", json::num(mbps)),
        ]));
    };

    let mut scalar = DecoderBuilder::new().backend_name("scalar")?.tile(tile).build()?;
    bench_cpu("scalar", scalar.as_frame_decoder(), f64::NAN);
    for (backend, scheme) in [
        ("cpu-radix2", "radix2"),
        ("cpu-radix4-noperm", "radix4_noperm"),
        ("cpu-radix4", "radix4"),
    ] {
        let pk = build_packing(&trellis, scheme).expect("known scheme");
        let q = pk.ops_per_stage();
        let mut dec = DecoderBuilder::new().backend_name(backend)?.tile(tile).build()?;
        bench_cpu(scheme, dec.as_frame_decoder(), q);
    }

    // PJRT artifacts: radix2 (b64_s96) vs radix4+perm (b64_s48)
    println!("\nPJRT artifacts (XLA-CPU; compare ratio radix4/radix2):");
    let mut pjrt_rows = Vec::new();
    for (name, variant, tile) in [
        ("radix2", defaults::VARIANT_RADIX2, defaults::TILE),
        ("radix4_noperm", defaults::VARIANT_RADIX4_NOPERM, defaults::TILE),
        ("radix4+perm", defaults::VARIANT, defaults::TILE),
    ] {
        let builder = DecoderBuilder::new()
            .variant(variant)
            .tile(tile)
            .termination(TerminationMode::Truncated) // mid-stream quarter slices
            .workers(3)
            .queue_depth(2048)
            .shards(1); // per-executable ablation: keep one engine
        let coord = match builder.serve() {
            Ok(c) => c,
            Err(e) => {
                println!("{name:>16} | SKIP ({e})");
                continue;
            }
        };
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for q in llr.chunks(llr.len() / 4) {
                let coord = &coord;
                s.spawn(move || coord.decode_stream_blocking(q).unwrap());
            }
        });
        let mbps = common::mbps(info_bits, t0.elapsed());
        println!("{name:>16} | {mbps:14.3} Mb/s");
        pjrt_rows.push(json::obj(vec![
            ("decoder", json::s(name)),
            ("pjrt_mbps", json::num(mbps)),
        ]));
        coord.shutdown()?;
    }

    common::write_json("ablation_radix", &json::obj(vec![
        ("experiment", json::s("E4/radix-ablation")),
        ("cpu", Json::Arr(rows)),
        ("pjrt", Json::Arr(pjrt_rows)),
    ]));
    Ok(())
}
