#![allow(dead_code)]
//! Shared bench harness utilities (hand-rolled; criterion is unavailable
//! offline). Each bench prints the paper-style table and writes JSON to
//! `bench_results/`.

use std::path::Path;
use std::time::{Duration, Instant};

use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{registry, Encoder};
use tcvd::util::json::Json;
use tcvd::util::rng::Rng;

/// Full-rigor mode (longer runs): set TCVD_BENCH_FULL=1.
pub fn full_rigor() -> bool {
    std::env::var("TCVD_BENCH_FULL").map_or(false, |v| v == "1")
}

/// Smoke mode (tiny budgets so CI can run the sweeps every push
/// without them rotting): set TCVD_BENCH_SMOKE=1. `full_rigor` wins if
/// both are set. `scripts/bench_snapshot.py --smoke` is the driver.
pub fn smoke() -> bool {
    !full_rigor() && std::env::var("TCVD_BENCH_SMOKE").map_or(false, |v| v == "1")
}

/// Pick an info-bit budget by rigor mode.
pub fn budget(smoke_bits: usize, default_bits: usize, full_bits: usize) -> usize {
    if full_rigor() {
        full_bits
    } else if smoke() {
        smoke_bits
    } else {
        default_bits
    }
}

/// Generate (payload, llr-stream) for the paper's code at an Eb/N0.
pub fn workload(seed: u64, info_bits: usize, ebn0_db: f64) -> (Vec<u8>, Vec<f32>) {
    let code = registry::paper_code();
    let mut payload = Rng::new(seed).bits(info_bits - 6);
    payload.extend_from_slice(&[0; 6]);
    let mut enc = Encoder::new(code.clone());
    let coded = enc.encode(&payload);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(ebn0_db, code.rate(), seed ^ 0xBEEF);
    let rx = ch.transmit(&tx);
    (payload, rx.iter().map(|&x| x as f32).collect())
}

/// Median wall time of `iters` runs of `f` (after one warmup).
pub fn time_median<F: FnMut()>(iters: usize, mut f: F) -> Duration {
    f(); // warmup
    let mut times: Vec<Duration> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    times.sort();
    times[times.len() / 2]
}

/// Write a JSON result document under bench_results/.
pub fn write_json(name: &str, j: &Json) {
    let dir = Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, j.to_string_pretty()) {
        eprintln!("(could not write {}: {e})", path.display());
    } else {
        println!("\nwrote {}", path.display());
    }
}

/// Mb/s from bits and a duration.
pub fn mbps(bits: usize, d: Duration) -> f64 {
    bits as f64 / d.as_secs_f64() / 1e6
}
