//! E3 — tiling-overlap ablation (paper §III, refs [4-7]): BER
//! degradation vs frame overlap length v, plus the Eq-5 memory overhead
//! factor (1 + v/f). Expected shape: sharp degradation below v ~ 4-5
//! constraint lengths, plateau at the unframed BER by v ~ 6k.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use tcvd::api::{BackendKind, DecoderBuilder};
use tcvd::ber::{measure_ber, BerSetup};
use tcvd::coding::{registry, trellis::Trellis};
use tcvd::util::json::{self, Json};
use tcvd::viterbi::tiled::TileConfig;

fn main() -> tcvd::Result<()> {
    let trellis = Arc::new(Trellis::new(registry::paper_code()));
    let ebn0 = 3.0; // mid-waterfall: truncation errors clearly visible
    let (max_bits, errors) = if common::full_rigor() {
        (2_000_000, 300)
    } else {
        (300_000, 150)
    };

    println!("E3 — BER vs overlap v at {ebn0} dB (payload f=64, k=7)\n");
    println!("{:>6} | {:>12} | {:>14} | {:>10}", "v", "BER", "vs v=96 ref", "Eq5 ovh");

    // v split evenly between head (metric warm-up) and tail (traceback)
    let vs = [0usize, 8, 16, 24, 32, 48, 64, 96];
    let mut rows = Vec::new();
    let mut reference = None;
    // compute reference (largest v) first
    let mut points = Vec::new();
    for &v in vs.iter().rev() {
        let tile = TileConfig { payload: 64, head: v / 2, tail: v - v / 2 };
        let mut dec = DecoderBuilder::new()
            .backend(BackendKind::cpu("radix4"))
            .tile(tile)
            .build()?;
        let setup = BerSetup { tile, target_errors: errors, max_bits, ..Default::default() };
        let p = measure_ber(dec.as_frame_decoder(), &trellis, ebn0, &setup)?;
        if reference.is_none() {
            reference = Some(p.ber().max(1e-12));
        }
        points.push((v, tile, p));
    }
    points.reverse();
    for (v, tile, p) in points {
        let ratio = p.ber() / reference.unwrap();
        println!("{v:6} | {:12.3e} | {ratio:14.2}x | {:10.3}", p.ber(), tile.overhead());
        rows.push(json::obj(vec![
            ("v", json::num(v as f64)),
            ("ber", json::num(p.ber())),
            ("ratio_vs_ref", json::num(ratio)),
            ("eq5_overhead", json::num(tile.overhead())),
            ("bits", json::num(p.bits as f64)),
            ("errors", json::num(p.errors as f64)),
        ]));
    }
    println!("\n(v is split head/tail; Eq 5 overhead = 1 + v/f is the survivor-");
    println!(" path memory factor the paper trades against parallelism)");

    common::write_json("ablation_overlap", &json::obj(vec![
        ("experiment", json::s("E3/overlap")),
        ("ebn0_db", json::num(ebn0)),
        ("rows", Json::Arr(rows)),
    ]));
    Ok(())
}
