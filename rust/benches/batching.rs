//! E5 — coordinator serving ablation: dynamic-batch size / deadline /
//! session-count sweep over the PJRT artifact backend, plus an engine
//! shard-scaling sweep over the CPU tensor-emulation backend. The
//! paper's throughput rests on frame-parallel launches; this shows how
//! batch occupancy drives throughput, what it costs in latency, and how
//! aggregate throughput scales when `serve()` is sharded across
//! multiple engine threads.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use tcvd::api::{DecoderBuilder, TerminationMode};
use tcvd::defaults;
use tcvd::util::json::{self, Json};
use tcvd::viterbi::tiled;
use tcvd::viterbi::types::FrameDecoder;

fn run(sessions: usize, max_batch: usize, deadline_us: u64, info_bits: usize)
       -> tcvd::Result<(f64, f64, f64, f64)> {
    let coord = Arc::new(
        DecoderBuilder::new()
            .max_batch(max_batch)
            .batch_deadline_us(deadline_us)
            .workers(3)
            .queue_depth(2048)
            .shards(1) // single engine: isolates the batching policy
            .serve()?,
    );
    let per_session = info_bits / sessions;
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for i in 0..sessions {
            let coord = coord.clone();
            s.spawn(move || {
                let (_, llr) = common::workload(7000 + i as u64, per_session, 5.0);
                coord.decode_stream_blocking(&llr).unwrap();
            });
        }
    });
    let wall = t0.elapsed();
    let snap = coord.metrics();
    let coord = Arc::try_unwrap(coord).ok().expect("done");
    coord.shutdown()?;
    Ok((
        common::mbps(info_bits, wall),
        snap.mean_batch,
        snap.latency_p50_us,
        snap.latency_p99_us,
    ))
}

/// Shard-scaling run on an always-available CPU backend (no artifacts
/// needed): N sessions decode concurrently through a coordinator with
/// `shards` engine threads. Outputs are checked bit-exact against the
/// transmitted payloads, so the sweep also witnesses the
/// shard-invariance guarantee — for the quantized `simd` backend this
/// additionally witnesses that quantization is transparent at 6 dB.
fn run_sharded(backend: &str, radix: usize, shards: usize, sessions: usize, info_bits: usize)
               -> tcvd::Result<(f64, f64, u64)> {
    let coord = Arc::new(
        DecoderBuilder::new()
            .backend_name(backend)?
            .radix(radix)
            .tile(defaults::CPU_TILE)
            .shards(shards)
            .workers(2)
            .max_batch(16)
            .batch_deadline_us(200)
            .queue_depth(2048)
            .serve()?,
    );
    let per_session = info_bits / sessions;
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for i in 0..sessions {
            let coord = coord.clone();
            s.spawn(move || {
                let (payload, llr) = common::workload(9000 + i as u64, per_session, 6.0);
                let out = coord.decode_stream_blocking(&llr).unwrap();
                assert_eq!(
                    out, payload,
                    "{backend} shards={shards} session {i}: output not bit-exact"
                );
            });
        }
    });
    let wall = t0.elapsed();
    let snap = coord.metrics();
    let coord = Arc::try_unwrap(coord).ok().expect("done");
    coord.shutdown()?;
    Ok((common::mbps(info_bits, wall), snap.mean_batch, snap.steals_total()))
}

/// Survivor-storage sweep (see `docs/MEMORY.md`): peak survivor bytes
/// per frame plus one-shot throughput for one survivor layout on the
/// default CPU tile (64 payload + 32/32 overlap = 128 stages). The
/// measured peak is the quantity the worked memory-budget example in
/// `docs/MEMORY.md` quotes; outputs are checked bit-exact so the sweep
/// also witnesses layout equivalence.
fn run_survivor(backend: &str, info_bits: usize) -> tcvd::Result<(f64, usize)> {
    let mut dec = DecoderBuilder::new()
        .backend_name(backend)?
        .tile(defaults::CPU_TILE)
        .shards(1)
        .build()?;
    let (payload, llr) = common::workload(4242, info_bits, 6.0);
    // peak survivor bytes per frame: forward real frames, read the
    // survivor store each one materialized
    let jobs = tiled::make_frames(&llr, 2, &defaults::CPU_TILE, TerminationMode::Flushed)?;
    let probe = dec.as_frame_decoder().forward_batch(&jobs[..jobs.len().min(4)]);
    let peak_bytes = probe.iter().map(|r| r.surv.bytes()).max().unwrap_or(0);
    let t0 = std::time::Instant::now();
    let out = dec.decode_stream(&llr)?;
    let wall = t0.elapsed();
    assert_eq!(out, payload, "{backend}: one-shot decode not bit-exact");
    Ok((common::mbps(info_bits, wall), peak_bytes))
}

/// Termination-mode sweep (see `docs/DECODING-MODES.md`): one-shot
/// decode throughput over a fleet of short blocks, flushed vs
/// tail-biting, at short frame lengths — the workload where the k-1
/// flush overhead matters. Info throughput counts *data* bits only, so
/// the flushed rows pay their per-block rate loss honestly (a flushed
/// `p`-stage block carries `p - 6` data bits, a tail-biting block all
/// `p`). Decoded blocks are checked against the payload, so the sweep
/// also witnesses tail-biting correctness at 6 dB.
fn run_termination(mode: tcvd::coding::TerminationMode, block_stages: usize, n_blocks: usize)
                   -> tcvd::Result<(f64, usize)> {
    use tcvd::channel::{awgn::AwgnChannel, bpsk};
    use tcvd::coding::{registry, Encoder};

    let code = registry::paper_code();
    let data_bits = block_stages - mode.flush_stages(code.k());
    let blocks: Vec<(Vec<u8>, Vec<f32>)> = (0..n_blocks)
        .map(|i| {
            let bits = tcvd::util::rng::Rng::new(0xB10C + i as u64).bits(data_bits);
            let mut enc = Encoder::new(code.clone());
            let (coded, _) = enc.encode_terminated(&bits, mode);
            let tx = bpsk::modulate(&coded);
            let mut ch = AwgnChannel::new(6.0, code.rate(), 0x7E12 ^ i as u64);
            let rx = ch.transmit(&tx);
            (bits, rx.iter().map(|&x| x as f32).collect())
        })
        .collect();
    let mut dec = DecoderBuilder::new()
        .backend_name("simd")?
        .tile_dims(block_stages, 32, 32)
        .termination(mode)
        .shards(1)
        .build()?;
    let t0 = std::time::Instant::now();
    let mut info_bits = 0usize;
    for (bits, llr) in &blocks {
        let out = dec.decode_stream(llr)?;
        info_bits += bits.len();
        assert_eq!(&out[..bits.len()], &bits[..], "{mode} block decode not bit-exact");
    }
    Ok((common::mbps(info_bits, t0.elapsed()), data_bits))
}

fn main() -> tcvd::Result<()> {
    let info_bits = common::budget(131_072, 524_288, 2_097_152);
    println!("E5 — dynamic batching sweep (radix-4 artifact, batch capacity 64)\n");
    println!(
        "{:>9} {:>10} {:>12} | {:>10} {:>11} {:>10} {:>10}",
        "sessions", "max_batch", "deadline_us", "Mb/s", "mean_batch", "p50 us", "p99 us"
    );
    let mut rows = Vec::new();
    let sweeps: Vec<(usize, usize, u64)> = vec![
        // batch-size sweep at 8 sessions
        (8, 1, 2000),
        (8, 4, 2000),
        (8, 16, 2000),
        (8, 64, 2000),
        // deadline sweep at full batch
        (8, 64, 100),
        (8, 64, 500),
        (8, 64, 8000),
        // session scaling at full batch
        (1, 64, 2000),
        (2, 64, 2000),
        (4, 64, 2000),
        (16, 64, 2000),
        (32, 64, 2000),
    ];
    for (sessions, max_batch, deadline) in sweeps {
        match run(sessions, max_batch, deadline, info_bits) {
            Ok((mbps, mean_batch, p50, p99)) => {
                println!(
                    "{sessions:>9} {max_batch:>10} {deadline:>12} | {mbps:>10.2} \
                     {mean_batch:>11.1} {p50:>10.0} {p99:>10.0}"
                );
                rows.push(json::obj(vec![
                    ("sessions", json::num(sessions as f64)),
                    ("max_batch", json::num(max_batch as f64)),
                    ("deadline_us", json::num(deadline as f64)),
                    ("mbps", json::num(mbps)),
                    ("mean_batch", json::num(mean_batch)),
                    ("p50_us", json::num(p50)),
                    ("p99_us", json::num(p99)),
                ]));
            }
            Err(e) => {
                println!("{sessions:>9} {max_batch:>10} {deadline:>12} | SKIP ({e})");
                break;
            }
        }
    }
    // shard scaling: aggregate serve() throughput vs engine shard count
    // per CPU backend (the snapshot's Mb/s-per-backend/shard matrix; no
    // artifacts needed). The simd backend runs at both radixes — the
    // per-rho rows feed `summary.radix2_vs_radix1` in bench_snapshot.py,
    // which CI holds against the committed bench_floors.json.
    let shard_bits = common::budget(131_072, 262_144, 1_048_576);
    let mut shard_rows = Vec::new();
    for (label, backend, radix) in
        [("cpu-radix4", "cpu-radix4", 1usize), ("simd", "simd", 1), ("simd-r2", "simd", 2)]
    {
        println!("\nshard scaling — 8 sessions, {label} backend, {shard_bits} info bits");
        println!(
            "{:>7} | {:>10} {:>11} {:>8} {:>9}",
            "shards", "Mb/s", "mean_batch", "steals", "speedup"
        );
        let mut base_mbps = None;
        for shards in [1usize, 2, 4, 8] {
            match run_sharded(backend, radix, shards, 8, shard_bits) {
                Ok((mbps, mean_batch, steals)) => {
                    let base = *base_mbps.get_or_insert(mbps);
                    println!(
                        "{shards:>7} | {mbps:>10.2} {mean_batch:>11.1} {steals:>8} {:>8.2}x",
                        mbps / base
                    );
                    shard_rows.push(json::obj(vec![
                        ("backend", json::s(label)),
                        ("radix", json::num(radix as f64)),
                        ("shards", json::num(shards as f64)),
                        ("mbps", json::num(mbps)),
                        ("mean_batch", json::num(mean_batch)),
                        ("steals", json::num(steals as f64)),
                        ("speedup", json::num(mbps / base)),
                    ]));
                }
                Err(e) => {
                    println!("{shards:>7} | SKIP ({e})");
                    break;
                }
            }
        }
    }
    // survivor-storage sweep: compact vs packed vs scalar vs quantized
    // simd layouts on the same tile geometry (docs/MEMORY.md model)
    let surv_bits = common::budget(131_072, 262_144, 1_048_576);
    println!(
        "\nsurvivor storage — one-shot decode, {} tile ({} stages), {surv_bits} info bits",
        "64+32/32", defaults::CPU_TILE.frame_stages()
    );
    println!(
        "{:>12} | {:>10} {:>16} {:>10}",
        "backend", "Mb/s", "surv bytes/frame", "vs scalar"
    );
    let mut surv_rows = Vec::new();
    let mut scalar_bytes: Option<usize> = None;
    for backend in ["scalar", "cpu-radix4", "compact", "simd"] {
        match run_survivor(backend, surv_bits) {
            Ok((mbps, bytes)) => {
                if backend == "scalar" {
                    scalar_bytes = Some(bytes);
                }
                let mut row = vec![
                    ("backend", json::s(backend)),
                    ("mbps", json::num(mbps)),
                    ("peak_survivor_bytes_per_frame", json::num(bytes as f64)),
                ];
                // the ratio column only exists relative to a measured
                // scalar baseline — never silently rebase on another row
                match scalar_bytes {
                    Some(base) => {
                        let ratio = base as f64 / bytes as f64;
                        println!("{backend:>12} | {mbps:>10.2} {bytes:>16} {ratio:>9.1}x");
                        row.push(("reduction_vs_scalar", json::num(ratio)));
                    }
                    None => println!("{backend:>12} | {mbps:>10.2} {bytes:>16} {:>10}", "-"),
                }
                surv_rows.push(json::obj(row));
            }
            Err(e) => println!("{backend:>12} | SKIP ({e})"),
        }
    }
    // termination-mode sweep: flushed vs tail-biting info throughput on
    // short blocks (the snapshot's per-mode rows; docs/DECODING-MODES.md)
    let n_blocks = common::budget(48, 256, 1024);
    println!("\ntermination modes — simd backend, one-shot short blocks, {n_blocks} blocks");
    println!(
        "{:>12} {:>8} | {:>10} {:>10} {:>10}",
        "mode", "stages", "data bits", "Mb/s", "rate eff."
    );
    let mut term_rows = Vec::new();
    for block_stages in [64usize, 128] {
        for mode in [
            tcvd::coding::TerminationMode::Flushed,
            tcvd::coding::TerminationMode::TailBiting,
        ] {
            match run_termination(mode, block_stages, n_blocks) {
                Ok((mbps, data_bits)) => {
                    let eff = data_bits as f64 / block_stages as f64;
                    println!(
                        "{:>12} {block_stages:>8} | {data_bits:>10} {mbps:>10.2} {eff:>10.3}",
                        mode.as_str()
                    );
                    term_rows.push(json::obj(vec![
                        ("mode", json::s(mode.as_str())),
                        ("block_stages", json::num(block_stages as f64)),
                        ("data_bits_per_block", json::num(data_bits as f64)),
                        ("info_mbps", json::num(mbps)),
                        ("rate_efficiency", json::num(eff)),
                    ]));
                }
                Err(e) => println!("{:>12} {block_stages:>8} | SKIP ({e})", mode.as_str()),
            }
        }
    }

    common::write_json("batching", &json::obj(vec![
        ("experiment", json::s("E5/batching")),
        ("info_bits", json::num(info_bits as f64)),
        ("rows", Json::Arr(rows)),
        ("shard_info_bits", json::num(shard_bits as f64)),
        ("shard_rows", Json::Arr(shard_rows)),
        ("survivor_info_bits", json::num(surv_bits as f64)),
        ("survivor_rows", Json::Arr(surv_rows)),
        ("termination_blocks", json::num(n_blocks as f64)),
        ("termination_rows", Json::Arr(term_rows)),
    ]));
    Ok(())
}
