//! E2 — paper Table I: decoder throughput for the four C/channel
//! precision combinations.
//!
//! The paper measured Gb/s on a V100; this testbed executes the same
//! tensor formulation on the XLA-CPU PJRT client, so absolute numbers
//! differ by construction. The claim under test is the *shape*: C
//! precision does not change throughput much, channel=half is faster
//! (smaller input transfers), and the combination single-C/half-channel
//! is the best valid configuration (paper: 21.4 vs 19.5 Gb/s).

#[path = "common/mod.rs"]
mod common;

use tcvd::api::DecoderBuilder;
use tcvd::defaults;
use tcvd::util::json::{self, Json};

fn run_combo(variant: &str, llr: &[f32]) -> tcvd::Result<(f64, f64)> {
    // default tile (64+16/16) matches the b64_s48 artifact frames
    // single shard: Table-I numbers are per-executable; shard scaling
    // is the batching bench's sweep
    let coord =
        DecoderBuilder::new().variant(variant).workers(3).queue_depth(2048).shards(1).serve()?;
    // split across 4 concurrent sessions to keep batches full
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let quarters: Vec<&[f32]> = llr.chunks(llr.len() / 4).collect();
        let mut joins = Vec::new();
        for q in quarters {
            let coord = &coord;
            joins.push(s.spawn(move || coord.decode_stream_blocking(q, false).unwrap()));
        }
        for j in joins {
            j.join().unwrap();
        }
    });
    let wall = t0.elapsed();
    let snap = coord.metrics();
    let info_bits = llr.len() / 2;
    coord.shutdown()?;
    Ok((common::mbps(info_bits, wall), snap.mean_batch))
}

fn main() -> tcvd::Result<()> {
    let info_bits = if common::full_rigor() { 4_194_304 } else { 1_048_576 };
    let (_, llr) = common::workload(2024, info_bits, 5.0);

    // (paper row, artifact variant)
    let combos = [
        ("single/single", defaults::VARIANT, 19.5),
        ("single/half", defaults::VARIANT_SINGLE_HALF, 21.4),
        ("half/single", defaults::VARIANT_HALF_SINGLE, 20.1),
        ("half/half", defaults::VARIANT_HALF_HALF, 22.2),
    ];
    println!("Table I — decoder throughput by C/channel precision");
    println!("(paper: V100 tensor cores in Gb/s; here: XLA-CPU PJRT in Mb/s —");
    println!(" compare RATIOS, not absolutes; BER validity is Fig 13's axis)\n");
    println!("{:>15} | {:>12} | {:>10} | {:>12}", "C/channel", "paper Gb/s", "this Mb/s", "mean batch");
    let mut rows = Vec::new();
    let mut base = None;
    for (name, variant, paper) in combos {
        match run_combo(variant, &llr) {
            Ok((mbps, mean_batch)) => {
                base.get_or_insert(mbps);
                println!("{name:>15} | {paper:12.1} | {mbps:10.2} | {mean_batch:12.1}");
                rows.push(json::obj(vec![
                    ("combo", json::s(name)),
                    ("paper_gbps", json::num(paper)),
                    ("measured_mbps", json::num(mbps)),
                    ("ratio_vs_single_single", json::num(mbps / base.unwrap())),
                    ("mean_batch", json::num(mean_batch)),
                ]));
            }
            Err(e) => println!("{name:>15} | {paper:12.1} | SKIP ({e})"),
        }
    }
    common::write_json(
        "table1_throughput",
        &json::obj(vec![
            ("experiment", json::s("E2/TableI")),
            ("info_bits", json::num(info_bits as f64)),
            ("rows", Json::Arr(rows)),
        ]),
    );
    Ok(())
}
