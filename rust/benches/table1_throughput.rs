//! E2 — paper Table I: decoder throughput for the four C/channel
//! precision combinations.
//!
//! The paper measured Gb/s on a V100; this testbed executes the same
//! tensor formulation on the XLA-CPU PJRT client, so absolute numbers
//! differ by construction. The claim under test is the *shape*: C
//! precision does not change throughput much, channel=half is faster
//! (smaller input transfers), and the combination single-C/half-channel
//! is the best valid configuration (paper: 21.4 vs 19.5 Gb/s).

#[path = "common/mod.rs"]
mod common;

use tcvd::api::{DecoderBuilder, TerminationMode};
use tcvd::defaults;
use tcvd::util::json::{self, Json};

fn run_combo(variant: &str, llr: &[f32]) -> tcvd::Result<(f64, f64)> {
    // default tile (64+16/16) matches the b64_s48 artifact frames
    // single shard: Table-I numbers are per-executable; shard scaling
    // is the batching bench's sweep
    // quarter-streams are mid-stream slices with no flushed end, so
    // the pipeline decodes them as truncated streams
    let coord = DecoderBuilder::new()
        .variant(variant)
        .termination(TerminationMode::Truncated)
        .workers(3)
        .queue_depth(2048)
        .shards(1)
        .serve()?;
    run_sessions(coord, llr)
}

/// Drive `llr` through a running coordinator as 4 concurrent sessions
/// (keeps batches full), then shut it down; returns (Mb/s, mean batch
/// occupancy).
fn run_sessions(coord: tcvd::coordinator::Coordinator, llr: &[f32])
                -> tcvd::Result<(f64, f64)> {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let coord = &coord;
        let quarters: Vec<&[f32]> = llr.chunks(llr.len() / 4).collect();
        let mut joins = Vec::new();
        for q in quarters {
            joins.push(s.spawn(move || coord.decode_stream_blocking(q).unwrap()));
        }
        for j in joins {
            j.join().unwrap();
        }
    });
    let wall = t0.elapsed();
    let snap = coord.metrics();
    let info_bits = llr.len() / 2;
    coord.shutdown()?;
    Ok((common::mbps(info_bits, wall), snap.mean_batch))
}

/// One CPU backend on the table-1 workload: single shard, CPU tile,
/// same 4-session drive as the artifact combos. This is the
/// scalar-vs-simd trajectory row of `BENCH_PR5.json`
/// (`scripts/bench_snapshot.py`).
fn run_cpu_backend(backend: &str, llr: &[f32]) -> tcvd::Result<(f64, f64)> {
    let coord = DecoderBuilder::new()
        .backend_name(backend)?
        .termination(TerminationMode::Truncated)
        .tile(defaults::CPU_TILE)
        .workers(3)
        .queue_depth(2048)
        .shards(1)
        .serve()?;
    run_sessions(coord, llr)
}

fn main() -> tcvd::Result<()> {
    let info_bits = common::budget(131_072, 1_048_576, 4_194_304);
    let (_, llr) = common::workload(2024, info_bits, 5.0);

    // (paper row, artifact variant)
    let combos = [
        ("single/single", defaults::VARIANT, 19.5),
        ("single/half", defaults::VARIANT_SINGLE_HALF, 21.4),
        ("half/single", defaults::VARIANT_HALF_SINGLE, 20.1),
        ("half/half", defaults::VARIANT_HALF_HALF, 22.2),
    ];
    println!("Table I — decoder throughput by C/channel precision");
    println!("(paper: V100 tensor cores in Gb/s; here: XLA-CPU PJRT in Mb/s —");
    println!(" compare RATIOS, not absolutes; BER validity is Fig 13's axis)\n");
    println!("{:>15} | {:>12} | {:>10} | {:>12}", "C/channel", "paper Gb/s", "this Mb/s", "mean batch");
    let mut rows = Vec::new();
    let mut base = None;
    for (name, variant, paper) in combos {
        match run_combo(variant, &llr) {
            Ok((mbps, mean_batch)) => {
                base.get_or_insert(mbps);
                println!("{name:>15} | {paper:12.1} | {mbps:10.2} | {mean_batch:12.1}");
                rows.push(json::obj(vec![
                    ("combo", json::s(name)),
                    ("paper_gbps", json::num(paper)),
                    ("measured_mbps", json::num(mbps)),
                    ("ratio_vs_single_single", json::num(mbps / base.unwrap())),
                    ("mean_batch", json::num(mean_batch)),
                ]));
            }
            Err(e) => println!("{name:>15} | {paper:12.1} | SKIP ({e})"),
        }
    }
    // CPU fast-path section: same workload, single shard, no artifacts
    // needed — the scalar-vs-simd ratio BENCH_PR5.json tracks across
    // PRs (the quantized SIMD ACS path must hold >= 3x scalar here)
    println!("\nCPU backends — table-1 workload, single shard, CPU tile (64+32/32)");
    println!("{:>12} | {:>10} | {:>12} | {:>10}", "backend", "this Mb/s", "mean batch", "vs scalar");
    let mut cpu_rows = Vec::new();
    let mut scalar_mbps = None;
    for backend in ["scalar", "compact", "cpu-radix4", "simd"] {
        match run_cpu_backend(backend, &llr) {
            Ok((mbps, mean_batch)) => {
                if backend == "scalar" {
                    scalar_mbps = Some(mbps);
                }
                let mut row = vec![
                    ("backend", json::s(backend)),
                    ("mbps", json::num(mbps)),
                    ("mean_batch", json::num(mean_batch)),
                ];
                match scalar_mbps {
                    Some(base) => {
                        println!(
                            "{backend:>12} | {mbps:>10.2} | {mean_batch:>12.1} | {:>9.2}x",
                            mbps / base
                        );
                        row.push(("speedup_vs_scalar", json::num(mbps / base)));
                    }
                    None => println!("{backend:>12} | {mbps:>10.2} | {mean_batch:>12.1} | {:>10}", "-"),
                }
                cpu_rows.push(json::obj(row));
            }
            Err(e) => println!("{backend:>12} | SKIP ({e})"),
        }
    }
    common::write_json(
        "table1_throughput",
        &json::obj(vec![
            ("experiment", json::s("E2/TableI")),
            ("info_bits", json::num(info_bits as f64)),
            ("rows", Json::Arr(rows)),
            ("cpu_rows", Json::Arr(cpu_rows)),
        ]),
    );
    Ok(())
}
