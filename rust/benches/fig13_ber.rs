//! E1 — paper Fig 13: BER vs Eb/N0 for the four C/channel precision
//! combinations, against the theory references (replacing MATLAB
//! bertool), plus the §II-C soft-vs-hard comparison (E6).
//!
//! Runs on the CPU tensor-emulation backend (identical arithmetic to the
//! artifact — cross-validated in rust/tests/integration_runtime.rs) so a
//! multi-point sweep finishes in minutes. Claims under test:
//! half C (accumulator) degrades BER visibly; half channel does not.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use tcvd::api::{AccPrecision, BackendKind, ChannelPrecision, DecoderBuilder, HalfKind};
use tcvd::ber::{measure_ber, sweep, theory, BerPoint, BerSetup};
use tcvd::coding::{registry, trellis::Trellis};
use tcvd::util::json::{self, Json};
use tcvd::viterbi::tiled::TileConfig;
use tcvd::Decoder;

fn decoder(tile: TileConfig, acc: AccPrecision, chan: ChannelPrecision,
           renorm: usize) -> tcvd::Result<Decoder> {
    DecoderBuilder::new()
        .backend(BackendKind::cpu("radix4"))
        .tile(tile)
        .precision(acc)
        .channel_precision(chan)
        .renorm_every(renorm)
        .build()
}

fn main() -> tcvd::Result<()> {
    let trellis = Arc::new(Trellis::new(registry::paper_code()));
    // Paper-faithful setup: exact LLRs (2y/sigma^2) and NO metric
    // renormalization — path metrics grow along the frame, so a half C
    // fragment loses resolution (its ulp grows with magnitude). Long
    // frames make the effect measurable, as the paper's do.
    let tile = TileConfig { payload: 256, head: 128, tail: 128 };
    let (max_bits, errors) = if common::full_rigor() {
        (2_000_000, 200)
    } else {
        (250_000, 120)
    };
    let setup = BerSetup {
        tile,
        target_errors: errors,
        max_bits,
        exact_llr: true,
        ..Default::default()
    };
    let snrs = sweep::parse_range(if common::full_rigor() { "0:7:0.5" } else { "0:6:1" })?;

    let half = HalfKind::Bf16; // TPU-analog "half"; f16 row added below
    let combos: Vec<(&str, AccPrecision, ChannelPrecision, usize)> = vec![
        ("C=f32 ch=f32", AccPrecision::Single, ChannelPrecision::Single, 0),
        ("C=f32 ch=half", AccPrecision::Single, ChannelPrecision::Half(half), 0),
        ("C=bf16 ch=f32", AccPrecision::Half(half), ChannelPrecision::Single, 0),
        ("C=f16 ch=f32", AccPrecision::Half(HalfKind::F16), ChannelPrecision::Single, 0),
        // extension beyond the paper: periodic renormalization rescues
        // the half accumulator (metrics stay small, ulp stays fine)
        ("C=bf16 renorm8", AccPrecision::Half(half), ChannelPrecision::Single, 8),
    ];

    println!("Fig 13 — BER vs Eb/N0 by precision (exact LLRs, no renorm = paper setup)\n");
    print!("{:>6}", "dB");
    for (name, _, _, _) in &combos {
        print!(" | {name:>16}");
    }
    println!(" | {:>10} | {:>10}", "hard dec.", "theory");

    let mut curves: Vec<(String, Vec<BerPoint>)> =
        combos.iter().map(|(n, _, _, _)| (n.trim().to_string(), vec![])).collect();
    let mut hard_curve: Vec<BerPoint> = Vec::new();

    for &db in &snrs {
        print!("{db:6.1}");
        for (i, (_, acc, chan, renorm)) in combos.iter().enumerate() {
            let mut dec = decoder(tile, *acc, *chan, *renorm)?;
            let p = measure_ber(dec.as_frame_decoder(), &trellis, db, &setup)?;
            print!(" | {:>14.3e}{}", p.ber(), if p.reliable() { "  " } else { " *" });
            curves[i].1.push(p);
        }
        let mut dec = decoder(tile, AccPrecision::Single, ChannelPrecision::Single, 0)?;
        let hard = measure_ber(dec.as_frame_decoder(), &trellis, db,
                               &BerSetup { hard_decision: true, ..setup.clone() })?;
        print!(" | {:>10.3e}", hard.ber());
        hard_curve.push(hard);
        println!(" | {:>10.3e}", theory::coded_union_bound(db));
    }
    println!("\n(* = fewer than 100 errors, unreliable per the paper's rule)");
    println!("expected shape (paper): half channel costs nothing; half C fails");
    println!("(bf16 worse than f16 — fewer mantissa bits); hard-decision needs");
    println!("~2 dB more (§II-C). Extension: renorm rescues the half C.");

    curves.push(("hard-decision".into(), hard_curve));
    common::write_json("fig13_ber", &json::obj(vec![
        ("experiment", json::s("E1/Fig13 + E6/soft-vs-hard")),
        ("data", sweep::curves_json(&curves)),
        ("half_kind", json::s("bf16 (TPU analog) + f16 (paper-faithful) rows")),
    ]));
    let _ = Json::Null;
    Ok(())
}
