//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The build image has no network access, so `tcvd` vendors the small
//! subset of anyhow it actually uses: a message-carrying `Error`, the
//! `Context` extension trait for `Result`/`Option`, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Error *chains* are flattened into the
//! message string at attachment time (`context: cause`), which is what
//! `{:#}` formatting of real anyhow prints anyway.
//!
//! This crate is an internal implementation detail of tcvd's lower
//! layers; the crate's public API surfaces the typed `tcvd::Error`
//! instead (see `rust/src/error.rs`).

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error: a message, with any source already folded in.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Build an error from a std error (source text is captured).
    pub fn new<E: StdError + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string() }
    }

    /// Attach context, `context: cause` style.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent with the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: `Result` defaulted to this crate's `Error`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context attachment for `Result` and `Option` (anyhow's main trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_into_message() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: missing");
        let e2 = e.context("startup");
        assert_eq!(e2.to_string(), "startup: reading config: missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "notanumber".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn macros_work() {
        fn g(x: usize) -> Result<usize> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert_eq!(g(3).unwrap(), 3);
        assert_eq!(g(1).unwrap_err().to_string(), "x too small: 1");
        assert_eq!(g(101).unwrap_err().to_string(), "x too large: 101");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
    }
}
