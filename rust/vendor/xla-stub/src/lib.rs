//! Offline stub of the `xla` (xla-rs) PJRT binding.
//!
//! The build image carries no XLA/PJRT shared libraries, so this crate
//! provides the exact type surface `tcvd::runtime` compiles against:
//!
//! * [`Literal`] is fully functional host-side (shape + f32/i32 storage)
//!   so literal packing round-trips and its tests work.
//! * [`PjRtClient::cpu`] succeeds and reports itself as a stub, so
//!   `tcvd info` can print a platform summary.
//! * [`HloModuleProto::from_text_file`] and [`PjRtClient::compile`]
//!   always fail with [`UNAVAILABLE`], which makes every artifact
//!   backend construction fail fast with a clear message — callers
//!   (selftest, quickstart, the coordinator) already treat that as
//!   "fall back to a CPU backend".
//!
//! To run real AOT artifacts, point the `xla` entry of the root
//! `Cargo.toml` at the actual xla-rs crate; no tcvd source changes are
//! required.

use std::error::Error as StdError;
use std::fmt;
use std::path::Path;

/// The message every unavailable PJRT entry point reports.
pub const UNAVAILABLE: &str = "PJRT runtime unavailable: tcvd was built against the vendored \
     xla stub (offline image); artifact backends are disabled — use a cpu-* or scalar backend, \
     or rebuild with the real xla-rs crate";

/// Stub error type (message only).
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold in this stub.
pub trait ElementType: Copy {
    #[doc(hidden)]
    fn store(v: &[Self]) -> Literal;
    #[doc(hidden)]
    fn load(lit: &Literal) -> Result<Vec<Self>>;
}

/// A host-side literal: flat storage plus a shape.
#[derive(Clone, Debug)]
pub struct Literal {
    f32s: Option<Vec<f32>>,
    i32s: Option<Vec<i32>>,
    dims: Vec<i64>,
}

impl ElementType for f32 {
    fn store(v: &[Self]) -> Literal {
        Literal { f32s: Some(v.to_vec()), i32s: None, dims: vec![v.len() as i64] }
    }

    fn load(lit: &Literal) -> Result<Vec<Self>> {
        lit.f32s.clone().ok_or_else(|| Error("literal does not hold f32 data".into()))
    }
}

impl ElementType for i32 {
    fn store(v: &[Self]) -> Literal {
        Literal { f32s: None, i32s: Some(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn load(lit: &Literal) -> Result<Vec<Self>> {
        lit.i32s.clone().ok_or_else(|| Error("literal does not hold i32 data".into()))
    }
}

impl Literal {
    /// Build a rank-1 literal from a flat slice.
    pub fn vec1<T: ElementType>(v: &[T]) -> Literal {
        T::store(v)
    }

    /// Number of stored elements.
    pub fn element_count(&self) -> usize {
        match (&self.f32s, &self.i32s) {
            (Some(v), _) => v.len(),
            (_, Some(v)) => v.len(),
            _ => 0,
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "cannot reshape {} elements to {:?}",
                self.element_count(),
                dims
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    /// Flat copy of the data as `T`.
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        T::load(self)
    }

    /// Destructure a 2-tuple literal. Tuple literals only come back from
    /// executions, which this stub cannot run.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module. Never constructible in the stub: parsing is part of
/// the PJRT runtime surface.
pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path;
        Err(Error::unavailable())
    }
}

/// An XLA computation handle (never constructible in the stub).
pub enum XlaComputation {}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match *proto {}
    }
}

/// A compiled executable (never constructible in the stub).
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// A device buffer (never constructible in the stub).
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match *self {}
    }
}

/// The PJRT client. Construction succeeds (so platform info prints);
/// compilation is where the stub reports unavailability.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn platform_version(&self) -> String {
        "stub (no PJRT runtime linked; artifact execution disabled)".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let lit = Literal::vec1(&data).reshape(&[2, 3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&data).reshape(&[4]).is_err());
    }

    #[test]
    fn client_is_stub() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert!(c.platform_version().contains("stub"));
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
