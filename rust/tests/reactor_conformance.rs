//! Cross-backend conformance suite for `tcvd::net::reactor`: the same
//! scripted event sequences run against the `poll(2)` and `epoll`
//! [`PollSet`] backends over loopback socket pairs, asserting the
//! backends report *identical* readiness outcomes tick by tick —
//! registration, interest modification, deregistration, partial-write
//! backpressure, peer hangup folding, EINTR handling and idle-tick
//! timing.
//!
//! Off Linux `PollerKind::Epoll` degrades to the `poll(2)` backend, so
//! the differential assertions become trivially true there; on Linux
//! (the CI target) every scenario genuinely exercises both kernels
//! interfaces.

#![cfg(unix)]

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use tcvd::net::reactor::{stream_fd, Fd, PollSet, PollerKind, READ, WRITE};

/// One poll set per backend under test, in a fixed order.
fn both() -> Vec<PollSet> {
    let sets =
        vec![PollSet::with_poller(PollerKind::Poll), PollSet::with_poller(PollerKind::Epoll)];
    #[cfg(target_os = "linux")]
    {
        assert_eq!(sets[0].kind(), "poll");
        assert_eq!(sets[1].kind(), "epoll", "conformance must cover the kernel backend");
    }
    sets
}

/// A loopback pair: `.0` is the registered (server) end, nonblocking;
/// `.1` is the peer driving events.
fn pair() -> (TcpStream, TcpStream) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let peer = TcpStream::connect(addr).unwrap();
    let (server, _) = listener.accept().unwrap();
    server.set_nonblocking(true).unwrap();
    (server, peer)
}

/// Run one conformance tick on every backend: identical registrations,
/// identical timeout; returns each backend's `(ready_count, readiness
/// per registered fd)`.
fn tick(sets: &mut [PollSet], regs: &[(Fd, u8)], timeout: Duration) -> Vec<(usize, Vec<u8>)> {
    sets.iter_mut()
        .map(|set| {
            set.clear();
            let toks: Vec<usize> = regs.iter().map(|&(fd, i)| set.register(fd, i)).collect();
            let n = set.poll(timeout);
            (n, toks.iter().map(|&t| set.readiness(t)).collect())
        })
        .collect()
}

/// Every backend must agree; returns the agreed outcome.
fn conform(
    sets: &[PollSet],
    mut outcomes: Vec<(usize, Vec<u8>)>,
    what: &str,
) -> (usize, Vec<u8>) {
    for (set, o) in sets.iter().zip(&outcomes).skip(1) {
        assert_eq!(
            *o,
            outcomes[0],
            "{what}: backend {:?} diverges from {:?}",
            set.kind(),
            sets[0].kind()
        );
    }
    outcomes.remove(0)
}

#[test]
fn fresh_pair_readiness_and_data_arrival() {
    let mut sets = both();
    let (server, mut peer) = pair();
    let fd = stream_fd(&server);

    // a fresh connected socket: writable, nothing to read
    let out = tick(&mut sets, &[(fd, READ | WRITE)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "fresh pair");
    assert_eq!(n, 1);
    assert_eq!(bits, vec![WRITE]);

    // peer data arrives: readable and still writable
    peer.write_all(b"ping").unwrap();
    let out = tick(&mut sets, &[(fd, READ | WRITE)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "data pending");
    assert_eq!(n, 1);
    assert_eq!(bits, vec![READ | WRITE]);

    // draining the data clears READ again
    let mut server = server;
    let mut buf = [0u8; 16];
    assert_eq!(server.read(&mut buf).unwrap(), 4);
    let out = tick(&mut sets, &[(fd, READ | WRITE)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "drained");
    assert_eq!(n, 1);
    assert_eq!(bits, vec![WRITE]);
}

#[test]
fn partial_write_backpressure_clears_when_the_peer_drains() {
    let mut sets = both();
    let (mut server, mut peer) = pair();
    let fd = stream_fd(&server);

    // fill the kernel send buffer until a write would block — the
    // condition a partially-flushed outbound frame leaves the reactor in
    let chunk = [0x5au8; 64 * 1024];
    loop {
        match server.write(&chunk) {
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) => panic!("filling send buffer: {e}"),
        }
    }
    let out = tick(&mut sets, &[(fd, WRITE)], Duration::from_millis(30));
    let (n, bits) = conform(&sets, out, "send buffer full");
    assert_eq!((n, bits), (0, vec![0]), "a full send buffer is not writable");

    // the peer drains; writability must come back on every backend at
    // the same tick (loopback flushes asynchronously, so poll until it
    // does — the conformance check runs on every intermediate tick too)
    peer.set_nonblocking(true).unwrap();
    let mut sink = vec![0u8; 256 * 1024];
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        loop {
            match peer.read(&mut sink) {
                Ok(0) => panic!("peer saw EOF while draining"),
                Ok(_) => {}
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => panic!("draining: {e}"),
            }
        }
        let out = tick(&mut sets, &[(fd, WRITE)], Duration::from_millis(50));
        let (n, bits) = conform(&sets, out, "draining");
        if bits[0] & WRITE != 0 {
            assert_eq!(n, 1);
            break;
        }
        assert!(Instant::now() < deadline, "socket never became writable after drain");
    }
}

#[test]
fn hangup_folds_into_both_bits_on_both_backends() {
    let mut sets = both();
    let (mut server, peer) = pair();
    let fd = stream_fd(&server);
    drop(peer);

    // READ interest: the graceful FIN is readable (the owner reads EOF)
    let out = tick(&mut sets, &[(fd, READ)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "hangup/READ");
    assert_eq!(n, 1);
    assert_eq!(bits[0] & READ, READ);

    // WRITE interest: the half-closed socket still accepts writes
    let out = tick(&mut sets, &[(fd, WRITE)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "hangup/WRITE");
    assert_eq!(n, 1);
    assert_eq!(bits[0] & WRITE, WRITE);

    // writing into the fully-closed peer draws an RST; the resulting
    // error condition is delivered even with an *empty* interest mask
    // and folds into both readiness bits identically on both backends
    let _ = server.write(b"x");
    let out = tick(&mut sets, &[(fd, 0)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "hangup/none after RST");
    assert_eq!(n, 1);
    assert_eq!(bits, vec![READ | WRITE]);
}

#[test]
fn interest_modification_and_deregistration_track_identically() {
    let mut sets = both();
    let (server, mut peer) = pair();
    let (decoy, _decoy_peer) = pair();
    let (fd, dfd) = (stream_fd(&server), stream_fd(&decoy));

    // tick 1: WRITE interest — writable (epoll: kernel-set ADD)
    let out = tick(&mut sets, &[(fd, WRITE), (dfd, READ)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "tick1 add");
    assert_eq!((n, bits), (1, vec![WRITE, 0]));

    // tick 2: interest modified down to READ on a quiet socket — no
    // readiness at all (epoll: kernel-set MOD)
    let out = tick(&mut sets, &[(fd, READ), (dfd, READ)], Duration::from_millis(30));
    let (n, bits) = conform(&sets, out, "tick2 modify");
    assert_eq!((n, bits), (0, vec![0, 0]));

    // tick 3: deregistered while data arrives — a backend must not
    // report readiness for an fd absent from this tick's registrations
    // (epoll: kernel-set DEL; the decoy keeps the set non-empty)
    peer.write_all(b"x").unwrap();
    let out = tick(&mut sets, &[(dfd, READ)], Duration::from_millis(30));
    let (n, bits) = conform(&sets, out, "tick3 deregister");
    assert_eq!((n, bits), (0, vec![0]));

    // tick 4: re-registered — the buffered byte surfaces (epoll: re-ADD)
    let out = tick(&mut sets, &[(fd, READ), (dfd, READ)], Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "tick4 re-add");
    assert_eq!(n, 1);
    assert_eq!(bits, vec![READ, 0]);
}

#[test]
fn readiness_is_per_fd_not_per_set() {
    let mut sets = both();
    let pairs: Vec<(TcpStream, TcpStream)> = (0..3).map(|_| pair()).collect();
    let regs: Vec<(Fd, u8)> = pairs.iter().map(|(s, _)| (stream_fd(s), READ)).collect();

    // quiet: nothing readable anywhere
    let out = tick(&mut sets, &regs, Duration::from_millis(30));
    let (n, bits) = conform(&sets, out, "all quiet");
    assert_eq!((n, bits), (0, vec![0, 0, 0]));

    // exactly one peer speaks: exactly that fd reports, on every backend
    let mut peer1 = &pairs[1].1;
    peer1.write_all(b"only me").unwrap();
    let out = tick(&mut sets, &regs, Duration::from_millis(2000));
    let (n, bits) = conform(&sets, out, "one speaker");
    assert_eq!(n, 1);
    assert_eq!(bits, vec![0, READ, 0]);
}

#[test]
fn idle_ticks_honor_the_timeout_on_every_backend() {
    let (server, _peer) = pair();
    let fd = stream_fd(&server);
    for kind in [PollerKind::Poll, PollerKind::Epoll] {
        let mut set = PollSet::with_poller(kind);

        // a quiet registered fd: the poll blocks for the full timeout
        set.register(fd, READ);
        let t0 = Instant::now();
        let n = set.poll(Duration::from_millis(60));
        let elapsed = t0.elapsed();
        assert_eq!(n, 0, "{}", set.kind());
        assert!(
            elapsed >= Duration::from_millis(50),
            "{}: idle tick returned after {elapsed:?}, expected ~60ms",
            set.kind()
        );

        // an empty set still sleeps the tick instead of spinning
        set.clear();
        let t0 = Instant::now();
        assert_eq!(set.poll(Duration::from_millis(60)), 0);
        assert!(
            t0.elapsed() >= Duration::from_millis(50),
            "{}: empty-set tick did not sleep",
            set.kind()
        );
    }
}

/// EINTR delivery: `poll(2)` and `epoll_wait(2)` are never restarted
/// after a signal (signal(7)), so an interrupted tick must surface as
/// "0 ready" — a timeout — on both backends, not an error or a panic.
#[cfg(target_os = "linux")]
#[test]
fn eintr_is_reported_as_a_timeout_on_both_backends() {
    mod sig {
        use std::os::raw::c_int;
        pub const SIGUSR1: c_int = 10;
        extern "C" {
            pub fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
            pub fn pthread_self() -> u64;
            pub fn pthread_kill(thread: u64, sig: c_int) -> c_int;
        }
        pub extern "C" fn noop(_sig: c_int) {}
    }
    unsafe {
        sig::signal(sig::SIGUSR1, sig::noop);
    }
    let (server, _peer) = pair();
    let fd = stream_fd(&server);
    for kind in [PollerKind::Poll, PollerKind::Epoll] {
        let mut set = PollSet::with_poller(kind);
        let tok = set.register(fd, READ);
        let me = unsafe { sig::pthread_self() };
        let killer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            assert_eq!(unsafe { sig::pthread_kill(me, sig::SIGUSR1) }, 0);
        });
        let t0 = Instant::now();
        let n = set.poll(Duration::from_millis(5000));
        let elapsed = t0.elapsed();
        killer.join().unwrap();
        assert_eq!(n, 0, "{}: EINTR must read as a timeout", set.kind());
        assert_eq!(set.readiness(tok), 0, "{}", set.kind());
        assert!(
            elapsed < Duration::from_millis(4000),
            "{}: the signal did not interrupt the wait ({elapsed:?})",
            set.kind()
        );
    }
}
