//! Socket front-end integration suite (`docs/NETWORKING.md`): a real
//! [`Server`] on loopback OS-assigned ports, exercised end to end.
//!
//! * **Bit-identity**: every block decoded over TCP and over UDP is
//!   identical to a one-shot in-process [`Decoder`] oracle decoding the
//!   same LLRs — across backends {scalar, compact, simd}, every
//!   termination mode, and shard counts {1, 2, 8}.
//! * **Lifecycle**: session cap, queue-saturation shedding, idle
//!   eviction (TCP read timeout + UDP flow sweep), dirty disconnects
//!   mid-block, and flow poisoning — each pinned with exact counter
//!   values from the metrics snapshot.
//! * **Observability**: the metrics endpoint serves parseable JSON with
//!   the net counters, and the loadgen harness soaks both transports.
//! * **Fault injection**: byte-dribbling and slow-reader clients, a
//!   mid-frame disconnect, corrupted CRC DATA frames, and a lossy /
//!   reordering / duplicating UDP shim — the reactor and the ack-window
//!   client absorb all of them with exact counter values.
//!
//! Everything binds `127.0.0.1:0`, so the suite is CI-safe.

use std::io::Write;
use std::net::{TcpStream, UdpSocket};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use tcvd::api::DecoderBuilder;
use tcvd::coding::registry;
use tcvd::net::loadgen::{self, make_block_llrs, LoadgenOptions, Transport};
use tcvd::net::protocol::{self, flags, kind, reject, Ack, ReadOutcome};
use tcvd::net::{
    fetch_metrics, Contract, DatagramSocket, NetConfig, PollerKind, Server, TcpClient,
    UdpClient, UdpPipelineOptions,
};
use tcvd::util::json::Json;

const BACKENDS: [&str; 3] = ["scalar", "compact", "simd"];
const MODES: [&str; 3] = ["flushed", "tail-biting", "truncated"];
const SHARDS: [usize; 3] = [1, 2, 8];

/// Small always-available pipeline: 16+8/8 tile (32-stage frames) on a
/// CPU backend, modest serving knobs.
fn builder(backend: &str, mode: &str, shards: usize) -> DecoderBuilder {
    DecoderBuilder::new()
        .backend_name(backend)
        .unwrap()
        .termination_name(mode)
        .unwrap()
        .tile_dims(16, 8, 8)
        .workers(2)
        .max_batch(8)
        .queue_depth(64)
        .shards(shards)
}

/// Start a loopback server (TCP + UDP) for `b`.
fn start(b: DecoderBuilder, net: NetConfig) -> Server {
    Server::start(b, Some("127.0.0.1:0"), Some("127.0.0.1:0"), net).unwrap()
}

/// One block's LLRs for the pipeline `b` describes (`stages` must be a
/// multiple of the tile payload).
fn block(b: &DecoderBuilder, stages: usize, seed: u64) -> Vec<f32> {
    let code = registry::lookup(b.code_name()).unwrap();
    make_block_llrs(&code, b.termination_mode(), stages, 6.0, seed)
}

/// Decode one whole block over a fresh TCP session, chunked one
/// payload tile at a time.
fn tcp_decode(addr: std::net::SocketAddr, b: &DecoderBuilder, llr: &[f32]) -> Vec<u8> {
    let code = registry::lookup(b.code_name()).unwrap();
    let chunk = b.tile_config().payload * code.beta();
    let mut c = TcpClient::connect(addr, b).unwrap();
    assert_eq!(c.ack().frame_stages, b.frame_stages() as u32);
    for part in llr.chunks(chunk) {
        c.push(part).unwrap();
    }
    c.finish().unwrap()
}

/// Poll `f` until it holds or `ms` elapse (counters race the
/// connection threads; eviction rides timeouts).
fn wait_for(ms: u64, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_millis(ms);
    loop {
        if f() {
            return true;
        }
        if Instant::now() >= deadline {
            return f();
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The full serving matrix: backends x termination modes x shard
/// counts, each block decoded over TCP *and* UDP and compared
/// bit-for-bit against the in-process oracle.
#[test]
fn tcp_and_udp_match_the_oracle_across_the_matrix() {
    for backend in BACKENDS {
        for mode in MODES {
            for shards in SHARDS {
                let b = builder(backend, mode, shards);
                let mut oracle = b.clone().shards(1).build().unwrap();
                let server = start(b.clone(), NetConfig::default());
                let tcp = server.tcp_addr().unwrap();
                let udp = server.udp_addr().unwrap();
                for seed in 0..2u64 {
                    let llr = block(&b, 64, 31 * seed + 7);
                    let want = oracle.decode_stream(&llr).unwrap();
                    assert!(!want.is_empty());
                    let got = tcp_decode(tcp, &b, &llr);
                    assert_eq!(got, want, "tcp {backend}/{mode}/shards={shards}/seed={seed}");
                    let mut u = UdpClient::connect(udp, 100 + seed).unwrap();
                    let got = u.decode_block(&llr).unwrap();
                    assert_eq!(got, want, "udp {backend}/{mode}/shards={shards}/seed={seed}");
                }
                let m = server.metrics();
                assert_eq!(m.net.sessions_accepted, 4, "{backend}/{mode}/{shards}");
                assert_eq!(m.net.sessions_evicted, 0);
                assert!(m.net.blocks >= 4, "latency recorded per block");
                assert!(m.net.bytes_in > 0 && m.net.bytes_out > 0);
                server.shutdown().unwrap();
            }
        }
    }
}

/// Concurrent sessions with interleaved pushes stay isolated: each
/// stream decodes to exactly its own oracle bits.
#[test]
fn interleaved_concurrent_sessions_stay_isolated() {
    let b = builder("simd", "flushed", 2);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();
    let code = registry::lookup(b.code_name()).unwrap();
    let chunk = b.tile_config().payload * code.beta();

    let blocks: Vec<Vec<f32>> = (0..3).map(|i| block(&b, 64, 900 + i)).collect();
    let wants: Vec<Vec<u8>> =
        blocks.iter().map(|llr| oracle.decode_stream(llr).unwrap()).collect();
    let mut clients: Vec<TcpClient> =
        (0..3).map(|_| TcpClient::connect(addr, &b).unwrap()).collect();
    // round-robin the chunks so all three sessions are in flight at once
    let n_chunks = blocks[0].len() / chunk;
    for j in 0..n_chunks {
        for (c, llr) in clients.iter_mut().zip(&blocks) {
            c.push(&llr[j * chunk..(j + 1) * chunk]).unwrap();
        }
    }
    for (c, want) in clients.into_iter().zip(&wants) {
        assert_eq!(&c.finish().unwrap(), want);
    }
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 3);
    assert_eq!(m.net.sessions_evicted, 0);
    assert_eq!(m.net.sessions_shed, 0);
    server.shutdown().unwrap();
}

/// The hard session cap sheds the third concurrent session with a
/// typed reject — and exactly one `sessions_shed` count.
#[test]
fn session_cap_sheds_the_third_session() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { max_sessions: 2, ..NetConfig::default() };
    let server = start(b.clone(), net);
    let addr = server.tcp_addr().unwrap();

    let a = TcpClient::connect(addr, &b).unwrap();
    let c2 = TcpClient::connect(addr, &b).unwrap();
    let e = TcpClient::connect(addr, &b).unwrap_err().to_string();
    assert!(e.contains("session-cap"), "{e}");
    assert!(e.contains("session cap 2 reached"), "{e}");

    // the held sessions are unharmed: both still decode cleanly
    let llr = block(&b, 32, 5);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let want = oracle.decode_stream(&llr).unwrap();
    for mut c in [a, c2] {
        c.push(&llr).unwrap();
        assert_eq!(c.finish().unwrap(), want);
    }
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 2);
    assert_eq!(m.net.sessions_shed, 1);
    assert_eq!(m.net.sessions_evicted, 0);
    server.shutdown().unwrap();
}

/// `shed_queue_depth = 0` makes the saturation signal always fire:
/// TCP admissions shed sessions, UDP sheds individual blocks while the
/// flow stays admitted.
#[test]
fn queue_saturation_sheds_tcp_sessions_and_udp_blocks() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { shed_queue_depth: Some(0), ..NetConfig::default() };
    let server = start(b.clone(), net);

    let e = TcpClient::connect(server.tcp_addr().unwrap(), &b).unwrap_err().to_string();
    assert!(e.contains("queue-saturated"), "{e}");

    let mut u = UdpClient::connect(server.udp_addr().unwrap(), 1).unwrap();
    let llr = block(&b, 32, 9);
    let e = u.decode_block(&llr).unwrap_err().to_string();
    assert!(e.contains("block shed"), "{e}");

    let m = server.metrics();
    assert_eq!(m.net.sessions_shed, 1, "the TCP admission");
    assert_eq!(m.net.blocks_shed, 1, "the UDP block");
    assert_eq!(m.net.sessions_accepted, 1, "the UDP flow itself was admitted");
    assert_eq!(m.net.handshake_rejects, 0);
    server.shutdown().unwrap();
}

/// A handshake asking for a different pipeline is a `config` reject,
/// counted separately from load shedding.
#[test]
fn handshake_mismatch_is_a_config_reject() {
    let b = builder("scalar", "flushed", 1);
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();

    let other_backend = builder("simd", "flushed", 1);
    let e = TcpClient::connect(addr, &other_backend).unwrap_err().to_string();
    assert!(e.contains("(config)"), "{e}");
    assert!(e.contains("backend mismatch"), "{e}");

    let other_tile = builder("scalar", "flushed", 1).tile_dims(32, 8, 8);
    let e = TcpClient::connect(addr, &other_tile).unwrap_err().to_string();
    assert!(e.contains("tile mismatch"), "{e}");

    let m = server.metrics();
    assert_eq!(m.net.handshake_rejects, 2);
    assert_eq!(m.net.sessions_accepted, 0);
    assert_eq!(m.net.sessions_shed, 0);
    server.shutdown().unwrap();
}

/// A TCP session that goes silent is evicted after the idle timeout
/// (exactly one `sessions_evicted`), and the client sees the typed
/// eviction error instead of a hang.
#[test]
fn idle_tcp_session_is_evicted() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { idle_timeout: Duration::from_millis(80), ..NetConfig::default() };
    let server = start(b.clone(), net);

    let mut c = TcpClient::connect(server.tcp_addr().unwrap(), &b).unwrap();
    c.push(&block(&b, 32, 3)).unwrap();
    // ... and never finish
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "eviction counter: {:?}",
        server.metrics().net
    );
    let e = c.finish().unwrap_err().to_string();
    assert!(e.contains("idle"), "{e}");
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 1);
    assert_eq!(m.net.sessions_evicted, 1);
    server.shutdown().unwrap();
}

/// Killing a TCP connection mid-block (a buffered tail-biting stream,
/// so the pipeline holds un-finished state) evicts the session and
/// leaves the pipeline healthy for the next clean session.
#[test]
fn dirty_tcp_disconnect_mid_block_then_clean_session() {
    let b = builder("scalar", "tail-biting", 2);
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();

    {
        let mut c = TcpClient::connect(addr, &b).unwrap();
        // half a payload tile: the stream can never complete
        c.push(&block(&b, 32, 4)[..16]).unwrap();
        // drop: the socket closes mid-block
    }
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "dirty disconnect must evict: {:?}",
        server.metrics().net
    );

    // the reassembler did not leak the dead session: a clean session
    // decodes to the oracle bits
    let llr = block(&b, 32, 6);
    let want = b.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(tcp_decode(addr, &b, &llr), want);
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 2);
    assert_eq!(m.net.sessions_evicted, 1);
    server.shutdown().unwrap();
}

/// A UDP block the pipeline rejects poisons its flow: the flow is
/// evicted (mirroring a dirty TCP disconnect) and the next block
/// re-admits it from scratch.
#[test]
fn udp_flow_poison_evicts_then_readmits() {
    let b = builder("scalar", "flushed", 1);
    let server = start(b.clone(), NetConfig::default());
    let mut u = UdpClient::connect(server.udp_addr().unwrap(), 77).unwrap();

    // 3 LLRs: not a multiple of beta, the session push rejects it
    let e = u.decode_block(&[0.5, -0.5, 0.5]).unwrap_err().to_string();
    assert!(e.contains("server error"), "{e}");
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 1, "the flow was admitted first");
    assert_eq!(m.net.sessions_evicted, 1, "then evicted by the poison block");

    let llr = block(&b, 32, 8);
    let want = b.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(u.decode_block(&llr).unwrap(), want);
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 2, "the same flow id re-admits");
    assert_eq!(m.net.sessions_evicted, 1);
    server.shutdown().unwrap();
}

/// An idle UDP flow is swept after the idle timeout.
#[test]
fn idle_udp_flow_is_swept() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { idle_timeout: Duration::from_millis(60), ..NetConfig::default() };
    let server = start(b.clone(), net);
    let mut u = UdpClient::connect(server.udp_addr().unwrap(), 5).unwrap();
    u.decode_block(&block(&b, 32, 2)).unwrap();
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "flow sweep: {:?}",
        server.metrics().net
    );
    assert_eq!(server.metrics().net.sessions_accepted, 1);
    server.shutdown().unwrap();
}

/// The metrics endpoint serves JSON with the net counters, both via
/// the one-shot fetch and mid-session.
#[test]
fn metrics_endpoint_serves_net_counters() {
    let b = builder("simd", "flushed", 2);
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();

    let llr = block(&b, 64, 11);
    tcp_decode(addr, &b, &llr);

    let snap = Json::parse(&fetch_metrics(addr).unwrap()).unwrap();
    let net = snap.get("net").unwrap();
    assert_eq!(net.get("sessions_accepted").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(net.get("sessions_evicted").unwrap().as_f64().unwrap(), 0.0);
    assert!(net.get("bytes_in").unwrap().as_f64().unwrap() > 0.0);
    assert!(net.get("blocks").unwrap().as_f64().unwrap() >= 1.0);
    assert!(net.get("block_p99_us").unwrap().as_f64().unwrap() >= 0.0);
    assert!(snap.get("frames_in").unwrap().as_f64().unwrap() > 0.0);

    // mid-session snapshot over an open client connection
    let mut c = TcpClient::connect(addr, &b).unwrap();
    let snap = Json::parse(&c.metrics_json().unwrap()).unwrap();
    assert_eq!(snap.get("net").unwrap().get("sessions_accepted").unwrap().as_f64().unwrap(), 2.0);
    server.shutdown().unwrap();
}

/// The loadgen harness soaks both transports on loopback: every block
/// bit-identical to the oracle, nothing abandoned.
#[test]
fn loadgen_soaks_both_transports() {
    let b = builder("simd", "flushed", 2);
    let server = start(b.clone(), NetConfig::default());
    let tcp = server.tcp_addr().unwrap().to_string();
    let udp = server.udp_addr().unwrap().to_string();
    for (addr, transport) in [(tcp, Transport::Tcp), (udp, Transport::Udp)] {
        let opts = LoadgenOptions {
            sessions: 4,
            blocks_per_session: 3,
            block_stages: 32,
            transport,
            ..LoadgenOptions::default()
        };
        let report = loadgen::run(&addr, &b, &opts).unwrap();
        assert_eq!(report.blocks, 12, "{transport:?}: {report:?}");
        assert_eq!(report.mismatches, 0);
        assert_eq!(report.failures, 0);
        report.check(None, None).unwrap();
    }
    let m = server.metrics();
    assert!(m.net.sessions_accepted >= 16, "churned sessions: {m:?}");
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Fault injection: hand-rolled wire clients and a lossy datagram shim.
// The reactor-facing half runs once per poller backend (`poll` and
// `epoll` — the latter degrades to poll off Linux), so the kernel event
// backend faces the same hostile clients as the portable one.
// ---------------------------------------------------------------------------

/// A `NetConfig` pinned to one poller backend.
fn net_with_poller(poller: PollerKind) -> NetConfig {
    NetConfig { poller, ..NetConfig::default() }
}

/// Open a raw socket and handshake by hand (`hello_flags` lets tests
/// offer e.g. [`flags::DATA_CRC`]); returns the stream and the ACK.
fn raw_connect(
    addr: std::net::SocketAddr,
    b: &DecoderBuilder,
    hello_flags: u16,
) -> (TcpStream, Ack) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut hello = Contract::of_builder(b).hello();
    hello.flags = hello_flags;
    protocol::write_frame(&mut s, kind::HELLO, &hello.encode().unwrap()).unwrap();
    match protocol::read_frame(&mut s, 1 << 22).unwrap() {
        ReadOutcome::Frame(k, p) => {
            assert_eq!(k, kind::ACK, "payload {p:?}");
            (s, Ack::decode(&p).unwrap())
        }
        other => panic!("expected ACK, got {other:?}"),
    }
}

/// Read reply frames until END, collecting BITS payloads.
fn drain_bits(s: &mut TcpStream) -> Vec<u8> {
    let mut bits = Vec::new();
    loop {
        match protocol::read_frame(s, 1 << 22).unwrap() {
            ReadOutcome::Frame(k, p) => match k {
                kind::BITS => bits.extend_from_slice(&p),
                kind::END => return bits,
                other => panic!("unexpected frame kind {other:#04x} in stream"),
            },
            other => panic!("expected BITS/END, got {other:?}"),
        }
    }
}

/// A byte-dribbling client — the whole conversation (HELLO, DATA,
/// FINISH) written one byte at a time with delays, so every frame
/// header and payload crosses a read boundary — decodes bit-identically.
fn byte_dribbling_client_on(poller: PollerKind) {
    let b = builder("scalar", "flushed", 1);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let server = start(b.clone(), net_with_poller(poller));
    let llr = block(&b, 32, 21);
    let want = oracle.decode_stream(&llr).unwrap();

    let mut wire = Vec::new();
    let hello = Contract::of_builder(&b).hello();
    protocol::write_frame(&mut wire, kind::HELLO, &hello.encode().unwrap()).unwrap();
    protocol::write_frame(&mut wire, kind::DATA, &protocol::encode_llrs(&llr)).unwrap();
    protocol::write_frame(&mut wire, kind::FINISH, &[]).unwrap();

    let mut s = TcpStream::connect(server.tcp_addr().unwrap()).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for (i, byte) in wire.iter().enumerate() {
        s.write_all(std::slice::from_ref(byte)).unwrap();
        if i % 8 == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    // first reply frame is the ACK, then the decoded stream
    match protocol::read_frame(&mut s, 1 << 22).unwrap() {
        ReadOutcome::Frame(k, p) => {
            assert_eq!(k, kind::ACK);
            assert_eq!(Ack::decode(&p).unwrap().flags & flags::DATA_CRC, 0);
        }
        other => panic!("expected ACK, got {other:?}"),
    }
    assert_eq!(drain_bits(&mut s), want);
    let m = server.metrics();
    assert_eq!(m.net.poller, poller.resolve().name(), "the gauge reports the live backend");
    assert_eq!(m.net.sessions_accepted, 1);
    assert_eq!(m.net.sessions_evicted, 0);
    server.shutdown().unwrap();
}

#[test]
fn byte_dribbling_client_decodes_bit_identically() {
    byte_dribbling_client_on(PollerKind::Poll);
}

#[test]
fn byte_dribbling_client_decodes_bit_identically_on_epoll() {
    byte_dribbling_client_on(PollerKind::Epoll);
}

/// A slow reader — the whole stream plus FINISH pushed before a single
/// BITS frame is drained, against a tiny write high-water mark — still
/// decodes bit-identically; the reactor buffers the backlog (visible in
/// the `write_buf_hwm` gauge) instead of blocking or dropping.
///
/// This is also the zero-copy BITS pin: with `write_high_water: 64`
/// every decoded chunk sits in the segmented outbound buffer (moved
/// from the reassembler, never copied into a flat staging `Vec`) across
/// many partial flushes before the client drains it — any segmentation
/// or ordering bug in that path breaks the bit-for-bit compare below.
fn slow_reader_client_on(poller: PollerKind) {
    let b = builder("simd", "flushed", 2);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let net = NetConfig { write_high_water: 64, ..net_with_poller(poller) };
    let server = start(b.clone(), net);
    let llr = block(&b, 256, 33);
    let want = oracle.decode_stream(&llr).unwrap();

    let (mut s, ack) = raw_connect(server.tcp_addr().unwrap(), &b, 0);
    assert_eq!(ack.flags & flags::DATA_CRC, 0);
    protocol::write_frame(&mut s, kind::DATA, &protocol::encode_llrs(&llr)).unwrap();
    protocol::write_frame(&mut s, kind::FINISH, &[]).unwrap();
    // never drain BITS until the decode is long since done server-side
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(drain_bits(&mut s), want, "zero-copy BITS path is bit-identical");
    let m = server.metrics();
    assert_eq!(m.net.poller, poller.resolve().name());
    assert!(m.net.write_buf_hwm > 0, "outbound buffering was observed: {:?}", m.net);
    assert!(m.net.reactor_ready_events > 0, "readiness events were counted: {:?}", m.net);
    assert_eq!(m.net.sessions_evicted, 0, "a slow reader is not an idle session");
    server.shutdown().unwrap();
}

#[test]
fn slow_reader_client_decodes_bit_identically() {
    slow_reader_client_on(PollerKind::Poll);
}

#[test]
fn slow_reader_client_decodes_bit_identically_on_epoll() {
    slow_reader_client_on(PollerKind::Epoll);
}

/// A connection dropped in the middle of a DATA frame (header promised
/// 100 bytes, 10 arrived) bumps the dirty-disconnect counter exactly
/// once, and the pipeline stays healthy for the next clean session.
fn mid_frame_disconnect_on(poller: PollerKind) {
    let b = builder("scalar", "tail-biting", 1);
    let server = start(b.clone(), net_with_poller(poller));
    let addr = server.tcp_addr().unwrap();

    {
        let (mut s, _ack) = raw_connect(addr, &b, 0);
        let mut partial = vec![kind::DATA];
        partial.extend_from_slice(&100u32.to_le_bytes());
        partial.extend_from_slice(&[0u8; 10]);
        s.write_all(&partial).unwrap();
        s.flush().unwrap();
        // drop: the socket closes mid-frame
    }
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "mid-frame disconnect must evict: {:?}",
        server.metrics().net
    );
    // exactly once: more reactor ticks must not move the counter
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(server.metrics().net.sessions_evicted, 1);

    let llr = block(&b, 32, 6);
    let want = b.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(tcp_decode(addr, &b, &llr), want);
    let m = server.metrics();
    assert_eq!(m.net.poller, poller.resolve().name());
    assert_eq!(m.net.sessions_accepted, 2);
    assert_eq!(m.net.sessions_evicted, 1);
    server.shutdown().unwrap();
}

#[test]
fn mid_frame_disconnect_evicts_exactly_once() {
    mid_frame_disconnect_on(PollerKind::Poll);
}

#[test]
fn mid_frame_disconnect_evicts_exactly_once_on_epoll() {
    mid_frame_disconnect_on(PollerKind::Epoll);
}

/// CRC32 negotiation end to end: an offering client decodes
/// bit-identically, a corrupted DATA frame draws the typed
/// `crc-mismatch` REJECT (and eviction), and a server run with
/// `net.crc = true` switches checksums on for a non-offering client
/// via the ACK.
#[test]
fn crc_sessions_negotiate_and_reject_corruption() {
    let b = builder("scalar", "flushed", 1);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let llr = block(&b, 32, 13);
    let want = oracle.decode_stream(&llr).unwrap();

    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();

    // 1) client offers a CRC, the ACK confirms, bits are identical
    let mut c = TcpClient::connect_opts(addr, &b, true).unwrap();
    assert!(c.crc());
    assert_eq!(c.ack().flags & flags::DATA_CRC, flags::DATA_CRC);
    c.push(&llr).unwrap();
    assert_eq!(c.finish().unwrap(), want);

    // 2) a corrupted DATA payload on a crc session: typed REJECT
    let (mut s, ack) = raw_connect(addr, &b, flags::DATA_CRC);
    assert_eq!(ack.flags & flags::DATA_CRC, flags::DATA_CRC);
    let mut payload = protocol::encode_data_payload(&llr, true);
    payload[7] ^= 0x20; // flip one LLR bit under the checksum
    protocol::write_frame(&mut s, kind::DATA, &payload).unwrap();
    match protocol::read_frame(&mut s, 1 << 22).unwrap() {
        ReadOutcome::Frame(k, p) => {
            assert_eq!(k, kind::REJECT);
            let (reason, detail) = protocol::decode_reject(&p).unwrap();
            assert_eq!(reason, reject::CRC_MISMATCH);
            assert!(detail.contains("crc-mismatch"), "{detail}");
        }
        other => panic!("expected REJECT, got {other:?}"),
    }
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "corrupted frame must evict: {:?}",
        server.metrics().net
    );
    server.shutdown().unwrap();

    // 3) server-mandated CRC: a plain client is switched on by the ACK
    let server = start(b.clone(), NetConfig { crc: true, ..NetConfig::default() });
    let mut c = TcpClient::connect(server.tcp_addr().unwrap(), &b).unwrap();
    assert!(c.crc(), "the ACK switched the checksum on");
    c.push(&llr).unwrap();
    assert_eq!(c.finish().unwrap(), want);
    server.shutdown().unwrap();
}

/// Deterministic fault script over a real socket, keyed by send index:
/// datagram 0 is delayed behind 1 (reorder), 2 is sent twice
/// (duplication), 3 is dropped once (loss); everything later passes
/// through untouched.
struct LossyShim {
    inner: UdpSocket,
    state: Mutex<ShimState>,
}

#[derive(Default)]
struct ShimState {
    sends: usize,
    stash: Option<Vec<u8>>,
}

impl DatagramSocket for LossyShim {
    fn send(&self, buf: &[u8]) -> tcvd::error::Result<()> {
        let mut st = self.state.lock().unwrap();
        let i = st.sends;
        st.sends += 1;
        match i {
            0 => st.stash = Some(buf.to_vec()),
            1 => {
                DatagramSocket::send(&self.inner, buf)?;
                if let Some(held) = st.stash.take() {
                    DatagramSocket::send(&self.inner, &held)?;
                }
            }
            2 => {
                DatagramSocket::send(&self.inner, buf)?;
                DatagramSocket::send(&self.inner, buf)?;
            }
            3 => {} // dropped
            _ => DatagramSocket::send(&self.inner, buf)?,
        }
        Ok(())
    }

    fn recv_timeout(
        &self,
        buf: &mut [u8],
        timeout: Duration,
    ) -> tcvd::error::Result<Option<usize>> {
        DatagramSocket::recv_timeout(&self.inner, buf, timeout)
    }
}

/// The pipelined ack-window client reassembles every block
/// bit-identically through loss, reordering, and duplication — with
/// exact retransmit / duplicate counters.
#[test]
fn udp_ack_window_survives_loss_reorder_and_duplication() {
    let b = builder("scalar", "tail-biting", 1);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let server = start(b.clone(), NetConfig::default());

    let blocks: Vec<Vec<f32>> = (0..4).map(|i| block(&b, 32, 500 + i)).collect();
    let wants: Vec<Vec<u8>> =
        blocks.iter().map(|llr| oracle.decode_stream(llr).unwrap()).collect();

    let inner = UdpSocket::bind(("127.0.0.1", 0)).unwrap();
    inner.connect(server.udp_addr().unwrap()).unwrap();
    let shim = LossyShim { inner, state: Mutex::new(ShimState::default()) };
    let mut c = UdpClient::with_socket(shim, 424_242);
    let opts = UdpPipelineOptions {
        window: 4,
        ack_timeout: Duration::from_millis(150),
        overall_timeout: Duration::from_secs(30),
    };
    let run = c.decode_blocks(&blocks, &opts).unwrap();
    assert_eq!(run.blocks, wants, "reassembled blocks are bit-identical");
    assert_eq!(run.stats.blocks, 4);
    assert_eq!(run.stats.acks, 4);
    assert_eq!(run.stats.retransmits, 1, "the dropped datagram was resent exactly once");
    assert_eq!(run.stats.duplicate_replies, 1, "the duplicated datagram drew one extra reply");
    assert_eq!(run.stats.shed_retries, 0);
    assert_eq!(run.latencies.len(), 4);
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 1, "one pipelined flow");
    server.shutdown().unwrap();
}

/// The reactor serves every connection from a fixed thread count: 32
/// concurrent idle sessions add no threads to the process (probed via
/// `/proc/self/task`; skipped where `/proc` is unavailable).
fn reactor_thread_count_on(poller: PollerKind) {
    fn thread_count() -> Option<usize> {
        std::fs::read_dir("/proc/self/task").ok().map(|d| d.count())
    }
    if thread_count().is_none() {
        return; // no /proc on this platform
    }
    let b = builder("scalar", "flushed", 1);
    let server = start(b.clone(), net_with_poller(poller));
    let addr = server.tcp_addr().unwrap();
    let before = thread_count().unwrap();

    let clients: Vec<TcpClient> =
        (0..32).map(|_| TcpClient::connect(addr, &b).unwrap()).collect();
    assert!(
        wait_for(5000, || server.metrics().net.sessions_accepted == 32),
        "admissions: {:?}",
        server.metrics().net
    );
    // a thread-per-connection server would be +32 here; allow headroom
    // for unrelated test threads in the shared process
    let during = thread_count().unwrap();
    assert!(
        during < before + 16,
        "server looks thread-per-connection: {before} -> {during} threads"
    );
    // the readiness gauges see the listener + all 32 connections
    assert!(
        wait_for(2000, || server.metrics().net.reactor_fds >= 33),
        "reactor_fds: {:?}",
        server.metrics().net
    );
    assert!(server.metrics().net.reactor_wakeups > 0);
    assert_eq!(server.metrics().net.poller, poller.resolve().name());
    drop(clients);
    server.shutdown().unwrap();
}

#[test]
fn reactor_thread_count_is_flat_across_connections() {
    reactor_thread_count_on(PollerKind::Poll);
}

#[test]
fn reactor_thread_count_is_flat_across_connections_on_epoll() {
    reactor_thread_count_on(PollerKind::Epoll);
}

/// Server-side UDP reply batching is invisible on the wire: the same
/// pipelined run decodes bit-identically with batching disabled
/// (`net.udp_batch = 1`) and enabled (`net.udp_batch = 8`), and the
/// batching counters move only on the batching server — every reply
/// leaves through either a batched send or the latched single-datagram
/// fallback, never silently.
#[test]
fn udp_reply_batching_is_bit_identical_across_batch_knobs() {
    let b = builder("scalar", "flushed", 2);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let blocks: Vec<Vec<f32>> = (0..8).map(|i| block(&b, 32, 700 + i)).collect();
    let wants: Vec<Vec<u8>> =
        blocks.iter().map(|llr| oracle.decode_stream(llr).unwrap()).collect();
    let opts = UdpPipelineOptions {
        window: 4,
        ack_timeout: Duration::from_millis(250),
        overall_timeout: Duration::from_secs(30),
    };

    let mut decoded = Vec::new();
    for udp_batch in [1usize, 8] {
        let net = NetConfig { udp_batch, ..NetConfig::default() };
        let server = start(b.clone(), net);
        let mut u = UdpClient::connect(server.udp_addr().unwrap(), 31_337).unwrap();
        let run = u.decode_blocks(&blocks, &opts).unwrap();
        assert_eq!(run.blocks, wants, "udp_batch={udp_batch} diverges from the oracle");
        let m = server.metrics();
        let replies = m.net.udp_batch_datagrams + m.net.udp_send_fallbacks;
        if udp_batch == 1 {
            assert_eq!(m.net.udp_batched_sends, 0, "batching disabled: {:?}", m.net);
            assert_eq!(replies, 0, "no batch-path counters at udp_batch=1: {:?}", m.net);
        } else {
            assert!(
                replies >= blocks.len() as u64,
                "every reply is accounted batched-or-fallback: {:?}",
                m.net
            );
            assert!(
                m.net.udp_batched_sends > 0 || m.net.udp_send_fallbacks > 0,
                "the batch path was exercised: {:?}",
                m.net
            );
        }
        decoded.push(run.blocks);
        server.shutdown().unwrap();
    }
    assert_eq!(decoded[0], decoded[1], "batched and unbatched replies carry identical bits");
}
