//! Socket front-end integration suite (`docs/NETWORKING.md`): a real
//! [`Server`] on loopback OS-assigned ports, exercised end to end.
//!
//! * **Bit-identity**: every block decoded over TCP and over UDP is
//!   identical to a one-shot in-process [`Decoder`] oracle decoding the
//!   same LLRs — across backends {scalar, compact, simd}, every
//!   termination mode, and shard counts {1, 2, 8}.
//! * **Lifecycle**: session cap, queue-saturation shedding, idle
//!   eviction (TCP read timeout + UDP flow sweep), dirty disconnects
//!   mid-block, and flow poisoning — each pinned with exact counter
//!   values from the metrics snapshot.
//! * **Observability**: the metrics endpoint serves parseable JSON with
//!   the net counters, and the loadgen harness soaks both transports.
//!
//! Everything binds `127.0.0.1:0`, so the suite is CI-safe.

use std::time::{Duration, Instant};

use tcvd::api::DecoderBuilder;
use tcvd::coding::registry;
use tcvd::net::loadgen::{self, make_block_llrs, LoadgenOptions, Transport};
use tcvd::net::{fetch_metrics, NetConfig, Server, TcpClient, UdpClient};
use tcvd::util::json::Json;

const BACKENDS: [&str; 3] = ["scalar", "compact", "simd"];
const MODES: [&str; 3] = ["flushed", "tail-biting", "truncated"];
const SHARDS: [usize; 3] = [1, 2, 8];

/// Small always-available pipeline: 16+8/8 tile (32-stage frames) on a
/// CPU backend, modest serving knobs.
fn builder(backend: &str, mode: &str, shards: usize) -> DecoderBuilder {
    DecoderBuilder::new()
        .backend_name(backend)
        .unwrap()
        .termination_name(mode)
        .unwrap()
        .tile_dims(16, 8, 8)
        .workers(2)
        .max_batch(8)
        .queue_depth(64)
        .shards(shards)
}

/// Start a loopback server (TCP + UDP) for `b`.
fn start(b: DecoderBuilder, net: NetConfig) -> Server {
    Server::start(b, Some("127.0.0.1:0"), Some("127.0.0.1:0"), net).unwrap()
}

/// One block's LLRs for the pipeline `b` describes (`stages` must be a
/// multiple of the tile payload).
fn block(b: &DecoderBuilder, stages: usize, seed: u64) -> Vec<f32> {
    let code = registry::lookup(b.code_name()).unwrap();
    make_block_llrs(&code, b.termination_mode(), stages, 6.0, seed)
}

/// Decode one whole block over a fresh TCP session, chunked one
/// payload tile at a time.
fn tcp_decode(addr: std::net::SocketAddr, b: &DecoderBuilder, llr: &[f32]) -> Vec<u8> {
    let code = registry::lookup(b.code_name()).unwrap();
    let chunk = b.tile_config().payload * code.beta();
    let mut c = TcpClient::connect(addr, b).unwrap();
    assert_eq!(c.ack().frame_stages, b.frame_stages() as u32);
    for part in llr.chunks(chunk) {
        c.push(part).unwrap();
    }
    c.finish().unwrap()
}

/// Poll `f` until it holds or `ms` elapse (counters race the
/// connection threads; eviction rides timeouts).
fn wait_for(ms: u64, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_millis(ms);
    loop {
        if f() {
            return true;
        }
        if Instant::now() >= deadline {
            return f();
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The full serving matrix: backends x termination modes x shard
/// counts, each block decoded over TCP *and* UDP and compared
/// bit-for-bit against the in-process oracle.
#[test]
fn tcp_and_udp_match_the_oracle_across_the_matrix() {
    for backend in BACKENDS {
        for mode in MODES {
            for shards in SHARDS {
                let b = builder(backend, mode, shards);
                let mut oracle = b.clone().shards(1).build().unwrap();
                let server = start(b.clone(), NetConfig::default());
                let tcp = server.tcp_addr().unwrap();
                let udp = server.udp_addr().unwrap();
                for seed in 0..2u64 {
                    let llr = block(&b, 64, 31 * seed + 7);
                    let want = oracle.decode_stream(&llr).unwrap();
                    assert!(!want.is_empty());
                    let got = tcp_decode(tcp, &b, &llr);
                    assert_eq!(got, want, "tcp {backend}/{mode}/shards={shards}/seed={seed}");
                    let mut u = UdpClient::connect(udp, 100 + seed).unwrap();
                    let got = u.decode_block(&llr).unwrap();
                    assert_eq!(got, want, "udp {backend}/{mode}/shards={shards}/seed={seed}");
                }
                let m = server.metrics();
                assert_eq!(m.net.sessions_accepted, 4, "{backend}/{mode}/{shards}");
                assert_eq!(m.net.sessions_evicted, 0);
                assert!(m.net.blocks >= 4, "latency recorded per block");
                assert!(m.net.bytes_in > 0 && m.net.bytes_out > 0);
                server.shutdown().unwrap();
            }
        }
    }
}

/// Concurrent sessions with interleaved pushes stay isolated: each
/// stream decodes to exactly its own oracle bits.
#[test]
fn interleaved_concurrent_sessions_stay_isolated() {
    let b = builder("simd", "flushed", 2);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();
    let code = registry::lookup(b.code_name()).unwrap();
    let chunk = b.tile_config().payload * code.beta();

    let blocks: Vec<Vec<f32>> = (0..3).map(|i| block(&b, 64, 900 + i)).collect();
    let wants: Vec<Vec<u8>> =
        blocks.iter().map(|llr| oracle.decode_stream(llr).unwrap()).collect();
    let mut clients: Vec<TcpClient> =
        (0..3).map(|_| TcpClient::connect(addr, &b).unwrap()).collect();
    // round-robin the chunks so all three sessions are in flight at once
    let n_chunks = blocks[0].len() / chunk;
    for j in 0..n_chunks {
        for (c, llr) in clients.iter_mut().zip(&blocks) {
            c.push(&llr[j * chunk..(j + 1) * chunk]).unwrap();
        }
    }
    for (c, want) in clients.into_iter().zip(&wants) {
        assert_eq!(&c.finish().unwrap(), want);
    }
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 3);
    assert_eq!(m.net.sessions_evicted, 0);
    assert_eq!(m.net.sessions_shed, 0);
    server.shutdown().unwrap();
}

/// The hard session cap sheds the third concurrent session with a
/// typed reject — and exactly one `sessions_shed` count.
#[test]
fn session_cap_sheds_the_third_session() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { max_sessions: 2, ..NetConfig::default() };
    let server = start(b.clone(), net);
    let addr = server.tcp_addr().unwrap();

    let a = TcpClient::connect(addr, &b).unwrap();
    let c2 = TcpClient::connect(addr, &b).unwrap();
    let e = TcpClient::connect(addr, &b).unwrap_err().to_string();
    assert!(e.contains("session-cap"), "{e}");
    assert!(e.contains("session cap 2 reached"), "{e}");

    // the held sessions are unharmed: both still decode cleanly
    let llr = block(&b, 32, 5);
    let mut oracle = b.clone().shards(1).build().unwrap();
    let want = oracle.decode_stream(&llr).unwrap();
    for mut c in [a, c2] {
        c.push(&llr).unwrap();
        assert_eq!(c.finish().unwrap(), want);
    }
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 2);
    assert_eq!(m.net.sessions_shed, 1);
    assert_eq!(m.net.sessions_evicted, 0);
    server.shutdown().unwrap();
}

/// `shed_queue_depth = 0` makes the saturation signal always fire:
/// TCP admissions shed sessions, UDP sheds individual blocks while the
/// flow stays admitted.
#[test]
fn queue_saturation_sheds_tcp_sessions_and_udp_blocks() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { shed_queue_depth: Some(0), ..NetConfig::default() };
    let server = start(b.clone(), net);

    let e = TcpClient::connect(server.tcp_addr().unwrap(), &b).unwrap_err().to_string();
    assert!(e.contains("queue-saturated"), "{e}");

    let mut u = UdpClient::connect(server.udp_addr().unwrap(), 1).unwrap();
    let llr = block(&b, 32, 9);
    let e = u.decode_block(&llr).unwrap_err().to_string();
    assert!(e.contains("block shed"), "{e}");

    let m = server.metrics();
    assert_eq!(m.net.sessions_shed, 1, "the TCP admission");
    assert_eq!(m.net.blocks_shed, 1, "the UDP block");
    assert_eq!(m.net.sessions_accepted, 1, "the UDP flow itself was admitted");
    assert_eq!(m.net.handshake_rejects, 0);
    server.shutdown().unwrap();
}

/// A handshake asking for a different pipeline is a `config` reject,
/// counted separately from load shedding.
#[test]
fn handshake_mismatch_is_a_config_reject() {
    let b = builder("scalar", "flushed", 1);
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();

    let other_backend = builder("simd", "flushed", 1);
    let e = TcpClient::connect(addr, &other_backend).unwrap_err().to_string();
    assert!(e.contains("(config)"), "{e}");
    assert!(e.contains("backend mismatch"), "{e}");

    let other_tile = builder("scalar", "flushed", 1).tile_dims(32, 8, 8);
    let e = TcpClient::connect(addr, &other_tile).unwrap_err().to_string();
    assert!(e.contains("tile mismatch"), "{e}");

    let m = server.metrics();
    assert_eq!(m.net.handshake_rejects, 2);
    assert_eq!(m.net.sessions_accepted, 0);
    assert_eq!(m.net.sessions_shed, 0);
    server.shutdown().unwrap();
}

/// A TCP session that goes silent is evicted after the idle timeout
/// (exactly one `sessions_evicted`), and the client sees the typed
/// eviction error instead of a hang.
#[test]
fn idle_tcp_session_is_evicted() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { idle_timeout: Duration::from_millis(80), ..NetConfig::default() };
    let server = start(b.clone(), net);

    let mut c = TcpClient::connect(server.tcp_addr().unwrap(), &b).unwrap();
    c.push(&block(&b, 32, 3)).unwrap();
    // ... and never finish
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "eviction counter: {:?}",
        server.metrics().net
    );
    let e = c.finish().unwrap_err().to_string();
    assert!(e.contains("idle"), "{e}");
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 1);
    assert_eq!(m.net.sessions_evicted, 1);
    server.shutdown().unwrap();
}

/// Killing a TCP connection mid-block (a buffered tail-biting stream,
/// so the pipeline holds un-finished state) evicts the session and
/// leaves the pipeline healthy for the next clean session.
#[test]
fn dirty_tcp_disconnect_mid_block_then_clean_session() {
    let b = builder("scalar", "tail-biting", 2);
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();

    {
        let mut c = TcpClient::connect(addr, &b).unwrap();
        // half a payload tile: the stream can never complete
        c.push(&block(&b, 32, 4)[..16]).unwrap();
        // drop: the socket closes mid-block
    }
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "dirty disconnect must evict: {:?}",
        server.metrics().net
    );

    // the reassembler did not leak the dead session: a clean session
    // decodes to the oracle bits
    let llr = block(&b, 32, 6);
    let want = b.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(tcp_decode(addr, &b, &llr), want);
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 2);
    assert_eq!(m.net.sessions_evicted, 1);
    server.shutdown().unwrap();
}

/// A UDP block the pipeline rejects poisons its flow: the flow is
/// evicted (mirroring a dirty TCP disconnect) and the next block
/// re-admits it from scratch.
#[test]
fn udp_flow_poison_evicts_then_readmits() {
    let b = builder("scalar", "flushed", 1);
    let server = start(b.clone(), NetConfig::default());
    let mut u = UdpClient::connect(server.udp_addr().unwrap(), 77).unwrap();

    // 3 LLRs: not a multiple of beta, the session push rejects it
    let e = u.decode_block(&[0.5, -0.5, 0.5]).unwrap_err().to_string();
    assert!(e.contains("server error"), "{e}");
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 1, "the flow was admitted first");
    assert_eq!(m.net.sessions_evicted, 1, "then evicted by the poison block");

    let llr = block(&b, 32, 8);
    let want = b.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(u.decode_block(&llr).unwrap(), want);
    let m = server.metrics();
    assert_eq!(m.net.sessions_accepted, 2, "the same flow id re-admits");
    assert_eq!(m.net.sessions_evicted, 1);
    server.shutdown().unwrap();
}

/// An idle UDP flow is swept after the idle timeout.
#[test]
fn idle_udp_flow_is_swept() {
    let b = builder("scalar", "flushed", 1);
    let net = NetConfig { idle_timeout: Duration::from_millis(60), ..NetConfig::default() };
    let server = start(b.clone(), net);
    let mut u = UdpClient::connect(server.udp_addr().unwrap(), 5).unwrap();
    u.decode_block(&block(&b, 32, 2)).unwrap();
    assert!(
        wait_for(5000, || server.metrics().net.sessions_evicted == 1),
        "flow sweep: {:?}",
        server.metrics().net
    );
    assert_eq!(server.metrics().net.sessions_accepted, 1);
    server.shutdown().unwrap();
}

/// The metrics endpoint serves JSON with the net counters, both via
/// the one-shot fetch and mid-session.
#[test]
fn metrics_endpoint_serves_net_counters() {
    let b = builder("simd", "flushed", 2);
    let server = start(b.clone(), NetConfig::default());
    let addr = server.tcp_addr().unwrap();

    let llr = block(&b, 64, 11);
    tcp_decode(addr, &b, &llr);

    let snap = Json::parse(&fetch_metrics(addr).unwrap()).unwrap();
    let net = snap.get("net").unwrap();
    assert_eq!(net.get("sessions_accepted").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(net.get("sessions_evicted").unwrap().as_f64().unwrap(), 0.0);
    assert!(net.get("bytes_in").unwrap().as_f64().unwrap() > 0.0);
    assert!(net.get("blocks").unwrap().as_f64().unwrap() >= 1.0);
    assert!(net.get("block_p99_us").unwrap().as_f64().unwrap() >= 0.0);
    assert!(snap.get("frames_in").unwrap().as_f64().unwrap() > 0.0);

    // mid-session snapshot over an open client connection
    let mut c = TcpClient::connect(addr, &b).unwrap();
    let snap = Json::parse(&c.metrics_json().unwrap()).unwrap();
    assert_eq!(snap.get("net").unwrap().get("sessions_accepted").unwrap().as_f64().unwrap(), 2.0);
    server.shutdown().unwrap();
}

/// The loadgen harness soaks both transports on loopback: every block
/// bit-identical to the oracle, nothing abandoned.
#[test]
fn loadgen_soaks_both_transports() {
    let b = builder("simd", "flushed", 2);
    let server = start(b.clone(), NetConfig::default());
    let tcp = server.tcp_addr().unwrap().to_string();
    let udp = server.udp_addr().unwrap().to_string();
    for (addr, transport) in [(tcp, Transport::Tcp), (udp, Transport::Udp)] {
        let opts = LoadgenOptions {
            sessions: 4,
            blocks_per_session: 3,
            block_stages: 32,
            transport,
            ..LoadgenOptions::default()
        };
        let report = loadgen::run(&addr, &b, &opts).unwrap();
        assert_eq!(report.blocks, 12, "{transport:?}: {report:?}");
        assert_eq!(report.mismatches, 0);
        assert_eq!(report.failures, 0);
        report.check(None, None).unwrap();
    }
    let m = server.metrics();
    assert!(m.net.sessions_accepted >= 16, "churned sessions: {m:?}");
    server.shutdown().unwrap();
}
