//! Wire-protocol conformance tests: every frame type round-trips
//! through the incremental parser (random payloads, including
//! zero-size and max-size), and every malformed input class — truncated
//! headers, oversize length prefixes, unknown frame tags, CRC-mismatch
//! DATA frames — is rejected with a typed `Error::Net`, never a panic.

use tcvd::defaults::NET_MAX_FRAME_BYTES;
use tcvd::error::Error;
use tcvd::net::protocol::{
    crc32, decode_data_payload, decode_llrs, decode_reject, encode_data_payload, encode_llrs,
    encode_reject, is_crc_mismatch, kind, reject, reject_reason_name, write_frame, Ack, FrameBuf,
    Hello, UdpBlock, UdpReply, FRAME_HEADER, PROTO_VERSION,
};
use tcvd::net::protocol::{flags, udp_status};
use tcvd::util::rng::Rng;

const ALL_KINDS: [u8; 10] = [
    kind::HELLO,
    kind::DATA,
    kind::FINISH,
    kind::METRICS_REQ,
    kind::ACK,
    kind::BITS,
    kind::END,
    kind::REJECT,
    kind::ERROR,
    kind::METRICS,
];

fn random_bytes(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.next_u64() as u8).collect()
}

/// A raw `[kind][len:u32le]` frame header (no payload behind it).
fn raw_header(tag: u8, len: u32) -> Vec<u8> {
    let mut h = vec![tag];
    h.extend_from_slice(&len.to_le_bytes());
    h
}

#[test]
fn every_frame_kind_roundtrips_through_the_parser() {
    let mut rng = Rng::new(0xF4A3);
    for (i, &k) in ALL_KINDS.iter().enumerate() {
        // sizes spread from empty to a few KiB, one random draw each
        let len = [0, 1, 5, 256, 4096][i % 5] + rng.next_below(7) as usize;
        let payload = random_bytes(&mut rng, len);
        let mut wire = Vec::new();
        write_frame(&mut wire, k, &payload).unwrap();
        assert_eq!(wire.len(), FRAME_HEADER + payload.len());

        // dribble the wire bytes in at random split points
        let mut fb = FrameBuf::new();
        let mut rest = &wire[..];
        let mut got = None;
        while !rest.is_empty() {
            let take = (1 + rng.next_below(3) as usize).min(rest.len());
            fb.extend(&rest[..take]);
            rest = &rest[take..];
            if let Some(f) = fb.next_frame(NET_MAX_FRAME_BYTES).unwrap() {
                got = Some(f);
            }
        }
        assert_eq!(got, Some((k, payload)), "kind {k:#04x}");
        assert!(fb.is_empty());
    }
}

#[test]
fn zero_size_and_max_size_payloads_roundtrip() {
    // zero-size: a bare header is a complete frame
    let mut fb = FrameBuf::new();
    let mut wire = Vec::new();
    write_frame(&mut wire, kind::FINISH, &[]).unwrap();
    fb.extend(&wire);
    assert_eq!(fb.next_frame(16).unwrap(), Some((kind::FINISH, vec![])));

    // max-size: a payload exactly at the limit parses ...
    let max = 4096;
    let payload = random_bytes(&mut Rng::new(7), max);
    let mut wire = Vec::new();
    write_frame(&mut wire, kind::DATA, &payload).unwrap();
    let mut fb = FrameBuf::new();
    fb.extend(&wire);
    assert_eq!(fb.next_frame(max).unwrap(), Some((kind::DATA, payload)));

    // ... and one byte over is a typed error, before any payload lands
    let mut fb = FrameBuf::new();
    fb.extend(&raw_header(kind::DATA, max as u32 + 1));
    let e = fb.next_frame(max).unwrap_err();
    assert!(matches!(e, Error::Net(_)), "{e}");
    assert!(e.to_string().contains("exceeds"), "{e}");
}

#[test]
fn truncated_headers_are_never_frames() {
    // any strict prefix of a frame header yields "need more bytes",
    // not a frame and not a panic
    let mut wire = Vec::new();
    write_frame(&mut wire, kind::BITS, &[1, 2, 3]).unwrap();
    for cut in 0..FRAME_HEADER {
        let mut fb = FrameBuf::new();
        fb.extend(&wire[..cut]);
        assert_eq!(fb.next_frame(1024).unwrap(), None, "cut at {cut}");
        assert_eq!(fb.buffered(), cut);
    }
}

#[test]
fn oversize_length_prefix_is_a_typed_error() {
    for len in [NET_MAX_FRAME_BYTES as u32 + 1, u32::MAX] {
        let mut fb = FrameBuf::new();
        fb.extend(&raw_header(kind::DATA, len));
        let e = fb.next_frame(NET_MAX_FRAME_BYTES).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "{e}");
        assert!(e.to_string().contains("exceeds"), "{e}");
    }
}

#[test]
fn unknown_frame_tags_are_typed_errors() {
    for tag in [0x00u8, 0x05, 0x7F, 0x80, 0x87, 0xFF] {
        let mut fb = FrameBuf::new();
        fb.extend(&raw_header(tag, 0));
        let e = fb.next_frame(1024).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "{e}");
        assert!(e.to_string().contains("unknown frame kind"), "tag {tag:#04x}: {e}");
    }
}

#[test]
fn hello_roundtrips_and_rejects_every_truncation() {
    let h = Hello {
        version: PROTO_VERSION,
        flags: flags::DATA_CRC,
        code: "ccsds".into(),
        backend: "simd".into(),
        termination: "flushed".into(),
        payload_stages: 64,
        head_stages: 32,
        tail_stages: 32,
    };
    let wire = h.encode().unwrap();
    assert_eq!(Hello::decode(&wire).unwrap(), h);
    // every strict prefix is a typed error (some field is cut short)
    for cut in 0..wire.len() {
        let e = Hello::decode(&wire[..cut]).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "cut at {cut}: {e}");
    }
    // trailing garbage is rejected too
    let mut long = wire.clone();
    long.push(0);
    assert!(Hello::decode(&long).is_err());
}

#[test]
fn ack_roundtrips_and_rejects_every_truncation() {
    let a = Ack { session: 0xDEAD_BEEF, frame_stages: 96, beta: 2, flags: 0 };
    let wire = a.encode();
    assert_eq!(Ack::decode(&wire).unwrap(), a);
    for cut in 0..wire.len() {
        let e = Ack::decode(&wire[..cut]).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "cut at {cut}: {e}");
    }
    let mut long = wire.clone();
    long.push(9);
    assert!(Ack::decode(&long).is_err());
}

#[test]
fn reject_roundtrips_every_reason() {
    for (reason, name) in [
        (reject::SESSION_CAP, "session-cap"),
        (reject::QUEUE_SATURATED, "queue-saturated"),
        (reject::CONFIG, "config"),
        (reject::CRC_MISMATCH, "crc-mismatch"),
    ] {
        let (r, detail) = decode_reject(&encode_reject(reason, "why")).unwrap();
        assert_eq!(r, reason);
        assert_eq!(reject_reason_name(r), name);
        assert_eq!(detail, "why");
    }
    assert!(decode_reject(&[]).is_err(), "empty REJECT is typed");
}

#[test]
fn data_payloads_roundtrip_with_and_without_crc() {
    let mut rng = Rng::new(0x11);
    for n in [0usize, 1, 64, 1000] {
        let llr: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
        assert_eq!(decode_data_payload(&encode_data_payload(&llr, false), false).unwrap(), llr);
        let wire = encode_data_payload(&llr, true);
        assert_eq!(wire.len(), 4 + llr.len() * 4);
        assert_eq!(decode_data_payload(&wire, true).unwrap(), llr);
    }
}

#[test]
fn crc_mismatch_data_frames_are_typed_errors() {
    let llr = vec![1.0f32, -1.0, 0.5, 2.5];
    let good = encode_data_payload(&llr, true);
    // flip one bit anywhere (header or payload): typed crc error
    for byte in [0usize, 3, 4, good.len() - 1] {
        let mut bad = good.clone();
        bad[byte] ^= 0x40;
        let e = decode_data_payload(&bad, true).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "{e}");
        assert!(is_crc_mismatch(&e), "byte {byte}: {e}");
    }
    // too short to even carry the checksum
    let e = decode_data_payload(&[1, 2], true).unwrap_err();
    assert!(e.to_string().contains("too short for its crc32"), "{e}");
    // alignment errors are not crc mismatches
    let e = decode_data_payload(&[0, 1, 2], false).unwrap_err();
    assert!(!is_crc_mismatch(&e), "{e}");
    // a stale-version peer sending un-prefixed LLRs on a crc session
    // fails the checksum (or alignment) check, never panics
    assert!(decode_data_payload(&encode_llrs(&llr), true).is_err());
}

#[test]
fn crc32_reference_vectors() {
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    assert_eq!(crc32(b""), 0);
    assert_eq!(crc32(b"\x00"), 0xD202_EF8D);
}

#[test]
fn udp_datagrams_roundtrip_and_reject_truncation() {
    let mut rng = Rng::new(0x22);
    for n in [0usize, 1, 512] {
        let b = UdpBlock {
            flow: rng.next_u64(),
            seq: rng.next_u64() as u32,
            llr: (0..n).map(|_| rng.next_gaussian() as f32).collect(),
        };
        assert_eq!(UdpBlock::decode(&b.encode()).unwrap(), b);
    }
    for status in [udp_status::OK, udp_status::SHED, udp_status::ERR] {
        let r = UdpReply { flow: 9, seq: 1, status, body: vec![1, 0, 1, 1] };
        assert_eq!(UdpReply::decode(&r.encode()).unwrap(), r);
    }
    // truncated fixed headers are typed errors
    for cut in 0..tcvd::net::protocol::UDP_HEADER {
        let wire = UdpBlock { flow: 1, seq: 2, llr: vec![] }.encode();
        assert!(matches!(UdpBlock::decode(&wire[..cut]), Err(Error::Net(_))), "cut {cut}");
    }
    // a reply needs at least header + status
    let wire = UdpReply { flow: 1, seq: 2, status: 0, body: vec![] }.encode();
    assert!(UdpReply::decode(&wire[..wire.len() - 1]).is_err());
    // misaligned LLR bytes in a block are typed errors
    let mut wire = UdpBlock { flow: 1, seq: 2, llr: vec![1.0] }.encode();
    wire.pop();
    assert!(matches!(UdpBlock::decode(&wire), Err(Error::Net(_))));
    assert!(decode_llrs(&[1, 2, 3]).is_err());
}
