//! Facade-level integration tests: `DecoderBuilder` validation, the
//! TOML -> builder mapping, bit-exact equivalence of the one-shot
//! `Decoder` with the scalar reference, and a serving smoke test — all
//! through `tcvd::api` only.

use std::sync::Arc;

use tcvd::api::{BackendKind, DecoderBuilder};
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::cli::Args;
use tcvd::coding::{registry, trellis::Trellis, Encoder};
use tcvd::coordinator::BackendSpec;
use tcvd::util::rng::Rng;
use tcvd::viterbi::scalar;
use tcvd::Error;

fn args(line: &str) -> Args {
    let argv: Vec<String> = line.split_whitespace().map(String::from).collect();
    Args::parse(&argv).unwrap()
}

fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
    let code = registry::paper_code();
    let mut enc = Encoder::new(code.clone());
    let mut bits = Rng::new(seed).bits(payload_bits - 6);
    bits.extend_from_slice(&[0; 6]);
    let coded = enc.encode(&bits);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0xFACE);
    let rx = ch.transmit(&tx);
    (bits, rx.iter().map(|&x| x as f32).collect())
}

#[test]
fn builder_rejects_bad_code_name() {
    let e = DecoderBuilder::new().code("martian").validate().unwrap_err();
    assert!(matches!(e, Error::Config(_)), "{e}");
    assert!(e.to_string().contains("unknown code"), "{e}");
}

#[test]
fn builder_rejects_zero_workers() {
    let e = DecoderBuilder::new().workers(0).validate().unwrap_err();
    assert!(matches!(e, Error::Config(_)), "{e}");
}

#[test]
fn builder_rejects_queue_smaller_than_batch() {
    let e = DecoderBuilder::new().max_batch(64).queue_depth(4).validate().unwrap_err();
    assert!(e.to_string().contains("queue_depth"), "{e}");
}

#[test]
fn builder_rejects_unknown_backend_and_scheme() {
    assert!(DecoderBuilder::new().backend_name("gpu-magic").is_err());
    let e = DecoderBuilder::new()
        .backend(BackendKind::cpu("radix8"))
        .validate()
        .unwrap_err();
    assert!(e.to_string().contains("packing scheme"), "{e}");
}

#[test]
fn toml_maps_onto_builder() {
    let b = DecoderBuilder::from_toml(
        r#"
code = "ccsds"
backend = "cpu-radix4"

[tile]
payload = 32
head = 16
tail = 16

[coordinator]
max_batch = 8
batch_deadline_us = 500
workers = 3
queue_depth = 32
"#,
    )
    .unwrap();
    let cfg = b.to_coordinator_config();
    assert_eq!(cfg.tile.payload, 32);
    assert_eq!(cfg.tile.frame_stages(), 64);
    assert_eq!(cfg.max_batch, 8);
    assert_eq!(cfg.batch_deadline.as_micros(), 500);
    assert_eq!(cfg.workers, 3);
    assert_eq!(cfg.queue_depth, 32);
    match cfg.backend {
        BackendSpec::CpuPacked { ref scheme, stages, .. } => {
            assert_eq!(scheme, "radix4");
            assert_eq!(stages, 64);
        }
        other => panic!("expected CpuPacked, got {other:?}"),
    }
}

#[test]
fn toml_then_cli_flags_override() {
    let b = DecoderBuilder::from_toml("[coordinator]\nworkers = 3\n")
        .unwrap()
        .apply_flags(&args("decode --workers 5 --payload 128 --backend scalar"))
        .unwrap();
    let cfg = b.to_coordinator_config();
    assert_eq!(cfg.workers, 5);
    assert_eq!(cfg.tile.payload, 128);
    assert!(matches!(cfg.backend, BackendSpec::Scalar { .. }));
}

#[test]
fn bad_flag_values_are_config_errors() {
    let e = DecoderBuilder::new().apply_flags(&args("decode --payload abc")).unwrap_err();
    assert!(matches!(e, Error::Config(_)), "{e}");
}

#[test]
fn decode_frame_matches_scalar_reference_bit_for_bit() {
    let t = Arc::new(Trellis::new(registry::paper_code()));
    let stages = 64;
    // noisy frame, flushed to state 0 at both ends
    let mut payload = Rng::new(17).bits(stages - 6);
    payload.extend_from_slice(&[0; 6]);
    let mut enc = Encoder::new(t.code().clone());
    let coded = enc.encode(&payload);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(4.0, 0.5, 99);
    let rx = ch.transmit(&tx);
    let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();

    // reference: scalar Alg 1 + Alg 2 directly
    let lam0 = scalar::initial_metrics(64, Some(0));
    let want = scalar::decode(&t, &llr, &lam0, Some(0));

    // facade: scalar backend, whole-frame tile
    let mut dec = DecoderBuilder::new()
        .backend(BackendKind::Scalar)
        .tile_dims(stages, 0, 0)
        .build()
        .unwrap();
    let got = dec.decode_frame(&llr, Some(0), Some(0)).unwrap();
    assert_eq!(got, want, "facade scalar decode differs from ScalarDecoder path");
    assert_eq!(got, payload, "4 dB frame should decode clean");
}

#[test]
fn decode_stream_through_facade_matches_payload() {
    let (bits, llr) = noisy_stream(31, 512, 5.0);
    let mut dec = DecoderBuilder::new()
        .backend(BackendKind::cpu("radix4"))
        .tile_dims(64, 32, 32)
        .build()
        .unwrap();
    let got = dec.decode_stream(&llr).unwrap();
    assert_eq!(got, bits);
}

#[test]
fn serve_smoke_on_cpu_backend() {
    let coord = DecoderBuilder::new()
        .backend(BackendKind::cpu("radix4"))
        .tile_dims(32, 16, 16)
        .max_batch(8)
        .batch_deadline_us(300)
        .workers(2)
        .queue_depth(64)
        .serve()
        .unwrap();
    let (bits, llr) = noisy_stream(77, 256, 5.5);
    let out = coord.decode_stream_blocking(&llr).unwrap();
    assert_eq!(out, bits);
    let snap = coord.metrics();
    assert_eq!(snap.frames_in, snap.frames_out);
    coord.shutdown().unwrap();
}

/// A fake artifacts dir with a manifest.json whose frame length
/// disagrees with the tile: the builder must reject the geometry
/// *before* trying to compile anything; with a matching geometry the
/// failure is the (typed) artifact-load error instead.
#[test]
fn artifact_tile_mismatch_is_config_error() {
    let dir = std::env::temp_dir().join(format!("tcvd-api-facade-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = r#"{
  "artifacts": [
    {
      "name": "fake_radix4_b8_s16",
      "path": "fake_radix4_b8_s16.hlo.txt",
      "scheme": "radix4",
      "impl": "jnp",
      "acc": "single",
      "chan": "single",
      "batch": 8,
      "n_steps": 16,
      "rho": 2,
      "gamma": 4,
      "width": 4,
      "n_ops": 1,
      "ops_per_stage": 0.5,
      "renorm_every": 16,
      "k": 7,
      "polys_octal": ["171", "133"],
      "n_states": 64,
      "stages_per_frame": 32
    }
  ]
}"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();

    // default tile is 96 stages; the fake artifact frame is 32
    let e = DecoderBuilder::new()
        .artifacts_dir(&dir)
        .variant("fake_radix4")
        .build()
        .map(|_| ())
        .unwrap_err();
    assert!(matches!(e, Error::Config(_)), "{e}");
    assert!(e.to_string().contains("does not match"), "{e}");

    // matching tile (32 = 16 + 8 + 8): geometry passes, artifact load
    // fails (no HLO / stub runtime) with a typed Artifact error
    let e2 = DecoderBuilder::new()
        .artifacts_dir(&dir)
        .variant("fake_radix4")
        .tile_dims(16, 8, 8)
        .build()
        .map(|_| ())
        .unwrap_err();
    assert!(matches!(e2, Error::Artifact(_)), "{e2}");

    let _ = std::fs::remove_dir_all(&dir);
}
