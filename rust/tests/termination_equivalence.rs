//! Termination-mode equivalence suite (`docs/DECODING-MODES.md`):
//!
//! * every CPU backend (scalar / compact / simd) decodes every
//!   termination mode **bit-identically** on grid LLRs, for random
//!   codes and geometries;
//! * the serving pipeline is shard-invariant for every (backend, mode)
//!   pair across shards {1, 2, 8};
//! * tail-biting recovers the payload at the operating SNR with no
//!   pinned states;
//! * BER sanity: tail-biting beats truncated at equal Eb/N0 on short
//!   blocks (the rate-free protection of the wrapped tail).
//!
//! Noisy-decode assertions use seeds pre-validated against an exact
//! reference simulation of the Rng/AWGN/tiler chain. Shared
//! samplers/stream generators live in `common/corpus.rs`.

use std::sync::Arc;

use tcvd::api::{DecoderBuilder, TerminationMode};
use tcvd::coding::{poly::Code, trellis::Trellis};
use tcvd::viterbi::compact::CompactDecoder;
use tcvd::viterbi::scalar::ScalarDecoder;
use tcvd::viterbi::simd::{Quantizer, SimdDecoder};
use tcvd::viterbi::tiled::{decode_stream, TileConfig};

#[path = "common/corpus.rs"]
mod corpus;

use corpus::mode_stream;

const MODES: [TerminationMode; 3] =
    [TerminationMode::Flushed, TerminationMode::TailBiting, TerminationMode::Truncated];

/// Snap LLRs onto the simd quantization grid, so the integer fast path
/// and the f64 oracle see identical inputs (the simd bit-identity
/// contract; see `docs/PERFORMANCE.md`).
fn to_grid(llr: &[f32], q: Quantizer) -> Vec<f32> {
    corpus::snap(q, llr)
}

/// All three survivor-storage backends decode every mode identically
/// on grid LLRs — random codes, both wrap-heavy and linear geometries.
#[test]
fn backends_bit_identical_for_every_mode() {
    let codes: Vec<(u32, Code)> = vec![
        (3, Code::from_octal(3, &["7", "5"]).unwrap()),
        (5, Code::from_octal(5, &["23", "33"]).unwrap()),
        (7, Code::from_octal(7, &["171", "133"]).unwrap()),
    ];
    let geometries =
        [TileConfig { payload: 32, head: 16, tail: 16 },
         TileConfig { payload: 16, head: 24, tail: 24 }]; // overlap > payload: multi-wrap
    for (k, code) in &codes {
        let t = Arc::new(Trellis::new(code.clone()));
        let quant = Quantizer::for_code(*k, code.beta());
        for cfg in &geometries {
            for mode in MODES {
                for seed in 0..3u64 {
                    // stream spans a whole number of payload tiles for
                    // every mode (flushed spends k-1 stages on the flush)
                    let flush = mode.flush_stages(*k);
                    let data_bits = 4 * cfg.payload - flush;
                    let (_, raw) =
                        mode_stream(code, mode, data_bits, 3.0, 500 + seed, 0x7357);
                    let llr = to_grid(&raw, quant);

                    let mut sdec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
                    let want = decode_stream(&mut sdec, &llr, 2, cfg, mode).unwrap();

                    let mut cdec = CompactDecoder::new(t.clone(), cfg.frame_stages());
                    let got_c = decode_stream(&mut cdec, &llr, 2, cfg, mode).unwrap();
                    assert_eq!(
                        got_c, want,
                        "k={k} mode={mode} payload={} seed={seed}: compact != scalar",
                        cfg.payload
                    );

                    let mut qdec = SimdDecoder::new(t.clone(), cfg.frame_stages(), 0);
                    let got_q = decode_stream(&mut qdec, &llr, 2, cfg, mode).unwrap();
                    assert_eq!(
                        got_q, want,
                        "k={k} mode={mode} payload={} seed={seed}: simd != scalar",
                        cfg.payload
                    );

                    // the radix-2 super-branch kernel shares the same
                    // grid for these codes, so it must match the same
                    // scalar reference under every mode too
                    let mut qdec2 =
                        SimdDecoder::with_radix(t.clone(), cfg.frame_stages(), 0, 2);
                    assert_eq!(qdec2.quantizer(), quant, "k={k}: rho=2 grid drifted");
                    let got_q2 = decode_stream(&mut qdec2, &llr, 2, cfg, mode).unwrap();
                    assert_eq!(
                        got_q2, want,
                        "k={k} mode={mode} payload={} seed={seed}: simd radix-2 != scalar",
                        cfg.payload
                    );
                }
            }
        }
    }
}

/// The serving pipeline decodes every (backend, mode) pair
/// bit-identically across shards {1, 2, 8} — the acceptance pin for
/// `tcvd --backend {scalar,compact,simd} --termination tail-biting`.
#[test]
fn pipeline_shard_invariant_per_backend_and_mode() {
    let code = tcvd::coding::registry::paper_code();
    let t = Arc::new(Trellis::new(code.clone()));
    let cfg = TileConfig { payload: 32, head: 16, tail: 16 };
    let quant = Quantizer::for_code(code.k(), code.beta());
    for mode in MODES {
        let flush = mode.flush_stages(code.k());
        let (_, raw) = mode_stream(&code, mode, 256 - flush, 5.0, 77, 0xC0DE);
        let llr = to_grid(&raw, quant);
        // one-shot scalar reference (same grid inputs)
        let mut sdec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
        let want = decode_stream(&mut sdec, &llr, 2, &cfg, mode).unwrap();

        for backend in ["scalar", "compact", "simd"] {
            for shards in [1usize, 2, 8] {
                let coord = DecoderBuilder::new()
                    .backend_name(backend)
                    .unwrap()
                    .tile(cfg)
                    .termination(mode)
                    .shards(shards)
                    .workers(2)
                    .max_batch(4)
                    .batch_deadline_us(100)
                    .queue_depth(64)
                    .serve()
                    .unwrap();
                assert_eq!(coord.termination(), mode);
                let got = coord.decode_stream_blocking(&llr).unwrap();
                assert_eq!(
                    got, want,
                    "{backend} mode={mode} shards={shards}: pipeline output diverged"
                );
                coord.shutdown().unwrap();
            }
        }
    }
}

/// Tail-biting blocks decode to the exact payload at the operating SNR
/// with *no* pinned trellis states (seeds pre-validated, 5 dB, 256-bit
/// blocks on the generous CPU tile).
#[test]
fn tail_biting_recovers_payload_at_operating_snr() {
    let code = tcvd::coding::registry::paper_code();
    for backend in ["scalar", "compact"] {
        let mut dec = DecoderBuilder::new()
            .backend_name(backend)
            .unwrap()
            .tile_dims(64, 32, 32)
            .termination(TerminationMode::TailBiting)
            .shards(1)
            .build()
            .unwrap();
        for seed in 1204..1208u64 {
            let (bits, llr) =
                mode_stream(&code, TerminationMode::TailBiting, 256, 5.0, seed, 0x7B17);
            let got = dec.decode_stream(&llr).unwrap();
            assert_eq!(got, bits, "{backend} seed {seed}: 5 dB tail-biting block decodes clean");
        }
    }
}

/// BER sanity at equal Eb/N0 on short blocks: the circularly-protected
/// tail-biting tail beats plain truncation by a wide margin (2.5 dB,
/// 64-bit blocks; the reference simulation measured 3 vs 67 bit errors
/// for these seeds).
#[test]
fn tail_biting_beats_truncated_at_equal_ebn0() {
    let code = tcvd::coding::registry::paper_code();
    let t = Arc::new(Trellis::new(code.clone()));
    let cfg = TileConfig { payload: 64, head: 32, tail: 32 };
    let mut dec = ScalarDecoder::new(t, cfg.frame_stages());
    let mut errors = |mode: TerminationMode| -> usize {
        let mut errs = 0usize;
        for i in 0..80u64 {
            let (bits, llr) = mode_stream(&code, mode, 64, 2.5, 9000 + i, 0x7E57);
            let got = decode_stream(&mut dec, &llr, 2, &cfg, mode).unwrap();
            errs += got.iter().zip(&bits).filter(|(a, b)| a != b).count();
        }
        errs
    };
    let tb = errors(TerminationMode::TailBiting);
    let tr = errors(TerminationMode::Truncated);
    assert!(tr > 15, "truncated short blocks must show tail errors at 2.5 dB (got {tr})");
    assert!(
        tb * 3 < tr,
        "tail-biting ({tb} errors) must clearly beat truncated ({tr} errors) at equal Eb/N0"
    );
}
