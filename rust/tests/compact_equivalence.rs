//! `BackendKind::Compact` equivalence suite: the bit-packed survivor
//! backend must decode **bit-identically** to the scalar reference for
//! every code, tile geometry and shard count, while its metrics
//! snapshot reports the 32x-smaller resident survivor memory that
//! `docs/MEMORY.md` budgets. Shared samplers/oracle live in
//! `common/corpus.rs`.

use std::sync::Arc;

use tcvd::api::{BackendKind, DecoderBuilder};
use tcvd::coding::poly::Code;
use tcvd::util::check::{forall, gen};
use tcvd::util::rng::Rng;
use tcvd::viterbi::compact::{forward_compact, CompactDecoder, CompactSurvivors};
use tcvd::viterbi::scalar::{self, ScalarDecoder};
use tcvd::coding::TerminationMode;
use tcvd::viterbi::tiled::decode_stream;
use tcvd::viterbi::traceback::traceback_compact;

#[path = "common/corpus.rs"]
mod corpus;

/// The channel-noise decorrelation constant this suite has always used
/// (pre-validated noisy-decode seeds depend on it).
const SEED_XOR: u64 = 0xC0DE;

fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
    corpus::noisy_stream(seed, payload_bits, ebn0, SEED_XOR)
}

/// The packed forward + traceback equals the scalar oracle on random
/// valid codes (not just the paper's), over generic continuous LLRs —
/// including state counts that do not fill a 64-bit word.
#[test]
fn prop_compact_matches_scalar_for_random_codes() {
    forall(
        0xC0117AC7,
        24,
        |r: &mut Rng| {
            let (k, polys) = corpus::sample_code(r);
            let llr = gen::llrs(r, 48 * polys.len(), 1.4);
            (k, polys, llr)
        },
        |(k, polys, llr)| {
            let code = Code::new(*k, polys.clone()).map_err(|e| e.to_string())?;
            let s_count = code.n_states();
            let t = tcvd::coding::trellis::Trellis::new(code);
            let oracle = corpus::oracle_decode(&t, llr, None, None);
            let lam0 = scalar::initial_metrics(s_count, None);
            let (surv, lam) = forward_compact(&t, llr, &lam0);
            let out = traceback_compact(&t, &surv, &lam, None);
            if out != oracle {
                return Err(format!("compact decode diverged (k={k}, S={s_count})"));
            }
            let scalar_bytes = oracle.len() * s_count * std::mem::size_of::<u32>();
            let packed = CompactSurvivors::words_per_step(s_count, 1) * 8 * oracle.len();
            if surv.bytes() != packed {
                return Err(format!("{} survivor bytes, expected {packed}", surv.bytes()));
            }
            // always strictly below the u32-per-state scalar layout
            // (32x when states fill whole 64-bit words)
            if surv.bytes() >= scalar_bytes {
                return Err("compact store not smaller than scalar".into());
            }
            Ok(())
        },
    );
}

/// Streamed decoding through the reference tiler: compact equals scalar
/// for random tile geometries (head/tail 0 included) on noisy streams.
#[test]
fn prop_compact_matches_scalar_across_tile_geometries() {
    forall(
        0x7115,
        12,
        |r: &mut Rng| {
            let cfg = corpus::sample_tile(r);
            let frames = 2 + r.next_below(3) as usize;
            (cfg, frames, r.next_u64())
        },
        |&(cfg, frames, seed)| {
            let t = corpus::paper_trellis();
            let (_, llr) = noisy_stream(seed % 100_000, cfg.payload * frames, 2.5);
            let mut sdec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
            let want = decode_stream(&mut sdec, &llr, 2, &cfg, TerminationMode::Flushed)
                .map_err(|e| e.to_string())?;
            let mut cdec = CompactDecoder::new(t, cfg.frame_stages());
            let got = decode_stream(&mut cdec, &llr, 2, &cfg, TerminationMode::Flushed)
                .map_err(|e| e.to_string())?;
            if got == want {
                Ok(())
            } else {
                Err(format!("tile {cfg:?}: compact stream decode diverged"))
            }
        },
    );
}

fn run_backend_sessions(backend: BackendKind, shards: usize, n_sessions: usize)
                        -> (Vec<Vec<u8>>, u64) {
    let coord = Arc::new(
        DecoderBuilder::new()
            .backend(backend)
            .tile_dims(32, 16, 16)
            .shards(shards)
            .workers(2)
            .max_batch(8)
            .batch_deadline_us(200)
            .queue_depth(256)
            .serve()
            .unwrap(),
    );
    let mut joins = Vec::new();
    for s in 0..n_sessions {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            let (_, llr) = noisy_stream(6000 + s as u64, 256 + 32 * (s % 3), 5.5);
            let mut session = c.open_session().unwrap();
            for chunk in llr.chunks(70) {
                session.push(chunk).unwrap();
            }
            session.finish_and_collect().unwrap()
        }));
    }
    let outs: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let peak = coord.metrics().survivor_bytes_peak();
    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    coord.shutdown().unwrap();
    (outs, peak)
}

/// The coordinator serving path: Compact output is invariant across
/// shard counts and identical to the scalar backend, and the per-shard
/// survivor-bytes gauge reports the bit-packed footprint (32x below the
/// scalar layout for the same geometry).
#[test]
fn compact_shard_invariance_and_survivor_gauge() {
    let n_sessions = 4;
    let (scalar_outs, scalar_peak) = run_backend_sessions(BackendKind::Scalar, 1, n_sessions);
    // 64-stage frames, 64 states: scalar stores u32 per (stage, state)
    assert_eq!(scalar_peak, 64 * 64 * 4, "scalar survivor bytes per frame");
    let mut compact_peak_seen = 0;
    for shards in [1usize, 2, 8] {
        let (outs, peak) = run_backend_sessions(BackendKind::Compact, shards, n_sessions);
        assert_eq!(
            outs, scalar_outs,
            "{shards}-shard compact output differs from the scalar reference"
        );
        // max_batch is clamped to the backend's (1), so the gauge holds
        // exactly one frame: 64 stages x 64 states / 8 bits per byte
        assert_eq!(peak, 64 * 64 / 8, "shards={shards}: compact survivor gauge");
        compact_peak_seen = peak;
    }
    assert_eq!(scalar_peak, 32 * compact_peak_seen, "compact is 32x smaller");
}
