//! Cross-layer integration: the AOT artifact executed through PJRT must
//! produce the same decodes as the Rust CPU mirrors and the scalar
//! oracle. Requires `make artifacts` (skips cleanly if absent).

use std::path::Path;
use std::sync::Arc;

use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{registry, trellis::Trellis, Encoder};
use tcvd::runtime::{client, Artifact, ArtifactDecoder, Manifest};
use tcvd::util::half::HalfKind;
use tcvd::util::rng::Rng;
use tcvd::viterbi::packed::presets;
use tcvd::viterbi::scalar;
use tcvd::viterbi::types::{FrameDecoder, FrameJob};

fn manifest() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e}); run `make artifacts`");
            None
        }
    }
}

fn noisy_frames(seed: u64, n_frames: usize, stages: usize, ebn0: f64) -> Vec<(Vec<u8>, Vec<f32>)> {
    let code = registry::paper_code();
    let mut out = Vec::new();
    for f in 0..n_frames {
        let mut enc = Encoder::new(code.clone());
        let mut bits = Rng::new(seed + f as u64).bits(stages - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ (f as u64 * 7919));
        let rx = ch.transmit(&tx);
        out.push((bits, rx.iter().map(|&x| x as f32).collect()));
    }
    out
}

fn jobs_from(frames: &[(Vec<u8>, Vec<f32>)], stages: usize) -> Vec<FrameJob> {
    frames
        .iter()
        .map(|(_, llr)| FrameJob {
            llr: llr.clone(),
            start_state: Some(0),
            end_state: Some(0),
            emit_from: 0,
            emit_len: stages,
        })
        .collect()
}

#[test]
fn artifact_matches_cpu_radix4_and_scalar() {
    let Some(m) = manifest() else { return };
    let meta = m.find("radix4_jnp_acc-single_ch-single_b8_s32").unwrap().clone();
    let cl = client::cpu_client().unwrap();
    let artifact = Arc::new(Artifact::load(&cl, &m, &meta).unwrap());
    let trellis = Arc::new(Trellis::new(artifact.code().unwrap()));
    let stages = meta.stages_per_frame;

    let frames = noisy_frames(11, meta.batch, stages, 4.0);
    let jobs = jobs_from(&frames, stages);

    let mut pjrt = ArtifactDecoder::new(artifact, trellis.clone());
    let out_pjrt = pjrt.decode_batch(&jobs);

    let mut cpu = presets::radix4(trellis.clone(), stages);
    let out_cpu = cpu.decode_batch(&jobs);

    for (i, ((bits, llr), (a, b))) in frames.iter().zip(out_pjrt.iter().zip(&out_cpu)).enumerate()
    {
        assert_eq!(a, b, "frame {i}: artifact vs cpu-radix4 disagree");
        assert_eq!(a, bits, "frame {i}: decode error at 4 dB");
        // scalar oracle on bf16-rounded LLRs (B matrix is half)
        let llr_h: Vec<f32> = llr.iter().map(|&x| HalfKind::Bf16.round(x)).collect();
        let lam0 = scalar::initial_metrics(64, Some(0));
        let oracle = scalar::decode(&trellis, &llr_h, &lam0, Some(0));
        assert_eq!(a, &oracle, "frame {i}: artifact vs scalar oracle disagree");
    }
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    let Some(m) = manifest() else { return };
    let cl = client::cpu_client().unwrap();
    let meta_j = m.find("radix4_jnp_acc-single_ch-single_b8_s32").unwrap().clone();
    let meta_p = m.find("radix4_pallas_acc-single_ch-single_b8_s32").unwrap().clone();
    let a_j = Arc::new(Artifact::load(&cl, &m, &meta_j).unwrap());
    let a_p = Arc::new(Artifact::load(&cl, &m, &meta_p).unwrap());
    let trellis = Arc::new(Trellis::new(a_j.code().unwrap()));
    let stages = meta_j.stages_per_frame;

    let frames = noisy_frames(23, meta_j.batch, stages, 3.0);
    let jobs = jobs_from(&frames, stages);
    let out_j = ArtifactDecoder::new(a_j, trellis.clone()).decode_batch(&jobs);
    let out_p = ArtifactDecoder::new(a_p, trellis).decode_batch(&jobs);
    assert_eq!(out_j, out_p, "pallas and jnp artifacts must decode identically");
}

#[test]
fn half_accumulator_artifact_loads_and_decodes() {
    let Some(m) = manifest() else { return };
    let Ok(meta) = m.find("radix4_jnp_acc-half_ch-half_b64_s48") else {
        eprintln!("SKIP: half artifact not built");
        return;
    };
    let meta = meta.clone();
    let cl = client::cpu_client().unwrap();
    let artifact = Arc::new(Artifact::load(&cl, &m, &meta).unwrap());
    let trellis = Arc::new(Trellis::new(artifact.code().unwrap()));
    let stages = meta.stages_per_frame;

    // easy SNR: half accumulate must still decode clean frames
    let frames = noisy_frames(31, 8, stages, 7.0);
    let mut jobs = jobs_from(&frames, stages);
    jobs.truncate(8);
    let mut dec = ArtifactDecoder::new(artifact, trellis);
    let out = dec.decode_batch(&jobs);
    for (i, ((bits, _), got)) in frames.iter().zip(&out).enumerate() {
        assert_eq!(got, bits, "frame {i}: half-acc artifact failed at 7 dB");
    }
}

#[test]
fn radix2_artifact_matches_cpu_radix2() {
    let Some(m) = manifest() else { return };
    let meta = m.find("radix2_jnp_acc-single_ch-single_b64_s96").unwrap().clone();
    let cl = client::cpu_client().unwrap();
    let artifact = Arc::new(Artifact::load(&cl, &m, &meta).unwrap());
    let trellis = Arc::new(Trellis::new(artifact.code().unwrap()));
    let stages = meta.stages_per_frame;

    let frames = noisy_frames(41, 16, stages, 4.0);
    let jobs = jobs_from(&frames, stages);
    let out_pjrt = ArtifactDecoder::new(artifact, trellis.clone()).decode_batch(&jobs);
    let out_cpu = presets::radix2(trellis, stages).decode_batch(&jobs);
    assert_eq!(out_pjrt, out_cpu);
}

#[test]
fn batch_padding_is_harmless() {
    // decoding 3 jobs through a batch-8 artifact must equal full batches
    let Some(m) = manifest() else { return };
    let meta = m.find("radix4_jnp_acc-single_ch-single_b8_s32").unwrap().clone();
    let cl = client::cpu_client().unwrap();
    let artifact = Arc::new(Artifact::load(&cl, &m, &meta).unwrap());
    let trellis = Arc::new(Trellis::new(artifact.code().unwrap()));
    let stages = meta.stages_per_frame;

    let frames = noisy_frames(53, 3, stages, 4.0);
    let jobs = jobs_from(&frames, stages);
    let mut dec = ArtifactDecoder::new(artifact, trellis);
    let out_small = dec.decode_batch(&jobs);
    for (i, ((bits, _), got)) in frames.iter().zip(&out_small).enumerate() {
        assert_eq!(got, bits, "padded-batch frame {i}");
    }
}
