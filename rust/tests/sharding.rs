//! Sharded-serving test suite: per-session output must be bit-exact,
//! in order, and invariant across shard counts under interleaved
//! multi-session load; per-shard metrics must sum to the session
//! totals; idle shards must steal work from a backlogged sibling.

use std::sync::Arc;

use tcvd::api::{BackendKind, DecoderBuilder, TerminationMode};
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{registry, Encoder};
use tcvd::coordinator::Coordinator;
use tcvd::util::rng::Rng;

fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
    let code = registry::paper_code();
    let mut enc = Encoder::new(code.clone());
    let mut bits = Rng::new(seed).bits(payload_bits - 6);
    bits.extend_from_slice(&[0; 6]);
    let coded = enc.encode(&bits);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0xD15);
    let rx = ch.transmit(&tx);
    (bits, rx.iter().map(|&x| x as f32).collect())
}

fn session_payload_bits(s: usize) -> usize {
    256 + 64 * (s % 3)
}

fn coordinator(shards: usize) -> Coordinator {
    DecoderBuilder::new()
        .backend(BackendKind::cpu("radix4"))
        .tile_dims(32, 16, 16)
        .shards(shards)
        .workers(2)
        .max_batch(8)
        .batch_deadline_us(200)
        .queue_depth(256)
        .serve()
        .unwrap()
}

/// Interleaved multi-session load: every session streams odd-sized LLR
/// chunks from its own thread. Returns each session's in-order decoded
/// payload; checks the metrics-consistency invariants on the way out.
fn run_sessions(shards: usize, n_sessions: usize) -> Vec<Vec<u8>> {
    let coord = Arc::new(coordinator(shards));
    let mut joins = Vec::new();
    for s in 0..n_sessions {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            let (_, llr) = noisy_stream(4000 + s as u64, session_payload_bits(s), 6.0);
            let mut session = c.open_session().unwrap();
            for chunk in llr.chunks(50) {
                // 25-stage chunks: exercises partial-frame buffering
                session.push(chunk).unwrap();
            }
            session.finish_and_collect().unwrap()
        }));
    }
    let outs: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    let snap = coord.metrics();
    assert_eq!(snap.frames_in, snap.frames_out, "shards={shards}: frames lost");
    assert_eq!(snap.shards.len(), shards, "one counter block per shard");
    let shard_frames: u64 = snap.shards.iter().map(|sh| sh.frames).sum();
    assert_eq!(
        shard_frames, snap.frames_out,
        "shards={shards}: per-shard frame counters must sum to the session total"
    );
    let shard_execs: u64 = snap.shards.iter().map(|sh| sh.execs).sum();
    assert_eq!(
        shard_execs, snap.execs,
        "shards={shards}: per-shard exec counters must sum to the global count"
    );
    for (i, sh) in snap.shards.iter().enumerate() {
        assert!(
            sh.frames > 0 || sh.throughput_mbps == 0.0,
            "shards={shards}: shard {i} reports throughput without decoding: {sh:?}"
        );
    }
    assert!(
        snap.shards.iter().any(|sh| sh.throughput_mbps > 0.0),
        "shards={shards}: no shard reports forward throughput: {:?}",
        snap.shards
    );

    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    coord.shutdown().unwrap();
    outs
}

#[test]
fn shard_counts_agree_bit_exactly() {
    let n_sessions = 6;
    let baseline = run_sessions(1, n_sessions);
    // the decoded payload is the transmitted payload, in order
    for (s, out) in baseline.iter().enumerate() {
        let (bits, _) = noisy_stream(4000 + s as u64, session_payload_bits(s), 6.0);
        assert_eq!(out, &bits, "session {s} output differs from its payload");
    }
    // shard count must never change any session's output
    for shards in [2usize, 8] {
        let outs = run_sessions(shards, n_sessions);
        assert_eq!(outs, baseline, "{shards} shards changed decoded output");
    }
}

#[test]
fn idle_shards_steal_from_a_backlogged_home_shard() {
    // one hot session (every frame hashes to the same home shard), four
    // shards, one frame per execution: the idle shards must pick up the
    // backlog via work-stealing, and the output must stay bit-exact.
    let coord = DecoderBuilder::new()
        .backend(BackendKind::cpu("radix4"))
        .tile_dims(32, 16, 16)
        .shards(4)
        .workers(2)
        .max_batch(1)
        .batch_deadline_us(0)
        .queue_depth(512)
        .serve()
        .unwrap();
    assert_eq!(coord.shards(), 4);
    let (bits, llr) = noisy_stream(9999, 4096, 6.0);
    let out = coord.decode_stream_blocking(&llr).unwrap();
    assert_eq!(out, bits);
    let snap = coord.metrics();
    assert!(
        snap.steals_total() > 0,
        "idle shards never stole from the backlogged home shard: {:?}",
        snap.shards
    );
    let active = snap.shards.iter().filter(|sh| sh.frames > 0).count();
    assert!(active > 1, "all work stayed on one shard: {:?}", snap.shards);
    coord.shutdown().unwrap();
}

#[test]
fn sharded_one_shot_decoder_matches_single_lane() {
    let (bits, llr) = noisy_stream(555, 2048, 5.5);
    let builder = DecoderBuilder::new()
        .backend(BackendKind::cpu("radix4"))
        .tile_dims(64, 32, 32);
    let reference = builder.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(reference, bits);
    for lanes in [2usize, 3, 8] {
        let got =
            builder.clone().shards(lanes).build().unwrap().decode_stream(&llr).unwrap();
        assert_eq!(got, reference, "{lanes}-lane one-shot decode diverged");
    }
}

/// Tail-biting sessions through the compact backend: wrapped frames
/// are exactly `head + payload + tail` stages, so they must fill — and
/// never overflow — the frame-bounded `DecisionRing`, and the
/// `survivor_bytes` / `throughput_mbps` gauges must be live and exact
/// under circular framing. Outputs stay bit-exact and shard-invariant.
#[test]
fn tail_biting_sessions_exercise_survivor_and_throughput_gauges() {
    // seeds pre-validated against the exact-chain reference simulation:
    // every session decodes error-free at 6 dB on this geometry
    fn tb_stream(seed: u64, data_bits: usize) -> (Vec<u8>, Vec<f32>) {
        let code = registry::paper_code();
        let bits = Rng::new(seed).bits(data_bits);
        let mut enc = Encoder::new(code.clone());
        let coded = enc.encode_tail_biting(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(6.0, code.rate(), seed ^ 0xD15);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }
    let mut baseline: Option<Vec<Vec<u8>>> = None;
    for shards in [1usize, 2, 8] {
        let coord = Arc::new(
            DecoderBuilder::new()
                .backend(BackendKind::Compact)
                .tile_dims(32, 16, 16)
                .termination(TerminationMode::TailBiting)
                .shards(shards)
                .workers(2)
                .max_batch(8)
                .batch_deadline_us(200)
                .queue_depth(256)
                .serve()
                .unwrap(),
        );
        let mut joins = Vec::new();
        for s in 0..6usize {
            let c = coord.clone();
            joins.push(std::thread::spawn(move || {
                let (bits, llr) = tb_stream(4100 + s as u64, 256 + 64 * (s % 3));
                let mut session = c.open_session().unwrap();
                for chunk in llr.chunks(50) {
                    session.push(chunk).unwrap();
                }
                let out = session.finish_and_collect().unwrap();
                assert_eq!(out, bits, "session {s}: tail-biting payload mismatch");
                out
            }));
        }
        let outs: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        match &baseline {
            None => baseline = Some(outs),
            Some(b) => assert_eq!(&outs, b, "{shards} shards changed tail-biting output"),
        }

        let snap = coord.metrics();
        assert_eq!(snap.frames_in, snap.frames_out, "shards={shards}: frames lost");
        // the compact survivor store of one wrapped 64-stage frame is
        // 64 stages x ceil(64 states / 64) words x 8 bytes = 512 bytes;
        // the gauge is a per-exec high-water mark over whole batches,
        // so it must be a nonzero multiple of that frame size (a frame
        // larger than the ring would have panicked the engine shard)
        let frame_bytes = 64 * 8;
        let peak = snap.survivor_bytes_peak() as usize;
        assert!(peak >= frame_bytes, "shards={shards}: survivor gauge never fed ({peak})");
        assert_eq!(peak % frame_bytes, 0, "shards={shards}: peak {peak} not whole frames");
        assert!(peak <= 8 * frame_bytes, "shards={shards}: peak {peak} exceeds max_batch");
        // forward-throughput EWMA must be live on every shard that
        // decoded frames, and on no shard that did not
        for (i, sh) in snap.shards.iter().enumerate() {
            if sh.frames > 0 {
                assert!(
                    sh.throughput_mbps > 0.0,
                    "shards={shards}: shard {i} decoded tail-biting frames but gauge is dead"
                );
            } else {
                assert_eq!(sh.throughput_mbps, 0.0, "shards={shards}: idle shard {i} non-zero");
            }
        }
        assert!(snap.shards.iter().any(|sh| sh.frames > 0));

        let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
        coord.shutdown().unwrap();
    }
}

#[test]
fn session_metrics_expose_shard_counters() {
    let coord = coordinator(2);
    let (_, llr) = noisy_stream(31, 512, 6.0);
    let mut session = coord.open_session().unwrap();
    session.push(&llr).unwrap();
    let snap = session.metrics();
    assert_eq!(snap.shards.len(), 2, "session metrics must carry per-shard counters");
    session.finish().unwrap();
    for _ in session {}
    let snap = coord.metrics();
    let shard_frames: u64 = snap.shards.iter().map(|sh| sh.frames).sum();
    assert_eq!(shard_frames, snap.frames_out);
    // the JSON view carries the shard array for dashboards
    let json = snap.to_json().to_string_pretty();
    assert!(json.contains("\"shards\""), "{json}");
    assert!(json.contains("steals"), "{json}");
    assert!(json.contains("throughput_mbps"), "{json}");
    // the workload drained, so at least one shard decoded frames and
    // its forward-throughput EWMA gauge must be live
    assert!(
        snap.shards.iter().any(|sh| sh.throughput_mbps > 0.0),
        "no shard reports forward throughput: {:?}",
        snap.shards
    );
    coord.shutdown().unwrap();
}
