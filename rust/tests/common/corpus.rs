//! Shared test corpus for the backend-equivalence suites
//! (`simd_equivalence`, `compact_equivalence`,
//! `termination_equivalence`): seeded code/geometry samplers, encoded
//! noisy-stream generators, grid snapping and the scalar f64 oracle.
//!
//! Each suite includes this file with
//! `#[path = "common/corpus.rs"] mod corpus;` — it is **not** a test
//! target of its own. Samplers draw from the caller's `Rng` in a fixed
//! order, so the suites keep their historical pre-validated seed
//! streams.
#![allow(dead_code)]

use std::sync::Arc;

use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{poly::Code, registry, trellis::Trellis, Encoder, TerminationMode};
use tcvd::util::rng::Rng;
use tcvd::viterbi::scalar;
use tcvd::viterbi::simd::Quantizer;
use tcvd::viterbi::tiled::TileConfig;

/// Sample a random valid code: constraint length k in 4..8 (8..128
/// states), 2..3 polynomials with the MSB and LSB taps forced on (so
/// every poly spans the full constraint length — the class the
/// samplers always drew). Draw order: k, beta, then each poly.
pub fn sample_code(r: &mut Rng) -> (u32, Vec<u32>) {
    let k = 4 + r.next_below(5) as u32;
    let beta = 2 + r.next_below(2) as usize;
    let polys: Vec<u32> = (0..beta)
        .map(|_| {
            let msb = 1u32 << (k - 1);
            (r.next_u64() as u32 & (msb - 1)) | msb | 1
        })
        .collect();
    (k, polys)
}

/// [`sample_code`] materialized into a `Code` (the sampler's taps are
/// always valid, so this cannot fail).
pub fn sample_code_built(r: &mut Rng) -> Code {
    let (k, polys) = sample_code(r);
    Code::new(k, polys).expect("sampled taps are valid")
}

/// Sample a tile geometry: payload {16, 32, 64}, head/tail
/// {0, 8, 17, 32} (zero-overlap and overlap > payload both included).
/// Draw order: payload, head, tail.
pub fn sample_tile(r: &mut Rng) -> TileConfig {
    let payload = [16usize, 32, 64][r.next_below(3) as usize];
    let head = [0usize, 8, 17, 32][r.next_below(4) as usize];
    let tail = [0usize, 8, 17, 32][r.next_below(4) as usize];
    TileConfig { payload, head, tail }
}

/// Encode `payload_bits` of the paper code (last 6 forced to the zero
/// flush) and push through BPSK + AWGN at `ebn0`. `seed_xor`
/// decorrelates the channel noise from the payload draw — each suite
/// keeps its historical constant so pre-validated seeds stay valid.
pub fn noisy_stream(
    seed: u64,
    payload_bits: usize,
    ebn0: f64,
    seed_xor: u64,
) -> (Vec<u8>, Vec<f32>) {
    let code = registry::paper_code();
    let mut enc = Encoder::new(code.clone());
    let mut bits = Rng::new(seed).bits(payload_bits - 6);
    bits.extend_from_slice(&[0; 6]);
    let coded = enc.encode(&bits);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ seed_xor);
    let rx = ch.transmit(&tx);
    (bits, rx.iter().map(|&x| x as f32).collect())
}

/// Encode `data_bits` info bits under `mode` and return (payload,
/// noisy LLR stream) spanning exactly `data_bits + flush` trellis
/// stages.
pub fn mode_stream(
    code: &Code,
    mode: TerminationMode,
    data_bits: usize,
    ebn0: f64,
    seed: u64,
    seed_xor: u64,
) -> (Vec<u8>, Vec<f32>) {
    let bits = Rng::new(seed).bits(data_bits);
    let mut enc = Encoder::new(code.clone());
    let (coded, _) = enc.encode_terminated(&bits, mode);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(ebn0, code.rate(), seed ^ seed_xor);
    let rx = ch.transmit(&tx);
    (bits, rx.iter().map(|&x| x as f32).collect())
}

/// Snap LLRs onto the quantization grid, so the scalar f64 oracle sees
/// exactly the channel values the i16 path accumulates (the simd
/// bit-identity contract; see `docs/PERFORMANCE.md`).
pub fn snap(q: Quantizer, llr: &[f32]) -> Vec<f32> {
    llr.iter().map(|&x| q.dequantize(q.quantize(x))).collect()
}

/// Run the scalar f64 oracle over one frame: initial metrics per
/// `start` (None = uniform), full forward + traceback ending at `end`
/// (None = argmax). This is the reference every backend must match
/// bit-for-bit.
pub fn oracle_decode(
    t: &Trellis,
    llr: &[f32],
    start: Option<u32>,
    end: Option<u32>,
) -> Vec<u8> {
    let lam0 = scalar::initial_metrics(t.code().n_states(), start);
    scalar::decode(t, llr, &lam0, end)
}

/// A trellis over the paper's (2,1,7) code, shared-pointer form.
pub fn paper_trellis() -> Arc<Trellis> {
    Arc::new(Trellis::new(registry::paper_code()))
}
