//! End-to-end coordinator pipeline tests over the PJRT artifact backend
//! (skip cleanly when artifacts are absent) plus stress tests on the CPU
//! backend: many sessions, chunked pushes, backpressure.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{registry, Encoder, TerminationMode};
use tcvd::coordinator::server::CoordinatorConfig;
use tcvd::coordinator::{BackendSpec, Coordinator};
use tcvd::util::rng::Rng;
use tcvd::viterbi::tiled::TileConfig;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: run `make artifacts`");
        None
    }
}

fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
    let code = registry::paper_code();
    let mut enc = Encoder::new(code.clone());
    let mut bits = Rng::new(seed).bits(payload_bits - 6);
    bits.extend_from_slice(&[0; 6]);
    let coded = enc.encode(&bits);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0xFEED);
    let rx = ch.transmit(&tx);
    (bits, rx.iter().map(|&x| x as f32).collect())
}

#[test]
fn pjrt_pipeline_decodes_multisession_workload() {
    let Some(dir) = artifacts_dir() else { return };
    let tile = TileConfig { payload: 64, head: 16, tail: 16 }; // 96 = b64_s48 frame
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendSpec::artifact(dir, "radix4_jnp_acc-single_ch-single_b64_s48"),
            tile,
            max_batch: 64,
            batch_deadline: Duration::from_micros(500),
            workers: 2,
            queue_depth: 512,
            shards: 2,
            termination: TerminationMode::Flushed,
        })
        .unwrap(),
    );
    let mut joins = Vec::new();
    for s in 0..6u64 {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            let (bits, llr) = noisy_stream(1000 + s, 4096, 5.0);
            let out = c.decode_stream_blocking(&llr).unwrap();
            assert_eq!(out.len(), bits.len());
            let errors = out.iter().zip(&bits).filter(|(a, b)| a != b).count();
            assert_eq!(errors, 0, "session {s}: {errors} errors at 5 dB");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    let snap = coord.metrics();
    assert_eq!(snap.frames_in, snap.frames_out);
    assert!(snap.mean_batch > 1.0, "batching never amortized: {}", snap.mean_batch);
    coord.shutdown().unwrap();
}

#[test]
fn cpu_pipeline_survives_many_small_sessions() {
    let tile = TileConfig { payload: 32, head: 16, tail: 16 };
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendSpec::CpuPacked {
                code: "ccsds".into(),
                scheme: "radix4".into(),
                stages: tile.frame_stages(),
                acc: tcvd::viterbi::AccPrecision::Single,
                chan: tcvd::channel::quantize::ChannelPrecision::Single,
                renorm_every: 16,
            },
            tile,
            max_batch: 16,
            batch_deadline: Duration::from_micros(200),
            workers: 3,
            queue_depth: 64,
            shards: 2,
            termination: TerminationMode::Flushed,
        })
        .unwrap(),
    );
    let mut joins = Vec::new();
    for s in 0..16u64 {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            let (bits, llr) = noisy_stream(2000 + s, 64 + 32 * (s as usize % 5), 6.0);
            let out = c.decode_stream_blocking(&llr).unwrap();
            assert_eq!(out, bits, "session {s}");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    coord.shutdown().unwrap();
}

#[test]
fn backpressure_blocks_but_does_not_lose_frames() {
    // tiny queue + slow deadline: pushes must block, never drop
    let tile = TileConfig { payload: 32, head: 8, tail: 8 };
    let coord = Coordinator::start(CoordinatorConfig {
        backend: BackendSpec::Scalar { code: "ccsds".into(), stages: tile.frame_stages() },
        tile,
        max_batch: 2,
        batch_deadline: Duration::from_micros(50),
        workers: 1,
        queue_depth: 2,
        shards: 1,
        termination: TerminationMode::Flushed,
    })
    .unwrap();
    let (bits, llr) = noisy_stream(77, 2048, 6.0);
    let out = coord.decode_stream_blocking(&llr).unwrap();
    assert_eq!(out, bits);
    let snap = coord.metrics();
    assert_eq!(snap.frames_in, snap.frames_out);
    coord.shutdown().unwrap();
}

#[test]
fn metrics_accumulate_sanely() {
    let tile = TileConfig { payload: 64, head: 16, tail: 16 };
    let coord = Coordinator::start(CoordinatorConfig {
        backend: BackendSpec::Scalar { code: "ccsds".into(), stages: tile.frame_stages() },
        tile,
        max_batch: 8,
        batch_deadline: Duration::from_micros(100),
        workers: 2,
        queue_depth: 64,
        shards: 1,
        termination: TerminationMode::Flushed,
    })
    .unwrap();
    let (_, llr) = noisy_stream(5, 1024, 5.0);
    let _ = coord.decode_stream_blocking(&llr).unwrap();
    let s = coord.metrics();
    assert_eq!(s.frames_out, 16);
    assert_eq!(s.bits_out, 1024);
    assert!(s.throughput_bps > 0.0);
    assert!(s.latency_p50_us > 0.0 && s.latency_p50_us <= s.latency_p99_us);
    assert!(s.forward_ns_total > 0 && s.traceback_ns_total > 0);
    coord.shutdown().unwrap();
}
