//! Randomized property tests over the coding/decoding invariants, run
//! with the built-in `util::check` property runner (offline substitute
//! for proptest).

use std::sync::Arc;

use tcvd::channel::bpsk;
use tcvd::coding::packing::build_packing;
use tcvd::coding::{poly::Code, registry, trellis::Trellis, Encoder};
use tcvd::util::check::{forall, gen};
use tcvd::util::half::HalfKind;
use tcvd::util::rng::Rng;
use tcvd::viterbi::compact::CompactSurvivors;
use tcvd::viterbi::packed::presets;
use tcvd::viterbi::scalar;
use tcvd::viterbi::traceback::{traceback_compact, traceback_radix};
use tcvd::coding::TerminationMode;
use tcvd::viterbi::tiled::{decode_stream, TileConfig};
use tcvd::viterbi::types::{FrameDecoder, FrameJob};

fn trellis() -> Arc<Trellis> {
    Arc::new(Trellis::new(registry::paper_code()))
}

/// Noiseless encode -> decode must be the identity for any payload.
#[test]
fn prop_noiseless_roundtrip_identity() {
    let t = trellis();
    forall(
        0xA11CE,
        64,
        |r| {
            let mut bits = gen::bits(r, 10, 120);
            bits.extend_from_slice(&[0; 6]);
            bits
        },
        |bits| {
            let mut enc = Encoder::new(t.code().clone());
            let coded = enc.encode(bits);
            let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
            let lam0 = scalar::initial_metrics(64, Some(0));
            let out = scalar::decode(&t, &llr, &lam0, Some(0));
            if out == *bits { Ok(()) } else { Err("roundtrip mismatch".into()) }
        },
    );
}

/// The tensor-formulated decoders agree with the scalar oracle on
/// arbitrary (generic, continuous) LLR inputs — not just encoder outputs.
#[test]
fn prop_packed_matches_scalar_on_arbitrary_llrs() {
    let t = trellis();
    forall(
        0xBEEF,
        24,
        |r| gen::llrs(r, 64 * 2, 1.5),
        |llr| {
            let llr_h: Vec<f32> = llr.iter().map(|&x| HalfKind::Bf16.round(x)).collect();
            let lam0 = scalar::initial_metrics(64, None);
            let oracle = scalar::decode(&t, &llr_h, &lam0, None);
            for mk in [presets::radix2, presets::radix4, presets::radix4_noperm] {
                let mut d = mk(t.clone(), 64);
                let out = d.decode_batch(&[FrameJob {
                    llr: llr.clone(),
                    start_state: None,
                    end_state: None,
                    emit_from: 0,
                    emit_len: 64,
                }]);
                if out[0] != oracle {
                    return Err(format!("{} disagrees with oracle", d.label()));
                }
            }
            Ok(())
        },
    );
}

/// Path metric invariance: adding a constant to all initial metrics
/// must not change any decode decision (max is translation-invariant).
#[test]
fn prop_metric_translation_invariance() {
    let t = trellis();
    forall(
        0xC0DE,
        24,
        |r| (gen::llrs(r, 48 * 2, 1.0), r.next_f64() as f32 * 50.0 - 25.0),
        |(llr, shift)| {
            let lam0a = vec![0.0f32; 64];
            let lam0b = vec![*shift; 64];
            let (phi_a, _) = scalar::forward(&t, llr, &lam0a);
            let (phi_b, _) = scalar::forward(&t, llr, &lam0b);
            if phi_a == phi_b { Ok(()) } else { Err("survivors changed under shift".into()) }
        },
    );
}

/// Tiled decoding with maximal overlap equals unframed decoding.
#[test]
fn prop_tiled_with_huge_overlap_equals_whole() {
    let t = trellis();
    forall(
        0xD00D,
        12,
        |r| {
            let mut bits = gen::bits(r, 250, 250);
            bits.extend_from_slice(&[0; 6]);
            (bits, r.next_u64())
        },
        |(bits, seed)| {
            let mut enc = Encoder::new(t.code().clone());
            let coded = enc.encode(bits);
            let tx = bpsk::modulate(&coded);
            let mut ch = tcvd::channel::awgn::AwgnChannel::new(4.5, 0.5, *seed);
            let llr: Vec<f32> = ch.transmit(&tx).iter().map(|&x| x as f32).collect();
            let lam0 = scalar::initial_metrics(64, Some(0));
            let whole = scalar::decode(&t, &llr, &lam0, Some(0));
            let cfg = TileConfig { payload: 64, head: 64, tail: 64 };
            let mut dec = scalar::ScalarDecoder::new(t.clone(), cfg.frame_stages());
            let tiled = decode_stream(&mut dec, &llr, 2, &cfg, TerminationMode::Flushed)
                .map_err(|e| e.to_string())?;
            if tiled == whole { Ok(()) } else { Err("tiled != whole".into()) }
        },
    );
}

/// Every packing scheme covers each state exactly once per step, for
/// random valid codes (not just the paper's).
#[test]
fn prop_packings_valid_for_random_codes() {
    forall(
        0xFACADE,
        20,
        |r: &mut Rng| {
            // random k in [4,8], beta in [2,3], random odd polynomials
            let k = 4 + r.next_below(5) as u32;
            let beta = 2 + r.next_below(2) as usize;
            let polys: Vec<u32> = (0..beta)
                .map(|_| {
                    let msb = 1 << (k - 1);
                    (r.next_u64() as u32 & (msb - 1)) | msb | 1 // MSB and LSB set
                })
                .collect();
            (k, polys)
        },
        |(k, polys)| {
            let code = Code::new(*k, polys.clone()).map_err(|e| e.to_string())?;
            let t = Trellis::new(code);
            for scheme in ["radix2", "radix4", "radix4_noperm"] {
                let pk = build_packing(&t, scheme).map_err(|e| e.to_string())?;
                pk.validate(1 << (k - 1)).map_err(|e| format!("{scheme}: {e}"))?;
            }
            Ok(())
        },
    );
}

/// `CompactSurvivors::from_radix` round-trips: packed selectors read
/// back exactly, the byte accounting matches the `words_per_step`
/// layout, and traceback over the packed store walks the same Thm-4
/// path as traceback over the raw radix selections — for random codes
/// and every selector width the simd backend can emit (rho in
/// {1, 2, 3}, the butterfly case included).
#[test]
fn prop_from_radix_roundtrips() {
    forall(
        0x5E1EC7,
        24,
        |r: &mut Rng| {
            let k = 4 + r.next_below(5) as u32; // 4..8, so rho < k holds
            let rho = 1 + r.next_below(3) as u32; // 1..3
            let steps = 3 + r.next_below(10) as usize;
            (k, rho, steps, r.next_u64())
        },
        |&(k, rho, steps, seed)| {
            let mut r = Rng::new(seed);
            let msb = 1u32 << (k - 1);
            let polys: Vec<u32> =
                (0..2).map(|_| (r.next_u64() as u32 & (msb - 1)) | msb | 1).collect();
            let code = Code::new(k, polys).map_err(|e| e.to_string())?;
            let t = Trellis::new(code);
            let s_count = t.code().n_states();
            // arbitrary rho-bit selections, one per (step, state): the
            // packing is pure layout, so any selector pattern is legal
            let phi: Vec<u8> = (0..steps * s_count)
                .map(|_| (r.next_u64() & ((1 << rho) - 1)) as u8)
                .collect();
            let surv = CompactSurvivors::from_radix(rho, &phi, s_count);
            if (surv.sel_bits(), surv.steps(), surv.n_states()) != (rho, steps, s_count) {
                return Err(format!(
                    "shape drifted: ({}, {}, {})",
                    surv.sel_bits(),
                    surv.steps(),
                    surv.n_states()
                ));
            }
            for tau in 0..steps {
                for s in 0..s_count {
                    if surv.get(tau, s) != phi[tau * s_count + s] as u32 {
                        return Err(format!(
                            "selector (step {tau}, state {s}) did not round-trip at rho {rho}"
                        ));
                    }
                }
            }
            let want = steps * CompactSurvivors::words_per_step(s_count, rho) * 8;
            if surv.bytes() != want {
                return Err(format!("{} packed bytes, expected {want}", surv.bytes()));
            }
            // packed and raw tracebacks walk the identical path from
            // pinned and argmax end states
            let lam: Vec<f32> =
                (0..s_count).map(|_| (r.next_u64() % 1000) as f32 - 500.0).collect();
            for end in [None, Some(0u32), Some(s_count as u32 - 1)] {
                let a = traceback_compact(&t, &surv, &lam, end);
                let b = traceback_radix(&t, rho, &phi, &lam, end);
                if a != b {
                    return Err(format!("traceback diverged (rho {rho}, end {end:?})"));
                }
            }
            Ok(())
        },
    );
}

/// Dragonfly-group permutation decodes equal no-permutation decodes for
/// random codes where groups exist (Thm 7 exploitation is lossless).
#[test]
fn prop_dg_permutation_is_lossless() {
    forall(
        0x9E37,
        12,
        |r| {
            let k = 5 + r.next_below(3) as u32; // 5..7
            let msb = 1u32 << (k - 1);
            let polys: Vec<u32> = (0..2)
                .map(|_| (r.next_u64() as u32 & (msb - 1)) | msb | 1)
                .collect();
            let llr = gen::llrs(r, 32 * 2, 1.2);
            (k, polys, llr)
        },
        |(k, polys, llr)| {
            let code = Code::new(*k, polys.clone()).map_err(|e| e.to_string())?;
            let t = Arc::new(Trellis::new(code));
            let s = t.code().n_states();
            let mk = |scheme: &str| {
                let pk = build_packing(&t, scheme).unwrap();
                tcvd::viterbi::PackedDecoder::new(
                    t.clone(),
                    pk,
                    32,
                    tcvd::viterbi::AccPrecision::Single,
                    HalfKind::Bf16,
                    tcvd::channel::quantize::ChannelPrecision::Single,
                    16,
                )
            };
            let job = FrameJob {
                llr: llr.clone(),
                start_state: None,
                end_state: None,
                emit_from: 0,
                emit_len: 32,
            };
            let a = mk("radix4").decode_batch(std::slice::from_ref(&job));
            let b = mk("radix4_noperm").decode_batch(std::slice::from_ref(&job));
            let _ = s;
            if a == b { Ok(()) } else { Err("perm vs noperm differ".into()) }
        },
    );
}
