//! `BackendKind::Simd` equivalence suite: the quantized (i16)
//! lane-parallel fast path must decode **bit-identically** to the
//! scalar f64 oracle on grid LLRs — for random codes, frame lengths,
//! renormalization intervals, tile geometries and shard counts, and
//! under saturation-stress LLRs at the quantization clamp. The
//! quantization/renormalization model is documented in
//! `docs/PERFORMANCE.md`.

use std::sync::Arc;

use tcvd::api::{BackendKind, DecoderBuilder};
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::coding::{poly::Code, registry, trellis::Trellis, Encoder};
use tcvd::util::check::{forall, gen};
use tcvd::util::rng::Rng;
use tcvd::viterbi::scalar::{self, ScalarDecoder};
use tcvd::viterbi::simd::{Quantizer, SimdDecoder};
use tcvd::coding::TerminationMode;
use tcvd::viterbi::tiled::{decode_stream, TileConfig};
use tcvd::viterbi::types::{FrameDecoder, FrameJob};

fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
    let code = registry::paper_code();
    let mut enc = Encoder::new(code.clone());
    let mut bits = Rng::new(seed).bits(payload_bits - 6);
    bits.extend_from_slice(&[0; 6]);
    let coded = enc.encode(&bits);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0x51AD);
    let rx = ch.transmit(&tx);
    (bits, rx.iter().map(|&x| x as f32).collect())
}

/// Snap LLRs onto the decoder's quantization grid, so the scalar
/// oracle sees exactly the channel values the i16 path accumulates.
fn snap(q: Quantizer, llr: &[f32]) -> Vec<f32> {
    llr.iter().map(|&x| q.dequantize(q.quantize(x))).collect()
}

/// SIMD forward + traceback equals the scalar oracle on random valid
/// codes (k 4..8, beta 2..3), random frame lengths and renormalization
/// intervals, for known and unknown trellis ends.
#[test]
fn prop_simd_matches_scalar_for_random_codes() {
    forall(
        0x51D0_C0DE,
        24,
        |r: &mut Rng| {
            let k = 4 + r.next_below(5) as u32; // 4..8 -> 8..128 states
            let beta = 2 + r.next_below(2) as usize;
            let polys: Vec<u32> = (0..beta)
                .map(|_| {
                    let msb = 1u32 << (k - 1);
                    (r.next_u64() as u32 & (msb - 1)) | msb | 1
                })
                .collect();
            let stages = 24 + r.next_below(41) as usize; // 24..64
            let renorm = [1usize, 4, 16, 0][r.next_below(4) as usize];
            let known_ends = r.next_bit() == 1;
            let llr = gen::llrs(r, stages * beta, 1.4);
            (k, polys, stages, renorm, known_ends, llr)
        },
        |(k, polys, stages, renorm, known_ends, llr)| {
            let code = Code::new(*k, polys.clone()).map_err(|e| e.to_string())?;
            let s_count = code.n_states();
            let t = Arc::new(Trellis::new(code));
            // known ends pin both trellis ends (the traceback starts at
            // state 0 instead of the argmax); unknown ends exercise the
            // argmax pick over the quantized final metrics
            let (start, end) = if *known_ends { (Some(0), Some(0)) } else { (None, None) };
            let mut dec = SimdDecoder::new(t.clone(), *stages, *renorm);
            let deq = snap(dec.quantizer(), llr);
            let lam0 = scalar::initial_metrics(s_count, start);
            let oracle = scalar::decode(&t, &deq, &lam0, end);
            let job = FrameJob {
                llr: llr.clone(),
                start_state: start,
                end_state: end,
                emit_from: 0,
                emit_len: *stages,
            };
            let out = dec.decode_batch(std::slice::from_ref(&job));
            if out[0] != oracle {
                return Err(format!(
                    "simd decode diverged (k={k}, S={s_count}, renorm={renorm})"
                ));
            }
            Ok(())
        },
    );
}

/// Streamed decoding through the reference tiler on grid LLRs: simd
/// equals scalar for random tile geometries (head/tail 0 included) and
/// renormalization intervals on noisy streams.
#[test]
fn prop_simd_matches_scalar_across_tile_geometries() {
    forall(
        0x71D5,
        12,
        |r: &mut Rng| {
            let payload = [16usize, 32, 64][r.next_below(3) as usize];
            let head = [0usize, 8, 17, 32][r.next_below(4) as usize];
            let tail = [0usize, 8, 17, 32][r.next_below(4) as usize];
            let frames = 2 + r.next_below(3) as usize;
            let renorm = [1usize, 7, 16, 0][r.next_below(4) as usize];
            (TileConfig { payload, head, tail }, frames, renorm, r.next_u64())
        },
        |&(cfg, frames, renorm, seed)| {
            let t = Arc::new(Trellis::new(registry::paper_code()));
            let quant = Quantizer::for_code(7, 2);
            let (_, raw) = noisy_stream(seed % 100_000, cfg.payload * frames, 2.5);
            let llr = snap(quant, &raw);
            let mut sdec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
            let want = decode_stream(&mut sdec, &llr, 2, &cfg, TerminationMode::Flushed)
                .map_err(|e| e.to_string())?;
            let mut qdec = SimdDecoder::new(t, cfg.frame_stages(), renorm);
            let got = decode_stream(&mut qdec, &llr, 2, &cfg, TerminationMode::Flushed)
                .map_err(|e| e.to_string())?;
            if got == want {
                Ok(())
            } else {
                Err(format!("tile {cfg:?} renorm {renorm}: simd stream decode diverged"))
            }
        },
    );
}

/// Saturation stress: LLR magnitudes at and far beyond the i16
/// quantization clamp. The grid clamps both decoders' channel inputs
/// identically and the renormalized i16 metrics must still produce the
/// oracle's bits.
#[test]
fn prop_simd_matches_scalar_under_saturation_stress() {
    forall(
        0x5A70,
        16,
        |r: &mut Rng| {
            let amp = [32.0f32, 64.0, 256.0, 4096.0][r.next_below(4) as usize];
            let renorm = [1usize, 16, 0][r.next_below(3) as usize];
            let stages = 32 + r.next_below(33) as usize;
            let mut llr = gen::llrs(r, stages * 2, 1.1);
            for v in llr.iter_mut() {
                *v *= amp;
            }
            (stages, renorm, llr)
        },
        |(stages, renorm, llr)| {
            let t = Arc::new(Trellis::new(registry::paper_code()));
            let mut dec = SimdDecoder::new(t.clone(), *stages, *renorm);
            let q = dec.quantizer();
            let deq = snap(q, llr);
            // the clamp must actually engage for this to stress anything
            if !deq.iter().any(|&x| x.abs() >= q.dequantize(q.qmax()).abs()) {
                return Err("stress case never reached the clamp".into());
            }
            let lam0 = scalar::initial_metrics(64, Some(0));
            let oracle = scalar::decode(&t, &deq, &lam0, None);
            let job = FrameJob {
                llr: llr.clone(),
                start_state: Some(0),
                end_state: None,
                emit_from: 0,
                emit_len: *stages,
            };
            let out = dec.decode_batch(std::slice::from_ref(&job));
            if out[0] != oracle {
                return Err(format!("saturation stress diverged (renorm {renorm})"));
            }
            Ok(())
        },
    );
}

fn run_backend_sessions(backend: BackendKind, shards: usize, n_sessions: usize)
                        -> (Vec<Vec<u8>>, u64) {
    let coord = Arc::new(
        DecoderBuilder::new()
            .backend(backend)
            .tile_dims(32, 16, 16)
            .shards(shards)
            .workers(2)
            .max_batch(8)
            .batch_deadline_us(200)
            .queue_depth(256)
            .serve()
            .unwrap(),
    );
    let mut joins = Vec::new();
    for s in 0..n_sessions {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            let (_, llr) = noisy_stream(6000 + s as u64, 256 + 32 * (s % 3), 5.5);
            let mut session = c.open_session().unwrap();
            for chunk in llr.chunks(70) {
                session.push(chunk).unwrap();
            }
            session.finish_and_collect().unwrap()
        }));
    }
    let outs: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let peak = coord.metrics().survivor_bytes_peak();
    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    coord.shutdown().unwrap();
    (outs, peak)
}

/// The coordinator serving path: simd output is invariant across shard
/// counts and — at an Eb/N0 where quantization is transparent —
/// identical to the scalar backend's, while the survivor gauge shows
/// the compact bit-packed layout (whole frames of 64 stages x 64
/// states / 8 bits, batched).
#[test]
fn simd_shard_invariance_against_scalar() {
    let n_sessions = 4;
    let (scalar_outs, _) = run_backend_sessions(BackendKind::Scalar, 1, n_sessions);
    let frame_bytes = 64 * 64 / 8;
    for shards in [1usize, 2, 8] {
        let (outs, peak) = run_backend_sessions(BackendKind::Simd, shards, n_sessions);
        assert_eq!(
            outs, scalar_outs,
            "{shards}-shard simd output differs from the scalar reference"
        );
        // simd batches frames over one shared ring; every batched
        // execution materializes whole bit-packed frames
        assert!(peak >= frame_bytes, "shards={shards}: gauge below one frame ({peak})");
        assert_eq!(peak % frame_bytes, 0, "shards={shards}: gauge not whole frames ({peak})");
    }
}

/// The one-shot fan-out path builds simd lanes from the spec: output
/// is invariant across lane counts and equal to the single-lane
/// reference.
#[test]
fn simd_one_shot_lanes_agree() {
    let (bits, llr) = noisy_stream(555, 2048, 5.5);
    let builder = DecoderBuilder::new().backend(BackendKind::Simd).tile_dims(64, 32, 32);
    let reference =
        builder.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(reference, bits, "5.5 dB decodes clean through the quantized path");
    for lanes in [2usize, 8] {
        let got =
            builder.clone().shards(lanes).build().unwrap().decode_stream(&llr).unwrap();
        assert_eq!(got, reference, "{lanes}-lane simd one-shot decode diverged");
    }
}
