//! `BackendKind::Simd` equivalence suite: the quantized (i16)
//! lane-parallel fast path must decode **bit-identically** to the
//! scalar f64 oracle on grid LLRs — for random codes, frame lengths,
//! renormalization intervals, tile geometries, shard counts,
//! termination modes and radixes (rho in {1, 2}), and under
//! saturation-stress LLRs at the quantization clamp. The
//! quantization/renormalization model is documented in
//! `docs/PERFORMANCE.md`; shared samplers/oracle live in
//! `common/corpus.rs`.

use std::sync::Arc;

use tcvd::api::{BackendKind, DecoderBuilder};
use tcvd::coding::{poly::Code, registry, trellis::Trellis};
use tcvd::util::check::{forall, gen};
use tcvd::util::rng::Rng;
use tcvd::viterbi::scalar::ScalarDecoder;
use tcvd::viterbi::simd::{Quantizer, SimdDecoder, NEG_Q};
use tcvd::coding::TerminationMode;
use tcvd::viterbi::tiled::{decode_stream, TileConfig};
use tcvd::viterbi::types::{FrameDecoder, FrameJob};

#[path = "common/corpus.rs"]
mod corpus;

/// The channel-noise decorrelation constant this suite has always used
/// (pre-validated noisy-decode seeds depend on it).
const SEED_XOR: u64 = 0x51AD;

fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
    corpus::noisy_stream(seed, payload_bits, ebn0, SEED_XOR)
}

/// SIMD forward + traceback equals the scalar oracle on random valid
/// codes (k 4..8, beta 2..3), random frame lengths and renormalization
/// intervals, for known and unknown trellis ends.
#[test]
fn prop_simd_matches_scalar_for_random_codes() {
    forall(
        0x51D0_C0DE,
        24,
        |r: &mut Rng| {
            let (k, polys) = corpus::sample_code(r);
            let stages = 24 + r.next_below(41) as usize; // 24..64
            let renorm = [1usize, 4, 16, 0][r.next_below(4) as usize];
            let known_ends = r.next_bit() == 1;
            let llr = gen::llrs(r, stages * polys.len(), 1.4);
            (k, polys, stages, renorm, known_ends, llr)
        },
        |(k, polys, stages, renorm, known_ends, llr)| {
            let code = Code::new(*k, polys.clone()).map_err(|e| e.to_string())?;
            let s_count = code.n_states();
            let t = Arc::new(Trellis::new(code));
            // known ends pin both trellis ends (the traceback starts at
            // state 0 instead of the argmax); unknown ends exercise the
            // argmax pick over the quantized final metrics
            let (start, end) = if *known_ends { (Some(0), Some(0)) } else { (None, None) };
            let mut dec = SimdDecoder::new(t.clone(), *stages, *renorm);
            let deq = corpus::snap(dec.quantizer(), llr);
            let oracle = corpus::oracle_decode(&t, &deq, start, end);
            let job = FrameJob {
                llr: llr.clone(),
                start_state: start,
                end_state: end,
                emit_from: 0,
                emit_len: *stages,
            };
            let out = dec.decode_batch(std::slice::from_ref(&job));
            if out[0] != oracle {
                return Err(format!(
                    "simd decode diverged (k={k}, S={s_count}, renorm={renorm})"
                ));
            }
            Ok(())
        },
    );
}

/// The radix-2 super-branch kernel equals the scalar oracle on random
/// valid codes (k 4..8, beta 2..3), random even frame lengths and
/// renormalization intervals — including one-stage requests, which
/// round up to a whole super-stage — for known and unknown ends.
#[test]
fn prop_radix2_matches_scalar_for_random_codes() {
    forall(
        0x2AD1_62,
        24,
        |r: &mut Rng| {
            let (k, polys) = corpus::sample_code(r);
            let stages = 2 * (12 + r.next_below(21) as usize); // even, 24..64
            let renorm = [1usize, 2, 4, 16, 0][r.next_below(5) as usize];
            let known_ends = r.next_bit() == 1;
            let llr = gen::llrs(r, stages * polys.len(), 1.4);
            (k, polys, stages, renorm, known_ends, llr)
        },
        |(k, polys, stages, renorm, known_ends, llr)| {
            let code = Code::new(*k, polys.clone()).map_err(|e| e.to_string())?;
            let s_count = code.n_states();
            let t = Arc::new(Trellis::new(code));
            let (start, end) = if *known_ends { (Some(0), Some(0)) } else { (None, None) };
            let mut dec = SimdDecoder::with_radix(t.clone(), *stages, *renorm, 2);
            let deq = corpus::snap(dec.quantizer(), llr);
            let oracle = corpus::oracle_decode(&t, &deq, start, end);
            let job = FrameJob {
                llr: llr.clone(),
                start_state: start,
                end_state: end,
                emit_from: 0,
                emit_len: *stages,
            };
            let out = dec.decode_batch(std::slice::from_ref(&job));
            if out[0] != oracle {
                return Err(format!(
                    "radix-2 decode diverged (k={k}, S={s_count}, renorm={renorm})"
                ));
            }
            Ok(())
        },
    );
}

/// Streamed decoding through the reference tiler on grid LLRs: simd
/// equals scalar for random tile geometries (head/tail 0 included) and
/// renormalization intervals on noisy streams — at both radixes when
/// the frame splits into super-stages.
#[test]
fn prop_simd_matches_scalar_across_tile_geometries() {
    forall(
        0x71D5,
        12,
        |r: &mut Rng| {
            let cfg = corpus::sample_tile(r);
            let frames = 2 + r.next_below(3) as usize;
            let renorm = [1usize, 7, 16, 0][r.next_below(4) as usize];
            (cfg, frames, renorm, r.next_u64())
        },
        |&(cfg, frames, renorm, seed)| {
            let t = corpus::paper_trellis();
            let quant = Quantizer::for_code(7, 2);
            let (_, raw) = noisy_stream(seed % 100_000, cfg.payload * frames, 2.5);
            let llr = corpus::snap(quant, &raw);
            let mut sdec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
            let want = decode_stream(&mut sdec, &llr, 2, &cfg, TerminationMode::Flushed)
                .map_err(|e| e.to_string())?;
            let mut qdec = SimdDecoder::new(t.clone(), cfg.frame_stages(), renorm);
            let got = decode_stream(&mut qdec, &llr, 2, &cfg, TerminationMode::Flushed)
                .map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("tile {cfg:?} renorm {renorm}: simd stream decode diverged"));
            }
            if cfg.frame_stages() % 2 == 0 {
                // the rho = 2 quantizer is identical for the paper code,
                // so the same grid stream must decode identically too
                let mut rdec = SimdDecoder::with_radix(t, cfg.frame_stages(), renorm, 2);
                let got2 = decode_stream(&mut rdec, &llr, 2, &cfg, TerminationMode::Flushed)
                    .map_err(|e| e.to_string())?;
                if got2 != want {
                    return Err(format!(
                        "tile {cfg:?} renorm {renorm}: radix-2 stream decode diverged"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Saturation stress: LLR magnitudes at and far beyond the i16
/// quantization clamp. The grid clamps both decoders' channel inputs
/// identically and the renormalized i16 metrics must still produce the
/// oracle's bits.
#[test]
fn prop_simd_matches_scalar_under_saturation_stress() {
    forall(
        0x5A70,
        16,
        |r: &mut Rng| {
            let amp = [32.0f32, 64.0, 256.0, 4096.0][r.next_below(4) as usize];
            let renorm = [1usize, 16, 0][r.next_below(3) as usize];
            let stages = 32 + r.next_below(33) as usize;
            let mut llr = gen::llrs(r, stages * 2, 1.1);
            for v in llr.iter_mut() {
                *v *= amp;
            }
            (stages, renorm, llr)
        },
        |(stages, renorm, llr)| {
            let t = corpus::paper_trellis();
            let mut dec = SimdDecoder::new(t.clone(), *stages, *renorm);
            let q = dec.quantizer();
            let deq = corpus::snap(q, llr);
            // the clamp must actually engage for this to stress anything
            if !deq.iter().any(|&x| x.abs() >= q.dequantize(q.qmax()).abs()) {
                return Err("stress case never reached the clamp".into());
            }
            let oracle = corpus::oracle_decode(&t, &deq, Some(0), None);
            let job = FrameJob {
                llr: llr.clone(),
                start_state: Some(0),
                end_state: None,
                emit_from: 0,
                emit_len: *stages,
            };
            let out = dec.decode_batch(std::slice::from_ref(&job));
            if out[0] != oracle {
                return Err(format!("saturation stress diverged (renorm {renorm})"));
            }
            Ok(())
        },
    );
}

/// Radix-2 saturation stress: every LLR of a super-stage pinned at the
/// clamp, decoded at the *widest* and the *narrowest* renormalization
/// periods. The headroom pin below is the regression guard for the
/// `for_code_radix` / renorm-cap arithmetic: a worst-case super-branch
/// sum (`rho * beta * qmax` on top of a metric that drifted a full
/// period plus the warm-up spread below the running maximum) must stay
/// representable, so no surviving path ever saturates.
#[test]
fn radix2_saturation_respects_i16_headroom() {
    let code = registry::paper_code();
    let t = Arc::new(Trellis::new(code.clone()));
    // headroom pin: (cap + 2(k-1) + rho) * bm_max <= i16::MAX, with the
    // cap floored to a super-stage boundary (16 for the paper code)
    let dec = SimdDecoder::with_radix(t.clone(), 64, 0, 2);
    let q = dec.quantizer();
    let bm_max = q.branch_metric_max(code.beta());
    let spread = 2 * (code.k() as i32 - 1) + 2;
    assert_eq!(dec.effective_renorm(), 16, "auto period at rho 2 for the paper code");
    assert!(
        (dec.effective_renorm() as i32 + spread) * bm_max <= i16::MAX as i32,
        "renorm cap must leave a full super-branch of i16 headroom"
    );
    assert_eq!(q.superbranch_metric_max(code.beta(), 2), 2 * bm_max);
    // the quantized minus-infinity still separates past the wider
    // rho = 2 horizon
    assert!(2 * (code.k() as i32 - 2 + 2) * bm_max < -(NEG_Q as i32));

    // worst-case amplitudes: every grid point at +/- qmax
    for (seed, renorm) in [(1u64, 0usize), (2, 0), (3, 2), (4, 2), (5, 16)] {
        let (_, mut llr) = noisy_stream(seed + 4200, 64, 2.0);
        for v in llr.iter_mut() {
            *v = v.signum() * 1e6;
        }
        let mut rdec = SimdDecoder::with_radix(t.clone(), 64, renorm, 2);
        let deq = corpus::snap(rdec.quantizer(), &llr);
        assert!(
            deq.iter().all(|&x| x.abs() == rdec.quantizer().dequantize(q.qmax()).abs()),
            "stress stream must sit exactly at the clamp"
        );
        let want = corpus::oracle_decode(&t, &deq, Some(0), None);
        let job = FrameJob {
            llr,
            start_state: Some(0),
            end_state: None,
            emit_from: 0,
            emit_len: 64,
        };
        let got = rdec.decode_batch(std::slice::from_ref(&job));
        assert_eq!(got[0], want, "seed {seed} renorm {renorm}: clamp stress diverged");
    }
}

/// The serving pipeline decodes radix 2 bit-identically to the scalar
/// reference for every termination mode across shards {1, 2, 8} — the
/// acceptance pin for `tcvd --backend simd --radix 2`.
#[test]
fn radix2_pipeline_matrix_matches_scalar() {
    let code = registry::paper_code();
    let t = Arc::new(Trellis::new(code.clone()));
    let cfg = TileConfig { payload: 32, head: 16, tail: 16 }; // 64-stage frames (even)
    let quant = Quantizer::for_code_radix(code.k(), code.beta(), 2);
    let modes =
        [TerminationMode::Flushed, TerminationMode::TailBiting, TerminationMode::Truncated];
    for mode in modes {
        let flush = mode.flush_stages(code.k());
        let (_, raw) = corpus::mode_stream(&code, mode, 256 - flush, 5.0, 77, 0xC0DE);
        let llr = corpus::snap(quant, &raw);
        let mut sdec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
        let want = decode_stream(&mut sdec, &llr, 2, &cfg, mode).unwrap();
        for shards in [1usize, 2, 8] {
            for renorm in [2usize, 0] {
                let coord = DecoderBuilder::new()
                    .backend_name("simd")
                    .unwrap()
                    .radix(2)
                    .renorm_every(renorm)
                    .tile(cfg)
                    .termination(mode)
                    .shards(shards)
                    .workers(2)
                    .max_batch(4)
                    .batch_deadline_us(100)
                    .queue_depth(64)
                    .serve()
                    .unwrap();
                let got = coord.decode_stream_blocking(&llr).unwrap();
                assert_eq!(
                    got, want,
                    "mode={mode} shards={shards} renorm={renorm}: radix-2 pipeline diverged"
                );
                coord.shutdown().unwrap();
            }
        }
    }
}

fn run_backend_sessions(backend: BackendKind, radix: usize, shards: usize,
                        n_sessions: usize) -> (Vec<Vec<u8>>, u64) {
    let coord = Arc::new(
        DecoderBuilder::new()
            .backend(backend)
            .radix(radix)
            .tile_dims(32, 16, 16)
            .shards(shards)
            .workers(2)
            .max_batch(8)
            .batch_deadline_us(200)
            .queue_depth(256)
            .serve()
            .unwrap(),
    );
    let mut joins = Vec::new();
    for s in 0..n_sessions {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            let (_, llr) = noisy_stream(6000 + s as u64, 256 + 32 * (s % 3), 5.5);
            let mut session = c.open_session().unwrap();
            for chunk in llr.chunks(70) {
                session.push(chunk).unwrap();
            }
            session.finish_and_collect().unwrap()
        }));
    }
    let outs: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let peak = coord.metrics().survivor_bytes_peak();
    let coord = Arc::try_unwrap(coord).ok().expect("sessions done");
    coord.shutdown().unwrap();
    (outs, peak)
}

/// The coordinator serving path: simd output is invariant across shard
/// counts and radixes and — at an Eb/N0 where quantization is
/// transparent — identical to the scalar backend's, while the survivor
/// gauge shows the compact bit-packed layout (whole frames of 64
/// stages x 64 states / 8 bits, batched; rho-bit selectors pack to the
/// same footprint at radix 2).
#[test]
fn simd_shard_invariance_against_scalar() {
    let n_sessions = 4;
    let (scalar_outs, _) = run_backend_sessions(BackendKind::Scalar, 1, 1, n_sessions);
    let frame_bytes = 64 * 64 / 8;
    for radix in [1usize, 2] {
        for shards in [1usize, 2, 8] {
            let (outs, peak) =
                run_backend_sessions(BackendKind::Simd, radix, shards, n_sessions);
            assert_eq!(
                outs, scalar_outs,
                "{shards}-shard radix-{radix} simd output differs from the scalar reference"
            );
            // simd batches frames over one shared ring; every batched
            // execution materializes whole bit-packed frames
            assert!(
                peak >= frame_bytes,
                "radix={radix} shards={shards}: gauge below one frame ({peak})"
            );
            assert_eq!(
                peak % frame_bytes,
                0,
                "radix={radix} shards={shards}: gauge not whole frames ({peak})"
            );
        }
    }
}

/// The one-shot fan-out path builds simd lanes from the spec: output
/// is invariant across lane counts and radixes and equal to the
/// single-lane radix-1 reference.
#[test]
fn simd_one_shot_lanes_agree() {
    let (bits, llr) = noisy_stream(555, 2048, 5.5);
    let builder = DecoderBuilder::new().backend(BackendKind::Simd).tile_dims(64, 32, 32);
    let reference =
        builder.clone().shards(1).build().unwrap().decode_stream(&llr).unwrap();
    assert_eq!(reference, bits, "5.5 dB decodes clean through the quantized path");
    for radix in [1usize, 2] {
        for lanes in [2usize, 8] {
            let got = builder
                .clone()
                .radix(radix)
                .shards(lanes)
                .build()
                .unwrap()
                .decode_stream(&llr)
                .unwrap();
            assert_eq!(got, reference, "{lanes}-lane radix-{radix} one-shot decode diverged");
        }
    }
}
