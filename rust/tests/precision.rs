//! Precision-behaviour integration tests: the Fig-13 mechanism (half
//! accumulator resolution loss at grown metric magnitudes) and the
//! renormalization mitigation, asserted as invariants.

use std::sync::Arc;

use tcvd::ber::{measure_ber, BerSetup};
use tcvd::channel::quantize::ChannelPrecision;
use tcvd::coding::{packing::build_packing, registry, trellis::Trellis};
use tcvd::util::half::HalfKind;
use tcvd::viterbi::packed::PackedDecoder;
use tcvd::viterbi::tiled::TileConfig;
use tcvd::viterbi::types::AccPrecision;

fn ber_at(acc: AccPrecision, renorm: usize, chan: ChannelPrecision) -> f64 {
    let t = Arc::new(Trellis::new(registry::paper_code()));
    let tile = TileConfig { payload: 256, head: 128, tail: 128 };
    let setup = BerSetup {
        tile,
        target_errors: 100,
        max_bits: 120_000,
        exact_llr: true, // metric growth — the paper's operating condition
        ..Default::default()
    };
    let pk = build_packing(&t, "radix4").unwrap();
    let mut dec = PackedDecoder::new(t.clone(), pk, tile.frame_stages(), acc,
                                     HalfKind::Bf16, chan, renorm);
    measure_ber(&mut dec, &t, 4.0, &setup).unwrap().ber()
}

#[test]
fn half_accumulator_degrades_without_renorm() {
    // paper Fig 13: C must be single precision
    let f32_ber = ber_at(AccPrecision::Single, 0, ChannelPrecision::Single);
    let bf16_ber = ber_at(AccPrecision::Half(HalfKind::Bf16), 0, ChannelPrecision::Single);
    assert!(
        bf16_ber > 50.0 * (f32_ber + 1e-6),
        "bf16 accumulator should fail hard: bf16={bf16_ber:.2e} f32={f32_ber:.2e}"
    );
}

#[test]
fn half_channel_costs_nothing() {
    // paper Fig 13: the channel array can be half without BER loss
    let single = ber_at(AccPrecision::Single, 0, ChannelPrecision::Single);
    let half = ber_at(AccPrecision::Single, 0, ChannelPrecision::Half(HalfKind::Bf16));
    assert!(
        (half - single).abs() <= 2e-4,
        "half channel should be free: half={half:.2e} single={single:.2e}"
    );
}

#[test]
fn renormalization_rescues_half_accumulator() {
    // extension beyond the paper: periodic metric renormalization keeps
    // magnitudes (and thus the half ulp) small
    let rescued = ber_at(AccPrecision::Half(HalfKind::Bf16), 8, ChannelPrecision::Single);
    let broken = ber_at(AccPrecision::Half(HalfKind::Bf16), 0, ChannelPrecision::Single);
    assert!(
        rescued < broken / 10.0,
        "renorm should rescue bf16: renorm8={rescued:.2e} renorm0={broken:.2e}"
    );
}

#[test]
fn f16_beats_bf16_as_accumulator() {
    // f16 has 11 significand bits vs bf16's 8: at equal magnitudes its
    // ulp is 8x finer, so it degrades later (why the paper's fp16 C
    // merely *degrades* while TPU-bf16 would fail harder)
    let f16 = ber_at(AccPrecision::Half(HalfKind::F16), 0, ChannelPrecision::Single);
    let bf16 = ber_at(AccPrecision::Half(HalfKind::Bf16), 0, ChannelPrecision::Single);
    assert!(f16 < bf16, "f16={f16:.2e} should beat bf16={bf16:.2e}");
}
