//! Chaos suite for shard supervision (`docs/RELIABILITY.md`), compiled
//! only with `--features failpoints` (registered in `Cargo.toml` with
//! `required-features`).
//!
//! Every test arms a deterministic failpoint spec through the builder,
//! drives real sessions through the pipeline, and pins the recovery
//! contract:
//!
//! * **Blast radius** — only sessions whose frames were in flight on
//!   the faulting shard see an error; every other session's output is
//!   bit-identical to the one-shot oracle.
//! * **Typed, retryable errors** — a poisoned session gets exactly one
//!   `Error::Pipeline` carrying the `shard-restart` token
//!   (`Error::is_retryable`), and retrying the block succeeds.
//! * **Counters** — `shard_panics` / `shard_restarts` / `degradations`
//!   / `sessions_poisoned` in the metrics snapshot are pinned exactly,
//!   not just `> 0`, because `hit:N` triggers fire deterministically.
#![cfg(feature = "failpoints")]

use tcvd::api::DecoderBuilder;
use tcvd::coding::registry;
use tcvd::error::Error;
use tcvd::fault::site;
use tcvd::net::loadgen::make_block_llrs;
use tcvd::net::{NetConfig, Server, TcpClient};

const BACKENDS: [&str; 3] = ["scalar", "compact", "simd"];
const SHARDS: [usize; 3] = [1, 2, 8];

/// Small always-available pipeline: 16+8/8 tile (32-stage frames) on a
/// CPU backend, modest serving knobs (mirrors `net_serving.rs`).
fn builder(backend: &str, shards: usize) -> DecoderBuilder {
    DecoderBuilder::new()
        .backend_name(backend)
        .unwrap()
        .tile_dims(16, 8, 8)
        .workers(2)
        .max_batch(8)
        .queue_depth(64)
        .shards(shards)
}

/// One block's LLRs for the pipeline `b` describes.
fn block(b: &DecoderBuilder, stages: usize, seed: u64) -> Vec<f32> {
    let code = registry::lookup(b.code_name()).unwrap();
    make_block_llrs(&code, b.termination_mode(), stages, 6.0, seed)
}

/// The blast-radius matrix: one injected engine panic per pipeline,
/// across backends and shard counts. Exactly one of the sequential
/// sessions is poisoned (the one whose frames were in the panicking
/// batch); it gets one typed retryable error and its retry succeeds;
/// every other session is bit-identical to the oracle.
#[test]
fn one_engine_panic_poisons_one_session_and_recovers_across_the_matrix() {
    for backend in BACKENDS {
        for shards in SHARDS {
            let mut oracle = builder(backend, 1).build().unwrap();
            let b = builder(backend, shards).failpoints("engine.exec=hit:3");
            let coord = b.serve().unwrap();
            let mut poisoned = 0usize;
            for seed in 0..6u64 {
                let llr = block(&builder(backend, shards), 64, 31 * seed + 7);
                let want = oracle.decode_stream(&llr).unwrap();
                match coord.decode_stream_blocking(&llr) {
                    Ok(got) => {
                        assert_eq!(got, want, "{backend}/shards={shards}/seed={seed}");
                    }
                    Err(e) => {
                        poisoned += 1;
                        assert!(e.is_retryable(), "poison must be retryable: {e}");
                        assert!(e.to_string().contains("shard-restart"), "{e}");
                        assert!(matches!(e, Error::Pipeline(_)), "{e}");
                        // the shard restarted: the same block decodes clean
                        let got = coord.decode_stream_blocking(&llr).unwrap();
                        assert_eq!(got, want, "retry {backend}/shards={shards}/seed={seed}");
                    }
                }
            }
            assert_eq!(poisoned, 1, "{backend}/shards={shards}: exactly one session poisoned");
            assert_eq!(coord.faults().fired(site::ENGINE_EXEC), 1);
            let snap = coord.metrics();
            assert_eq!(snap.shard_panics, 1, "{backend}/shards={shards}");
            assert_eq!(snap.shard_restarts, 1);
            assert_eq!(snap.sessions_poisoned, 1);
            assert_eq!(snap.degradations, 0, "one fault with progress after: no degradation");
            assert_eq!(snap.shards.iter().map(|s| s.panics).sum::<u64>(), 1);
            assert_eq!(snap.shards.iter().map(|s| s.restarts).sum::<u64>(), 1);
            coord.shutdown().unwrap();
        }
    }
}

/// Every rebuild failing walks the degradation chain (simd -> compact
/// -> scalar) to exhaustion; the dead shard then fails sessions with a
/// typed, *non*-retryable abort (there is nothing left to retry
/// against).
#[test]
fn failed_rebuilds_walk_the_degradation_chain_then_kill_the_shard() {
    let llr = block(&builder("simd", 1), 64, 9);
    let coord = builder("simd", 1)
        .failpoints("engine.exec=hit:1,engine.build=every:1")
        .serve()
        .unwrap();
    // first session: in flight during the panic, poisoned retryably
    let e = coord.decode_stream_blocking(&llr).unwrap_err();
    assert!(e.is_retryable(), "{e}");
    // the chain is exhausted (every rebuild fails): the shard is dead
    // and a fresh session gets the non-retryable abort
    let e = coord.decode_stream_blocking(&llr).unwrap_err();
    assert!(!e.is_retryable(), "dead shard must not invite retries: {e}");
    assert!(e.to_string().contains("degradation chain"), "{e}");
    let snap = coord.metrics();
    assert_eq!(snap.shard_panics, 1);
    assert_eq!(snap.shard_restarts, 1);
    assert_eq!(snap.degradations, 2, "simd -> compact -> scalar");
    assert_eq!(snap.sessions_poisoned, 2);
    coord.shutdown().unwrap();
}

/// A shard that faults on every batch exhausts its restart budget:
/// early sessions see retryable poisons, then the budget-exhausted
/// abort takes over (non-retryable), with the restart/degradation
/// counters pinned by the supervision arithmetic.
#[test]
fn restart_budget_exhaustion_kills_the_shard() {
    let llr = block(&builder("compact", 1), 64, 13);
    let coord = builder("compact", 1)
        .failpoints("engine.exec=every:1")
        .max_restarts(2)
        .serve()
        .unwrap();
    let mut saw_retryable = false;
    let mut dead = None;
    for _attempt in 0..20 {
        match coord.decode_stream_blocking(&llr) {
            Ok(_) => panic!("every:1 exec faults can never decode a block"),
            Err(e) if e.is_retryable() => saw_retryable = true,
            Err(e) => {
                dead = Some(e);
                break;
            }
        }
    }
    assert!(saw_retryable, "pre-budget faults poison retryably");
    let dead = dead.expect("the shard must die within the restart budget");
    assert!(dead.to_string().contains("restart budget"), "{dead}");
    let snap = coord.metrics();
    // panic 1: restart 1 (consecutive=1); panic 2: restart 2,
    // consecutive=2 => degrade compact -> scalar; panic 3: budget
    // (2 restarts) exhausted => dead. Independent of batch splits,
    // because *every* batch faults.
    assert_eq!(snap.shard_panics, 3);
    assert_eq!(snap.shard_restarts, 2);
    assert_eq!(snap.degradations, 1, "compact -> scalar");
    coord.shutdown().unwrap();
}

/// The framer failpoint surfaces as a typed `Error::Pipeline` on
/// `push` — the chunk is dropped, the session stays usable, nothing is
/// poisoned.
#[test]
fn framer_push_failpoint_drops_one_chunk_with_a_typed_error() {
    let bb = builder("scalar", 1);
    let llr = block(&bb, 64, 17);
    let mut oracle = builder("scalar", 1).build().unwrap();
    let want = oracle.decode_stream(&llr).unwrap();
    let coord = bb.failpoints("framer.push=hit:1").serve().unwrap();
    let mut s = coord.open_session().unwrap();
    let e = s.push(&llr).unwrap_err();
    assert!(matches!(e, Error::Pipeline(_)), "{e}");
    assert!(e.to_string().contains("framer.push"), "{e}");
    // the failpoint consumed the chunk, not the session
    s.push(&llr).unwrap();
    assert_eq!(s.finish_and_collect().unwrap(), want);
    let snap = coord.metrics();
    assert_eq!(snap.shard_panics, 0);
    assert_eq!(snap.sessions_poisoned, 0);
    coord.shutdown().unwrap();
}

/// The reassembly-delivery failpoint poisons exactly the delivering
/// session; a retry decodes clean and no engine-side counters move.
#[test]
fn reassembly_deliver_failpoint_poisons_the_delivering_session() {
    let bb = builder("compact", 2);
    let llr = block(&bb, 64, 11);
    let mut oracle = builder("compact", 1).build().unwrap();
    let want = oracle.decode_stream(&llr).unwrap();
    let coord = bb.failpoints("reassembly.deliver=hit:1").serve().unwrap();
    let e = coord.decode_stream_blocking(&llr).unwrap_err();
    assert!(e.to_string().contains("reassembly.deliver"), "{e}");
    assert_eq!(coord.decode_stream_blocking(&llr).unwrap(), want);
    let snap = coord.metrics();
    assert_eq!(snap.sessions_poisoned, 1);
    assert_eq!(snap.shard_panics, 0, "no engine fault involved");
    assert_eq!(snap.shard_restarts, 0);
    coord.shutdown().unwrap();
}

/// End-to-end over loopback TCP: a mid-decode shard panic surfaces to
/// the wire client as a transient failure (normally the typed
/// `shard-restart` REJECT), a retry of the same block succeeds, and
/// every delivered block is bit-identical to the oracle.
#[test]
fn tcp_client_retries_through_a_mid_decode_shard_panic() {
    let b = builder("simd", 2).failpoints("engine.exec=hit:2");
    let mut oracle = builder("simd", 1).build().unwrap();
    let server = Server::start(b.clone(), Some("127.0.0.1:0"), None, NetConfig::default())
        .unwrap();
    let addr = server.tcp_addr().unwrap();
    let mut saw_retryable = false;
    for seed in 0..4u64 {
        let llr = block(&b, 64, 40 + seed);
        let want = oracle.decode_stream(&llr).unwrap();
        let mut got = None;
        for _attempt in 0..10 {
            // one push for the whole block, so the fault lands while
            // the client waits in finish() and arrives as a REJECT
            let r = (|| -> tcvd::Result<Vec<u8>> {
                let mut c = TcpClient::connect(addr, &b)?;
                c.push(&llr)?;
                c.finish()
            })();
            match r {
                Ok(bits) => {
                    got = Some(bits);
                    break;
                }
                Err(e) => {
                    if e.is_retryable() {
                        saw_retryable = true;
                    }
                }
            }
        }
        assert_eq!(got.expect("block decoded within 10 attempts"), want, "seed {seed}");
    }
    assert!(saw_retryable, "the injected panic must surface as a retryable reject");
    let m = server.metrics();
    assert_eq!(m.shard_panics, 1);
    assert!(m.shard_restarts >= 1, "snapshot: {}", m.to_json().to_string_pretty());
    assert!(m.sessions_poisoned >= 1);
    server.shutdown().unwrap();
}

/// With the feature compiled in but nothing armed, the pipeline runs
/// clean: no fault counters move and the fault map is empty.
#[test]
fn unarmed_pipelines_run_clean_with_the_feature_compiled_in() {
    let bb = builder("simd", 2);
    let llr = block(&bb, 64, 21);
    let mut oracle = builder("simd", 1).build().unwrap();
    let want = oracle.decode_stream(&llr).unwrap();
    let coord = bb.serve().unwrap();
    assert_eq!(coord.decode_stream_blocking(&llr).unwrap(), want);
    assert!(coord.faults().is_empty());
    let snap = coord.metrics();
    assert_eq!(
        snap.shard_panics + snap.shard_restarts + snap.degradations + snap.sessions_poisoned,
        0
    );
    coord.shutdown().unwrap();
}
