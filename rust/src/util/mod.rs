//! Self-contained substrate utilities (the image is offline; no external
//! crates beyond `xla`/`anyhow`, so PRNG, half-float emulation, JSON,
//! TOML-subset parsing and property-test helpers are built here).

pub mod rng;
pub mod half;
pub mod bitvec;
pub mod json;
pub mod toml;
pub mod check;
pub mod stats;
pub mod queue;
