//! A small blocking MPMC queue (Mutex + Condvar). std's mpsc `Receiver`
//! is single-consumer; wrapping it in a mutex would hold the lock across
//! a blocking `recv`, serializing the traceback worker pool. This queue
//! releases the lock while waiting.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Blocking multi-producer multi-consumer FIFO.
pub struct Queue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> Default for Queue<T> {
    fn default() -> Self {
        Queue { inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }), cv: Condvar::new() }
    }
}

impl<T> Queue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Push an item; returns false (dropping the item) if closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        g.q.push_back(item);
        drop(g);
        self.cv.notify_one();
        true
    }

    /// Pop, blocking until an item arrives or the queue is closed and
    /// drained (then `None`).
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Close the queue: consumers drain remaining items then see `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = Queue::new();
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_drains_then_none() {
        let q = Queue::new();
        q.push(1);
        q.close();
        assert!(!q.push(2)); // rejected after close
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn concurrent_consumers_get_everything_once() {
        let q = Arc::new(Queue::new());
        let n = 10_000;
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for i in 0..n {
            q.push(i);
        }
        q.close();
        let mut all: Vec<i32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn blocked_pop_wakes_on_close() {
        let q: Arc<Queue<i32>> = Arc::new(Queue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }
}
