//! Software half-precision floats: IEEE 754 binary16 (`f16`, the paper's
//! "half") and bfloat16 (`bf16`, the TPU-native analog used by the MXU
//! mapping — see DESIGN.md §Hardware-Adaptation).
//!
//! Only conversion + round-to-nearest-even are needed: the CPU mirrors of
//! the tensor kernels compute in f32 and *round through* the half format
//! after every accumulate, exactly reproducing a half-precision C/D
//! matrix fragment (paper §IX-B).

/// Round an f32 to bfloat16 precision (round-to-nearest-even) and back.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    // NaN: keep quiet NaN
    if x.is_nan() {
        return f32::from_bits((bits | 0x0040_0000) & 0xFFFF_0000);
    }
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7FFF + lsb) & 0xFFFF_0000;
    let _ = round_bit;
    f32::from_bits(rounded)
}

/// Convert f32 -> IEEE binary16 bit pattern (round-to-nearest-even,
/// handling subnormals, overflow to infinity).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Re-bias: f32 exp-127, f16 exp-15
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal f16
        let e = (unbiased + 15) as u32;
        let m = mant >> 13;
        let rem = mant & 0x1FFF;
        let mut h = (sign as u32) | (e << 10) | m;
        // round to nearest even
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            h += 1; // may carry into exponent — that is correct rounding
        }
        return h as u16;
    }
    if unbiased >= -25 {
        // subnormal f16
        let full_mant = mant | 0x0080_0000;
        let shift = (-14 - unbiased) as u32 + 13;
        let m = full_mant >> shift;
        let rem = full_mant & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = (sign as u32) | m;
        if rem > half || (rem == half && (m & 1) == 1) {
            h += 1;
        }
        return h as u16;
    }
    sign // underflow to +-0
}

/// Convert IEEE binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf/nan
    } else if exp == 0 {
        // zero or subnormal: value = mant * 2^-24, exact in f32
        let v = mant as f32 * (1.0 / 16_777_216.0);
        return if sign != 0 { -v } else { v };
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through IEEE binary16 (the paper's half precision).
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Rounding mode used by the half-precision decode paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HalfKind {
    /// IEEE binary16 — what V100 tensor cores use (paper-faithful).
    F16,
    /// bfloat16 — what the TPU MXU uses (hardware-adaptation-faithful).
    Bf16,
}

impl HalfKind {
    #[inline]
    pub fn round(self, x: f32) -> f32 {
        match self {
            HalfKind::F16 => f16_round(x),
            HalfKind::Bf16 => bf16_round(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_exact_values() {
        // +-1, small integers and powers of two are exact in bf16
        for v in [0.0f32, 1.0, -1.0, 2.0, 0.5, -0.25, 128.0] {
            assert_eq!(bf16_round(v), v);
        }
    }

    #[test]
    fn bf16_rounds_mantissa() {
        // bf16 has 8 total mantissa bits (7 stored): 1 + 2^-9 rounds to 1
        assert_eq!(bf16_round(1.0 + 1.0 / 512.0), 1.0);
        // 1 + 2^-7 is representable
        let x = 1.0 + 1.0 / 128.0;
        assert_eq!(bf16_round(x), x);
    }

    #[test]
    fn f16_roundtrip_exact() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, -65504.0, 1.0 / 1024.0] {
            assert_eq!(f16_round(v), v, "{v}");
        }
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert!(f16_round(70000.0).is_infinite());
        assert!(f16_round(-70000.0).is_infinite());
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 5.96e-8; // smallest positive f16 subnormal ~5.96e-8
        let r = f16_round(tiny);
        assert!(r > 0.0 && r < 1.2e-7, "{r}");
        assert_eq!(f16_round(1e-9), 0.0); // underflow
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 2048 + 1 = 2049 is not representable (11-bit significand);
        // rounds to 2048 (even). 2048+3 rounds to 2052.
        assert_eq!(f16_round(2049.0), 2048.0);
        assert_eq!(f16_round(2051.0), 2052.0);
    }

    #[test]
    fn f16_sweep_roundtrip_monotone() {
        // every f16 value round-trips bit-exactly through f32
        for h in 0..=0xFFFFu16 {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(f), h, "h={h:#06x} f={f}");
        }
    }

    #[test]
    fn nan_stays_nan() {
        assert!(bf16_round(f32::NAN).is_nan());
        assert!(f16_round(f32::NAN).is_nan());
    }
}
