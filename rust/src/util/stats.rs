//! Latency/throughput statistics for the coordinator and benches:
//! streaming mean/variance (Welford) and a fixed-bucket log-scale
//! histogram with percentile queries.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Log-scale histogram over (0, ~17 min] in nanoseconds: 64 buckets per
/// power of two. Percentile error is bounded by the bucket width (<1.6%).
#[derive(Clone)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
}

const SUB: usize = 64; // sub-buckets per octave
const OCTAVES: usize = 40; // up to 2^40 ns ≈ 18 min

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { buckets: vec![0; SUB * OCTAVES], count: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn index(v: u64) -> usize {
        let v = v.max(1);
        let oct = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let sub = if oct == 0 {
            0
        } else if oct <= 6 {
            // small values: spread over available low bits
            ((v - (1 << oct)) as usize) << (6 - oct)
        } else {
            ((v >> (oct - 6)) - 64) as usize
        };
        (oct.min(OCTAVES - 1)) * SUB + sub.min(SUB - 1)
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate value at percentile p in [0, 100].
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let oct = i / SUB;
                let sub = (i % SUB) as u64;
                let base = 1u64 << oct;
                let width = if oct <= 6 { 1u64.max(base >> 6) } else { base >> 6 };
                return base + sub * width;
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_close() {
        let mut h = LogHistogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000); // 1us .. 10ms
        }
        let p50 = h.percentile(50.0) as f64;
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.05, "p50={p50}");
        let p99 = h.percentile(99.0) as f64;
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn histogram_handles_extremes() {
        let mut h = LogHistogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.percentile(10.0) <= 2);
    }

    #[test]
    fn merge_sums() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(100);
        b.record(200);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
