//! TOML-subset parser for the config system (offline image: no external
//! TOML crate). Supports:
//!
//! * `[table]` and `[table.subtable]` headers
//! * `key = value` with string / integer / float / boolean / array values
//! * `#` comments, blank lines
//!
//! This covers everything `tcvd.toml` uses; unsupported syntax errors out
//! loudly instead of mis-parsing.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_i64()?;
        usize::try_from(v).map_err(|_| anyhow!("negative integer {v}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Flat document: dotted table path -> key -> value.
#[derive(Clone, Debug, Default)]
pub struct Toml {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut table = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated table header", ln + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty table name", ln + 1);
                }
                table = name.to_string();
                doc.tables.entry(table.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", ln + 1))?;
            let key = k.trim();
            if key.is_empty() {
                bail!("line {}: empty key", ln + 1);
            }
            let val = parse_value(v.trim())
                .map_err(|e| anyhow!("line {}: {e}", ln + 1))?;
            doc.tables.entry(table.clone()).or_default().insert(key.to_string(), val);
        }
        Ok(doc)
    }

    /// Look up `table.key`; empty table name addresses top-level keys.
    pub fn get(&self, table: &str, key: &str) -> Option<&Value> {
        self.tables.get(table).and_then(|t| t.get(key))
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a string literal must not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        if inner.contains('"') {
            bail!("embedded quote in string (escapes unsupported)");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Arr(items));
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_types() {
        let doc = Toml::parse(
            r#"
# top comment
top = 1

[frame]
f = 64          # decoded bits per frame
overlap = 24
name = "radix4"
ratio = 0.5
flag = true
sizes = [1, 2, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_i64().unwrap(), 1);
        assert_eq!(doc.get("frame", "f").unwrap().as_i64().unwrap(), 64);
        assert_eq!(doc.get("frame", "name").unwrap().as_str().unwrap(), "radix4");
        assert_eq!(doc.get("frame", "ratio").unwrap().as_f64().unwrap(), 0.5);
        assert!(doc.get("frame", "flag").unwrap().as_bool().unwrap());
        assert_eq!(
            doc.get("frame", "sizes").unwrap(),
            &Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
    }

    #[test]
    fn dotted_tables() {
        let doc = Toml::parse("[a.b]\nx = 2\n").unwrap();
        assert_eq!(doc.get("a.b", "x").unwrap().as_i64().unwrap(), 2);
    }

    #[test]
    fn hash_inside_string() {
        let doc = Toml::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors_are_loud() {
        assert!(Toml::parse("[open\n").is_err());
        assert!(Toml::parse("novalue\n").is_err());
        assert!(Toml::parse("k = @bad\n").is_err());
    }
}
