//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core, plus a
//! Box–Muller Gaussian source for the AWGN channel. No external crates.
//!
//! xoshiro256** (Blackman & Vigna) passes BigCrush and is the default
//! engine in several standard libraries; SplitMix64 is the recommended
//! seed expander for it.

/// SplitMix64 step: expands a u64 seed into a stream of well-mixed u64s.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG with Box–Muller Gaussian caching.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a u64 (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent stream (for per-thread / per-session RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling (biased by < 2^-64
        // only when n is astronomically large; fine for simulation use).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// A single random bit.
    #[inline]
    pub fn next_bit(&mut self) -> u8 {
        (self.next_u64() >> 63) as u8
    }

    /// Fill with uniform random bits (0/1 bytes).
    pub fn bits(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.next_bit()).collect()
    }

    /// Standard normal via Box–Muller (caches the second deviate).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_cache.take() {
            return g;
        }
        // u in (0,1] so ln(u) is finite.
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_cache = Some(r * s);
        r * c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            m += g;
            v += g * g;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn fork_independent() {
        let mut a = Rng::new(5);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
