//! Seeded randomized property-test runner (offline image: no proptest).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it reports the failing case index and re-derivable
//! seed instead of shrinking. Deterministic by construction: the same
//! seed always replays the same cases.

use super::rng::Rng;

/// Run a property over generated cases; panic with a replayable seed on
/// the first failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (replay seed {case_seed:#x}):\n  \
                 input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Generator helpers used by the property tests.
pub mod gen {
    use super::super::rng::Rng;

    /// Random bit payload of length in [lo, hi].
    pub fn bits(rng: &mut Rng, lo: usize, hi: usize) -> Vec<u8> {
        let n = lo + rng.next_below((hi - lo + 1) as u64) as usize;
        rng.bits(n)
    }

    /// Generic continuous LLRs (no ties in practice): gaussian around
    /// +-1 with the given noise sigma.
    pub fn llrs(rng: &mut Rng, n: usize, sigma: f64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let s = if rng.next_bit() == 0 { 1.0 } else { -1.0 };
                (s + sigma * rng.next_gaussian()) as f32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(1, 50, |r| r.next_below(100), |&x| {
            if x < 100 { Ok(()) } else { Err(format!("{x} >= 100")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        forall(2, 50, |r| r.next_below(100), |&x| {
            if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) }
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut first = Vec::new();
        forall(3, 10, |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second = Vec::new();
        forall(3, 10, |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
