//! Minimal JSON parser + writer (offline image: no serde). Parses the
//! artifact manifest written by `python/compile/aot.py` and emits bench
//! result files. Supports the full JSON value grammar; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a nonnegative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    // -- writer --------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < a.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for result emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // no surrogate-pair support needed for manifests
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the byte slice
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number {txt:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let j = obj(vec![
            ("name", s("tcvd")),
            ("n", num(8.0)),
            ("list", Json::Arr(vec![num(1.0), num(2.5)])),
        ]);
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(j, Json::Str("café é".into()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "code": {"k": 7, "polys_octal": ["171", "133"]},
          "artifacts": [{"name": "x", "batch": 8, "ops_per_stage": 0.5}]
        }"#;
        let j = Json::parse(text).unwrap();
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("batch").unwrap().as_usize().unwrap(), 8);
        assert_eq!(a.get("ops_per_stage").unwrap().as_f64().unwrap(), 0.5);
    }
}
