//! Compact bit storage + the paper's §III output compaction (32 decoded
//! bits per 32-bit word) used on the coordinator's output path.

/// A growable bit vector packed into u32 words (LSB-first within a word,
/// matching the paper's "every 32 output decoded bits as a 32-bit value").
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u32>,
    len: usize,
}

impl BitVec {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bits: usize) -> Self {
        BitVec { words: Vec::with_capacity(bits.div_ceil(32)), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn push(&mut self, bit: u8) {
        let (w, b) = (self.len / 32, self.len % 32);
        if b == 0 {
            self.words.push(0);
        }
        self.words[w] |= ((bit & 1) as u32) << b;
        self.len += 1;
    }

    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        assert!(i < self.len, "bit index {i} out of range {}", self.len);
        ((self.words[i / 32] >> (i % 32)) & 1) as u8
    }

    #[inline]
    pub fn set(&mut self, i: usize, bit: u8) {
        assert!(i < self.len);
        let (w, b) = (i / 32, i % 32);
        self.words[w] = (self.words[w] & !(1 << b)) | (((bit & 1) as u32) << b);
    }

    /// Raw packed words (the wire format of the coordinator output).
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    pub fn from_bits(bits: &[u8]) -> Self {
        let mut v = BitVec::with_capacity(bits.len());
        for &b in bits {
            v.push(b);
        }
        v
    }

    pub fn to_bits(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Append another bitvec.
    pub fn extend(&mut self, other: &BitVec) {
        for i in 0..other.len {
            self.push(other.get(i));
        }
    }

    /// Count positions where two equal-length bitvecs differ (bit errors).
    pub fn hamming(&self, other: &BitVec) -> usize {
        assert_eq!(self.len, other.len, "length mismatch");
        let mut d = 0usize;
        for (i, (a, b)) in self.words.iter().zip(&other.words).enumerate() {
            let mut x = a ^ b;
            if (i + 1) * 32 > self.len {
                x &= (1u32 << (self.len % 32)) - 1;
            }
            d += x.count_ones() as usize;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_get_roundtrip() {
        let bits: Vec<u8> = (0..100).map(|i| (i % 3 == 0) as u8).collect();
        let v = BitVec::from_bits(&bits);
        assert_eq!(v.len(), 100);
        assert_eq!(v.to_bits(), bits);
    }

    #[test]
    fn word_packing_lsb_first() {
        let mut v = BitVec::new();
        v.push(1);
        v.push(0);
        v.push(1);
        assert_eq!(v.words()[0], 0b101);
    }

    #[test]
    fn set_overwrites() {
        let mut v = BitVec::from_bits(&[0, 0, 0, 0]);
        v.set(2, 1);
        assert_eq!(v.to_bits(), vec![0, 0, 1, 0]);
        v.set(2, 0);
        assert_eq!(v.to_bits(), vec![0, 0, 0, 0]);
    }

    #[test]
    fn hamming_counts_errors() {
        let a = BitVec::from_bits(&[1, 0, 1, 1, 0]);
        let b = BitVec::from_bits(&[1, 1, 1, 0, 0]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn hamming_masks_tail() {
        // differences beyond len must not count
        let mut a = BitVec::from_bits(&[1; 33]);
        let mut b = BitVec::from_bits(&[1; 33]);
        a.push(1);
        b.push(0);
        assert_eq!(a.hamming(&b), 1);
    }

    #[test]
    fn extend_concatenates() {
        let mut a = BitVec::from_bits(&[1, 0]);
        let b = BitVec::from_bits(&[1, 1, 0]);
        a.extend(&b);
        assert_eq!(a.to_bits(), vec![1, 0, 1, 1, 0]);
    }
}
