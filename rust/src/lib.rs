//! tcvd — Tensor-formulated parallel Viterbi decoder.
//!
//! Reproduction of "High-Throughput Parallel Viterbi Decoder on GPU Tensor
//! Cores" (Mohammadidoost & Hashemi, 2020) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) express the
//!   paper's tensor-core ACS formulation (radix-2 butterflies, radix-4
//!   dragonflies, dragonfly-group permutation) as MXU matmuls.
//! * **L2** — a JAX model (`python/compile/model.py`) scans the kernel
//!   over a frame and is AOT-lowered to HLO text (`make artifacts`).
//! * **L3** — this crate: a streaming SDR coordinator that frames LLR
//!   streams, batches frames across sessions, executes the AOT artifact
//!   on a PJRT CPU client, and performs traceback + reassembly on the
//!   hot path. Python is never on the request path.
//!
//! The supported entry point is the builder-first facade in [`api`]:
//! [`DecoderBuilder`] validates one coherent parameter set and lowers
//! it to either a one-shot [`Decoder`] or the serving
//! [`Coordinator`](coordinator::Coordinator), which scales across
//! engine shards ([`api::DecoderBuilder::shards`]). All public entry
//! points report the typed [`Error`]; `anyhow` is internal plumbing
//! only. The serving pipeline's data flow, threading model and
//! ordering guarantees are documented in `docs/ARCHITECTURE.md`.
//!
//! # Quick start
//!
//! One-shot decoding on the scalar baseline (no artifacts needed):
//!
//! ```
//! use tcvd::{BackendKind, DecoderBuilder};
//!
//! let mut dec = DecoderBuilder::new()
//!     .backend(BackendKind::Scalar)
//!     .tile_dims(16, 0, 0)
//!     .build()?;
//! // 16 trellis stages of rate-1/2 LLRs (positive LLR ⇒ bit 0)
//! let bits = dec.decode_stream(&vec![1.0f32; 16 * 2])?;
//! assert_eq!(bits, vec![0u8; 16]);
//! # Ok::<(), tcvd::Error>(())
//! ```
//!
//! Streaming many concurrent sessions through the sharded coordinator:
//!
//! ```
//! use tcvd::{BackendKind, DecoderBuilder};
//!
//! let coord = DecoderBuilder::new()
//!     .backend(BackendKind::cpu("radix4"))
//!     .tile_dims(32, 16, 16)
//!     .shards(2) // two engine threads, each with its own backend
//!     .serve()?;
//! let mut session = coord.open_session()?;
//! session.push(&vec![0.5f32; 32 * 2])?;
//! let bits = session.finish_and_collect()?;
//! assert_eq!(bits.len(), 32);
//! // per-shard counters: frames, execs, steals, queue depth
//! assert_eq!(coord.metrics().shards.len(), 2);
//! coord.shutdown()?;
//! # Ok::<(), tcvd::Error>(())
//! ```

pub mod util;
pub mod error;
pub mod fault;
pub mod defaults;
pub mod cli;
pub mod coding;
pub mod channel;
pub mod viterbi;
pub mod ber;
pub mod config;
pub mod runtime;
pub mod coordinator;
pub mod net;
pub mod api;

pub use api::{BackendKind, Decoder, DecoderBuilder};
pub use coding::TerminationMode;
pub use error::{Error, Result};
