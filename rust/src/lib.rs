//! tcvd — Tensor-formulated parallel Viterbi decoder.
//!
//! Reproduction of "High-Throughput Parallel Viterbi Decoder on GPU Tensor
//! Cores" (Mohammadidoost & Hashemi, 2020) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) express the
//!   paper's tensor-core ACS formulation (radix-2 butterflies, radix-4
//!   dragonflies, dragonfly-group permutation) as MXU matmuls.
//! * **L2** — a JAX model (`python/compile/model.py`) scans the kernel
//!   over a frame and is AOT-lowered to HLO text (`make artifacts`).
//! * **L3** — this crate: a streaming SDR coordinator that frames LLR
//!   streams, batches frames across sessions, executes the AOT artifact
//!   on a PJRT CPU client, and performs traceback + reassembly on the
//!   hot path. Python is never on the request path.
//!
//! The supported entry point is the builder-first facade in [`api`]:
//! [`DecoderBuilder`] validates one coherent parameter set and lowers
//! it to either a one-shot [`Decoder`] or the serving
//! [`Coordinator`](coordinator::Coordinator). All public entry points
//! report the typed [`Error`]; `anyhow` is internal plumbing only.

pub mod util;
pub mod error;
pub mod defaults;
pub mod cli;
pub mod coding;
pub mod channel;
pub mod viterbi;
pub mod ber;
pub mod config;
pub mod runtime;
pub mod coordinator;
pub mod api;

pub use api::{BackendKind, Decoder, DecoderBuilder};
pub use error::{Error, Result};
