//! Typed configuration for the `tcvd` binary: a `tcvd.toml` file
//! (parsed by the built-in TOML-subset parser).
//!
//! `Config` is a thin file-format view; the supported construction path
//! is [`crate::api::DecoderBuilder::from_toml`] (+ CLI-flag overrides
//! via [`crate::api::DecoderBuilder::apply_flags`]), which consumes
//! this struct and owns validation. Defaults mirror
//! [`crate::defaults`].

use std::path::Path;

use crate::defaults;
use crate::error::{Error, Result, ResultExt};
use crate::util::toml::Toml;
use crate::viterbi::tiled::TileConfig;

/// Parsed `tcvd.toml` contents, with defaults for missing keys.
#[derive(Clone, Debug)]
pub struct Config {
    /// Standard code name (registry key).
    pub code: String,
    /// Backend name (see `api::BACKEND_NAMES`).
    pub backend: String,
    /// Tile geometry for stream decoding.
    pub tile: TileConfig,
    /// Artifact directory.
    pub artifacts_dir: String,
    /// Preferred artifact variant name (or unique substring).
    pub variant: String,
    /// Dynamic batcher: max frames per PJRT execution (<= artifact batch).
    pub max_batch: usize,
    /// Dynamic batcher: flush deadline in microseconds.
    pub batch_deadline_us: u64,
    /// Traceback worker threads.
    pub workers: usize,
    /// Bounded queue depth (frames) before backpressure.
    pub queue_depth: usize,
    /// Engine shards (backend instances); default: available parallelism.
    pub shards: usize,
    /// Trellis stages folded per `simd` ACS pass (radix-2^rho, 1 or
    /// 2); validated against the code/tile geometry when the builder
    /// consumes this config.
    pub radix: usize,
    /// Stream termination mode name (see
    /// `coding::TerminationMode::NAMES`); validated when the builder
    /// consumes this config.
    pub termination: String,
    /// `[net] listen`: TCP listen address for `tcvd serve` (absent =
    /// no TCP serving unless given on the command line).
    pub net_listen: Option<String>,
    /// `[net] udp`: UDP bind address for `tcvd serve`.
    pub net_udp: Option<String>,
    /// `[net] max_sessions`: concurrent-session cap (TCP + UDP flows).
    pub net_max_sessions: usize,
    /// `[net] idle_timeout_ms`: idle session eviction timeout.
    pub net_idle_timeout_ms: u64,
    /// `[net] shed_queue_depth`: shed admissions once the summed shard
    /// queue depth reaches this (absent = the pipeline `queue_depth`).
    pub net_shed_queue_depth: Option<usize>,
    /// `[net] write_high_water`: per-connection outbound buffer
    /// high-water mark in bytes (slow-reader backpressure bound).
    pub net_write_high_water: usize,
    /// `[net] crc`: require a CRC32 on every DATA frame, even from
    /// clients that did not offer one in their HELLO.
    pub net_crc: bool,
    /// `[net] poller`: reactor readiness backend, `"auto"` (epoll on
    /// Linux, poll elsewhere), `"poll"` or `"epoll"`.
    pub net_poller: String,
    /// `[net] udp_batch`: UDP reply batching factor (datagrams per
    /// batched flush; 1 disables batching).
    pub net_udp_batch: usize,
    /// `[fault] points`: deterministic failpoint spec
    /// (`site=trigger,...`; see `docs/RELIABILITY.md`). Rejected at
    /// pipeline start unless the crate was compiled with
    /// `--features failpoints`.
    pub fault_points: Option<String>,
    /// `[coordinator] max_restarts`: supervised restart budget per
    /// engine shard before the shard is declared dead.
    pub max_restarts: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            code: defaults::CODE.into(),
            backend: defaults::BACKEND.into(),
            tile: defaults::TILE,
            artifacts_dir: defaults::ARTIFACTS_DIR.into(),
            variant: defaults::VARIANT.into(),
            max_batch: defaults::MAX_BATCH,
            batch_deadline_us: defaults::BATCH_DEADLINE_US,
            workers: defaults::WORKERS,
            queue_depth: defaults::QUEUE_DEPTH,
            shards: defaults::default_shards(),
            radix: defaults::RADIX,
            termination: defaults::TERMINATION.as_str().to_string(),
            net_listen: None,
            net_udp: None,
            net_max_sessions: defaults::NET_MAX_SESSIONS,
            net_idle_timeout_ms: defaults::NET_IDLE_TIMEOUT_MS,
            net_shed_queue_depth: None,
            net_write_high_water: defaults::NET_WRITE_HIGH_WATER,
            net_crc: false,
            net_poller: defaults::NET_POLLER.into(),
            net_udp_batch: defaults::NET_UDP_BATCH,
            fault_points: None,
            max_restarts: defaults::MAX_SHARD_RESTARTS,
        }
    }
}

impl Config {
    /// Load from a TOML file, with defaults for missing keys.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .or_config(format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Parse TOML text, with defaults for missing keys.
    pub fn from_toml(text: &str) -> Result<Config> {
        let doc = Toml::parse(text).or_config("parsing TOML")?;
        let mut cfg = Config::default();
        if let Some(v) = doc.get("", "code") {
            cfg.code = v.as_str().or_config("code")?.to_string();
        }
        if let Some(v) = doc.get("", "backend") {
            cfg.backend = v.as_str().or_config("backend")?.to_string();
        }
        if let Some(v) = doc.get("tile", "payload") {
            cfg.tile.payload = v.as_usize().or_config("tile.payload")?;
        }
        if let Some(v) = doc.get("tile", "head") {
            cfg.tile.head = v.as_usize().or_config("tile.head")?;
        }
        if let Some(v) = doc.get("tile", "tail") {
            cfg.tile.tail = v.as_usize().or_config("tile.tail")?;
        }
        if let Some(v) = doc.get("runtime", "artifacts_dir") {
            cfg.artifacts_dir = v.as_str().or_config("runtime.artifacts_dir")?.to_string();
        }
        if let Some(v) = doc.get("runtime", "variant") {
            cfg.variant = v.as_str().or_config("runtime.variant")?.to_string();
        }
        if let Some(v) = doc.get("runtime", "backend") {
            cfg.backend = v.as_str().or_config("runtime.backend")?.to_string();
        }
        if let Some(v) = doc.get("coordinator", "max_batch") {
            cfg.max_batch = v.as_usize().or_config("coordinator.max_batch")?;
        }
        if let Some(v) = doc.get("coordinator", "batch_deadline_us") {
            cfg.batch_deadline_us =
                v.as_usize().or_config("coordinator.batch_deadline_us")? as u64;
        }
        if let Some(v) = doc.get("coordinator", "workers") {
            cfg.workers = v.as_usize().or_config("coordinator.workers")?;
        }
        if let Some(v) = doc.get("coordinator", "queue_depth") {
            cfg.queue_depth = v.as_usize().or_config("coordinator.queue_depth")?;
        }
        if let Some(v) = doc.get("coordinator", "shards") {
            cfg.shards = v.as_usize().or_config("coordinator.shards")?;
        }
        if let Some(v) = doc.get("coordinator", "max_restarts") {
            cfg.max_restarts = v.as_usize().or_config("coordinator.max_restarts")?;
        }
        if let Some(v) = doc.get("fault", "points") {
            cfg.fault_points = Some(v.as_str().or_config("fault.points")?.to_string());
        }
        if let Some(v) = doc.get("", "radix") {
            cfg.radix = v.as_usize().or_config("radix")?;
        }
        if let Some(v) = doc.get("", "termination") {
            cfg.termination = v.as_str().or_config("termination")?.to_string();
        }
        if let Some(v) = doc.get("net", "listen") {
            cfg.net_listen = Some(v.as_str().or_config("net.listen")?.to_string());
        }
        if let Some(v) = doc.get("net", "udp") {
            cfg.net_udp = Some(v.as_str().or_config("net.udp")?.to_string());
        }
        if let Some(v) = doc.get("net", "max_sessions") {
            cfg.net_max_sessions = v.as_usize().or_config("net.max_sessions")?;
        }
        if let Some(v) = doc.get("net", "idle_timeout_ms") {
            cfg.net_idle_timeout_ms = v.as_usize().or_config("net.idle_timeout_ms")? as u64;
        }
        if let Some(v) = doc.get("net", "shed_queue_depth") {
            cfg.net_shed_queue_depth = Some(v.as_usize().or_config("net.shed_queue_depth")?);
        }
        if let Some(v) = doc.get("net", "write_high_water") {
            cfg.net_write_high_water = v.as_usize().or_config("net.write_high_water")?;
        }
        if let Some(v) = doc.get("net", "crc") {
            cfg.net_crc = v.as_bool().or_config("net.crc")?;
        }
        if let Some(v) = doc.get("net", "poller") {
            cfg.net_poller = v.as_str().or_config("net.poller")?.to_string();
        }
        if let Some(v) = doc.get("net", "udp_batch") {
            cfg.net_udp_batch = v.as_usize().or_config("net.udp_batch")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural sanity checks (full validation happens in the
    /// builder, which also knows the backend semantics).
    pub fn validate(&self) -> Result<()> {
        if self.tile.payload == 0 {
            return Err(Error::config("tile.payload must be positive"));
        }
        if self.max_batch == 0 {
            return Err(Error::config("max_batch must be positive"));
        }
        if self.workers == 0 {
            return Err(Error::config("workers must be positive"));
        }
        if self.shards == 0 {
            return Err(Error::config("shards must be positive"));
        }
        if self.queue_depth < self.max_batch {
            return Err(Error::config(format!(
                "queue_depth ({}) must be >= max_batch ({})",
                self.queue_depth, self.max_batch
            )));
        }
        if self.net_max_sessions == 0 {
            return Err(Error::config("net.max_sessions must be positive"));
        }
        if self.net_idle_timeout_ms == 0 {
            return Err(Error::config("net.idle_timeout_ms must be positive"));
        }
        if self.net_write_high_water == 0 {
            return Err(Error::config("net.write_high_water must be positive"));
        }
        if crate::net::reactor::PollerKind::parse(&self.net_poller).is_none() {
            return Err(Error::config(format!(
                "net.poller must be \"auto\", \"poll\" or \"epoll\" (got {:?})",
                self.net_poller
            )));
        }
        if self.net_udp_batch == 0 {
            return Err(Error::config("net.udp_batch must be positive"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn defaults_come_from_defaults_module() {
        let cfg = Config::default();
        assert_eq!(cfg.code, defaults::CODE);
        assert_eq!(cfg.backend, defaults::BACKEND);
        assert_eq!(cfg.variant, defaults::VARIANT);
        assert_eq!(cfg.tile.frame_stages(), defaults::TILE.frame_stages());
    }

    #[test]
    fn parses_compact_backend() {
        let cfg = Config::from_toml("backend = \"compact\"\n").unwrap();
        assert_eq!(cfg.backend, "compact");
    }

    #[test]
    fn parses_simd_backend() {
        let cfg = Config::from_toml("backend = \"simd\"\n").unwrap();
        assert_eq!(cfg.backend, "simd");
        crate::api::DecoderBuilder::from_config(&cfg).unwrap();
    }

    #[test]
    fn parses_radix() {
        assert_eq!(Config::default().radix, defaults::RADIX);
        let cfg = Config::from_toml("backend = \"simd\"\nradix = 2\n").unwrap();
        assert_eq!(cfg.radix, 2);
        let b = crate::api::DecoderBuilder::from_config(&cfg).unwrap();
        b.validate().unwrap();
        // an out-of-range radix is rejected when the builder validates
        let bad = Config::from_toml("backend = \"simd\"\nradix = 3\n").unwrap();
        let b = crate::api::DecoderBuilder::from_config(&bad).unwrap();
        assert!(b.validate().is_err());
    }

    #[test]
    fn parses_termination() {
        use crate::coding::TerminationMode;
        let cfg = Config::from_toml("termination = \"tail-biting\"\n").unwrap();
        assert_eq!(cfg.termination, "tail-biting");
        let b = crate::api::DecoderBuilder::from_config(&cfg).unwrap();
        assert_eq!(b.termination_mode(), TerminationMode::TailBiting);
        assert_eq!(Config::default().termination, "flushed");
        // an unknown mode name is rejected when the builder consumes it
        let bad = Config::from_toml("termination = \"rocket\"\n").unwrap();
        assert!(crate::api::DecoderBuilder::from_config(&bad).is_err());
    }

    #[test]
    fn parses_full_config() {
        let cfg = Config::from_toml(
            r#"
code = "gsm"
backend = "cpu-radix4"

[tile]
payload = 128
head = 24
tail = 24

[runtime]
variant = "radix2"

[coordinator]
max_batch = 8
batch_deadline_us = 500
workers = 4
queue_depth = 64
shards = 6
"#,
        )
        .unwrap();
        assert_eq!(cfg.code, "gsm");
        assert_eq!(cfg.backend, "cpu-radix4");
        assert_eq!(cfg.tile.payload, 128);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.shards, 6);
    }

    #[test]
    fn parses_net_section() {
        let cfg = Config::from_toml(
            "[net]\nlisten = \"127.0.0.1:7000\"\nudp = \"127.0.0.1:7001\"\n\
             max_sessions = 64\nidle_timeout_ms = 5000\nshed_queue_depth = 48\n\
             write_high_water = 65536\ncrc = true\npoller = \"epoll\"\nudp_batch = 16\n",
        )
        .unwrap();
        assert_eq!(cfg.net_listen.as_deref(), Some("127.0.0.1:7000"));
        assert_eq!(cfg.net_udp.as_deref(), Some("127.0.0.1:7001"));
        assert_eq!(cfg.net_max_sessions, 64);
        assert_eq!(cfg.net_idle_timeout_ms, 5000);
        assert_eq!(cfg.net_shed_queue_depth, Some(48));
        assert_eq!(cfg.net_write_high_water, 65536);
        assert!(cfg.net_crc);
        assert_eq!(cfg.net_poller, "epoll");
        assert_eq!(cfg.net_udp_batch, 16);
        // defaults: no listen addresses, defaults-module cap/timeout
        let d = Config::default();
        assert_eq!(d.net_listen, None);
        assert_eq!(d.net_max_sessions, defaults::NET_MAX_SESSIONS);
        assert_eq!(d.net_shed_queue_depth, None);
        assert_eq!(d.net_write_high_water, defaults::NET_WRITE_HIGH_WATER);
        assert!(!d.net_crc);
        assert_eq!(d.net_poller, defaults::NET_POLLER);
        assert_eq!(d.net_udp_batch, defaults::NET_UDP_BATCH);
        // net bounds are validated structurally
        assert!(Config::from_toml("[net]\nmax_sessions = 0\n").is_err());
        assert!(Config::from_toml("[net]\nidle_timeout_ms = 0\n").is_err());
        assert!(Config::from_toml("[net]\nwrite_high_water = 0\n").is_err());
        assert!(Config::from_toml("[net]\ncrc = 7\n").is_err());
        assert!(Config::from_toml("[net]\npoller = \"kqueue\"\n").is_err());
        assert!(Config::from_toml("[net]\nudp_batch = 0\n").is_err());
        // the NetConfig lowering carries the new knobs through
        let net = crate::net::NetConfig::from_config(&cfg);
        assert_eq!(net.poller, crate::net::PollerKind::Epoll);
        assert_eq!(net.udp_batch, 16);
    }

    #[test]
    fn parses_fault_section() {
        let cfg = Config::from_toml(
            "[coordinator]\nmax_restarts = 3\n\n[fault]\npoints = \"engine.exec=hit:2\"\n",
        )
        .unwrap();
        assert_eq!(cfg.fault_points.as_deref(), Some("engine.exec=hit:2"));
        assert_eq!(cfg.max_restarts, 3);
        // defaults: no failpoints armed, defaults-module restart budget
        let d = Config::default();
        assert_eq!(d.fault_points, None);
        assert_eq!(d.max_restarts, defaults::MAX_SHARD_RESTARTS);
        // and the builder carries both through
        let b = crate::api::DecoderBuilder::from_config(&cfg).unwrap();
        assert_eq!(b.to_coordinator_config().max_restarts, 3);
    }

    #[test]
    fn rejects_invalid() {
        let e = Config::from_toml("[coordinator]\nmax_batch = 0\n").unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        assert!(Config::from_toml("[coordinator]\nqueue_depth = 1\n").is_err());
        assert!(Config::from_toml("[coordinator]\nshards = 0\n").is_err());
    }
}
