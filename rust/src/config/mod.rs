//! Typed configuration for the `tcvd` binary: a `tcvd.toml` file (parsed
//! by the built-in TOML-subset parser) merged with CLI overrides.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::toml::Toml;
use crate::viterbi::tiled::TileConfig;

/// Full runtime configuration with defaults matching the paper's setup.
#[derive(Clone, Debug)]
pub struct Config {
    /// Standard code name (registry key).
    pub code: String,
    /// Tile geometry for stream decoding.
    pub tile: TileConfig,
    /// Artifact directory.
    pub artifacts_dir: String,
    /// Preferred artifact variant name substring (e.g. "radix4_jnp_acc-single_ch-single").
    pub variant: String,
    /// Dynamic batcher: max frames per PJRT execution (<= artifact batch).
    pub max_batch: usize,
    /// Dynamic batcher: flush deadline in microseconds.
    pub batch_deadline_us: u64,
    /// Traceback worker threads.
    pub workers: usize,
    /// Bounded queue depth (frames) before backpressure.
    pub queue_depth: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            code: "ccsds".into(),
            tile: TileConfig { payload: 64, head: 16, tail: 16 },
            artifacts_dir: "artifacts".into(),
            variant: "radix4_jnp_acc-single_ch-single_b64".into(),
            max_batch: 64,
            batch_deadline_us: 2000,
            workers: 2,
            queue_depth: 1024,
        }
    }
}

impl Config {
    /// Load from a TOML file, with defaults for missing keys.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Config> {
        let doc = Toml::parse(text)?;
        let mut cfg = Config::default();
        if let Some(v) = doc.get("", "code") {
            cfg.code = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("tile", "payload") {
            cfg.tile.payload = v.as_usize()?;
        }
        if let Some(v) = doc.get("tile", "head") {
            cfg.tile.head = v.as_usize()?;
        }
        if let Some(v) = doc.get("tile", "tail") {
            cfg.tile.tail = v.as_usize()?;
        }
        if let Some(v) = doc.get("runtime", "artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("runtime", "variant") {
            cfg.variant = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("coordinator", "max_batch") {
            cfg.max_batch = v.as_usize()?;
        }
        if let Some(v) = doc.get("coordinator", "batch_deadline_us") {
            cfg.batch_deadline_us = v.as_usize()? as u64;
        }
        if let Some(v) = doc.get("coordinator", "workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("coordinator", "queue_depth") {
            cfg.queue_depth = v.as_usize()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.tile.payload > 0, "tile.payload must be positive");
        anyhow::ensure!(self.max_batch > 0, "max_batch must be positive");
        anyhow::ensure!(self.workers > 0, "workers must be positive");
        anyhow::ensure!(self.queue_depth >= self.max_batch,
                        "queue_depth must be >= max_batch");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let cfg = Config::from_toml(
            r#"
code = "gsm"

[tile]
payload = 128
head = 24
tail = 24

[runtime]
variant = "radix2"

[coordinator]
max_batch = 8
batch_deadline_us = 500
workers = 4
queue_depth = 64
"#,
        )
        .unwrap();
        assert_eq!(cfg.code, "gsm");
        assert_eq!(cfg.tile.payload, 128);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.workers, 4);
    }

    #[test]
    fn rejects_invalid() {
        assert!(Config::from_toml("[coordinator]\nmax_batch = 0\n").is_err());
        assert!(Config::from_toml("[coordinator]\nqueue_depth = 1\n").is_err());
    }
}
