//! Deterministic failpoint injection for the serving pipeline.
//!
//! A *failpoint* is a named site in the pipeline (see [`site`]) where a
//! fault can be provoked on demand: the engine exec loop panicking
//! mid-batch, a backend rebuild failing, admission control shedding a
//! healthy request. Production code never trips them — the whole
//! mechanism compiles to an inlined `false` unless the crate is built
//! with the `failpoints` feature — but with the feature on, the chaos
//! suite (`rust/tests/fault_injection.rs`), the CI `failpoints` job and
//! manual soak runs can script exact failure sequences and assert the
//! supervisor's recovery behavior (see `docs/RELIABILITY.md`).
//!
//! # Arming
//!
//! A spec is a comma-separated list of `site=trigger` clauses:
//!
//! ```text
//! engine.exec=hit:3,net.shed=prob:0.05:42
//! ```
//!
//! armed through any of (highest precedence first):
//!
//! 1. the `TCVD_FAILPOINTS` environment variable,
//! 2. `DecoderBuilder::failpoints` / `tcvd serve --failpoints`,
//! 3. the TOML `[fault] points` key.
//!
//! Triggers:
//!
//! | trigger        | behavior                                          |
//! |----------------|---------------------------------------------------|
//! | `hit:N`        | fires exactly once, on the Nth visit (1-based)    |
//! | `every:N`      | fires on every Nth visit                          |
//! | `prob:P[:S]`   | fires with probability `P` per visit, seeded by   |
//! |                | `S` (default 0) — a pure hash of `(S, visit #)`,  |
//! |                | so a given spec replays the same fault sequence   |
//!
//! # Determinism
//!
//! There is no global registry: each [`Coordinator`] owns one
//! [`FaultMap`] (shared `Arc` across its shards, framer, reassembly and
//! the net front-end), so concurrently running tests cannot perturb
//! each other. `prob` triggers derive their decision from a counter
//! hash, not a clock or thread-local RNG, so a spec replays
//! identically run over run.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};

/// Failpoint site names. Arming a spec with a name outside this list is
/// a typed config error — a misspelled site must not silently never
/// fire.
pub mod site {
    /// Engine shard exec loop, fired with a batch in flight: a hit
    /// panics the shard worker mid-batch (the supervisor catches it,
    /// poisons the in-flight sessions and restarts the shard).
    pub const ENGINE_EXEC: &str = "engine.exec";
    /// Backend rebuild after a shard restart: a hit fails the build,
    /// forcing the supervisor one step down the degradation chain.
    pub const ENGINE_BUILD: &str = "engine.build";
    /// Session framer push: a hit surfaces a typed `Error::Pipeline`
    /// to the caller instead of accepting the chunk.
    pub const FRAMER_PUSH: &str = "framer.push";
    /// Reassembly delivery: a hit poisons the delivering session (its
    /// consumer sees the gapless prefix, then one typed error).
    pub const REASSEMBLY_DELIVER: &str = "reassembly.deliver";
    /// Net load-shed probe: a hit reports the shard queues as
    /// saturated, shedding the request with the retryable REJECT/SHED
    /// path.
    pub const NET_SHED: &str = "net.shed";
    /// Session-table admission: a hit denies the admission as if the
    /// session cap were reached.
    pub const NET_ADMIT: &str = "net.admit";

    /// Every valid site name (the catalog `parse` validates against).
    pub const ALL: &[&str] = &[
        ENGINE_EXEC,
        ENGINE_BUILD,
        FRAMER_PUSH,
        REASSEMBLY_DELIVER,
        NET_SHED,
        NET_ADMIT,
    ];
}

/// When an armed site fires, relative to its visit counter.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Trigger {
    /// Fire exactly once, on the `n`th visit (1-based).
    Hit { n: u64 },
    /// Fire on every `n`th visit.
    Every { n: u64 },
    /// Fire with probability `p` per visit, decided by a pure hash of
    /// `(seed, visit #)`.
    Prob { p: f64, seed: u64 },
}

/// One armed site: its trigger plus visit/fire counters.
#[derive(Debug)]
struct Armed {
    trigger: Trigger,
    hits: AtomicU64,
    fired: AtomicU64,
}

impl Armed {
    fn new(trigger: Trigger) -> Armed {
        Armed { trigger, hits: AtomicU64::new(0), fired: AtomicU64::new(0) }
    }

    fn fire(&self) -> bool {
        let visit = self.hits.fetch_add(1, Ordering::Relaxed) + 1;
        let hit = match self.trigger {
            Trigger::Hit { n } => visit == n,
            Trigger::Every { n } => visit % n == 0,
            Trigger::Prob { p, seed } => {
                // splitmix64 of (seed, visit): deterministic per spec,
                // independent of wall clock and thread interleaving
                let mut z = seed ^ visit.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
                z ^= z >> 31;
                (z >> 11) as f64 / (1u64 << 53) as f64 // uniform [0, 1)
            }
            .lt(&p),
        };
        if hit {
            self.fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }
}

/// The set of armed failpoints of one `Coordinator` (and the net
/// front-end serving it). `Default` is empty: every site reports "do
/// not fire". Parsing is always compiled (so specs are validated even
/// in production builds, which then refuse them with a typed error);
/// [`fire`](FaultMap::fire) only consults the map when the crate is
/// built with the `failpoints` feature and is an inlined `false`
/// otherwise.
#[derive(Debug, Default)]
pub struct FaultMap {
    sites: HashMap<&'static str, Armed>,
}

/// Whether failpoint injection is compiled into this build. When
/// `false`, arming a non-empty spec is a typed config error instead of
/// a silent no-op.
pub const fn enabled() -> bool {
    cfg!(feature = "failpoints")
}

impl FaultMap {
    /// Parse a spec (`site=trigger,site=trigger,...`) into an armed
    /// map. Unknown sites, malformed triggers and out-of-range
    /// parameters are typed config errors.
    pub fn parse(spec: &str) -> Result<FaultMap> {
        let mut sites = HashMap::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (name, trig) = clause.split_once('=').ok_or_else(|| {
                Error::config(format!("failpoint clause `{clause}` is not of the form site=trigger"))
            })?;
            let name = site::ALL.iter().find(|&&s| s == name.trim()).copied().ok_or_else(|| {
                Error::config(format!(
                    "unknown failpoint site `{}` (known sites: {})",
                    name.trim(),
                    site::ALL.join(", ")
                ))
            })?;
            sites.insert(name, Armed::new(Self::parse_trigger(trig.trim())?));
        }
        Ok(FaultMap { sites })
    }

    fn parse_trigger(t: &str) -> Result<Trigger> {
        let bad = |why: &str| Error::config(format!("failpoint trigger `{t}`: {why}"));
        let mut parts = t.split(':');
        let kind = parts.next().unwrap_or("");
        match kind {
            "hit" | "every" => {
                let n: u64 = parts
                    .next()
                    .ok_or_else(|| bad("missing count"))?
                    .parse()
                    .map_err(|_| bad("count is not an integer"))?;
                if n == 0 {
                    return Err(bad("count must be >= 1"));
                }
                if parts.next().is_some() {
                    return Err(bad("trailing fields"));
                }
                Ok(if kind == "hit" { Trigger::Hit { n } } else { Trigger::Every { n } })
            }
            "prob" => {
                let p: f64 = parts
                    .next()
                    .ok_or_else(|| bad("missing probability"))?
                    .parse()
                    .map_err(|_| bad("probability is not a number"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(bad("probability must be in [0, 1]"));
                }
                let seed: u64 = match parts.next() {
                    None => 0,
                    Some(s) => s.parse().map_err(|_| bad("seed is not an integer"))?,
                };
                if parts.next().is_some() {
                    return Err(bad("trailing fields"));
                }
                Ok(Trigger::Prob { p, seed })
            }
            _ => Err(bad("expected hit:N, every:N or prob:P[:SEED]")),
        }
    }

    /// True when no site is armed.
    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    /// Should the fault at `site` fire on this visit? The only call
    /// that belongs on hot paths: without the `failpoints` feature it
    /// is an inlined `false` (the map is never consulted and visit
    /// counters do not advance).
    #[cfg(feature = "failpoints")]
    pub fn fire(&self, site: &str) -> bool {
        self.sites.get(site).is_some_and(Armed::fire)
    }

    /// No-op stub: injection is not compiled into this build.
    #[cfg(not(feature = "failpoints"))]
    #[inline(always)]
    pub fn fire(&self, site: &str) -> bool {
        let _ = site;
        false
    }

    /// How many times `site` has fired (0 when unarmed or when the
    /// `failpoints` feature is off).
    pub fn fired(&self, site: &str) -> u64 {
        self.sites.get(site).map_or(0, |a| a.fired.load(Ordering::Relaxed))
    }

    /// How many times `site` has been visited (0 when unarmed or when
    /// the `failpoints` feature is off).
    pub fn hits(&self, site: &str) -> u64 {
        self.sites.get(site).map_or(0, |a| a.hits.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_grammar() {
        let m = FaultMap::parse("engine.exec=hit:3, net.shed=prob:0.5:42 ,framer.push=every:2")
            .unwrap();
        assert!(!m.is_empty());
        assert!(FaultMap::parse("").unwrap().is_empty());
        assert!(FaultMap::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_unknown_sites_and_bad_triggers() {
        for bad in [
            "engine.exce=hit:1",      // typo'd site
            "engine.exec",            // no trigger
            "engine.exec=hit",        // no count
            "engine.exec=hit:0",      // zero count
            "engine.exec=hit:1:2",    // trailing field
            "engine.exec=prob:1.5",   // out-of-range probability
            "engine.exec=prob:x",     // non-numeric
            "engine.exec=often:3",    // unknown trigger kind
        ] {
            let e = FaultMap::parse(bad).unwrap_err();
            assert!(matches!(e, Error::Config(_)), "{bad}: {e}");
        }
        let e = FaultMap::parse("bogus.site=hit:1").unwrap_err();
        assert!(e.to_string().contains("engine.exec"), "error lists known sites: {e}");
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let m = FaultMap::parse("engine.exec=hit:1").unwrap();
        assert!(!m.fire(site::NET_SHED));
        assert_eq!(m.fired(site::NET_SHED), 0);
    }

    #[cfg(not(feature = "failpoints"))]
    #[test]
    fn without_the_feature_armed_sites_are_noops() {
        let m = FaultMap::parse("engine.exec=hit:1,net.shed=prob:1.0").unwrap();
        for _ in 0..10 {
            assert!(!m.fire(site::ENGINE_EXEC));
            assert!(!m.fire(site::NET_SHED));
        }
        assert_eq!(m.fired(site::ENGINE_EXEC), 0);
        assert_eq!(m.hits(site::ENGINE_EXEC), 0);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn hit_fires_exactly_once_on_the_nth_visit() {
        let m = FaultMap::parse("engine.exec=hit:3").unwrap();
        let fires: Vec<bool> = (0..6).map(|_| m.fire(site::ENGINE_EXEC)).collect();
        assert_eq!(fires, vec![false, false, true, false, false, false]);
        assert_eq!(m.fired(site::ENGINE_EXEC), 1);
        assert_eq!(m.hits(site::ENGINE_EXEC), 6);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn every_fires_periodically() {
        let m = FaultMap::parse("reassembly.deliver=every:2").unwrap();
        let fires: Vec<bool> = (0..6).map(|_| m.fire(site::REASSEMBLY_DELIVER)).collect();
        assert_eq!(fires, vec![false, true, false, true, false, true]);
        assert_eq!(m.fired(site::REASSEMBLY_DELIVER), 3);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn prob_is_deterministic_per_seed_and_roughly_calibrated() {
        let run = |spec: &str| -> Vec<bool> {
            let m = FaultMap::parse(spec).unwrap();
            (0..1000).map(|_| m.fire(site::NET_SHED)).collect()
        };
        let a = run("net.shed=prob:0.3:7");
        assert_eq!(a, run("net.shed=prob:0.3:7"), "same seed replays identically");
        assert_ne!(a, run("net.shed=prob:0.3:8"), "different seed, different sequence");
        let rate = a.iter().filter(|&&f| f).count();
        assert!((200..400).contains(&rate), "~30% of 1000 visits, got {rate}");
        assert!(run("net.shed=prob:0").iter().all(|&f| !f));
        assert!(run("net.shed=prob:1").iter().all(|&f| f));
    }
}
