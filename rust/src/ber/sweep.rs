//! Eb/N0 sweeps producing Fig-13-style curves, with JSON emission for the
//! bench harness.

use crate::coding::trellis::Trellis;
use crate::error::{Error, Result, ResultExt};
use crate::util::json::{self, Json};
use crate::viterbi::types::FrameDecoder;

use super::harness::{measure_ber, BerPoint, BerSetup};
use super::theory;

/// Parse a sweep spec "start:stop:step" in dB.
pub fn parse_range(spec: &str) -> Result<Vec<f64>> {
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.len() != 3 {
        return Err(Error::config(format!("range must be start:stop:step, got {spec:?}")));
    }
    let (a, b, s) = (
        parts[0].parse::<f64>().or_config(format!("bad range {spec:?}"))?,
        parts[1].parse::<f64>().or_config(format!("bad range {spec:?}"))?,
        parts[2].parse::<f64>().or_config(format!("bad range {spec:?}"))?,
    );
    if !(s > 0.0 && b >= a) {
        return Err(Error::config(format!("bad range {spec:?}")));
    }
    let mut v = Vec::new();
    let mut x = a;
    while x <= b + 1e-9 {
        v.push((x * 1e6).round() / 1e6);
        x += s;
    }
    Ok(v)
}

/// Run a BER sweep over the given Eb/N0 points.
pub fn sweep(dec: &mut dyn FrameDecoder, trellis: &Trellis, ebn0_dbs: &[f64],
             setup: &BerSetup) -> Result<Vec<BerPoint>> {
    ebn0_dbs.iter().map(|&db| measure_ber(dec, trellis, db, setup)).collect()
}

/// Serialize a labelled family of curves + theory references as JSON
/// (consumed by `EXPERIMENTS.md` tables and external plotting).
pub fn curves_json(curves: &[(String, Vec<BerPoint>)]) -> Json {
    let mut items = Vec::new();
    for (label, points) in curves {
        let pts: Vec<Json> = points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("ebn0_db", json::num(p.ebn0_db)),
                    ("ber", json::num(p.ber())),
                    ("bits", json::num(p.bits as f64)),
                    ("errors", json::num(p.errors as f64)),
                    ("reliable", Json::Bool(p.reliable())),
                ])
            })
            .collect();
        items.push(json::obj(vec![
            ("label", json::s(label)),
            ("points", Json::Arr(pts)),
        ]));
    }
    // theory references over the union of measured x-values
    let mut xs: Vec<f64> = curves.iter().flat_map(|(_, ps)| ps.iter().map(|p| p.ebn0_db)).collect();
    xs.sort_by(f64::total_cmp);
    xs.dedup();
    let theory_pts: Vec<Json> = xs
        .iter()
        .map(|&db| {
            json::obj(vec![
                ("ebn0_db", json::num(db)),
                ("uncoded_bpsk", json::num(theory::uncoded_bpsk(db))),
                ("coded_union_bound", json::num(theory::coded_union_bound(db))),
                ("coded_hard_bound", json::num(theory::coded_union_bound_hard(db))),
            ])
        })
        .collect();
    json::obj(vec![
        ("curves", Json::Arr(items)),
        ("theory", Json::Arr(theory_pts)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_range_works() {
        assert_eq!(parse_range("0:2:0.5").unwrap(), vec![0.0, 0.5, 1.0, 1.5, 2.0]);
        assert_eq!(parse_range("3:3:1").unwrap(), vec![3.0]);
        assert!(parse_range("5:1:1").is_err());
        assert!(parse_range("1:2").is_err());
    }

    #[test]
    fn curves_json_shape() {
        let pts = vec![BerPoint { ebn0_db: 1.0, bits: 1000, errors: 10 }];
        let j = curves_json(&[("test".to_string(), pts)]);
        let text = j.to_string_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.get("curves").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(back.get("theory").unwrap().as_arr().unwrap().len(), 1);
    }
}
