//! End-to-end BER measurement harness (paper Fig 12): generate -> encode
//! -> BPSK -> AWGN -> decode -> compare, accumulating until a target
//! error count (the paper's "BER valid above 100/n" rule) or a bit cap.

use crate::channel::awgn::AwgnChannel;
use crate::channel::bpsk;
use crate::coding::trellis::Trellis;
use crate::coding::{Encoder, TerminationMode};
use crate::error::Result;
use crate::util::rng::Rng;
use crate::viterbi::tiled::{decode_stream, TileConfig};
use crate::viterbi::types::FrameDecoder;

/// Measurement configuration.
#[derive(Clone, Debug)]
pub struct BerSetup {
    pub tile: TileConfig,
    /// How each simulated round is terminated (and decoded): flushed
    /// rounds spend `k - 1` stages on the flush; tail-biting/truncated
    /// rounds carry payload in every stage. See `docs/DECODING-MODES.md`
    /// for the BER implications of each mode.
    pub termination: TerminationMode,
    /// Stop once this many bit errors are seen (paper's 100 rule).
    pub target_errors: usize,
    /// Hard cap on simulated information bits per point.
    pub max_bits: usize,
    /// Payload bits simulated per round (multiple of tile.payload after
    /// flush bits are added; the harness enforces alignment).
    pub bits_per_round: usize,
    /// Use hard-decision (+-1) inputs instead of soft LLRs (§II-C study).
    pub hard_decision: bool,
    /// Form exact LLRs (2y/sigma^2, §II-C) instead of raw symbols. The
    /// max-metric is scale-invariant in f32, but the scale drives metric
    /// magnitudes — and therefore half-precision resolution loss (the
    /// Fig 13 mechanism).
    pub exact_llr: bool,
    pub seed: u64,
}

impl Default for BerSetup {
    fn default() -> Self {
        BerSetup {
            tile: TileConfig { payload: 64, head: 32, tail: 32 },
            termination: TerminationMode::Flushed,
            target_errors: 100,
            max_bits: 2_000_000,
            bits_per_round: 4096,
            hard_decision: false,
            exact_llr: false,
            seed: 0x7C5D,
        }
    }
}

/// One measured BER point.
#[derive(Clone, Copy, Debug)]
pub struct BerPoint {
    pub ebn0_db: f64,
    pub bits: usize,
    pub errors: usize,
}

impl BerPoint {
    pub fn ber(&self) -> f64 {
        if self.bits == 0 { 0.0 } else { self.errors as f64 / self.bits as f64 }
    }

    /// The paper's validity rule: BER is reliable if errors >= 100 (i.e.
    /// BER > 100/n for n tested bits).
    pub fn reliable(&self) -> bool {
        self.errors >= 100
    }
}

/// Measure BER at one Eb/N0 through an arbitrary frame decoder.
pub fn measure_ber(dec: &mut dyn FrameDecoder, trellis: &Trellis, ebn0_db: f64,
                   setup: &BerSetup) -> Result<BerPoint> {
    let code = trellis.code();
    let beta = code.beta();
    let flush = setup.termination.flush_stages(code.k());
    // payload size: fill whole frames after any flush stages
    let round_bits = {
        let p = setup.tile.payload;
        let want = setup.bits_per_round.max(p);
        (want + flush).div_ceil(p) * p - flush
    };

    let mut rng = Rng::new(setup.seed ^ ebn0_db.to_bits());
    let mut channel = AwgnChannel::new(ebn0_db, code.rate(), rng.next_u64());
    let mut enc = Encoder::new(code.clone());

    let mut bits_done = 0usize;
    let mut errors = 0usize;
    while errors < setup.target_errors && bits_done < setup.max_bits {
        let payload = rng.bits(round_bits);
        let (coded, n_stages) = enc.encode_terminated(&payload, setup.termination);
        debug_assert_eq!(n_stages, round_bits + flush);
        debug_assert!(setup.termination != TerminationMode::Flushed || enc.state() == 0);
        let tx = bpsk::modulate(&coded);
        let rx = channel.transmit(&tx);
        let llr: Vec<f32> = if setup.hard_decision {
            bpsk::hard_llrs(&rx).iter().map(|&x| x as f32).collect()
        } else if setup.exact_llr {
            let scale = crate::channel::llr::llr_scale(channel.sigma());
            rx.iter().map(|&x| (x * scale) as f32).collect()
        } else {
            rx.iter().map(|&x| x as f32).collect()
        };
        let decoded = decode_stream(dec, &llr, beta, &setup.tile, setup.termination)?;
        // count errors over the information payload only (not flush)
        errors += decoded[..round_bits]
            .iter()
            .zip(&payload[..round_bits])
            .filter(|(a, b)| a != b)
            .count();
        bits_done += round_bits;
    }
    Ok(BerPoint { ebn0_db, bits: bits_done, errors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ber::theory;
    use crate::coding::poly::Code;
    use crate::viterbi::scalar::ScalarDecoder;
    use std::sync::Arc;

    fn trellis() -> Arc<Trellis> {
        Arc::new(Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap()))
    }

    #[test]
    fn zero_noise_like_snr_has_no_errors() {
        let t = trellis();
        let setup = BerSetup {
            target_errors: 10,
            max_bits: 20_000,
            bits_per_round: 2048,
            ..Default::default()
        };
        let mut dec = ScalarDecoder::new(t.clone(), setup.tile.frame_stages());
        let p = measure_ber(&mut dec, &t, 10.0, &setup).unwrap();
        assert_eq!(p.errors, 0, "10 dB should be error-free over 20k bits");
        assert!(!p.reliable());
    }

    #[test]
    fn low_snr_ber_in_theory_ballpark() {
        let t = trellis();
        let setup = BerSetup {
            target_errors: 150,
            max_bits: 60_000,
            bits_per_round: 4096,
            tile: TileConfig { payload: 64, head: 40, tail: 40 },
            ..Default::default()
        };
        let mut dec = ScalarDecoder::new(t.clone(), setup.tile.frame_stages());
        let p = measure_ber(&mut dec, &t, 2.0, &setup).unwrap();
        let ber = p.ber();
        // union bound at 2 dB is loose; measured soft-decision BER for
        // this code at 2 dB is ~1-3e-2 in the literature
        assert!(ber > 1e-3 && ber < 1e-1, "ber at 2 dB = {ber}");
        let _ = theory::coded_union_bound(2.0);
    }

    #[test]
    fn tail_biting_rounds_are_whole_tiles_and_clean_at_high_snr() {
        let t = trellis();
        let setup = BerSetup {
            termination: TerminationMode::TailBiting,
            target_errors: 10,
            max_bits: 20_000,
            bits_per_round: 2048,
            ..Default::default()
        };
        let mut dec = ScalarDecoder::new(t.clone(), setup.tile.frame_stages());
        let p = measure_ber(&mut dec, &t, 10.0, &setup).unwrap();
        assert_eq!(p.errors, 0, "10 dB tail-biting should be error-free over 20k bits");
        // no flush stages: every simulated stage carries payload
        assert_eq!(p.bits % setup.tile.payload, 0);
    }

    #[test]
    fn hard_decision_is_worse() {
        let t = trellis();
        let setup = BerSetup {
            target_errors: 80,
            max_bits: 40_000,
            tile: TileConfig { payload: 64, head: 40, tail: 40 },
            ..Default::default()
        };
        let mut dec = ScalarDecoder::new(t.clone(), setup.tile.frame_stages());
        let soft = measure_ber(&mut dec, &t, 3.0, &setup).unwrap();
        let hard_setup = BerSetup { hard_decision: true, ..setup };
        let hard = measure_ber(&mut dec, &t, 3.0, &hard_setup).unwrap();
        assert!(hard.ber() > soft.ber(), "hard {} <= soft {}", hard.ber(), soft.ber());
    }
}
