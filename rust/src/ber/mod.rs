//! BER measurement (paper Fig 12 / §IX-B): end-to-end tx -> AWGN ->
//! decode -> count, plus the closed-form theoretical references that
//! replace MATLAB's `bertool`.

pub mod theory;
pub mod harness;
pub mod sweep;

pub use harness::{measure_ber, BerPoint, BerSetup};
