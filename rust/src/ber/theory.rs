//! Theoretical BER references (replacing the paper's MATLAB `bertool`):
//! uncoded BPSK in closed form and the union bound for the (2,1,7)
//! 171/133 code from its distance spectrum.

/// Complementary error function, fractional error < 1.2e-7 everywhere
/// (Numerical Recipes' Chebyshev fit `erfcc`).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t * (-z * z - 1.26551223
        + t * (1.00002368
            + t * (0.37409196
                + t * (0.09678418
                    + t * (-0.18628806
                        + t * (0.27886807
                            + t * (-1.13520398
                                + t * (1.48851587
                                    + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 { ans } else { 2.0 - ans }
}

/// Gaussian tail function Q(x) = P(N(0,1) > x).
pub fn q(x: f64) -> f64 {
    0.5 * erfc(x / std::f64::consts::SQRT_2)
}

/// Uncoded BPSK bit error rate at Eb/N0 (dB).
pub fn uncoded_bpsk(ebn0_db: f64) -> f64 {
    let ebn0 = 10f64.powf(ebn0_db / 10.0);
    q((2.0 * ebn0).sqrt())
}

/// Information-bit weight spectrum B_d of the (2,1,7) 171/133 code for
/// d = 10,12,...,20 (d_free = 10; standard published values).
pub const K7_BIT_WEIGHTS: &[(u32, f64)] = &[
    (10, 36.0),
    (12, 211.0),
    (14, 1404.0),
    (16, 11633.0),
    (18, 77433.0),
    (20, 502690.0),
];

/// Union-bound estimate of soft-decision Viterbi BER for (2,1,7) 171/133
/// at rate R = 1/2: `Pb <= sum_d B_d * Q(sqrt(2 d R Eb/N0))`. Tight above
/// ~3 dB; a (loose) upper bound below.
pub fn coded_union_bound(ebn0_db: f64) -> f64 {
    let ebn0 = 10f64.powf(ebn0_db / 10.0);
    let r = 0.5;
    let pb: f64 = K7_BIT_WEIGHTS
        .iter()
        .map(|&(d, bd)| bd * q((2.0 * d as f64 * r * ebn0).sqrt()))
        .sum();
    pb.min(0.5)
}

/// Hard-decision union bound (Chernoff form) for the same code, using
/// `P2(d) ~ [4p(1-p)]^{d/2}` with p the raw channel bit error rate —
/// used for the §II-C soft-vs-hard (~2 dB) comparison curve.
pub fn coded_union_bound_hard(ebn0_db: f64) -> f64 {
    let ebn0 = 10f64.powf(ebn0_db / 10.0);
    let p = q((2.0 * 0.5 * ebn0).sqrt()); // raw BER at Es/N0 = R*Eb/N0
    let z = (4.0 * p * (1.0 - p)).sqrt();
    let pb: f64 = K7_BIT_WEIGHTS.iter().map(|&(d, bd)| bd * z.powi(d as i32)).sum();
    pb.min(0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erfc_reference_values() {
        // erfc(0)=1, erfc(1)=0.157299..., erfc(2)=0.004677...
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.15729921).abs() < 1e-6);
        assert!((erfc(2.0) - 0.00467773).abs() < 1e-7);
        assert!((erfc(-1.0) - (2.0 - 0.15729921)).abs() < 1e-6);
    }

    #[test]
    fn q_function_values() {
        assert!((q(0.0) - 0.5).abs() < 1e-6); // erfcc fit: ~1.2e-7 abs error
        assert!((q(1.0) - 0.158655).abs() < 1e-5);
        assert!((q(3.0) - 1.349898e-3).abs() < 1e-7);
    }

    #[test]
    fn uncoded_bpsk_known_points() {
        // classic values: ~0.0786 at 0 dB, ~7.7e-4 at 7 dB (6.99 dB->~8e-4)
        assert!((uncoded_bpsk(0.0) - 0.0786).abs() < 1e-3);
        assert!(uncoded_bpsk(9.6) < 1.1e-5, "{}", uncoded_bpsk(9.6));
    }

    #[test]
    fn coded_beats_uncoded_above_3db() {
        for db in [3.0, 4.0, 5.0, 6.0] {
            assert!(coded_union_bound(db) < uncoded_bpsk(db), "at {db} dB");
        }
    }

    #[test]
    fn soft_beats_hard_by_about_2db() {
        // find Eb/N0 where each hits 1e-4: difference should be ~2 dB
        let find = |f: &dyn Fn(f64) -> f64| {
            let mut db = 0.0;
            while f(db) > 1e-4 && db < 12.0 {
                db += 0.01;
            }
            db
        };
        let soft = find(&coded_union_bound);
        let hard = find(&coded_union_bound_hard);
        let gap = hard - soft;
        assert!((1.2..3.2).contains(&gap), "soft={soft:.2} hard={hard:.2} gap={gap:.2}");
    }

    #[test]
    fn bounds_monotone_decreasing() {
        let mut prev = 1.0;
        for i in 0..20 {
            let v = coded_union_bound(i as f64 * 0.5);
            assert!(v <= prev + 1e-12);
            prev = v;
        }
    }
}
