//! Tiled / overlapped frame decoding of long streams (paper §III,
//! refs \[4-7\]): the n-stage stream is cut into frames of `f` payload
//! stages plus `head` + `tail` overlap stages; frames decode
//! independently (the parallelism source) and only the payload bits are
//! emitted. Larger overlap carries more history and restores BER at the
//! cost of redundant work — the E3 ablation sweeps this.
//!
//! Frame independence is what every parallel layer above builds on: the
//! coordinator's engine shards batch frames from many sessions and
//! steal across queues, and the one-shot
//! [`Decoder::decode_stream`](crate::api::Decoder::decode_stream) fans
//! frames out over threads — all bit-identical to the serial reference
//! tiler in this module because each [`FrameJob`] is decoded in
//! isolation.
//!
//! How the stream *ends* is a workload axis of its own
//! ([`TerminationMode`], `docs/DECODING-MODES.md`): a flushed stream
//! pins both trellis ends to state 0, a truncated stream pins only the
//! head, and a tail-biting block pins neither — instead every frame
//! (including the first and last) is extended **circularly**, wrapping
//! head/tail context around the block so the boundary frames converge
//! exactly like mid-stream tiles.
//!
//! ```
//! use tcvd::coding::TerminationMode;
//! use tcvd::viterbi::tiled::{make_frames, TileConfig};
//!
//! let cfg = TileConfig { payload: 32, head: 8, tail: 8 };
//! let llr = vec![1.0f32; 64 * 2]; // 64 stages of rate-1/2 LLRs
//! let jobs = make_frames(&llr, 2, &cfg, TerminationMode::Flushed).unwrap();
//! assert_eq!(jobs.len(), 2); // one frame per payload tile
//! assert_eq!(jobs[0].start_state, Some(0)); // stream head is pinned
//! assert_eq!(jobs[1].emit_from, 8); // warm-up overlap is not emitted
//! // Eq-5 redundancy (f + v) / f, with the paper's overlap v realized
//! // as head + tail stages of context around the payload:
//! assert!((cfg.overhead() - (32.0 + 8.0 + 8.0) / 32.0).abs() < 1e-12);
//! assert!((cfg.overhead() - 1.5).abs() < 1e-12);
//!
//! // tail-biting: no pinned states anywhere; every frame carries full
//! // circular context, so even frame 0 warms up over `head` stages
//! let tb = make_frames(&llr, 2, &cfg, TerminationMode::TailBiting).unwrap();
//! assert!(tb.iter().all(|j| j.start_state.is_none() && j.end_state.is_none()));
//! assert!(tb.iter().all(|j| j.emit_from == 8));
//! ```

use crate::coding::TerminationMode;
use crate::error::{Error, Result};

use super::types::{FrameDecoder, FrameJob};

/// Frame geometry.
///
/// The paper's Eq-5 models one overlap quantity `v` per frame; our
/// geometry splits that context into `head` (metric warm-up *before*
/// the payload) and `tail` (traceback convergence *after* it), so the
/// paper's `v` maps to `head + tail` here.
/// [`overhead`](TileConfig::overhead) and its doctest pin this
/// correspondence.
#[derive(Clone, Copy, Debug)]
pub struct TileConfig {
    /// Payload stages decoded per frame (paper's `f`).
    pub payload: usize,
    /// Warm-up stages before the payload (history for metric
    /// convergence; part of the paper's `v`).
    pub head: usize,
    /// Stages after the payload (traceback convergence; part of the
    /// paper's `v`).
    pub tail: usize,
}

impl TileConfig {
    pub fn frame_stages(&self) -> usize {
        self.head + self.payload + self.tail
    }

    /// The paper's Eq-5 storage/compute overhead factor `(f + v) / f`,
    /// with `v = head + tail` (both overlap sides count toward the
    /// redundant stages a frame decodes but does not emit):
    /// `(payload + head + tail) / payload`.
    pub fn overhead(&self) -> f64 {
        1.0 + (self.head + self.tail) as f64 / self.payload as f64
    }
}

/// Cut an LLR stream into overlapped `FrameJob`s.
///
/// `llr` covers `n` stages (`n * beta` values); `n` must be a multiple
/// of `payload` (pad upstream if needed). What the frames may assume
/// about the trellis ends follows the [`TerminationMode`]:
///
/// * [`Flushed`](TerminationMode::Flushed) — the first frame pins
///   `start_state = 0` (and carries no head overlap: the known state
///   replaces warm-up history); the last frame pins `end_state = 0`
///   when its window ends exactly at the stream end. Context beyond the
///   stream is zero-padded (uninformative LLRs).
/// * [`Truncated`](TerminationMode::Truncated) — like `Flushed` but the
///   last frame never claims an end state (traceback starts from the
///   best-metric state).
/// * [`TailBiting`](TerminationMode::TailBiting) — no frame pins any
///   state. Instead each frame's `head`/`tail` context is read
///   **circularly** from the block (`stage (pay_start - head + s) mod
///   n`), so the first frame warms up over the block's tail and the
///   last frame's traceback converges over the block's head — every
///   frame behaves like a mid-stream tile, which is what makes the
///   single-wrap approximation converge (see `docs/DECODING-MODES.md`).
pub fn make_frames(llr: &[f32], beta: usize, cfg: &TileConfig,
                   termination: TerminationMode) -> Result<Vec<FrameJob>> {
    if llr.len() % beta != 0 {
        return Err(Error::pipeline(format!(
            "llr length {} not a multiple of beta {beta}",
            llr.len()
        )));
    }
    let n = llr.len() / beta;
    if n % cfg.payload != 0 {
        return Err(Error::pipeline(format!(
            "stream stages {n} not a multiple of payload {}",
            cfg.payload
        )));
    }
    if termination == TerminationMode::TailBiting {
        return Ok(tail_biting_frames(llr, beta, cfg));
    }
    let stages = cfg.frame_stages();
    let n_frames = n / cfg.payload;
    let mut jobs = Vec::with_capacity(n_frames);
    for fi in 0..n_frames {
        let pay_start = fi * cfg.payload; // stage index of first payload bit
        let start = pay_start.saturating_sub(cfg.head);
        let head = pay_start - start;
        // frame covers [start, start + stages); clamp to stream, pad zeros
        let mut frame = vec![0f32; stages * beta];
        let avail = (n - start).min(stages);
        frame[..avail * beta].copy_from_slice(&llr[start * beta..(start + avail) * beta]);
        let is_first = fi == 0;
        let is_last = fi == n_frames - 1;
        let flushed_end = termination == TerminationMode::Flushed;
        jobs.push(FrameJob {
            llr: frame,
            start_state: if is_first { Some(0) } else { None },
            end_state: if is_last && flushed_end && avail == n - start {
                // flush lands exactly at stream end; the padded stages (if
                // any) would desync state 0, so only claim it when the
                // frame ends at the true stream end
                if start + stages == n { Some(0) } else { None }
            } else {
                None
            },
            emit_from: head,
            emit_len: cfg.payload.min(n - pay_start),
        });
    }
    Ok(jobs)
}

/// The circularly-extended frames of one whole tail-biting block
/// (`n` stages, already validated as a multiple of `cfg.payload`).
/// Every frame gets the full `head + payload + tail` window read
/// modulo the block length — blocks shorter than the overlap simply
/// wrap more than once (the WAVA-style repeated-block view) — with
/// uniform initial metrics and a best-end-state traceback
/// (`start_state`/`end_state` both `None`). Shared by [`make_frames`]
/// and the streaming `coordinator::framer::Framer`.
pub(crate) fn tail_biting_frames(llr: &[f32], beta: usize, cfg: &TileConfig) -> Vec<FrameJob> {
    let n = llr.len() / beta;
    let stages = cfg.frame_stages();
    let n_frames = n / cfg.payload;
    let mut jobs = Vec::with_capacity(n_frames);
    for fi in 0..n_frames {
        let pay_start = fi * cfg.payload;
        let mut frame = vec![0f32; stages * beta];
        for s in 0..stages {
            let src = ((pay_start + s) as i64 - cfg.head as i64).rem_euclid(n as i64) as usize;
            frame[s * beta..(s + 1) * beta].copy_from_slice(&llr[src * beta..(src + 1) * beta]);
        }
        jobs.push(FrameJob {
            llr: frame,
            start_state: None,
            end_state: None,
            emit_from: cfg.head,
            emit_len: cfg.payload,
        });
    }
    jobs
}

/// Decode a whole stream through a `FrameDecoder`, reassembling payload
/// bits in order. This is the single-threaded reference tiler; the
/// coordinator implements the same contract with pipelined batching.
pub fn decode_stream(dec: &mut dyn FrameDecoder, llr: &[f32], beta: usize,
                     cfg: &TileConfig, termination: TerminationMode) -> Result<Vec<u8>> {
    if dec.frame_stages() != cfg.frame_stages() {
        return Err(Error::pipeline(format!(
            "decoder frame ({}) != tile geometry ({})",
            dec.frame_stages(),
            cfg.frame_stages()
        )));
    }
    let jobs = make_frames(llr, beta, cfg, termination)?;
    let mut out = Vec::with_capacity(llr.len() / beta);
    for chunk in jobs.chunks(dec.max_batch().max(1)) {
        for bits in dec.decode_batch(chunk) {
            out.extend_from_slice(&bits);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{poly::Code, trellis::Trellis, Encoder};
    use crate::viterbi::packed::presets;
    use crate::viterbi::scalar::{self, ScalarDecoder};
    use std::sync::Arc;

    fn trellis() -> Arc<Trellis> {
        Arc::new(Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap()))
    }

    fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(seed).bits(payload_bits - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0x5EED);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }

    #[test]
    fn geometry() {
        let cfg = TileConfig { payload: 64, head: 16, tail: 24 };
        assert_eq!(cfg.frame_stages(), 104);
        assert!((cfg.overhead() - 1.625).abs() < 1e-12);
    }

    #[test]
    fn frames_cover_stream_exactly_once() {
        let cfg = TileConfig { payload: 32, head: 8, tail: 8 };
        let llr = vec![0.5f32; 128 * 2];
        let jobs = make_frames(&llr, 2, &cfg, TerminationMode::Flushed).unwrap();
        assert_eq!(jobs.len(), 4);
        let total: usize = jobs.iter().map(|j| j.emit_len).sum();
        assert_eq!(total, 128);
        assert_eq!(jobs[0].start_state, Some(0));
        assert_eq!(jobs[0].emit_from, 0); // no head on first frame
        assert!(jobs[1].start_state.is_none());
        assert_eq!(jobs[1].emit_from, 8);
    }

    #[test]
    fn tiled_matches_unframed_at_good_snr() {
        let t = trellis();
        let (bits, llr) = noisy_stream(3, 256, 5.0);
        // unframed reference
        let lam0 = scalar::initial_metrics(64, Some(0));
        let whole = scalar::decode(&t, &llr, &lam0, Some(0));
        assert_eq!(whole, bits);
        // tiled with generous overlap
        let cfg = TileConfig { payload: 64, head: 32, tail: 32 };
        let mut dec = ScalarDecoder::new(t, cfg.frame_stages());
        let tiled = decode_stream(&mut dec, &llr, 2, &cfg, TerminationMode::Flushed).unwrap();
        assert_eq!(tiled, bits);
    }

    #[test]
    fn tiled_packed_radix4_decodes_stream() {
        let t = trellis();
        let (bits, llr) = noisy_stream(5, 512, 5.0);
        let cfg = TileConfig { payload: 64, head: 32, tail: 32 };
        let mut dec = presets::radix4(t, cfg.frame_stages());
        let tiled = decode_stream(&mut dec, &llr, 2, &cfg, TerminationMode::Flushed).unwrap();
        assert_eq!(tiled, bits);
    }

    #[test]
    fn tail_biting_frames_wrap_circularly() {
        // distinct LLR per stage so the wrap positions are verifiable
        let cfg = TileConfig { payload: 32, head: 8, tail: 12 };
        let n = 64usize;
        let llr: Vec<f32> = (0..n * 2).map(|i| i as f32).collect();
        let jobs = make_frames(&llr, 2, &cfg, TerminationMode::TailBiting).unwrap();
        assert_eq!(jobs.len(), 2);
        for (fi, job) in jobs.iter().enumerate() {
            assert_eq!(job.start_state, None);
            assert_eq!(job.end_state, None);
            assert_eq!(job.emit_from, 8);
            assert_eq!(job.emit_len, 32);
            for s in 0..cfg.frame_stages() {
                let src = ((fi * 32 + s) as i64 - 8).rem_euclid(n as i64) as usize;
                assert_eq!(
                    &job.llr[s * 2..s * 2 + 2],
                    &llr[src * 2..src * 2 + 2],
                    "frame {fi} stage {s} must map to stream stage {src}"
                );
            }
        }
        // frame 0's head context is the *end* of the block (the wrap)
        assert_eq!(jobs[0].llr[0], llr[(n - 8) * 2]);
        // the last frame's tail context wraps to the block's head
        let last = &jobs[1];
        assert_eq!(last.llr[(8 + 32) * 2], llr[0]);
    }

    #[test]
    fn short_block_wraps_more_than_once() {
        // overlap longer than the block: the circular extension repeats
        // the block (the WAVA repeated-block view) instead of padding
        let cfg = TileConfig { payload: 16, head: 24, tail: 24 };
        let llr: Vec<f32> = (0..16 * 2).map(|i| i as f32).collect();
        let jobs = make_frames(&llr, 2, &cfg, TerminationMode::TailBiting).unwrap();
        assert_eq!(jobs.len(), 1);
        let job = &jobs[0];
        for s in 0..cfg.frame_stages() {
            let src = (s as i64 - 24).rem_euclid(16) as usize;
            assert_eq!(&job.llr[s * 2..s * 2 + 2], &llr[src * 2..src * 2 + 2], "stage {s}");
        }
    }

    #[test]
    fn tail_biting_stream_decodes_noiseless_and_noisy() {
        let t = trellis();
        let cfg = TileConfig { payload: 32, head: 32, tail: 32 };
        let mut dec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
        // noiseless: exact recovery for single- and multi-frame blocks
        for (seed, n_bits) in [(1u64, 32usize), (2, 64), (3, 128)] {
            let bits = crate::util::rng::Rng::new(seed).bits(n_bits);
            let mut enc = Encoder::new(t.code().clone());
            let coded = enc.encode_tail_biting(&bits);
            let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
            let out = decode_stream(&mut dec, &llr, 2, &cfg, TerminationMode::TailBiting)
                .unwrap();
            assert_eq!(out, bits, "noiseless tail-biting block of {n_bits} bits");
        }
        // noisy at 5 dB (seeds pre-validated against the exact-chain
        // reference simulation — margin is large at this SNR)
        let cfg5 = TileConfig { payload: 64, head: 32, tail: 32 };
        let mut dec5 = ScalarDecoder::new(t.clone(), cfg5.frame_stages());
        for seed in 1200..1204u64 {
            let bits = crate::util::rng::Rng::new(seed).bits(256);
            let mut enc = Encoder::new(t.code().clone());
            let coded = enc.encode_tail_biting(&bits);
            let tx = bpsk::modulate(&coded);
            let mut ch = AwgnChannel::new(5.0, 0.5, seed ^ 0x7B17);
            let rx = ch.transmit(&tx);
            let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();
            let out = decode_stream(&mut dec5, &llr, 2, &cfg5, TerminationMode::TailBiting)
                .unwrap();
            assert_eq!(out, bits, "seed {seed}: 5 dB tail-biting block decodes clean");
        }
    }

    #[test]
    fn truncated_stream_decodes_noiseless() {
        let t = trellis();
        let cfg = TileConfig { payload: 32, head: 16, tail: 16 };
        let mut dec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
        let bits = crate::util::rng::Rng::new(4).bits(96);
        let mut enc = Encoder::new(t.code().clone());
        let coded = enc.encode_truncated(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let out = decode_stream(&mut dec, &llr, 2, &cfg, TerminationMode::Truncated).unwrap();
        assert_eq!(out, bits);
        // the last frame must not have claimed a flushed end state
        let jobs = make_frames(&llr, 2, &cfg, TerminationMode::Truncated).unwrap();
        assert!(jobs.iter().all(|j| j.end_state.is_none()));
        assert_eq!(jobs[0].start_state, Some(0), "truncated still pins the known start");
    }

    #[test]
    fn zero_overlap_degrades() {
        // with no overlap and noise, framed decoding must differ from the
        // unframed decode at low SNR (this is the E3 phenomenon)
        let t = trellis();
        let (_, llr) = noisy_stream(11, 1024, 1.0);
        let lam0 = scalar::initial_metrics(64, Some(0));
        let whole = scalar::decode(&t, &llr, &lam0, Some(0));
        let cfg = TileConfig { payload: 32, head: 0, tail: 0 };
        let mut dec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
        let tiled = decode_stream(&mut dec, &llr, 2, &cfg, TerminationMode::Flushed).unwrap();
        assert_ne!(tiled, whole, "expected tile truncation errors at 1 dB");
        // generous overlap should recover (nearly) the unframed output
        let cfg2 = TileConfig { payload: 32, head: 48, tail: 48 };
        let mut dec2 = ScalarDecoder::new(t, cfg2.frame_stages());
        let tiled2 = decode_stream(&mut dec2, &llr, 2, &cfg2, TerminationMode::Flushed).unwrap();
        let diff: usize = tiled2.iter().zip(&whole).filter(|(a, b)| a != b).count();
        assert!(diff * 100 < whole.len(), "overlap 48 should nearly match: {diff}");
    }

    #[test]
    fn rejects_misaligned_stream() {
        let cfg = TileConfig { payload: 64, head: 0, tail: 0 };
        for mode in [TerminationMode::Truncated, TerminationMode::TailBiting] {
            assert!(make_frames(&vec![0.0; 130], 2, &cfg, mode).is_err());
            assert!(make_frames(&vec![0.0; 127], 2, &cfg, mode).is_err());
        }
    }
}
