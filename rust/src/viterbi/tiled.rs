//! Tiled / overlapped frame decoding of long streams (paper §III,
//! refs \[4-7\]): the n-stage stream is cut into frames of `f` payload
//! stages plus `head` + `tail` overlap stages; frames decode
//! independently (the parallelism source) and only the payload bits are
//! emitted. Larger overlap carries more history and restores BER at the
//! cost of redundant work — the E3 ablation sweeps this.
//!
//! Frame independence is what every parallel layer above builds on: the
//! coordinator's engine shards batch frames from many sessions and
//! steal across queues, and the one-shot
//! [`Decoder::decode_stream`](crate::api::Decoder::decode_stream) fans
//! frames out over threads — all bit-identical to the serial reference
//! tiler in this module because each [`FrameJob`] is decoded in
//! isolation.
//!
//! ```
//! use tcvd::viterbi::tiled::{make_frames, TileConfig};
//!
//! let cfg = TileConfig { payload: 32, head: 8, tail: 8 };
//! let llr = vec![1.0f32; 64 * 2]; // 64 stages of rate-1/2 LLRs
//! let jobs = make_frames(&llr, 2, &cfg, true).unwrap();
//! assert_eq!(jobs.len(), 2); // one frame per payload tile
//! assert_eq!(jobs[0].start_state, Some(0)); // stream head is pinned
//! assert_eq!(jobs[1].emit_from, 8); // warm-up overlap is not emitted
//! // Eq-5 redundancy (f + v) / f, with the paper's overlap v realized
//! // as head + tail stages of context around the payload:
//! assert!((cfg.overhead() - (32.0 + 8.0 + 8.0) / 32.0).abs() < 1e-12);
//! assert!((cfg.overhead() - 1.5).abs() < 1e-12);
//! ```

use crate::error::{Error, Result};

use super::types::{FrameDecoder, FrameJob};

/// Frame geometry.
///
/// The paper's Eq-5 models one overlap quantity `v` per frame; our
/// geometry splits that context into `head` (metric warm-up *before*
/// the payload) and `tail` (traceback convergence *after* it), so the
/// paper's `v` maps to `head + tail` here.
/// [`overhead`](TileConfig::overhead) and its doctest pin this
/// correspondence.
#[derive(Clone, Copy, Debug)]
pub struct TileConfig {
    /// Payload stages decoded per frame (paper's `f`).
    pub payload: usize,
    /// Warm-up stages before the payload (history for metric
    /// convergence; part of the paper's `v`).
    pub head: usize,
    /// Stages after the payload (traceback convergence; part of the
    /// paper's `v`).
    pub tail: usize,
}

impl TileConfig {
    pub fn frame_stages(&self) -> usize {
        self.head + self.payload + self.tail
    }

    /// The paper's Eq-5 storage/compute overhead factor `(f + v) / f`,
    /// with `v = head + tail` (both overlap sides count toward the
    /// redundant stages a frame decodes but does not emit):
    /// `(payload + head + tail) / payload`.
    pub fn overhead(&self) -> f64 {
        1.0 + (self.head + self.tail) as f64 / self.payload as f64
    }
}

/// Cut an LLR stream into overlapped `FrameJob`s.
///
/// `llr` covers `n` stages (`n * beta` values); `n` must be a multiple of
/// `payload` (pad upstream if needed). The first frame has no head
/// overlap (the encoder start state is known instead); the last frame
/// has no tail overlap (`end_state` applies if the stream was flushed).
pub fn make_frames(llr: &[f32], beta: usize, cfg: &TileConfig,
                   flushed_end: bool) -> Result<Vec<FrameJob>> {
    if llr.len() % beta != 0 {
        return Err(Error::pipeline(format!(
            "llr length {} not a multiple of beta {beta}",
            llr.len()
        )));
    }
    let n = llr.len() / beta;
    if n % cfg.payload != 0 {
        return Err(Error::pipeline(format!(
            "stream stages {n} not a multiple of payload {}",
            cfg.payload
        )));
    }
    let stages = cfg.frame_stages();
    let n_frames = n / cfg.payload;
    let mut jobs = Vec::with_capacity(n_frames);
    for fi in 0..n_frames {
        let pay_start = fi * cfg.payload; // stage index of first payload bit
        let start = pay_start.saturating_sub(cfg.head);
        let head = pay_start - start;
        // frame covers [start, start + stages); clamp to stream, pad zeros
        let mut frame = vec![0f32; stages * beta];
        let avail = (n - start).min(stages);
        frame[..avail * beta].copy_from_slice(&llr[start * beta..(start + avail) * beta]);
        let is_first = fi == 0;
        let is_last = fi == n_frames - 1;
        jobs.push(FrameJob {
            llr: frame,
            start_state: if is_first { Some(0) } else { None },
            end_state: if is_last && flushed_end && avail == n - start {
                // flush lands exactly at stream end; the padded stages (if
                // any) would desync state 0, so only claim it when the
                // frame ends at the true stream end
                if start + stages == n { Some(0) } else { None }
            } else {
                None
            },
            emit_from: head,
            emit_len: cfg.payload.min(n - pay_start),
        });
    }
    Ok(jobs)
}

/// Decode a whole stream through a `FrameDecoder`, reassembling payload
/// bits in order. This is the single-threaded reference tiler; the
/// coordinator implements the same contract with pipelined batching.
pub fn decode_stream(dec: &mut dyn FrameDecoder, llr: &[f32], beta: usize,
                     cfg: &TileConfig, flushed_end: bool) -> Result<Vec<u8>> {
    if dec.frame_stages() != cfg.frame_stages() {
        return Err(Error::pipeline(format!(
            "decoder frame ({}) != tile geometry ({})",
            dec.frame_stages(),
            cfg.frame_stages()
        )));
    }
    let jobs = make_frames(llr, beta, cfg, flushed_end)?;
    let mut out = Vec::with_capacity(llr.len() / beta);
    for chunk in jobs.chunks(dec.max_batch().max(1)) {
        for bits in dec.decode_batch(chunk) {
            out.extend_from_slice(&bits);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{poly::Code, trellis::Trellis, Encoder};
    use crate::viterbi::packed::presets;
    use crate::viterbi::scalar::{self, ScalarDecoder};
    use std::sync::Arc;

    fn trellis() -> Arc<Trellis> {
        Arc::new(Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap()))
    }

    fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(seed).bits(payload_bits - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0x5EED);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }

    #[test]
    fn geometry() {
        let cfg = TileConfig { payload: 64, head: 16, tail: 24 };
        assert_eq!(cfg.frame_stages(), 104);
        assert!((cfg.overhead() - 1.625).abs() < 1e-12);
    }

    #[test]
    fn frames_cover_stream_exactly_once() {
        let cfg = TileConfig { payload: 32, head: 8, tail: 8 };
        let llr = vec![0.5f32; 128 * 2];
        let jobs = make_frames(&llr, 2, &cfg, true).unwrap();
        assert_eq!(jobs.len(), 4);
        let total: usize = jobs.iter().map(|j| j.emit_len).sum();
        assert_eq!(total, 128);
        assert_eq!(jobs[0].start_state, Some(0));
        assert_eq!(jobs[0].emit_from, 0); // no head on first frame
        assert!(jobs[1].start_state.is_none());
        assert_eq!(jobs[1].emit_from, 8);
    }

    #[test]
    fn tiled_matches_unframed_at_good_snr() {
        let t = trellis();
        let (bits, llr) = noisy_stream(3, 256, 5.0);
        // unframed reference
        let lam0 = scalar::initial_metrics(64, Some(0));
        let whole = scalar::decode(&t, &llr, &lam0, Some(0));
        assert_eq!(whole, bits);
        // tiled with generous overlap
        let cfg = TileConfig { payload: 64, head: 32, tail: 32 };
        let mut dec = ScalarDecoder::new(t, cfg.frame_stages());
        let tiled = decode_stream(&mut dec, &llr, 2, &cfg, true).unwrap();
        assert_eq!(tiled, bits);
    }

    #[test]
    fn tiled_packed_radix4_decodes_stream() {
        let t = trellis();
        let (bits, llr) = noisy_stream(5, 512, 5.0);
        let cfg = TileConfig { payload: 64, head: 32, tail: 32 };
        let mut dec = presets::radix4(t, cfg.frame_stages());
        let tiled = decode_stream(&mut dec, &llr, 2, &cfg, true).unwrap();
        assert_eq!(tiled, bits);
    }

    #[test]
    fn zero_overlap_degrades() {
        // with no overlap and noise, framed decoding must differ from the
        // unframed decode at low SNR (this is the E3 phenomenon)
        let t = trellis();
        let (_, llr) = noisy_stream(11, 1024, 1.0);
        let lam0 = scalar::initial_metrics(64, Some(0));
        let whole = scalar::decode(&t, &llr, &lam0, Some(0));
        let cfg = TileConfig { payload: 32, head: 0, tail: 0 };
        let mut dec = ScalarDecoder::new(t.clone(), cfg.frame_stages());
        let tiled = decode_stream(&mut dec, &llr, 2, &cfg, true).unwrap();
        assert_ne!(tiled, whole, "expected tile truncation errors at 1 dB");
        // generous overlap should recover (nearly) the unframed output
        let cfg2 = TileConfig { payload: 32, head: 48, tail: 48 };
        let mut dec2 = ScalarDecoder::new(t, cfg2.frame_stages());
        let tiled2 = decode_stream(&mut dec2, &llr, 2, &cfg2, true).unwrap();
        let diff: usize = tiled2.iter().zip(&whole).filter(|(a, b)| a != b).count();
        assert!(diff * 100 < whole.len(), "overlap 48 should nearly match: {diff}");
    }

    #[test]
    fn rejects_misaligned_stream() {
        let cfg = TileConfig { payload: 64, head: 0, tail: 0 };
        assert!(make_frames(&vec![0.0; 130], 2, &cfg, false).is_err());
        assert!(make_frames(&vec![0.0; 127], 2, &cfg, false).is_err());
    }
}
