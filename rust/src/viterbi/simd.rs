//! Quantized lane-parallel ACS fast path (`BackendKind::Simd`): the
//! CPU analogue of the paper's tensor-core forward pass.
//!
//! The forward recursion is the cost center of every CPU backend, and
//! the scalar baseline runs it one state, one f64 add at a time. This
//! backend reformulates the same butterfly ACS update so wide integer
//! units execute it many states per instruction:
//!
//! * **i16 path metrics.** LLRs are quantized once per frame
//!   (`q = round(llr * SIMD_LLR_SCALE)`, clamped to `±qmax`); path
//!   metrics accumulate in `i16` with *saturating* adds and periodic
//!   renormalization (subtract the running maximum), mirroring the
//!   paper's reduced-precision concerns in §IX-B. Sixteen metrics fit
//!   one 256-bit lane where the scalar oracle moves one f64.
//! * **Per-symbol branch-metric dedup.** A stage has only `2^beta`
//!   distinct branch metrics (4 for the paper's rate-1/2 code — Eq 2
//!   depends on the branch *output symbol* alone, not on the
//!   `n_states x 2` branches). The kernel
//!   never materializes a per-state `delta` table: per butterfly
//!   branch class it multiplies precomputed `±1` sign planes by the
//!   stage's `beta` quantized LLRs — the vector form of the
//!   `bm[2^beta]` lookup, with no gather in the hot loop.
//! * **Structure-of-arrays butterflies.** State `j` and `j + S/2` share
//!   the predecessor pair `{2f, 2f+1}` (`f = j mod S/2`, Thm 1), so
//!   one even/odd split of the metric vector feeds two contiguous,
//!   dependency-free half-loops that autovectorize; on x86_64 with
//!   AVX2 (checked at runtime) an explicit `core::arch` kernel runs
//!   the same update 16 butterflies per instruction, with the portable
//!   loop as fallback everywhere else. Both produce identical bits.
//! * **Radix-2^rho super-stages.** With `radix = 2` (the paper's Thm
//!   3–7 trick, [`SimdDecoder::with_radix`]) the stage loop collapses
//!   pairs of trellis stages into one pass over 2^rho-way
//!   super-branches: 16 precomputed `(y_left, y_right)` sign planes
//!   turn two stages of branch metrics into one plane sweep, a
//!   four-candidate tournament replaces two dependent butterfly
//!   updates, and the 2-bit winners go straight into
//!   [`CompactSurvivors::from_radix`](super::compact::CompactSurvivors::from_radix)
//!   so traceback walks the exact Thm-4 path the packed backends use.
//!   The trip count of the serial stage recursion — the part no lane
//!   width can hide — halves.
//! * **Zero-alloc steady state.** All scratch (quantized LLRs, metric
//!   split, branch-metric planes, decision lanes) and the bit-packed
//!   [`DecisionRing`] are allocated once at construction and reused
//!   across every frame of every `forward_batch` call; the per-stage
//!   loop performs no heap allocation (debug-asserted). Decisions go
//!   straight into the ring and come out as the same
//!   [`CompactSurvivors`](super::compact::CompactSurvivors) snapshots
//!   the `compact` backend emits — one
//!   shared ring serves the whole batch.
//!
//! **Bit-identity.** On LLRs that lie on the quantization grid the
//! decoded bits are identical to the scalar f64 oracle: integer adds
//! are exact, renormalization shifts every metric uniformly (ACS
//! compares are unaffected), and the quantized "minus infinity"
//! [`NEG_Q`] is chosen so a real path beats a NEG-descendant in every
//! compare during the first `k - 1` stages (after which every state
//! has a real path). Saturation at `i16::MIN` can reorder metrics only
//! among hopeless states that the surviving path never visits.
//!
//! At `radix = 2` the same theorem holds because the tournament is the
//! scalar recursion, reassociated: within a predecessor pair both
//! candidates share the second-stage branch metric (the mid state to
//! right state hop is common), so the pair compare equals the scalar
//! stage-`t+1` compare, the cross-pair compare equals the scalar
//! stage-`t+2` compare, and strict-greater-wins at both levels
//! composes to the scalar `l0 >= l1` tie-break exactly. The headroom
//! spread widens by one stage (`2(k-1) + rho`) and the NEG-Q
//! separation horizon by `rho - 1` stages
//! (`|NEG_Q| > 2 (k-2+rho) beta qmax`), both enforced by
//! [`Quantizer::for_code_radix`]; renormalization lands on super-stage
//! boundaries, which is still a uniform shift. `docs/PERFORMANCE.md`
//! spells the argument out.
//!
//! `rust/tests/simd_equivalence.rs` pins all of this across random
//! codes, geometries, renorm intervals, shard counts, termination
//! modes, radixes and saturation-stress LLRs.
//!
//! ```
//! use std::sync::Arc;
//! use tcvd::coding::{registry, trellis::Trellis};
//! use tcvd::viterbi::simd::SimdDecoder;
//! use tcvd::viterbi::types::{FrameDecoder, FrameJob};
//!
//! let t = Arc::new(Trellis::new(registry::paper_code()));
//! let mut dec = SimdDecoder::new(t, 16, 0); // renorm 0 = auto period
//! let job = FrameJob {
//!     llr: vec![1.0f32; 16 * 2], // positive LLR ⇒ bit 0
//!     start_state: Some(0),
//!     end_state: Some(0),
//!     emit_from: 0,
//!     emit_len: 16,
//! };
//! let bits = dec.decode_batch(std::slice::from_ref(&job));
//! assert_eq!(bits[0], vec![0u8; 16]);
//! ```

use std::sync::Arc;

use crate::coding::trellis::Trellis;
use crate::defaults;

use super::compact::{CompactSurvivors, DecisionRing};
use super::types::{FrameDecoder, FrameJob, RawFrame, Survivors};

/// Finite "minus infinity" for quantized path metrics: low enough that
/// a NEG-descendant loses every ACS compare against a real path while
/// the trellis warms up (`|NEG_Q| > 2 (k-1) beta qmax`, enforced by
/// [`Quantizer::for_code`]), high enough above `i16::MIN` that one
/// stage of saturating adds cannot wrap its ordering.
pub const NEG_Q: i16 = -28000;

/// LLR quantization for the i16 fast path: fixed scale, per-code clamp.
///
/// The grid is `q = round(x * SIMD_LLR_SCALE).clamp(±qmax)`. The clamp
/// is [`defaults::SIMD_QMAX`] for every practical code and only
/// shrinks for extreme `k * beta` products, preserving the NEG-Q
/// separation invariant above. [`dequantize`](Quantizer::dequantize)
/// maps a grid point back to the exact `f32` the scalar oracle must
/// see for bit-identical comparison (multiples of `1/SIMD_LLR_SCALE`
/// are exact in f32 and their stage sums are exact in f64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Quantizer {
    qmax: i16,
}

impl Quantizer {
    /// The quantizer for a code geometry (single-stage passes).
    pub fn for_code(k: u32, beta: usize) -> Quantizer {
        Quantizer::for_code_radix(k, beta, 1)
    }

    /// The quantizer for a code geometry decoded in radix-2^rho
    /// super-stages. At `rho = 1` this is exactly [`for_code`]; at
    /// `rho > 1` both invariants below widen by the extra stages a
    /// single super-branch add spans.
    ///
    /// [`for_code`]: Quantizer::for_code
    pub fn for_code_radix(k: u32, beta: usize, rho: usize) -> Quantizer {
        let (k, beta, rho) = (k as i64, beta as i64, rho as i64);
        // separation: a NEG-descendant can survive into a compare up to
        // rho - 1 stages past the k - 1 warm-up horizon, so require
        // NEG_Q + 2 (k-2+rho) * bm_max < 0 with bm_max = beta*qmax
        // (reduces to 2 (k-1) at rho = 1)
        let sep = (-(NEG_Q as i64) - 1) / (2 * (k - 2 + rho) * beta);
        // headroom: even at the narrowest renormalization period (one
        // super-stage), every real-path value — floor
        // `-(rho + 2(k-1)) * bm_max` below the running maximum, plus
        // one more rho-stage super-branch add — stays above i16::MIN,
        // so exactness never depends on the generator polynomials
        // keeping the metric maximum monotone
        let headroom = i16::MAX as i64 / ((2 * (k - 1) + 2 * rho) * beta);
        Quantizer { qmax: defaults::SIMD_QMAX.min(sep.min(headroom).max(1) as i16) }
    }

    /// Per-LLR clamp magnitude on the quantized grid.
    pub fn qmax(&self) -> i16 {
        self.qmax
    }

    /// One LLR onto the grid (round half away from zero, then clamp).
    #[inline]
    pub fn quantize(&self, x: f32) -> i16 {
        let q = (x * defaults::SIMD_LLR_SCALE).round();
        q.clamp(-(self.qmax as f32), self.qmax as f32) as i16
    }

    /// The exact `f32` a grid point represents.
    #[inline]
    pub fn dequantize(&self, q: i16) -> f32 {
        q as f32 / defaults::SIMD_LLR_SCALE
    }

    /// Largest per-stage branch-metric magnitude on the grid.
    pub fn branch_metric_max(&self, beta: usize) -> i32 {
        self.qmax as i32 * beta as i32
    }

    /// Largest per-super-stage branch-metric magnitude on the grid —
    /// `rho` stages land in one saturating add at radix 2^rho.
    pub fn superbranch_metric_max(&self, beta: usize, rho: usize) -> i32 {
        self.branch_metric_max(beta) * rho as i32
    }
}

/// `FrameDecoder` for the quantized SIMD fast path — the
/// `BackendKind::Simd` backend. Emits the same bit-packed
/// [`CompactSurvivors`](super::compact::CompactSurvivors) snapshots as
/// the `compact` backend (1 bit per state per stage, one shared
/// [`DecisionRing`] across the batch) and decodes bit-identically to
/// the scalar oracle on grid LLRs.
pub struct SimdDecoder {
    trellis: Arc<Trellis>,
    stages: usize,
    /// Effective renormalization period in stages (>= rho, a multiple
    /// of rho; user value clamped to the i16 headroom cap, 0 selects
    /// the cap).
    renorm_every: usize,
    quant: Quantizer,
    beta: usize,
    /// Trellis stages folded per pass (1 = butterfly ACS, 2 =
    /// radix-4 super-branch tournament).
    rho: usize,
    /// Butterfly count `S / 2`.
    h: usize,
    /// Dragonfly count `S / 2^rho` (== `h` at rho 1).
    ndf: usize,
    /// `±1` sign planes, `[class][bit][butterfly]` flattened: class 0/1
    /// feed states `f` (low half, input 0) from predecessors `2f` /
    /// `2f+1`, class 2/3 feed states `h + f` (high half, input 1).
    /// Empty at rho 2.
    sgn: Vec<i16>,
    /// rho = 2 super-branch sign planes,
    /// `[class][bit][dragonfly]` flattened with
    /// `class = (y_right << 2) | y_left` and `rho * beta` bits per
    /// class. Empty at rho 1.
    sgn2: Vec<i16>,
    // --- scratch, allocated once, reused for every frame ---
    q: Vec<i16>,
    lam: Vec<i16>,
    ev: Vec<i16>,
    od: Vec<i16>,
    /// Left-metric quarter gather at rho 2: `g[y*ndf + f] = lam[4f+y]`
    /// (Thm 4 left states of dragonfly `f`). Empty at rho 1.
    g: Vec<i16>,
    /// Per-stage branch metrics, `[class][butterfly]` flattened.
    bm: Vec<i16>,
    /// Per-super-stage branch metrics at rho 2, `[class][dragonfly]`
    /// flattened (16 classes). Empty at rho 1.
    bm2: Vec<i16>,
    /// Decision lanes (nonzero = the high predecessor won; at rho 2,
    /// bit 0 of the tournament winner).
    dec: Vec<i16>,
    /// Second decision lane at rho 2 (bit 1 of the winner). Empty at
    /// rho 1.
    dec_hi: Vec<i16>,
    /// rho-bit winner staging for a whole frame at rho 2, step-major
    /// (`[step][state]`), fed to `CompactSurvivors::from_radix`. Empty
    /// at rho 1.
    phi: Vec<u8>,
    ring: DecisionRing,
    use_avx2: bool,
}

impl SimdDecoder {
    /// A decoder for `stages`-stage frames; `renorm_every` is the
    /// renormalization period in stages (0 = the widest period the i16
    /// headroom allows; larger requests are clamped to it).
    pub fn new(trellis: Arc<Trellis>, stages: usize, renorm_every: usize) -> Self {
        SimdDecoder::with_radix(trellis, stages, renorm_every, 1)
    }

    /// A decoder folding `rho in {1, 2}` trellis stages per pass
    /// (radix-2^rho super-branches, the paper's Thm 3–7). `rho = 1` is
    /// exactly [`new`](SimdDecoder::new); `rho = 2` requires an even
    /// `stages` and `rho < k` (validated by
    /// [`DecoderBuilder::radix`](crate::api::DecoderBuilder::radix)
    /// before construction — this constructor panics on misuse).
    pub fn with_radix(trellis: Arc<Trellis>, stages: usize, renorm_every: usize,
                      rho: usize) -> Self {
        let code = trellis.code();
        assert!(rho == 1 || rho == 2, "simd radix must be 1 or 2, got {rho}");
        assert!((rho as u32) < code.k(), "radix-2^{rho} invalid for k={}", code.k());
        assert_eq!(stages % rho, 0,
                   "frame stages {stages} not divisible by radix rho={rho}");
        let s_count = code.n_states();
        let beta = code.beta();
        let h = s_count / 2;
        let ndf = trellis.n_dragonflies(rho as u32);
        let quant = Quantizer::for_code_radix(code.k(), beta, rho);
        // headroom cap on the renormalization period R: real-path
        // metrics live in [-(R + 2(k-1)) * bm_max, R * bm_max] around
        // the running maximum (which may drift down bm_max per stage
        // for codes whose branch outputs are not complementary), so
        // (R + 2(k-1) + rho) * bm_max <= i16::MAX keeps every compared
        // value exact — no saturation on any surviving path (the
        // `+ rho` is the one super-branch add past the window). The
        // period is floored to a multiple of rho so renormalization
        // always lands on a super-stage boundary.
        let bm_max = quant.branch_metric_max(beta);
        let spread = 2 * (code.k() as i32 - 1) + rho as i32;
        let cap = (i16::MAX as i32 / bm_max - spread).max(rho as i32) as usize;
        let renorm = if renorm_every == 0 { cap } else { renorm_every.min(cap) };
        let renorm = (renorm / rho * rho).max(rho);

        let mut sgn = Vec::new();
        let mut sgn2 = Vec::new();
        if rho == 1 {
            sgn = vec![0i16; 4 * beta * h];
            for f in 0..h {
                // branch classes: (class, predecessor, input bit u); states
                // f and h + f share predecessors {2f, 2f+1} (Thm 1) and
                // consume u = 0 / u = 1 respectively (u is the MSB of j)
                for (cls, src, u) in [(0usize, 2 * f, 0usize), (1, 2 * f + 1, 0),
                                      (2, 2 * f, 1), (3, 2 * f + 1, 1)] {
                    let sym = trellis.out[src][u];
                    for b in 0..beta {
                        sgn[(cls * beta + b) * h + f] =
                            if (sym >> b) & 1 == 0 { 1 } else { -1 };
                    }
                }
            }
        } else {
            // 16 super-branch classes (y_left, y_right), each rho*beta
            // output bits per dragonfly (Thm 6: the path, hence the
            // output, is unique given the endpoints)
            let rb = rho * beta;
            sgn2 = vec![0i16; 16 * rb * ndf];
            for yr in 0..4u32 {
                for yl in 0..4u32 {
                    let cls = ((yr << 2) | yl) as usize;
                    for f in 0..ndf {
                        let o = trellis.superbranch_output(2, f as u32, yl, yr);
                        for b in 0..rb {
                            sgn2[(cls * rb + b) * ndf + f] =
                                if (o >> b) & 1 == 0 { 1 } else { -1 };
                        }
                    }
                }
            }
        }

        #[cfg(target_arch = "x86_64")]
        let use_avx2 = std::arch::is_x86_feature_detected!("avx2");
        #[cfg(not(target_arch = "x86_64"))]
        let use_avx2 = false;

        SimdDecoder {
            stages,
            renorm_every: renorm,
            quant,
            beta,
            rho,
            h,
            ndf,
            sgn,
            sgn2,
            q: Vec::with_capacity(stages * beta),
            lam: vec![0i16; s_count],
            ev: vec![0i16; h],
            od: vec![0i16; h],
            g: if rho == 2 { vec![0i16; s_count] } else { Vec::new() },
            bm: vec![0i16; 4 * h],
            bm2: if rho == 2 { vec![0i16; 16 * ndf] } else { Vec::new() },
            dec: vec![0i16; s_count],
            dec_hi: if rho == 2 { vec![0i16; s_count] } else { Vec::new() },
            phi: if rho == 2 { vec![0u8; stages / 2 * s_count] } else { Vec::new() },
            ring: DecisionRing::new(stages, s_count),
            trellis,
            use_avx2,
        }
    }

    /// The quantizer this decoder applies to incoming LLRs (tests use
    /// it to put the scalar oracle on the same grid).
    pub fn quantizer(&self) -> Quantizer {
        self.quant
    }

    /// Effective renormalization period in stages.
    pub fn effective_renorm(&self) -> usize {
        self.renorm_every
    }

    /// Trellis stages folded per pass (the rho of radix-2^rho).
    pub fn radix(&self) -> usize {
        self.rho
    }

    /// Survivor bytes a full frame occupies — identical to the
    /// `compact` layout (`frame_stages * ceil(n_states / 64) * 8` at
    /// radix 1; rho-bit selectors over `stages / rho` steps pack to
    /// the same total at radix 2).
    pub fn survivor_bytes_per_frame(&self) -> usize {
        if self.rho == 2 {
            let wps = CompactSurvivors::words_per_step(self.lam.len(), 2);
            self.stages / 2 * wps * std::mem::size_of::<u64>()
        } else {
            self.ring.bytes()
        }
    }

    /// Force the portable (non-AVX2) kernel; the lanes produce
    /// identical bits either way, this exists so tests can pin that.
    #[doc(hidden)]
    pub fn force_portable(&mut self) {
        self.use_avx2 = false;
    }

    /// Quantized forward pass for one frame already loaded into
    /// `self.q`; decisions land in the ring, metrics in `self.lam`.
    fn forward_quantized(&mut self, start_state: Option<u32>) {
        let h = self.h;
        let beta = self.beta;
        assert_eq!(self.q.len() % beta, 0, "llr length must be a multiple of beta");
        let n = self.q.len() / beta;

        match start_state {
            Some(s) => {
                self.lam.fill(NEG_Q);
                self.lam[s as usize] = 0;
            }
            None => self.lam.fill(0),
        }
        self.ring.begin_frame();

        #[cfg(debug_assertions)]
        let scratch_ptrs = (self.q.as_ptr(), self.lam.as_ptr(), self.ev.as_ptr(),
                            self.od.as_ptr(), self.bm.as_ptr(), self.dec.as_ptr());

        for t in 0..n {
            if t > 0 && t % self.renorm_every == 0 {
                let m = self.lam.iter().copied().max().unwrap_or(0);
                for v in self.lam.iter_mut() {
                    *v = v.saturating_sub(m);
                }
            }
            // even/odd split: ev[f] = lam[2f], od[f] = lam[2f+1]
            for f in 0..h {
                self.ev[f] = self.lam[2 * f];
                self.od[f] = self.lam[2 * f + 1];
            }
            // branch metrics per class, one sign-plane pass per LLR bit
            // (the per-symbol dedup: every state's delta is one of the
            // 2^beta values these planes reproduce)
            self.bm.fill(0);
            for b in 0..beta {
                let lb = self.q[t * beta + b];
                for cls in 0..4usize {
                    let plane = &self.sgn[(cls * beta + b) * h..(cls * beta + b) * h + h];
                    let out = &mut self.bm[cls * h..cls * h + h];
                    for f in 0..h {
                        out[f] += plane[f] * lb;
                    }
                }
            }
            // butterfly ACS: two contiguous half-loops (low half from
            // classes 0/1, high half from classes 2/3)
            let (bm_lo, bm_hi) = self.bm.split_at(2 * h);
            let (bm_lo0, bm_lo1) = bm_lo.split_at(h);
            let (bm_hi0, bm_hi1) = bm_hi.split_at(h);
            let (lam_lo, lam_hi) = self.lam.split_at_mut(h);
            let (dec_lo, dec_hi) = self.dec.split_at_mut(h);
            acs_half(&self.ev, &self.od, bm_lo0, bm_lo1, lam_lo, dec_lo, self.use_avx2);
            acs_half(&self.ev, &self.od, bm_hi0, bm_hi1, lam_hi, dec_hi, self.use_avx2);
            // pack decision lanes into the ring's stage word
            let w = self.ring.push_stage();
            for (j, &d) in self.dec.iter().enumerate() {
                if d != 0 {
                    w[j >> 6] |= 1u64 << (j & 63);
                }
            }
        }

        #[cfg(debug_assertions)]
        debug_assert_eq!(
            scratch_ptrs,
            (self.q.as_ptr(), self.lam.as_ptr(), self.ev.as_ptr(),
             self.od.as_ptr(), self.bm.as_ptr(), self.dec.as_ptr()),
            "steady-state stage loop must not reallocate scratch"
        );
    }

    /// Radix-4 (rho = 2) forward pass for one frame already loaded
    /// into `self.q`: 2-bit tournament winners land in `self.phi`
    /// (step-major), metrics in `self.lam`. Returns the super-step
    /// count.
    fn forward_quantized_radix2(&mut self, start_state: Option<u32>) -> usize {
        let ndf = self.ndf;
        let beta = self.beta;
        let rb = 2 * beta;
        let s_count = self.lam.len();
        assert_eq!(self.q.len() % rb, 0,
                   "llr length must cover whole super-stages (rho * beta)");
        let steps = self.q.len() / rb;
        assert!(steps * s_count <= self.phi.len(),
                "frame exceeds phi staging capacity of {} stages", self.stages);

        match start_state {
            Some(s) => {
                self.lam.fill(NEG_Q);
                self.lam[s as usize] = 0;
            }
            None => self.lam.fill(0),
        }

        #[cfg(debug_assertions)]
        let scratch_ptrs = (self.q.as_ptr(), self.lam.as_ptr(), self.g.as_ptr(),
                            self.bm2.as_ptr(), self.dec.as_ptr(),
                            self.dec_hi.as_ptr(), self.phi.as_ptr());

        for tau in 0..steps {
            let stage = 2 * tau;
            if stage > 0 && stage % self.renorm_every == 0 {
                let m = self.lam.iter().copied().max().unwrap_or(0);
                for v in self.lam.iter_mut() {
                    *v = v.saturating_sub(m);
                }
            }
            // quarter gather: g[y*ndf + f] = lam[4f + y] — the four
            // left local states of dragonfly f (Thm 4 / Eq 28)
            for f in 0..ndf {
                let base = f << 2;
                self.g[f] = self.lam[base];
                self.g[ndf + f] = self.lam[base + 1];
                self.g[2 * ndf + f] = self.lam[base + 2];
                self.g[3 * ndf + f] = self.lam[base + 3];
            }
            // super-branch metrics for all 16 (y_left, y_right)
            // classes: one sign-plane pass per quantized LLR of the
            // stage pair (the rho-stage form of the per-symbol dedup)
            self.bm2.fill(0);
            for b in 0..rb {
                let lb = self.q[stage * beta + b];
                for cls in 0..16usize {
                    let plane = &self.sgn2[(cls * rb + b) * ndf..(cls * rb + b) * ndf + ndf];
                    let out = &mut self.bm2[cls * ndf..cls * ndf + ndf];
                    for f in 0..ndf {
                        out[f] += plane[f] * lb;
                    }
                }
            }
            // four-candidate tournament per right local state; the
            // quarters of lam/dec are the ndf right states at each y
            for yr in 0..4usize {
                let cb = (yr << 2) * ndf;
                acs_super4(
                    [&self.g[..ndf], &self.g[ndf..2 * ndf],
                     &self.g[2 * ndf..3 * ndf], &self.g[3 * ndf..4 * ndf]],
                    [&self.bm2[cb..cb + ndf], &self.bm2[cb + ndf..cb + 2 * ndf],
                     &self.bm2[cb + 2 * ndf..cb + 3 * ndf],
                     &self.bm2[cb + 3 * ndf..cb + 4 * ndf]],
                    &mut self.lam[yr * ndf..(yr + 1) * ndf],
                    &mut self.dec[yr * ndf..(yr + 1) * ndf],
                    &mut self.dec_hi[yr * ndf..(yr + 1) * ndf],
                    self.use_avx2,
                );
            }
            // pack the two decision lanes into 2-bit winners
            let pw = &mut self.phi[tau * s_count..(tau + 1) * s_count];
            for j in 0..s_count {
                pw[j] = (((self.dec_hi[j] != 0) as u8) << 1) | (self.dec[j] != 0) as u8;
            }
        }

        #[cfg(debug_assertions)]
        debug_assert_eq!(
            scratch_ptrs,
            (self.q.as_ptr(), self.lam.as_ptr(), self.g.as_ptr(),
             self.bm2.as_ptr(), self.dec.as_ptr(), self.dec_hi.as_ptr(),
             self.phi.as_ptr()),
            "steady-state super-stage loop must not reallocate scratch"
        );
        steps
    }
}

/// One half of the butterfly ACS update over `h` butterflies:
/// `m0 = ev + bm0`, `m1 = od + bm1` (saturating), keep the max, record
/// whether the high predecessor strictly won (ties keep the low
/// predecessor, matching the scalar oracle's `l0 >= l1`).
fn acs_half(ev: &[i16], od: &[i16], bm0: &[i16], bm1: &[i16],
            lam: &mut [i16], dec: &mut [i16], use_avx2: bool) {
    let h = ev.len();
    let f0 = acs_half_vector(ev, od, bm0, bm1, lam, dec, use_avx2);
    for f in f0..h {
        let m0 = ev[f].saturating_add(bm0[f]);
        let m1 = od[f].saturating_add(bm1[f]);
        lam[f] = m0.max(m1);
        dec[f] = (m1 > m0) as i16;
    }
}

/// One radix-4 super-stage tournament over `lam.len()` dragonflies:
/// candidate `T[y] = g[y] + bm[y]` (saturating) per left local state,
/// two strict-greater pair compares pick within-pair winners, one
/// strict-greater cross compare picks the pair — exactly the scalar
/// oracle's two dependent `l0 >= l1` stages, reassociated (within a
/// pair both candidates share the second-stage branch metric, so the
/// pair compare *is* the first-stage compare). `dec0`/`dec1` get bits
/// 0/1 of the winning left local state.
fn acs_super4(g: [&[i16]; 4], bm: [&[i16]; 4], lam: &mut [i16],
              dec0: &mut [i16], dec1: &mut [i16], use_avx2: bool) {
    let n = lam.len();
    let f0 = acs_super4_vector(g, bm, lam, dec0, dec1, use_avx2);
    for f in f0..n {
        let t0 = g[0][f].saturating_add(bm[0][f]);
        let t1 = g[1][f].saturating_add(bm[1][f]);
        let t2 = g[2][f].saturating_add(bm[2][f]);
        let t3 = g[3][f].saturating_add(bm[3][f]);
        let m0 = t0.max(t1);
        let m1 = t2.max(t3);
        let hi = m1 > m0;
        lam[f] = m0.max(m1);
        dec0[f] = if hi { (t3 > t2) as i16 } else { (t1 > t0) as i16 };
        dec1[f] = hi as i16;
    }
}

/// Run the explicit vector kernel over the largest prefix it covers,
/// returning the first butterfly left for the portable tail (0 when no
/// vector kernel applies).
#[cfg(target_arch = "x86_64")]
fn acs_half_vector(ev: &[i16], od: &[i16], bm0: &[i16], bm1: &[i16],
                   lam: &mut [i16], dec: &mut [i16], use_avx2: bool) -> usize {
    if use_avx2 && ev.len() >= 16 {
        // SAFETY: AVX2 presence was checked at decoder construction
        // (`use_avx2` is never set without the runtime feature check)
        // and all six slices have length ev.len().
        unsafe { avx2::acs_half_16(ev, od, bm0, bm1, lam, dec) };
        ev.len() & !15
    } else {
        0
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn acs_half_vector(_ev: &[i16], _od: &[i16], _bm0: &[i16], _bm1: &[i16],
                   _lam: &mut [i16], _dec: &mut [i16], _use_avx2: bool) -> usize {
    0
}

/// Vector prefix of the radix-4 tournament, mirroring
/// [`acs_half_vector`]'s dispatch contract.
#[cfg(target_arch = "x86_64")]
fn acs_super4_vector(g: [&[i16]; 4], bm: [&[i16]; 4], lam: &mut [i16],
                     dec0: &mut [i16], dec1: &mut [i16], use_avx2: bool) -> usize {
    if use_avx2 && lam.len() >= 16 {
        // SAFETY: AVX2 presence was checked at decoder construction
        // and all eleven slices have length lam.len().
        unsafe { avx2::acs_super4_16(g, bm, lam, dec0, dec1) };
        lam.len() & !15
    } else {
        0
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn acs_super4_vector(_g: [&[i16]; 4], _bm: [&[i16]; 4], _lam: &mut [i16],
                     _dec0: &mut [i16], _dec1: &mut [i16], _use_avx2: bool) -> usize {
    0
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// The `acs_half` update, 16 butterflies per iteration, over the
    /// largest multiple-of-16 prefix (the caller finishes the tail).
    /// `_mm256_adds_epi16` is `i16::saturating_add`, `_mm256_max_epi16`
    /// the max, `_mm256_cmpgt_epi16(m1, m0)` the strict high-wins test
    /// — lane for lane the portable loop.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available and all slices have length
    /// >= `ev.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn acs_half_16(ev: &[i16], od: &[i16], bm0: &[i16], bm1: &[i16],
                              lam: &mut [i16], dec: &mut [i16]) {
        let n = ev.len() & !15;
        let mut f = 0usize;
        while f < n {
            let e = _mm256_loadu_si256(ev.as_ptr().add(f) as *const __m256i);
            let o = _mm256_loadu_si256(od.as_ptr().add(f) as *const __m256i);
            let b0 = _mm256_loadu_si256(bm0.as_ptr().add(f) as *const __m256i);
            let b1 = _mm256_loadu_si256(bm1.as_ptr().add(f) as *const __m256i);
            let m0 = _mm256_adds_epi16(e, b0);
            let m1 = _mm256_adds_epi16(o, b1);
            _mm256_storeu_si256(lam.as_mut_ptr().add(f) as *mut __m256i,
                                _mm256_max_epi16(m0, m1));
            _mm256_storeu_si256(dec.as_mut_ptr().add(f) as *mut __m256i,
                                _mm256_cmpgt_epi16(m1, m0));
            f += 16;
        }
    }

    /// The radix-4 tournament, 16 dragonflies per iteration, over the
    /// largest multiple-of-16 prefix (the caller finishes the tail).
    /// Pair selects come from `_mm256_cmpgt_epi16` (strict, so ties
    /// keep the low candidate), the winning pair's select is routed to
    /// `dec0` with `_mm256_blendv_epi8` — the `hi` mask is a full
    /// 0/0xFFFF i16 lane, so its per-byte blend picks whole lanes —
    /// lane for lane the portable loop.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available and all slices have length
    /// >= `lam.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn acs_super4_16(g: [&[i16]; 4], bm: [&[i16]; 4], lam: &mut [i16],
                                dec0: &mut [i16], dec1: &mut [i16]) {
        let n = lam.len() & !15;
        let mut f = 0usize;
        while f < n {
            let g0 = _mm256_loadu_si256(g[0].as_ptr().add(f) as *const __m256i);
            let g1 = _mm256_loadu_si256(g[1].as_ptr().add(f) as *const __m256i);
            let g2 = _mm256_loadu_si256(g[2].as_ptr().add(f) as *const __m256i);
            let g3 = _mm256_loadu_si256(g[3].as_ptr().add(f) as *const __m256i);
            let b0 = _mm256_loadu_si256(bm[0].as_ptr().add(f) as *const __m256i);
            let b1 = _mm256_loadu_si256(bm[1].as_ptr().add(f) as *const __m256i);
            let b2 = _mm256_loadu_si256(bm[2].as_ptr().add(f) as *const __m256i);
            let b3 = _mm256_loadu_si256(bm[3].as_ptr().add(f) as *const __m256i);
            let t0 = _mm256_adds_epi16(g0, b0);
            let t1 = _mm256_adds_epi16(g1, b1);
            let t2 = _mm256_adds_epi16(g2, b2);
            let t3 = _mm256_adds_epi16(g3, b3);
            let s0 = _mm256_cmpgt_epi16(t1, t0);
            let s1 = _mm256_cmpgt_epi16(t3, t2);
            let m0 = _mm256_max_epi16(t0, t1);
            let m1 = _mm256_max_epi16(t2, t3);
            let hi = _mm256_cmpgt_epi16(m1, m0);
            _mm256_storeu_si256(lam.as_mut_ptr().add(f) as *mut __m256i,
                                _mm256_max_epi16(m0, m1));
            _mm256_storeu_si256(dec0.as_mut_ptr().add(f) as *mut __m256i,
                                _mm256_blendv_epi8(s0, s1, hi));
            _mm256_storeu_si256(dec1.as_mut_ptr().add(f) as *mut __m256i, hi);
            f += 16;
        }
    }
}

impl FrameDecoder for SimdDecoder {
    fn frame_stages(&self) -> usize {
        self.stages
    }

    fn max_batch(&self) -> usize {
        // frames are independent; batching amortizes queue hops and
        // keeps the shared ring hot across the whole batch
        defaults::MAX_BATCH
    }

    fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    fn forward_batch(&mut self, jobs: &[FrameJob]) -> Vec<RawFrame> {
        let mut out = Vec::with_capacity(jobs.len());
        for job in jobs {
            self.q.clear();
            let quant = self.quant;
            self.q.extend(job.llr.iter().map(|&x| quant.quantize(x)));
            let surv = if self.rho == 2 {
                let steps = self.forward_quantized_radix2(job.start_state);
                let n_states = self.lam.len();
                CompactSurvivors::from_radix(2, &self.phi[..steps * n_states], n_states)
            } else {
                self.forward_quantized(job.start_state);
                self.ring.snapshot()
            };
            let lam = self.lam.iter().map(|&v| v as f32).collect();
            out.push(RawFrame { surv: Survivors::Compact(surv), lam });
        }
        out
    }

    fn label(&self) -> String {
        "simd".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{poly::Code, Encoder};
    use crate::viterbi::scalar::{self, ScalarDecoder};

    fn trellis() -> Arc<Trellis> {
        Arc::new(Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap()))
    }

    fn noisy_llrs(seed: u64, n_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(seed).bits(n_bits - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0x51D0);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }

    /// The scalar oracle fed the decoder's own grid values.
    fn oracle_on_grid(t: &Trellis, q: Quantizer, llr: &[f32], start: Option<u32>,
                      end: Option<u32>) -> Vec<u8> {
        let deq: Vec<f32> = llr.iter().map(|&x| q.dequantize(q.quantize(x))).collect();
        let lam0 = scalar::initial_metrics(t.code().n_states(), start);
        scalar::decode(t, &deq, &lam0, end)
    }

    #[test]
    fn quantizer_grid_roundtrips() {
        let q = Quantizer::for_code(7, 2);
        assert_eq!(q.qmax(), defaults::SIMD_QMAX);
        assert_eq!(q.quantize(1.0), 8);
        assert_eq!(q.quantize(-1.0), -8);
        assert_eq!(q.quantize(1e9), q.qmax());
        assert_eq!(q.quantize(-1e9), -q.qmax());
        assert_eq!(q.dequantize(q.quantize(0.33)), 0.375); // 3/8
        // separation invariant behind NEG_Q
        assert!(2 * 6 * q.branch_metric_max(2) < -(NEG_Q as i32));
    }

    #[test]
    fn extreme_codes_shrink_the_clamp() {
        let q = Quantizer::for_code(16, 4);
        assert!(q.qmax() < defaults::SIMD_QMAX);
        assert!(2 * 15 * q.branch_metric_max(4) < -(NEG_Q as i32));
        assert!(q.qmax() >= 1);
    }

    #[test]
    fn matches_scalar_on_noisy_frames() {
        let t = trellis();
        let mut dec = SimdDecoder::new(t.clone(), 128, 0);
        for seed in 0..8u64 {
            let (bits, llr) = noisy_llrs(seed + 40, 128, 4.0);
            let want = oracle_on_grid(&t, dec.quantizer(), &llr, Some(0), Some(0));
            let job = FrameJob {
                llr,
                start_state: Some(0),
                end_state: Some(0),
                emit_from: 0,
                emit_len: 128,
            };
            let got = dec.decode_batch(std::slice::from_ref(&job));
            assert_eq!(got[0], want, "seed {seed}");
            assert_eq!(got[0], bits, "seed {seed}: 4 dB n=128 decodes clean");
        }
    }

    #[test]
    fn renorm_periods_do_not_change_bits() {
        let t = trellis();
        let (_, llr) = noisy_llrs(77, 96, 3.0);
        let job = FrameJob {
            llr: llr.clone(),
            start_state: Some(0),
            end_state: None,
            emit_from: 0,
            emit_len: 96,
        };
        let base = SimdDecoder::new(t.clone(), 96, 0);
        let want = oracle_on_grid(&t, base.quantizer(), &llr, Some(0), None);
        for renorm in [1usize, 4, 16, 0] {
            let mut dec = SimdDecoder::new(t.clone(), 96, renorm);
            let got = dec.decode_batch(std::slice::from_ref(&job));
            assert_eq!(got[0], want, "renorm {renorm}");
        }
        // 32767/1024 - (2*6 + 1) = 31 - 13: headroom minus warm-up spread
        assert_eq!(base.effective_renorm(), 18, "auto period for qmax 512, beta 2, k 7");
        assert_eq!(SimdDecoder::new(t, 96, 1000).effective_renorm(), 18, "cap applies");
    }

    #[test]
    fn saturation_stress_matches_oracle_on_grid() {
        // amplitudes at and far beyond the clamp: the grid clamps both
        // decoders' inputs identically, decoded bits must still agree
        let t = trellis();
        let mut dec = SimdDecoder::new(t.clone(), 64, 16);
        for (seed, amp) in [(1u64, 60.0f32), (2, 64.0), (3, 500.0)] {
            let (_, mut llr) = noisy_llrs(seed + 700, 64, 2.0);
            for v in llr.iter_mut() {
                *v *= amp;
            }
            let want = oracle_on_grid(&t, dec.quantizer(), &llr, Some(0), Some(0));
            let job = FrameJob {
                llr,
                start_state: Some(0),
                end_state: Some(0),
                emit_from: 0,
                emit_len: 64,
            };
            let got = dec.decode_batch(std::slice::from_ref(&job));
            assert_eq!(got[0], want, "seed {seed} amp {amp}");
        }
    }

    #[test]
    fn avx2_and_portable_kernels_agree() {
        let t = trellis();
        let (_, llr) = noisy_llrs(123, 256, 3.5);
        let job = FrameJob {
            llr,
            start_state: Some(0),
            end_state: None,
            emit_from: 0,
            emit_len: 256,
        };
        let mut fast = SimdDecoder::new(t.clone(), 256, 8);
        let mut slow = SimdDecoder::new(t, 256, 8);
        slow.force_portable();
        let a = fast.decode_batch(std::slice::from_ref(&job));
        let b = slow.decode_batch(std::slice::from_ref(&job));
        assert_eq!(a, b, "explicit and portable kernels must be lane-identical");
    }

    #[test]
    fn ring_is_shared_across_the_batch_and_calls() {
        let t = trellis();
        let mut dec = SimdDecoder::new(t.clone(), 32, 0);
        assert_eq!(dec.survivor_bytes_per_frame(), 32 * 8);
        let mut sdec = ScalarDecoder::new(t.clone(), 32);
        let jobs: Vec<FrameJob> = (0..5u64)
            .map(|seed| {
                let (_, raw) = noisy_llrs(seed + 900, 32, 5.0);
                let llr: Vec<f32> = raw
                    .iter()
                    .map(|&x| dec.quantizer().dequantize(dec.quantizer().quantize(x)))
                    .collect();
                FrameJob { llr, start_state: Some(0), end_state: Some(0),
                           emit_from: 0, emit_len: 32 }
            })
            .collect();
        // one batched call over the shared ring ...
        let got = dec.decode_batch(&jobs);
        let want = sdec.decode_batch(&jobs);
        assert_eq!(got, want, "batched decode over one ring diverged from scalar");
        // ... then the same ring again on a later call (wrap-around)
        let got2 = dec.decode_batch(&jobs[..2]);
        assert_eq!(got2[..], want[..2], "ring reuse across calls diverged");
    }

    #[test]
    fn radix_quantizer_keeps_the_paper_grid() {
        let q = Quantizer::for_code_radix(7, 2, 2);
        assert_eq!(q.qmax(), defaults::SIMD_QMAX);
        // rho = 2 separation: a NEG-descendant can reach a compare one
        // super-stage past the k-1 warm-up horizon
        assert!(2 * (7 - 2 + 2) * q.branch_metric_max(2) < -(NEG_Q as i32));
        assert_eq!(q.superbranch_metric_max(2, 2), 2 * q.branch_metric_max(2));
        // rho = 1 delegates: identical grid to for_code
        assert_eq!(Quantizer::for_code_radix(7, 2, 1), Quantizer::for_code(7, 2));
        assert_eq!(Quantizer::for_code_radix(16, 4, 1), Quantizer::for_code(16, 4));
    }

    #[test]
    fn radix2_matches_scalar_on_noisy_frames() {
        let t = trellis();
        let mut dec = SimdDecoder::with_radix(t.clone(), 128, 0, 2);
        assert_eq!(dec.radix(), 2);
        for seed in 0..8u64 {
            let (bits, llr) = noisy_llrs(seed + 40, 128, 4.0);
            let want = oracle_on_grid(&t, dec.quantizer(), &llr, Some(0), Some(0));
            let job = FrameJob {
                llr,
                start_state: Some(0),
                end_state: Some(0),
                emit_from: 0,
                emit_len: 128,
            };
            let got = dec.decode_batch(std::slice::from_ref(&job));
            assert_eq!(got[0], want, "seed {seed}");
            assert_eq!(got[0], bits, "seed {seed}: 4 dB n=128 decodes clean");
        }
    }

    #[test]
    fn radix2_renorm_periods_do_not_change_bits() {
        let t = trellis();
        let (_, llr) = noisy_llrs(77, 96, 3.0);
        let job = FrameJob {
            llr: llr.clone(),
            start_state: Some(0),
            end_state: None,
            emit_from: 0,
            emit_len: 96,
        };
        let base = SimdDecoder::with_radix(t.clone(), 96, 0, 2);
        let want = oracle_on_grid(&t, base.quantizer(), &llr, Some(0), None);
        for renorm in [1usize, 2, 4, 16, 0] {
            let mut dec = SimdDecoder::with_radix(t.clone(), 96, renorm, 2);
            let got = dec.decode_batch(std::slice::from_ref(&job));
            assert_eq!(got[0], want, "renorm {renorm}");
        }
        // 32767/1024 - (2*6 + 2) = 31 - 14 = 17 stages, floored to the
        // super-stage boundary
        assert_eq!(base.effective_renorm(), 16, "auto period at rho 2");
        // a one-stage request rounds up to one whole super-stage
        assert_eq!(SimdDecoder::with_radix(t, 96, 1, 2).effective_renorm(), 2);
    }

    #[test]
    fn radix2_avx2_and_portable_kernels_agree() {
        let t = trellis();
        let (_, llr) = noisy_llrs(123, 256, 3.5);
        let job = FrameJob {
            llr,
            start_state: Some(0),
            end_state: None,
            emit_from: 0,
            emit_len: 256,
        };
        let mut fast = SimdDecoder::with_radix(t.clone(), 256, 8, 2);
        let mut slow = SimdDecoder::with_radix(t, 256, 8, 2);
        slow.force_portable();
        let a = fast.decode_batch(std::slice::from_ref(&job));
        let b = slow.decode_batch(std::slice::from_ref(&job));
        assert_eq!(a, b, "explicit and portable radix-4 kernels must be lane-identical");
    }

    #[test]
    fn radix2_survivor_bytes_match_radix1() {
        // 2-bit winners over stages/2 steps pack to the same bits per
        // state per stage as the 1-bit ring
        let t = trellis();
        assert_eq!(SimdDecoder::new(t.clone(), 32, 0).survivor_bytes_per_frame(), 32 * 8);
        assert_eq!(SimdDecoder::with_radix(t, 32, 0, 2).survivor_bytes_per_frame(), 32 * 8);
    }

    #[test]
    fn radix2_small_code_uses_the_scalar_tail() {
        // k = 3 at rho = 2 -> a single dragonfly per super-stage, far
        // below one AVX2 vector: the portable tail is the whole kernel
        let t = Arc::new(Trellis::new(Code::from_octal(3, &["7", "5"]).unwrap()));
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(9).bits(30);
        bits.extend_from_slice(&[0; 2]);
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let mut dec = SimdDecoder::with_radix(t.clone(), 32, 0, 2);
        let want = oracle_on_grid(&t, dec.quantizer(), &llr, Some(0), Some(0));
        let job = FrameJob {
            llr,
            start_state: Some(0),
            end_state: Some(0),
            emit_from: 0,
            emit_len: 32,
        };
        let got = dec.decode_batch(std::slice::from_ref(&job));
        assert_eq!(got[0], want);
        assert_eq!(got[0], bits);
    }

    #[test]
    #[should_panic(expected = "not divisible by radix")]
    fn radix2_rejects_odd_stage_counts() {
        let _ = SimdDecoder::with_radix(trellis(), 33, 0, 2);
    }

    #[test]
    fn small_code_exercises_scalar_tail() {
        // k = 3 -> 4 states, h = 2 butterflies: far below one AVX2
        // vector, so the portable tail is the whole kernel
        let t = Arc::new(Trellis::new(Code::from_octal(3, &["7", "5"]).unwrap()));
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(9).bits(30);
        bits.extend_from_slice(&[0; 2]);
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let mut dec = SimdDecoder::new(t.clone(), 32, 0);
        let want = oracle_on_grid(&t, dec.quantizer(), &llr, Some(0), Some(0));
        let job = FrameJob {
            llr,
            start_state: Some(0),
            end_state: Some(0),
            emit_from: 0,
            emit_len: 32,
        };
        let got = dec.decode_batch(std::slice::from_ref(&job));
        assert_eq!(got[0], want);
        assert_eq!(got[0], bits);
    }
}
