//! Memory-efficient survivor storage (`BackendKind::Compact`): the
//! scalar Alg-1 forward pass with survivors stored as **bit-packed
//! per-stage decision words** instead of one `u32` predecessor per
//! (stage, state).
//!
//! Every trellis state has exactly two predecessors (`prev[j] =
//! [i0, i1]`, low index first), so the add-compare-select outcome is a
//! single bit: *which* predecessor won. Storing that bit — rather than
//! the predecessor's global index — shrinks survivor memory 32× against
//! the scalar layout (`u32` per state per stage) and 8× against the
//! radix layout (`u8` per state per step); in the radix-2^rho view the
//! same store costs exactly `rho` bits per super-branch selection per
//! step, which is the information-theoretic floor. This is the
//! memory-efficient survivor organization of Mohammadidoost & Hashemi
//! (arXiv 2011.09337) applied to our tiled frames; the full memory
//! model (layouts, Eq-5 overhead interplay, per-shard budgets) is
//! documented in `docs/MEMORY.md`.
//!
//! Decisions live in a [`DecisionRing`]: a fixed-capacity ring of at
//! most `head + payload + tail` stages, allocated once per decoder and
//! rewritten in place frame after frame, so the forward pass never
//! materializes survivor state beyond one frame geometry. The per-frame
//! [`CompactSurvivors`] snapshot handed to the traceback pool is the
//! same bit-packed size.
//!
//! ```
//! use std::sync::Arc;
//! use tcvd::coding::{registry, trellis::Trellis};
//! use tcvd::viterbi::compact::CompactDecoder;
//! use tcvd::viterbi::types::{FrameDecoder, FrameJob};
//!
//! let t = Arc::new(Trellis::new(registry::paper_code()));
//! let mut dec = CompactDecoder::new(t, 16);
//! // 1 bit per state per stage: 16 stages x 64 states = 128 bytes
//! assert_eq!(dec.survivor_bytes_per_frame(), 128);
//! let job = FrameJob {
//!     llr: vec![1.0f32; 16 * 2], // positive LLR ⇒ bit 0
//!     start_state: Some(0),
//!     end_state: Some(0),
//!     emit_from: 0,
//!     emit_len: 16,
//! };
//! let bits = dec.decode_batch(std::slice::from_ref(&job));
//! assert_eq!(bits[0], vec![0u8; 16]);
//! ```

use std::sync::Arc;

use crate::coding::trellis::Trellis;

use super::scalar::initial_metrics;
use super::types::{FrameDecoder, FrameJob, RawFrame, Survivors};

/// Bit-packed survivor selections: `sel_bits` bits per (step, state).
///
/// Two layouts share this type, distinguished by `sel_bits`:
///
/// * `sel_bits == 1` — per-stage butterfly decisions (which of
///   `prev[j]`'s two predecessors won); one step per trellis stage.
///   This is what [`CompactDecoder`] emits.
/// * `sel_bits == rho >= 2` — radix-2^rho super-branch selections (the
///   winning left *local* state), one step per `rho` stages; the
///   packed form of [`Survivors::Radix`](super::types::Survivors).
///
/// Both are decoded by
/// [`traceback_compact`](super::traceback::traceback_compact), which
/// applies the Thm-4 dragonfly index math (a butterfly is the rho = 1
/// dragonfly). Entries are packed `64 / sel_bits` to a word, step-major
/// then state-major, with each step starting on a word boundary so a
/// step is a contiguous word slice.
#[derive(Clone, Debug)]
pub struct CompactSurvivors {
    sel_bits: u32,
    steps: usize,
    n_states: usize,
    words: Vec<u64>,
}

impl CompactSurvivors {
    /// Packed entries per 64-bit word for a selector width.
    #[inline]
    fn entries_per_word(sel_bits: u32) -> usize {
        64 / sel_bits as usize
    }

    /// Words needed to store one step (`n_states` selectors).
    pub fn words_per_step(n_states: usize, sel_bits: u32) -> usize {
        n_states.div_ceil(Self::entries_per_word(sel_bits))
    }

    /// Wrap pre-packed words (as produced by [`DecisionRing::snapshot`]).
    pub fn from_words(sel_bits: u32, steps: usize, n_states: usize, words: Vec<u64>) -> Self {
        assert!(sel_bits >= 1 && sel_bits <= 8, "selector width {sel_bits} out of range");
        assert_eq!(
            words.len(),
            steps * Self::words_per_step(n_states, sel_bits),
            "packed word count does not match {steps} steps x {n_states} states"
        );
        CompactSurvivors { sel_bits, steps, n_states, words }
    }

    /// Pack radix-form selections (`phi[tau * n_states + s]` = winning
    /// left local state, `rho` bits each) into the compact layout.
    pub fn from_radix(rho: u32, phi: &[u8], n_states: usize) -> Self {
        assert_eq!(phi.len() % n_states, 0);
        let steps = phi.len() / n_states;
        let wps = Self::words_per_step(n_states, rho);
        let epw = Self::entries_per_word(rho);
        let mut words = vec![0u64; steps * wps];
        for tau in 0..steps {
            for s in 0..n_states {
                let sel = phi[tau * n_states + s] as u64;
                debug_assert!(sel < (1 << rho), "selector {sel} exceeds {rho} bits");
                words[tau * wps + s / epw] |= sel << ((s % epw) as u32 * rho);
            }
        }
        CompactSurvivors { sel_bits: rho, steps, n_states, words }
    }

    /// Selector width in bits (1 for per-stage decisions, rho for
    /// radix-form selections).
    pub fn sel_bits(&self) -> u32 {
        self.sel_bits
    }

    /// Steps stored (stages for `sel_bits == 1`, stages / rho otherwise).
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Trellis states per step.
    pub fn n_states(&self) -> usize {
        self.n_states
    }

    /// The selector for (step, state).
    #[inline]
    pub fn get(&self, step: usize, state: usize) -> u32 {
        let epw = Self::entries_per_word(self.sel_bits);
        let wps = self.n_states.div_ceil(epw);
        let w = self.words[step * wps + state / epw];
        ((w >> ((state % epw) as u32 * self.sel_bits)) & ((1 << self.sel_bits) - 1)) as u32
    }

    /// Resident heap bytes of the packed store (the quantity the
    /// per-shard `survivor_bytes` gauge and `docs/MEMORY.md` count).
    pub fn bytes(&self) -> usize {
        self.words.len() * std::mem::size_of::<u64>()
    }
}

/// Fixed-capacity ring of bit-packed per-stage decision words.
///
/// Capacity is the frame geometry (`head + payload + tail` stages), set
/// once at decoder construction; the forward pass writes stage slots
/// with wrap-around addressing, so survivor storage stays bounded by
/// one frame no matter how many frames stream through.
/// [`snapshot`](DecisionRing::snapshot) linearizes the current frame's
/// stages into a [`CompactSurvivors`] for the traceback pool.
pub struct DecisionRing {
    cap: usize,
    wps: usize,
    n_states: usize,
    words: Vec<u64>,
    /// Ring slot holding the current frame's stage 0.
    start: usize,
    /// Stages written for the current frame.
    len: usize,
}

impl DecisionRing {
    /// A ring holding at most `cap_stages` stages of 1-bit decisions.
    pub fn new(cap_stages: usize, n_states: usize) -> Self {
        let wps = CompactSurvivors::words_per_step(n_states, 1);
        DecisionRing {
            cap: cap_stages,
            wps,
            n_states,
            words: vec![0u64; cap_stages * wps],
            start: 0,
            len: 0,
        }
    }

    /// Begin a new frame: subsequent stages overwrite the oldest slots.
    pub fn begin_frame(&mut self) {
        if self.cap > 0 {
            self.start = (self.start + self.len) % self.cap;
        }
        self.len = 0;
    }

    /// The (zeroed) word slot for the next stage; set bit `j` to record
    /// that state `j`'s *high* predecessor (`prev[j][1]`) won.
    pub fn push_stage(&mut self) -> &mut [u64] {
        assert!(
            self.len < self.cap,
            "frame exceeds ring capacity of {} stages (head + payload + tail)",
            self.cap
        );
        let slot = (self.start + self.len) % self.cap;
        self.len += 1;
        let w = &mut self.words[slot * self.wps..(slot + 1) * self.wps];
        w.fill(0);
        w
    }

    /// Ring capacity in stages.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Resident bytes of the ring itself (capacity, not fill level).
    pub fn bytes(&self) -> usize {
        self.words.len() * std::mem::size_of::<u64>()
    }

    /// Linearize the current frame's stages into a packed survivor
    /// store (stage 0 first, whatever the ring rotation).
    pub fn snapshot(&self) -> CompactSurvivors {
        let mut words = Vec::with_capacity(self.len * self.wps);
        for i in 0..self.len {
            let slot = (self.start + i) % self.cap;
            words.extend_from_slice(&self.words[slot * self.wps..(slot + 1) * self.wps]);
        }
        CompactSurvivors::from_words(1, self.len, self.n_states, words)
    }
}

/// The Alg-1 forward pass with bit-packed decisions written into
/// `ring` (arithmetic identical to [`scalar::forward`] — f64 metric
/// accumulation, ties select the low predecessor — so the decoded bits
/// are bit-identical to the scalar reference).
///
/// Returns the final path metrics; the decisions for the frame are
/// `ring.snapshot()`.
///
/// [`scalar::forward`]: super::scalar::forward
pub fn forward_into(t: &Trellis, llr: &[f32], lam0: &[f32], ring: &mut DecisionRing) -> Vec<f32> {
    let s_count = t.code().n_states();
    let beta = t.code().beta();
    assert_eq!(llr.len() % beta, 0, "llr length must be a multiple of beta");
    assert_eq!(lam0.len(), s_count);
    let n = llr.len() / beta;

    let nsym = 1usize << beta;
    let mut lam: Vec<f64> = lam0.iter().map(|&x| x as f64).collect();
    let mut lam_next = vec![0f64; s_count];
    let mut bm = vec![0f64; nsym];
    ring.begin_frame();

    for t_idx in 0..n {
        let l = &llr[t_idx * beta..(t_idx + 1) * beta];
        // branch metric once per distinct output symbol (Eq 2), exactly
        // as scalar::forward_with computes it
        for a in 0..nsym {
            let mut d = 0f64;
            for (b, &lb) in l.iter().enumerate() {
                d += if (a >> b) & 1 == 0 { lb as f64 } else { -(lb as f64) };
            }
            bm[a] = d;
        }
        let w = ring.push_stage();
        for j in 0..s_count {
            let [i0, i1] = t.prev[j];
            let u = t.code().branch_input(j as u32) as usize;
            let l0 = lam[i0 as usize] + bm[t.out[i0 as usize][u] as usize];
            let l1 = lam[i1 as usize] + bm[t.out[i1 as usize][u] as usize];
            if l0 >= l1 {
                lam_next[j] = l0;
            } else {
                lam_next[j] = l1;
                w[j / 64] |= 1u64 << (j % 64);
            }
        }
        std::mem::swap(&mut lam, &mut lam_next);
    }
    lam.iter().map(|&x| x as f32).collect()
}

/// One-shot forward pass allocating its own ring (tests, doc-examples;
/// the decoder reuses a ring across frames instead).
pub fn forward_compact(t: &Trellis, llr: &[f32], lam0: &[f32]) -> (CompactSurvivors, Vec<f32>) {
    let n = llr.len() / t.code().beta();
    let mut ring = DecisionRing::new(n.max(1), t.code().n_states());
    let lam = forward_into(t, llr, lam0, &mut ring);
    (ring.snapshot(), lam)
}

/// `FrameDecoder` with bit-packed survivor storage — the
/// `BackendKind::Compact` backend. Decodes bit-identically to
/// [`ScalarDecoder`](super::scalar::ScalarDecoder) at 1/32 of its
/// survivor memory.
pub struct CompactDecoder {
    trellis: Arc<Trellis>,
    stages: usize,
    ring: DecisionRing,
}

impl CompactDecoder {
    pub fn new(trellis: Arc<Trellis>, stages: usize) -> Self {
        let n_states = trellis.code().n_states();
        CompactDecoder { ring: DecisionRing::new(stages, n_states), trellis, stages }
    }

    /// Survivor bytes a full frame occupies (the `docs/MEMORY.md`
    /// per-frame quantity: `frame_stages * ceil(n_states / 64) * 8`).
    pub fn survivor_bytes_per_frame(&self) -> usize {
        self.ring.bytes()
    }
}

impl FrameDecoder for CompactDecoder {
    fn frame_stages(&self) -> usize {
        self.stages
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    fn forward_batch(&mut self, jobs: &[FrameJob]) -> Vec<RawFrame> {
        let s_count = self.trellis.code().n_states();
        let mut out = Vec::with_capacity(jobs.len());
        for job in jobs {
            let lam0 = initial_metrics(s_count, job.start_state);
            let lam = forward_into(&self.trellis, &job.llr, &lam0, &mut self.ring);
            out.push(RawFrame { surv: Survivors::Compact(self.ring.snapshot()), lam });
        }
        out
    }

    fn label(&self) -> String {
        "compact".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{poly::Code, Encoder};
    use crate::viterbi::scalar::{self, ScalarDecoder};
    use crate::viterbi::traceback::{traceback_compact, traceback_scalar};

    fn trellis() -> Arc<Trellis> {
        Arc::new(Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap()))
    }

    fn noisy_llrs(seed: u64, n_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(seed).bits(n_bits - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0xC0FFEE);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }

    #[test]
    fn forward_decisions_match_scalar_predecessors() {
        let t = trellis();
        let (_, llr) = noisy_llrs(42, 64, 3.0);
        let lam0 = scalar::initial_metrics(64, Some(0));
        let (phi, lam_s) = scalar::forward(&t, &llr, &lam0);
        let (surv, lam_c) = forward_compact(&t, &llr, &lam0);
        assert_eq!(lam_s, lam_c, "final metrics must be identical");
        assert_eq!(surv.steps(), 64);
        for stage in 0..64 {
            for j in 0..64usize {
                let pred = phi[stage * 64 + j];
                let bit = surv.get(stage, j);
                assert_eq!(
                    t.prev[j][bit as usize], pred,
                    "stage {stage} state {j}: decision bit does not select the scalar predecessor"
                );
            }
        }
    }

    #[test]
    fn compact_decode_equals_scalar_decode() {
        let t = trellis();
        for seed in 0..6u64 {
            let (bits, llr) = noisy_llrs(seed + 300, 128, 4.0);
            let lam0 = scalar::initial_metrics(64, Some(0));
            let oracle = scalar::decode(&t, &llr, &lam0, Some(0));
            let (surv, lam) = forward_compact(&t, &llr, &lam0);
            let out = traceback_compact(&t, &surv, &lam, Some(0));
            assert_eq!(out, oracle, "seed {seed}");
            assert_eq!(out, bits, "seed {seed}: 4 dB n=128 decodes clean");
        }
    }

    #[test]
    fn ring_reuses_capacity_across_frames() {
        let t = trellis();
        let mut dec = CompactDecoder::new(t.clone(), 32);
        let bytes = dec.survivor_bytes_per_frame();
        assert_eq!(bytes, 32 * 8, "32 stages x 64 states / 8 bits-per-byte");
        let mut sdec = ScalarDecoder::new(t, 32);
        // several frames through the same ring: wrap-around must not
        // corrupt decisions (start rotates with every frame)
        for seed in 0..5u64 {
            let (_, llr) = noisy_llrs(seed + 900, 32, 5.0);
            let job = FrameJob {
                llr,
                start_state: Some(0),
                end_state: Some(0),
                emit_from: 0,
                emit_len: 32,
            };
            let got = dec.decode_batch(std::slice::from_ref(&job));
            let want = sdec.decode_batch(std::slice::from_ref(&job));
            assert_eq!(got, want, "frame {seed} diverged after ring reuse");
        }
    }

    #[test]
    fn survivor_bytes_are_32x_smaller_than_scalar() {
        let t = trellis();
        let (_, llr) = noisy_llrs(7, 96, 5.0);
        let lam0 = scalar::initial_metrics(64, Some(0));
        let (phi, _) = scalar::forward(&t, &llr, &lam0);
        let (surv, _) = forward_compact(&t, &llr, &lam0);
        let scalar_bytes = phi.len() * std::mem::size_of::<u32>();
        assert_eq!(surv.bytes() * 32, scalar_bytes);
    }

    #[test]
    fn from_radix_roundtrips_selectors() {
        // rho = 2: 32 selectors per word, values 0..4
        let phi: Vec<u8> = (0..3 * 64).map(|i| (i % 4) as u8).collect();
        let c = CompactSurvivors::from_radix(2, &phi, 64);
        assert_eq!(c.sel_bits(), 2);
        assert_eq!(c.steps(), 3);
        assert_eq!(c.bytes(), 3 * 2 * 8);
        for tau in 0..3 {
            for s in 0..64 {
                assert_eq!(c.get(tau, s), (phi[tau * 64 + s]) as u32, "tau {tau} s {s}");
            }
        }
    }

    #[test]
    fn odd_state_counts_pack_correctly() {
        // k = 5 -> 16 states: exercises a non-64-multiple state count
        let t = Arc::new(Trellis::new(Code::from_octal(5, &["23", "33"]).unwrap()));
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(77).bits(28);
        bits.extend_from_slice(&[0; 4]);
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let lam0 = scalar::initial_metrics(16, Some(0));
        let (phi, lam) = scalar::forward(&t, &llr, &lam0);
        let oracle = traceback_scalar(&t, &phi, &lam, Some(0));
        let (surv, lam_c) = forward_compact(&t, &llr, &lam0);
        let out = traceback_compact(&t, &surv, &lam_c, Some(0));
        assert_eq!(out, oracle);
        assert_eq!(out, bits);
    }
}
