//! CPU execution of a tensor packing spec — the paper's tensor-core
//! forward pass (Eq 16-22 / Eq 33-38) with precision semantics mirroring
//! the AOT artifact bit-for-bit where the packing is the same:
//!
//! * A entries are ±1/0 (exact in any float format);
//! * B entries are LLRs rounded through half precision (tensor cores /
//!   the MXU take half A/B only — paper §IX-B);
//! * products accumulate in f32 (Volta WMMA and the MXU both widen),
//!   then `D = prod + C` is rounded through the accumulator precision;
//! * the max/argmax epilogue ties break to the first row (jnp.argmax).
//!
//! This is what lets BER sweeps (Fig 13) run at CPU speed while staying
//! faithful to the tensor formulation; cross-checked against the PJRT
//! artifact in `rust/tests/integration_runtime.rs`.
//!
//! A forward + traceback round trip (the split the serving pipeline
//! runs on different threads — forward on the engine shard, traceback
//! on the worker pool):
//!
//! ```
//! use std::sync::Arc;
//! use tcvd::coding::{registry, trellis::Trellis};
//! use tcvd::viterbi::packed::presets;
//! use tcvd::viterbi::types::{FrameDecoder, FrameJob};
//!
//! let t = Arc::new(Trellis::new(registry::paper_code()));
//! let mut dec = presets::radix4(t, 16); // 16 stages = 8 radix-4 steps
//! let job = FrameJob {
//!     llr: vec![1.0f32; 16 * 2], // positive LLR ⇒ bit 0
//!     start_state: Some(0),
//!     end_state: Some(0),
//!     emit_from: 0,
//!     emit_len: 16,
//! };
//! // forward pass: radix-form survivors + final path metrics ...
//! let raws = dec.forward_batch(std::slice::from_ref(&job));
//! assert_eq!(raws.len(), 1);
//! // ... then the backward procedure (Alg 2) emits the bits
//! let trellis = dec.trellis().clone();
//! let bits = raws[0].traceback(&trellis, &job);
//! assert_eq!(bits, vec![0u8; 16]);
//! ```

use std::sync::Arc;

use crate::channel::quantize::ChannelPrecision;
use crate::coding::packing::Packing;
use crate::coding::trellis::Trellis;
use crate::util::half::HalfKind;

use super::types::{neg_for, AccPrecision, FrameDecoder, FrameJob, RawFrame, Survivors};

/// Tensor-formulated decoder executing a `Packing` on the CPU.
pub struct PackedDecoder {
    trellis: Arc<Trellis>,
    pk: Packing,
    acc: AccPrecision,
    b_half: HalfKind,
    chan: ChannelPrecision,
    renorm_every: usize,
    stages: usize,
    // flattened hot tables
    theta: Vec<f32>,      // [o][c][r][e] = A[o][r][erow_oc(e)]
    col_used: Vec<bool>,  // [o][c]
    cg: Vec<i32>,         // [o][r][c]
    pinv: Vec<u32>,       // [o][c][gamma]
    src: Vec<(usize, usize, usize)>,
    // scratch (allocated once, reused across frames and batches)
    lam: Vec<f32>,
    lam_next: Vec<f32>,
    dvals: Vec<f32>, // [o][r][c] D matrix
    llr_q: Vec<f32>, // channel-quantized LLR staging buffer
    lam0: Vec<f32>,  // initial-metric staging buffer
}

impl PackedDecoder {
    pub fn new(trellis: Arc<Trellis>, pk: Packing, stages: usize, acc: AccPrecision,
               b_half: HalfKind, chan: ChannelPrecision, renorm_every: usize) -> Self {
        assert_eq!(stages % pk.rho as usize, 0, "stages must divide rho");
        let s_count = trellis.code().n_states();
        let (o_n, w) = (pk.n_ops, pk.width);

        // THETA[o][c][r][e] = A[o][r][row_of_e] where E[o][row][c] == e
        let mut theta = vec![0f32; o_n * 16 * 16 * w];
        let mut col_used = vec![false; o_n * 16];
        for o in 0..o_n {
            for c in 0..16 {
                // find the E row for each LLR entry e in this column
                let mut erow = vec![usize::MAX; w];
                for r in 0..16 {
                    let e = pk.e[o][r][c];
                    if e >= 0 {
                        erow[e as usize] = r;
                    }
                }
                if erow.iter().all(|&r| r == usize::MAX) {
                    continue; // unused column
                }
                col_used[o * 16 + c] = true;
                for r in 0..16 {
                    for (e, &br) in erow.iter().enumerate() {
                        if br != usize::MAX {
                            theta[((o * 16 + c) * 16 + r) * w + e] = pk.a[o][r][br];
                        }
                    }
                }
            }
        }
        // cg tiled [o][c][r] to match the dvals/theta tile layout
        let mut cg = vec![-1i32; o_n * 16 * 16];
        for o in 0..o_n {
            for r in 0..16 {
                for c in 0..16 {
                    cg[(o * 16 + c) * 16 + r] = pk.cg[o][r][c];
                }
            }
        }
        let mut pinv = vec![0u32; o_n * 16 * pk.gamma];
        for o in 0..o_n {
            for c in 0..16 {
                for g in 0..pk.gamma {
                    pinv[(o * 16 + c) * pk.gamma + g] = pk.pinv[o][c][g];
                }
            }
        }
        PackedDecoder {
            src: pk.src.clone(),
            lam: vec![0.0; s_count],
            lam_next: vec![0.0; s_count],
            dvals: vec![0.0; o_n * 16 * 16],
            llr_q: Vec::with_capacity(stages * trellis.code().beta()),
            lam0: Vec::with_capacity(s_count),
            trellis,
            pk,
            acc,
            b_half,
            chan,
            renorm_every,
            stages,
            theta,
            col_used,
            cg,
            pinv,
        }
    }

    pub fn packing(&self) -> &Packing {
        &self.pk
    }

    /// Forward pass over one frame: `llr` is `stages * beta` flat values
    /// (already channel-quantized by the caller if applicable).
    /// Returns (phi \[n_steps * S\] left-local selections, final metrics).
    pub fn forward(&mut self, llr: &[f32], lam0: &[f32]) -> (Vec<u8>, Vec<f32>) {
        let s_count = self.trellis.code().n_states();
        let beta = self.trellis.code().beta();
        assert_eq!(llr.len(), self.stages * beta, "llr length mismatch");
        let (rho, w, gamma, o_n) = (self.pk.rho as usize, self.pk.width, self.pk.gamma, self.pk.n_ops);
        let n_steps = self.stages / rho;
        let neg = neg_for(self.acc);
        let groups = 16 / gamma;

        self.lam.copy_from_slice(lam0);
        for v in self.lam.iter_mut() {
            *v = self.acc.round(*v);
        }
        let mut phi = vec![0u8; n_steps * s_count];
        let mut lh = [0f32; 8]; // w <= 8 for every supported packing
        assert!(w <= 8, "packing width {w} exceeds the fast-path buffer");
        let identity_acc = matches!(self.acc, AccPrecision::Single);

        #[cfg(debug_assertions)]
        let scratch_ptrs = (self.lam.as_ptr(), self.lam_next.as_ptr(), self.dvals.as_ptr());

        for tau in 0..n_steps {
            // renormalize (paper half-precision saturation mitigation)
            if self.renorm_every != 0 && tau % self.renorm_every == 0 {
                let m = self.lam.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                for v in self.lam.iter_mut() {
                    *v = self.acc.round(*v - m);
                }
            }
            // the L vector for this step, rounded through half (B is half)
            for e in 0..w {
                lh[e] = self.b_half.round(llr[tau * w + e]);
            }
            // D = A @ B + C, rounded through the accumulator precision.
            // dvals is tiled [o][c][r]: the epilogue reads gamma-groups
            // of rows contiguously.
            for o in 0..o_n {
                for c in 0..16 {
                    if !self.col_used[o * 16 + c] {
                        continue;
                    }
                    let tile = (o * 16 + c) * 16;
                    let theta = &self.theta[tile * w..(tile + 16) * w];
                    let cg = &self.cg[tile..tile + 16];
                    let out = &mut self.dvals[tile..tile + 16];
                    if w == 4 && identity_acc {
                        // hot path: radix-4, f32 accumulate
                        let (l0, l1, l2, l3) = (lh[0], lh[1], lh[2], lh[3]);
                        for r in 0..16 {
                            let t = &theta[r * 4..r * 4 + 4];
                            let g = cg[r];
                            let lam_g = if g >= 0 { self.lam[g as usize] } else { neg };
                            out[r] = t[0] * l0 + t[1] * l1 + t[2] * l2 + t[3] * l3 + lam_g;
                        }
                    } else {
                        for r in 0..16 {
                            let g = cg[r];
                            let lam_g = if g >= 0 { self.lam[g as usize] } else { neg };
                            let mut prod = 0f32;
                            for e in 0..w {
                                prod += theta[r * w + e] * lh[e];
                            }
                            out[r] = self.acc.round(prod + lam_g);
                        }
                    }
                }
            }
            // epilogue: max/argmax per gamma-group (contiguous rows in the
            // [o][c][r] tiling), scatter to states
            let phi_t = &mut phi[tau * s_count..(tau + 1) * s_count];
            for s in 0..s_count {
                let (o, g, c) = self.src[s];
                let _ = groups;
                let base = ((o * 16 + c) * 16) + g * gamma;
                let grp = &self.dvals[base..base + gamma];
                let mut best = grp[0];
                let mut sel = 0usize;
                for (i, &v) in grp.iter().enumerate().skip(1) {
                    if v > best {
                        best = v;
                        sel = i;
                    }
                }
                self.lam_next[s] = best;
                phi_t[s] = self.pinv[(o * 16 + c) * gamma + sel] as u8;
            }
            std::mem::swap(&mut self.lam, &mut self.lam_next);
        }
        #[cfg(debug_assertions)]
        {
            let now = (self.lam.as_ptr(), self.lam_next.as_ptr(), self.dvals.as_ptr());
            // lam/lam_next swap per step, so compare as unordered pairs
            debug_assert!(
                (now.0 == scratch_ptrs.0 || now.0 == scratch_ptrs.1)
                    && (now.1 == scratch_ptrs.0 || now.1 == scratch_ptrs.1)
                    && now.2 == scratch_ptrs.2,
                "steady-state stage loop must not reallocate scratch"
            );
        }
        (phi, self.lam.clone())
    }
}

impl FrameDecoder for PackedDecoder {
    fn frame_stages(&self) -> usize {
        self.stages
    }

    fn max_batch(&self) -> usize {
        1 // CPU path decodes frame-at-a-time; batching is the PJRT path
    }

    fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    fn forward_batch(&mut self, jobs: &[FrameJob]) -> Vec<RawFrame> {
        let s_count = self.trellis.code().n_states();
        let rho = self.pk.rho;
        let neg = neg_for(self.acc);
        // the staging buffers leave self while forward borrows it
        // mutably; their allocations are reused across the whole batch
        // and across forward_batch calls
        let mut llr_q = std::mem::take(&mut self.llr_q);
        let mut lam0 = std::mem::take(&mut self.lam0);
        let mut out = Vec::with_capacity(jobs.len());
        for job in jobs {
            llr_q.clear();
            llr_q.extend_from_slice(&job.llr);
            self.chan.quantize(&mut llr_q);
            super::scalar::initial_metrics_into(&mut lam0, s_count, job.start_state);
            for v in lam0.iter_mut() {
                if *v < 0.0 {
                    *v = neg;
                }
            }
            let (phi, lam) = self.forward(&llr_q, &lam0);
            out.push(RawFrame { surv: Survivors::Radix { rho, phi }, lam });
        }
        self.llr_q = llr_q;
        self.lam0 = lam0;
        out
    }

    fn label(&self) -> String {
        format!("{}-cpu(acc={:?})", self.pk.scheme, self.acc)
    }
}

/// Named constructors matching the paper's configurations.
pub mod presets {
    use super::*;
    use crate::coding::packing::build_packing;

    /// Radix-4 + dragonfly-group permutation (Fig 15), f32 accumulate.
    pub fn radix4(trellis: Arc<Trellis>, stages: usize) -> PackedDecoder {
        let pk = build_packing(&trellis, "radix4").expect("radix4 packs");
        PackedDecoder::new(trellis, pk, stages, AccPrecision::Single,
                           HalfKind::Bf16, ChannelPrecision::Single, 16)
    }

    /// Radix-2 butterflies (Fig 5), f32 accumulate.
    pub fn radix2(trellis: Arc<Trellis>, stages: usize) -> PackedDecoder {
        let pk = build_packing(&trellis, "radix2").expect("radix2 packs");
        PackedDecoder::new(trellis, pk, stages, AccPrecision::Single,
                           HalfKind::Bf16, ChannelPrecision::Single, 16)
    }

    /// Radix-4 without the permutation optimization (Fig 14).
    pub fn radix4_noperm(trellis: Arc<Trellis>, stages: usize) -> PackedDecoder {
        let pk = build_packing(&trellis, "radix4_noperm").expect("packs");
        PackedDecoder::new(trellis, pk, stages, AccPrecision::Single,
                           HalfKind::Bf16, ChannelPrecision::Single, 16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{poly::Code, Encoder};
    use crate::viterbi::scalar;

    fn trellis() -> Arc<Trellis> {
        Arc::new(Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap()))
    }

    fn noisy_llrs(seed: u64, n_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(seed).bits(n_bits - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0xABCD);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }

    #[test]
    fn all_schemes_match_scalar_on_noisy_data() {
        let t = trellis();
        for seed in 0..5u64 {
            let (bits, llr) = noisy_llrs(seed + 100, 64, 4.0);
            // scalar reference on HALF-ROUNDED llrs (B is always half)
            let llr_h: Vec<f32> = llr.iter().map(|&x| HalfKind::Bf16.round(x)).collect();
            let lam0 = scalar::initial_metrics(64, Some(0));
            let out_ref = scalar::decode(&t, &llr_h, &lam0, Some(0));
            for mk in [presets::radix2, presets::radix4, presets::radix4_noperm] {
                let mut d = mk(t.clone(), 64);
                let out = d.decode_batch(&[FrameJob {
                    llr: llr.clone(),
                    start_state: Some(0),
                    end_state: Some(0),
                    emit_from: 0,
                    emit_len: 64,
                }]);
                assert_eq!(out[0], out_ref, "seed {seed} {}", d.label());
                assert_eq!(out[0], bits, "seed {seed}: 4 dB n=64 decodes clean");
            }
        }
    }

    #[test]
    fn half_accumulator_still_decodes_easy_frames() {
        let t = trellis();
        let (bits, llr) = noisy_llrs(7, 64, 6.0);
        let pk = crate::coding::packing::build_packing(&t, "radix4").unwrap();
        let mut d = PackedDecoder::new(t, pk, 64, AccPrecision::Half(HalfKind::Bf16),
                                       HalfKind::Bf16, ChannelPrecision::Single, 8);
        let out = d.decode_batch(&[FrameJob {
            llr,
            start_state: Some(0),
            end_state: Some(0),
            emit_from: 0,
            emit_len: 64,
        }]);
        assert_eq!(out[0], bits);
    }

    #[test]
    fn renorm_keeps_metrics_bounded() {
        let t = trellis();
        let (_, llr) = noisy_llrs(9, 512, 4.0);
        let pk = crate::coding::packing::build_packing(&t, "radix4").unwrap();
        let mut d = PackedDecoder::new(t, pk, 512, AccPrecision::Single,
                                       HalfKind::Bf16, ChannelPrecision::Single, 4);
        let lam0 = vec![0.0f32; 64];
        let (_, lam) = d.forward(&llr, &lam0);
        // with renorm every 4 steps, metrics stay within ~max-step-gain
        assert!(lam.iter().all(|&v| v.abs() < 200.0),
                "metrics unbounded: {:?}", &lam[..4]);
    }
}
