//! Shared decoder types.

use std::sync::Arc;

use crate::coding::trellis::Trellis;
use crate::util::half::HalfKind;

/// Finite "minus infinity" for path metrics (stays representable in
/// bf16/f16 and survives repeated additions within a frame).
pub const NEG: f32 = -1.0e9;

/// Accumulator (C/D fragment) precision — the paper's Table I axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccPrecision {
    /// f32 path metrics ("single").
    Single,
    /// 16-bit path metrics ("half"); rounding applied after every
    /// accumulate, mirroring a half C/D fragment.
    Half(HalfKind),
}

impl AccPrecision {
    #[inline]
    pub fn round(self, x: f32) -> f32 {
        match self {
            AccPrecision::Single => x,
            AccPrecision::Half(kind) => kind.round(x),
        }
    }
}

/// One frame decode request (produced by the tiler / coordinator framer).
#[derive(Clone, Debug)]
pub struct FrameJob {
    /// Flat LLRs for `stages` trellis stages: `stages * beta` values.
    pub llr: Vec<f32>,
    /// Known encoder state at frame start (stream head / after flush),
    /// or None for a mid-stream tile (all-equal initial metrics).
    pub start_state: Option<u32>,
    /// Known end state (flushed stream tail), or None (argmax pick).
    pub end_state: Option<u32>,
    /// Which decoded bit positions to emit (skips warm-up overlap).
    pub emit_from: usize,
    pub emit_len: usize,
}

/// Survivor information produced by a forward pass, in whichever form
/// the backend emits it. The forms trade memory for lookup directness;
/// `docs/MEMORY.md` quantifies each layout.
#[derive(Clone, Debug)]
pub enum Survivors {
    /// Alg-1 form: predecessor *global state* per (stage, state).
    Scalar(Vec<u32>),
    /// Radix form: winning left *local* state (0..2^rho) per (step, state).
    Radix { rho: u32, phi: Vec<u8> },
    /// Bit-packed form: the same selections at `rho` bits each (1 bit
    /// per state per stage for butterfly decisions) — the
    /// memory-efficient layout of `BackendKind::Compact`.
    Compact(super::compact::CompactSurvivors),
}

impl Survivors {
    /// Resident heap bytes of the survivor store for one frame — the
    /// quantity the per-shard `survivor_bytes` metrics gauge reports
    /// and `docs/MEMORY.md` budgets.
    pub fn bytes(&self) -> usize {
        match self {
            Survivors::Scalar(phi) => phi.len() * std::mem::size_of::<u32>(),
            Survivors::Radix { phi, .. } => phi.len(),
            Survivors::Compact(c) => c.bytes(),
        }
    }
}

/// Raw output of a forward pass for one frame (traceback still pending).
#[derive(Clone, Debug)]
pub struct RawFrame {
    pub surv: Survivors,
    /// Final path metrics `[n_states]`.
    pub lam: Vec<f32>,
}

impl RawFrame {
    /// Run the backward procedure (Alg 2) and emit the requested window.
    pub fn traceback(&self, trellis: &Trellis, job: &FrameJob) -> Vec<u8> {
        let bits = match &self.surv {
            Survivors::Scalar(phi) => {
                super::traceback::traceback_scalar(trellis, phi, &self.lam, job.end_state)
            }
            Survivors::Radix { rho, phi } => {
                super::traceback::traceback_radix(trellis, *rho, phi, &self.lam, job.end_state)
            }
            Survivors::Compact(surv) => {
                super::traceback::traceback_compact(trellis, surv, &self.lam, job.end_state)
            }
        };
        bits[job.emit_from..job.emit_from + job.emit_len].to_vec()
    }
}

/// A frame decoder: fixed frame geometry, batch-oriented API so tensor
/// backends can amortize (the paper's frame-parallel launches). The
/// forward pass and traceback are split so the coordinator can pipeline
/// them across threads (forward on the PJRT engine thread, traceback on
/// worker threads — the paper's tensor-core/CUDA-core split).
pub trait FrameDecoder {
    /// Trellis stages a frame must contain.
    fn frame_stages(&self) -> usize;

    /// Largest batch the backend can take in one call (1 for scalar).
    fn max_batch(&self) -> usize;

    /// The trellis this decoder was built over.
    fn trellis(&self) -> &Arc<Trellis>;

    /// Forward pass only: survivors + final metrics per frame.
    fn forward_batch(&mut self, jobs: &[FrameJob]) -> Vec<RawFrame>;

    /// Decode a batch of frames; returns the emitted bits per frame
    /// (job.emit_from .. emit_from+emit_len).
    fn decode_batch(&mut self, jobs: &[FrameJob]) -> Vec<Vec<u8>> {
        let trellis = self.trellis().clone();
        self.forward_batch(jobs)
            .iter()
            .zip(jobs)
            .map(|(raw, job)| raw.traceback(&trellis, job))
            .collect()
    }

    /// Short backend label for logs/benches.
    fn label(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_round_is_identity() {
        assert_eq!(AccPrecision::Single.round(1.234567), 1.234567);
    }

    #[test]
    fn half_round_quantizes() {
        let x = 1.0 + 1.0 / 4096.0;
        assert_eq!(AccPrecision::Half(HalfKind::Bf16).round(x), 1.0);
    }

    #[test]
    fn neg_is_half_safe() {
        for kind in [HalfKind::Bf16, HalfKind::F16] {
            let r = kind.round(NEG);
            assert!(r.is_finite() || kind == HalfKind::F16, "{kind:?} {r}");
        }
        // f16 saturates NEG to inf — decoders clamp lam0 for f16 kinds
        // via `neg_for`.
        assert!(AccPrecision::Half(HalfKind::Bf16).round(NEG).is_finite());
    }
}

/// A "minus infinity" that stays finite in the given precision (binary16
/// overflows at 65504, so use a large-but-finite value there).
pub fn neg_for(acc: AccPrecision) -> f32 {
    match acc {
        AccPrecision::Half(HalfKind::F16) => -30000.0,
        _ => NEG,
    }
}
