//! Scalar Viterbi decoder: Algorithms 1 and 2 verbatim (the paper's §II-B
//! description; the baseline approach of refs [2,3] when run one frame
//! per thread). The correctness oracle for every other Rust path.

use std::sync::Arc;

use crate::coding::trellis::Trellis;

use super::traceback::traceback_scalar;
use super::types::{FrameDecoder, FrameJob, RawFrame, Survivors, NEG};

/// Reusable forward-pass scratch: path-metric double buffer plus the
/// per-symbol branch-metric table. One instance per decoder, reused
/// across `forward_batch` jobs so the steady-state batch loop allocates
/// nothing but its per-frame outputs.
pub struct ForwardScratch {
    lam: Vec<f64>,
    lam_next: Vec<f64>,
    /// Branch metric per distinct output symbol (`2^beta` entries): a
    /// stage only ever produces `2^beta` distinct `delta` values, not
    /// `n_states * 2` (Eq 2 depends on the branch output alone).
    bm: Vec<f64>,
}

impl ForwardScratch {
    pub fn new(s_count: usize, beta: usize) -> Self {
        ForwardScratch {
            lam: Vec::with_capacity(s_count),
            lam_next: vec![0f64; s_count],
            bm: vec![0f64; 1 << beta],
        }
    }
}

/// Forward procedure (Alg 1) over `n` stages.
///
/// `llr`: flat `n * beta` soft values; `lam0`: initial path metrics.
/// Returns (`phi` \[n\]\[S\] predecessor states, final metrics \[S\]).
///
/// `compact::forward_into` mirrors this arithmetic with a bit-packed
/// decision store, and `simd::SimdDecoder` mirrors it in quantized i16
/// — any change to the metric accumulation or tie-break here must be
/// applied there too (the cross-backend property tests in
/// `rust/tests/compact_equivalence.rs` and
/// `rust/tests/simd_equivalence.rs` pin the bit-identity).
pub fn forward(t: &Trellis, llr: &[f32], lam0: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let mut scratch = ForwardScratch::new(t.code().n_states(), t.code().beta());
    forward_with(t, llr, lam0, &mut scratch)
}

/// [`forward`] with caller-provided scratch (the hot-path entry: no
/// allocations beyond the `phi`/`lam` outputs).
///
/// The branch metric is computed **once per distinct output symbol**
/// per stage (`2^beta` values — 4 for the paper's rate-1/2 code, far
/// below `n_states * 2` for any code) instead of once per
/// `(state, input)` branch; the per-symbol sum runs over the LLRs in
/// the same order as the old per-branch loop, so the f64 results — and
/// therefore every ACS decision — are bit-identical.
pub fn forward_with(
    t: &Trellis,
    llr: &[f32],
    lam0: &[f32],
    scratch: &mut ForwardScratch,
) -> (Vec<u32>, Vec<f32>) {
    let s_count = t.code().n_states();
    let beta = t.code().beta();
    let nsym = 1usize << beta;
    assert_eq!(llr.len() % beta, 0, "llr length must be a multiple of beta");
    assert_eq!(lam0.len(), s_count);
    let n = llr.len() / beta;

    scratch.lam.clear();
    scratch.lam.extend(lam0.iter().map(|&x| x as f64));
    scratch.lam_next.clear();
    scratch.lam_next.resize(s_count, 0f64);
    scratch.bm.clear();
    scratch.bm.resize(nsym, 0f64);
    let mut phi = vec![0u32; n * s_count];

    for t_idx in 0..n {
        let l = &llr[t_idx * beta..(t_idx + 1) * beta];
        // branch metric once per distinct output symbol (Eq 2)
        for a in 0..nsym {
            let mut d = 0f64;
            for (b, &lb) in l.iter().enumerate() {
                d += if (a >> b) & 1 == 0 { lb as f64 } else { -(lb as f64) };
            }
            scratch.bm[a] = d;
        }
        for j in 0..s_count {
            let [i0, i1] = t.prev[j];
            let u = t.code().branch_input(j as u32) as usize;
            let l0 = scratch.lam[i0 as usize] + scratch.bm[t.out[i0 as usize][u] as usize];
            let l1 = scratch.lam[i1 as usize] + scratch.bm[t.out[i1 as usize][u] as usize];
            if l0 >= l1 {
                scratch.lam_next[j] = l0;
                phi[t_idx * s_count + j] = i0;
            } else {
                scratch.lam_next[j] = l1;
                phi[t_idx * s_count + j] = i1;
            }
        }
        std::mem::swap(&mut scratch.lam, &mut scratch.lam_next);
    }
    (phi, scratch.lam.iter().map(|&x| x as f32).collect())
}

/// Full decode: forward + traceback.
pub fn decode(t: &Trellis, llr: &[f32], lam0: &[f32], end_state: Option<u32>) -> Vec<u8> {
    let (phi, lam) = forward(t, llr, lam0);
    traceback_scalar(t, &phi, &lam, end_state)
}

/// Initial metrics: known start state or all-equal.
pub fn initial_metrics(s_count: usize, start_state: Option<u32>) -> Vec<f32> {
    let mut l = Vec::new();
    initial_metrics_into(&mut l, s_count, start_state);
    l
}

/// [`initial_metrics`] into a reusable buffer (cleared first).
pub fn initial_metrics_into(buf: &mut Vec<f32>, s_count: usize, start_state: Option<u32>) {
    buf.clear();
    match start_state {
        Some(s) => {
            buf.resize(s_count, NEG);
            buf[s as usize] = 0.0;
        }
        None => buf.resize(s_count, 0.0),
    }
}

/// `FrameDecoder` wrapper for the scalar path.
pub struct ScalarDecoder {
    trellis: Arc<Trellis>,
    stages: usize,
    scratch: ForwardScratch,
    lam0: Vec<f32>,
}

impl ScalarDecoder {
    pub fn new(trellis: Arc<Trellis>, stages: usize) -> Self {
        let scratch = ForwardScratch::new(trellis.code().n_states(), trellis.code().beta());
        ScalarDecoder { trellis, stages, scratch, lam0: Vec::new() }
    }
}

impl FrameDecoder for ScalarDecoder {
    fn frame_stages(&self) -> usize {
        self.stages
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    fn forward_batch(&mut self, jobs: &[FrameJob]) -> Vec<RawFrame> {
        let s_count = self.trellis.code().n_states();
        jobs.iter()
            .map(|job| {
                initial_metrics_into(&mut self.lam0, s_count, job.start_state);
                let (phi, lam) =
                    forward_with(&self.trellis, &job.llr, &self.lam0, &mut self.scratch);
                RawFrame { surv: Survivors::Scalar(phi), lam }
            })
            .collect()
    }

    fn label(&self) -> String {
        "scalar".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{poly::Code, Encoder};

    fn trellis() -> Trellis {
        Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap())
    }

    #[test]
    fn decodes_noiseless() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let bits = vec![1, 0, 1, 1, 0, 1, 0, 0, 1, 1, 0, 0, 0, 0, 0, 0];
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let lam0 = initial_metrics(64, Some(0));
        let out = decode(&t, &llr, &lam0, Some(0));
        assert_eq!(out, bits);
    }

    #[test]
    fn corrects_noise() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut payload = crate::util::rng::Rng::new(11).bits(250);
        payload.extend_from_slice(&[0; 6]); // flush
        let coded = enc.encode(&payload);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(5.0, 0.5, 3);
        let rx = ch.transmit(&tx);
        let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();
        let lam0 = initial_metrics(64, Some(0));
        let out = decode(&t, &llr, &lam0, Some(0));
        assert_eq!(out, payload, "5 dB should decode error-free at n=256");
    }

    #[test]
    fn hard_decision_also_corrects_single_flip() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let bits = vec![1, 1, 0, 1, 0, 0, 0, 0, 0, 0];
        let coded = enc.encode(&bits);
        let mut llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        llr[4] = -llr[4]; // flip one coded bit (hard error)
        let lam0 = initial_metrics(64, Some(0));
        assert_eq!(decode(&t, &llr, &lam0, Some(0)), bits);
    }

    #[test]
    fn unknown_end_state_uses_argmax() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let bits = vec![1, 0, 0, 1, 1, 0, 1, 0, 1, 0, 1, 1];
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let lam0 = initial_metrics(64, Some(0));
        let out = decode(&t, &llr, &lam0, None);
        assert_eq!(out, bits, "noiseless: argmax end state is the true path");
    }

    #[test]
    fn frame_decoder_emits_requested_range() {
        let t = Arc::new(trellis());
        let mut enc = Encoder::new(t.code().clone());
        let bits = crate::util::rng::Rng::new(5).bits(32);
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let mut d = ScalarDecoder::new(t, 32);
        let out = d.decode_batch(&[FrameJob {
            llr,
            start_state: Some(0),
            end_state: None,
            emit_from: 4,
            emit_len: 16,
        }]);
        assert_eq!(out[0], bits[4..20].to_vec());
    }
}
