//! Scalar Viterbi decoder: Algorithms 1 and 2 verbatim (the paper's §II-B
//! description; the baseline approach of refs [2,3] when run one frame
//! per thread). The correctness oracle for every other Rust path.

use std::sync::Arc;

use crate::coding::trellis::Trellis;

use super::traceback::traceback_scalar;
use super::types::{FrameDecoder, FrameJob, RawFrame, Survivors, NEG};

/// Forward procedure (Alg 1) over `n` stages.
///
/// `llr`: flat `n * beta` soft values; `lam0`: initial path metrics.
/// Returns (`phi` \[n\]\[S\] predecessor states, final metrics \[S\]).
///
/// `compact::forward_into` mirrors this arithmetic with a bit-packed
/// decision store — any change to the metric accumulation or tie-break
/// here must be applied there too (the cross-backend property tests in
/// `rust/tests/compact_equivalence.rs` pin the bit-identity).
pub fn forward(t: &Trellis, llr: &[f32], lam0: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let s_count = t.code().n_states();
    let beta = t.code().beta();
    assert_eq!(llr.len() % beta, 0, "llr length must be a multiple of beta");
    assert_eq!(lam0.len(), s_count);
    let n = llr.len() / beta;

    let mut lam: Vec<f64> = lam0.iter().map(|&x| x as f64).collect();
    let mut lam_next = vec![0f64; s_count];
    let mut phi = vec![0u32; n * s_count];

    // branch metric delta[i][u] recomputed per stage (Eq 2)
    let mut delta = vec![[0f64; 2]; s_count];
    for t_idx in 0..n {
        let l = &llr[t_idx * beta..(t_idx + 1) * beta];
        for i in 0..s_count {
            for u in 0..2usize {
                let a = t.out[i][u];
                let mut d = 0f64;
                for (b, &lb) in l.iter().enumerate() {
                    d += if (a >> b) & 1 == 0 { lb as f64 } else { -(lb as f64) };
                }
                delta[i][u] = d;
            }
        }
        for j in 0..s_count {
            let [i0, i1] = t.prev[j];
            let u = t.code().branch_input(j as u32) as usize;
            let l0 = lam[i0 as usize] + delta[i0 as usize][u];
            let l1 = lam[i1 as usize] + delta[i1 as usize][u];
            if l0 >= l1 {
                lam_next[j] = l0;
                phi[t_idx * s_count + j] = i0;
            } else {
                lam_next[j] = l1;
                phi[t_idx * s_count + j] = i1;
            }
        }
        std::mem::swap(&mut lam, &mut lam_next);
    }
    (phi, lam.iter().map(|&x| x as f32).collect())
}

/// Full decode: forward + traceback.
pub fn decode(t: &Trellis, llr: &[f32], lam0: &[f32], end_state: Option<u32>) -> Vec<u8> {
    let (phi, lam) = forward(t, llr, lam0);
    traceback_scalar(t, &phi, &lam, end_state)
}

/// Initial metrics: known start state or all-equal.
pub fn initial_metrics(s_count: usize, start_state: Option<u32>) -> Vec<f32> {
    match start_state {
        Some(s) => {
            let mut l = vec![NEG; s_count];
            l[s as usize] = 0.0;
            l
        }
        None => vec![0.0; s_count],
    }
}

/// `FrameDecoder` wrapper for the scalar path.
pub struct ScalarDecoder {
    trellis: Arc<Trellis>,
    stages: usize,
}

impl ScalarDecoder {
    pub fn new(trellis: Arc<Trellis>, stages: usize) -> Self {
        ScalarDecoder { trellis, stages }
    }
}

impl FrameDecoder for ScalarDecoder {
    fn frame_stages(&self) -> usize {
        self.stages
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    fn forward_batch(&mut self, jobs: &[FrameJob]) -> Vec<RawFrame> {
        let s_count = self.trellis.code().n_states();
        jobs.iter()
            .map(|job| {
                let lam0 = initial_metrics(s_count, job.start_state);
                let (phi, lam) = forward(&self.trellis, &job.llr, &lam0);
                RawFrame { surv: Survivors::Scalar(phi), lam }
            })
            .collect()
    }

    fn label(&self) -> String {
        "scalar".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{poly::Code, Encoder};

    fn trellis() -> Trellis {
        Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap())
    }

    #[test]
    fn decodes_noiseless() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let bits = vec![1, 0, 1, 1, 0, 1, 0, 0, 1, 1, 0, 0, 0, 0, 0, 0];
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let lam0 = initial_metrics(64, Some(0));
        let out = decode(&t, &llr, &lam0, Some(0));
        assert_eq!(out, bits);
    }

    #[test]
    fn corrects_noise() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut payload = crate::util::rng::Rng::new(11).bits(250);
        payload.extend_from_slice(&[0; 6]); // flush
        let coded = enc.encode(&payload);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(5.0, 0.5, 3);
        let rx = ch.transmit(&tx);
        let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();
        let lam0 = initial_metrics(64, Some(0));
        let out = decode(&t, &llr, &lam0, Some(0));
        assert_eq!(out, payload, "5 dB should decode error-free at n=256");
    }

    #[test]
    fn hard_decision_also_corrects_single_flip() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let bits = vec![1, 1, 0, 1, 0, 0, 0, 0, 0, 0];
        let coded = enc.encode(&bits);
        let mut llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        llr[4] = -llr[4]; // flip one coded bit (hard error)
        let lam0 = initial_metrics(64, Some(0));
        assert_eq!(decode(&t, &llr, &lam0, Some(0)), bits);
    }

    #[test]
    fn unknown_end_state_uses_argmax() {
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let bits = vec![1, 0, 0, 1, 1, 0, 1, 0, 1, 0, 1, 1];
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let lam0 = initial_metrics(64, Some(0));
        let out = decode(&t, &llr, &lam0, None);
        assert_eq!(out, bits, "noiseless: argmax end state is the true path");
    }

    #[test]
    fn frame_decoder_emits_requested_range() {
        let t = Arc::new(trellis());
        let mut enc = Encoder::new(t.code().clone());
        let bits = crate::util::rng::Rng::new(5).bits(32);
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let mut d = ScalarDecoder::new(t, 32);
        let out = d.decode_batch(&[FrameJob {
            llr,
            start_state: Some(0),
            end_state: None,
            emit_from: 4,
            emit_len: 16,
        }]);
        assert_eq!(out[0], bits[4..20].to_vec());
    }
}
