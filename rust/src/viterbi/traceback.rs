//! The backward procedure (Alg 2). Cannot be expressed as a matmul
//! (paper §V-A), so it runs here — on the Rust hot path for artifact
//! decodes, mirroring the paper's scalar-CUDA traceback. In the serving
//! pipeline these functions run on the shared traceback worker pool;
//! offline they are reached through
//! [`RawFrame::traceback`](super::types::RawFrame::traceback), which
//! dispatches on the survivor form the forward pass emitted:
//!
//! * [`traceback_scalar`] — predecessor *global state* per
//!   (stage, state), the Alg-1 `phi` layout (`u32` each);
//! * [`traceback_radix`] — winning left *local* state per
//!   (step, state), the radix-2^rho layout (`u8` each);
//! * [`traceback_compact`] — the same selections bit-packed to `rho`
//!   bits each (1 bit per state per stage for the butterfly case); see
//!   `docs/MEMORY.md` for the storage comparison.
//!
//! A full forward + traceback round trip against the scalar reference:
//!
//! ```
//! use tcvd::coding::{registry, trellis::Trellis};
//! use tcvd::viterbi::{compact, scalar, traceback};
//!
//! let t = Trellis::new(registry::paper_code());
//! // noiseless LLRs for the all-zero 8-stage stream (positive ⇒ bit 0)
//! let llr = vec![1.0f32; 8 * 2];
//! let lam0 = scalar::initial_metrics(64, Some(0));
//!
//! // Alg 1 (scalar survivor layout) + Alg 2
//! let (phi, lam) = scalar::forward(&t, &llr, &lam0);
//! let bits = traceback::traceback_scalar(&t, &phi, &lam, Some(0));
//! assert_eq!(bits, vec![0u8; 8]);
//!
//! // the bit-packed survivor layout decodes identically
//! let (surv, lam_c) = compact::forward_compact(&t, &llr, &lam0);
//! let bits_c = traceback::traceback_compact(&t, &surv, &lam_c, Some(0));
//! assert_eq!(bits_c, bits);
//! ```

use crate::coding::trellis::Trellis;

use super::compact::CompactSurvivors;

/// Traceback over scalar-form survivors (`phi[t*S + j]` = predecessor
/// *global state* of j at stage t). Returns the decoded input bits.
pub fn traceback_scalar(t: &Trellis, phi: &[u32], lam_final: &[f32],
                        end_state: Option<u32>) -> Vec<u8> {
    let s_count = t.code().n_states();
    assert_eq!(phi.len() % s_count, 0);
    let n = phi.len() / s_count;
    let mut j = end_state.unwrap_or_else(|| argmax(lam_final) as u32);
    let mut out = vec![0u8; n];
    for stage in (0..n).rev() {
        out[stage] = t.code().branch_input(j) as u8; // alpha_in into j
        j = phi[stage * s_count + j as usize];
    }
    out
}

/// Traceback over radix-form selections (`phi[tau*S + s]` = winning left
/// *local* state, 0..2^rho-1, of the super-branch into global state s over
/// stages [tau*rho, (tau+1)*rho)). Emits rho bits per step: the input bit
/// consumed at local step x is bit x of the right local state (Thm 4).
/// [`traceback_compact`] applies the same index math to the bit-packed
/// store — keep the two walks in lockstep (the equivalence is pinned by
/// this module's tests and `rust/tests/compact_equivalence.rs`).
pub fn traceback_radix(t: &Trellis, rho: u32, phi: &[u8], lam_final: &[f32],
                       end_state: Option<u32>) -> Vec<u8> {
    let s_count = t.code().n_states();
    assert_eq!(phi.len() % s_count, 0);
    let n_steps = phi.len() / s_count;
    let ndf = t.n_dragonflies(rho) as u32;
    let mut j = end_state.unwrap_or_else(|| argmax(lam_final) as u32);
    let mut out = vec![0u8; n_steps * rho as usize];
    for tau in (0..n_steps).rev() {
        let f = j % ndf;
        let jloc = j / ndf;
        for x in 0..rho {
            out[tau * rho as usize + x as usize] = ((jloc >> x) & 1) as u8;
        }
        let iloc = phi[tau * s_count + j as usize] as u32;
        debug_assert!(iloc < (1 << rho), "phi out of range: {iloc}");
        j = (f << rho) + iloc; // Thm 4, local stage x = 0
    }
    out
}

/// Traceback over bit-packed selections (`surv.get(tau, s)` = winning
/// left local state, `sel_bits` wide). The index math is Thm 4 with
/// rho = `sel_bits`; rho = 1 is the butterfly case, where the selector
/// picks between the two predecessors `prv(j)` and this reduces to
/// [`traceback_scalar`] exactly (`prv(j) = {2f, 2f+1}` for the
/// dragonfly f = j mod S/2, so `2f + selector` *is* the predecessor).
pub fn traceback_compact(t: &Trellis, surv: &CompactSurvivors, lam_final: &[f32],
                         end_state: Option<u32>) -> Vec<u8> {
    let s_count = t.code().n_states();
    assert_eq!(surv.n_states(), s_count, "survivor store built for a different trellis");
    let rho = surv.sel_bits();
    let n_steps = surv.steps();
    let ndf = t.n_dragonflies(rho) as u32;
    let mut j = end_state.unwrap_or_else(|| argmax(lam_final) as u32);
    let mut out = vec![0u8; n_steps * rho as usize];
    for tau in (0..n_steps).rev() {
        let f = j % ndf;
        let jloc = j / ndf;
        for x in 0..rho {
            out[tau * rho as usize + x as usize] = ((jloc >> x) & 1) as u8;
        }
        let iloc = surv.get(tau, j as usize);
        debug_assert!(iloc < (1 << rho), "selector out of range: {iloc}");
        j = (f << rho) + iloc; // Thm 4, local stage x = 0
    }
    out
}

/// argmax over a metric slice (first max wins, matching jnp.argmax).
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::bpsk;
    use crate::coding::{poly::Code, Encoder};
    use crate::viterbi::scalar;

    fn trellis() -> Trellis {
        Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap())
    }

    #[test]
    fn argmax_first_wins_on_tie() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn scalar_and_radix_agree() {
        // build scalar survivors, convert conceptually by decoding both
        let t = trellis();
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(21).bits(58);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let lam0 = scalar::initial_metrics(64, Some(0));
        let (phi_s, lam) = scalar::forward(&t, &llr, &lam0);
        let out_s = traceback_scalar(&t, &phi_s, &lam, Some(0));

        // radix-2 form derived from scalar survivors: left local state =
        // predecessor minus 2f (Thm 1)
        let mut phi_r = vec![0u8; phi_s.len()];
        for stage in 0..64 {
            for s in 0..64usize {
                let pred = phi_s[stage * 64 + s];
                let f = (s as u32) % 32;
                phi_r[stage * 64 + s] = (pred - 2 * f) as u8;
            }
        }
        let out_r = traceback_radix(&t, 1, &phi_r, &lam, Some(0));
        assert_eq!(out_s, out_r);
        assert_eq!(out_s, bits);

        // the bit-packed form of the same selections decodes identically
        let surv = CompactSurvivors::from_radix(1, &phi_r, 64);
        let out_c = traceback_compact(&t, &surv, &lam, Some(0));
        assert_eq!(out_c, out_s);
    }

    #[test]
    fn compact_rho2_agrees_with_radix4() {
        // pack a radix-4 forward pass's selections (u8 each) into the
        // 2-bit compact layout: traceback must be unchanged
        use crate::viterbi::packed::presets;

        let t = std::sync::Arc::new(trellis());
        let mut enc = Encoder::new(t.code().clone());
        let mut bits = crate::util::rng::Rng::new(5150).bits(58);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let mut dec = presets::radix4(t.clone(), 64);
        let lam0 = scalar::initial_metrics(64, Some(0));
        let (phi, lam) = dec.forward(&llr, &lam0);
        let out_r = traceback_radix(&t, 2, &phi, &lam, Some(0));
        let surv = CompactSurvivors::from_radix(2, &phi, 64);
        assert_eq!(surv.bytes() * 4, phi.len(), "2-bit packing is 4x denser than u8");
        let out_c = traceback_compact(&t, &surv, &lam, Some(0));
        assert_eq!(out_c, out_r);
        assert_eq!(out_c, bits);
    }
}
