//! Decoder implementations.
//!
//! * `scalar` — Alg 1 + Alg 2 verbatim (the CPU baseline of refs [2,3]).
//! * `packed` — CPU execution of a tensor packing spec: the *same
//!   arithmetic* as the AOT artifact (matmul + add, round through the
//!   accumulator precision, max/argmax epilogue), so BER studies can run
//!   at CPU speed while staying faithful to the tensor formulation.
//! * `radix2` / `radix4` — named constructors over `packed`.
//! * `compact` — the scalar forward pass with bit-packed survivor
//!   storage (1 bit per state per stage), the memory-efficient layout
//!   of arXiv 2011.09337; see `docs/MEMORY.md` for the memory model.
//! * `simd` — the quantized (i16) lane-parallel ACS fast path:
//!   per-symbol branch-metric dedup, structure-of-arrays butterflies,
//!   saturating adds with periodic renormalization, decisions straight
//!   into the `compact` bit-packed ring; the CPU analogue of the
//!   tensor-core formulation (see `docs/PERFORMANCE.md`).
//! * `traceback` — the backward procedure (shared by every path; in the
//!   paper it runs on scalar CUDA cores because it cannot be a matmul).
//! * `tiled` — framed/overlapped decoding of long streams (§III).

pub mod types;
pub mod scalar;
pub mod packed;
pub mod compact;
pub mod simd;
pub mod traceback;
pub mod tiled;

pub use compact::CompactDecoder;
pub use packed::PackedDecoder;
pub use scalar::ScalarDecoder;
pub use simd::SimdDecoder;
pub use types::{AccPrecision, FrameDecoder, FrameJob, Survivors, NEG};
