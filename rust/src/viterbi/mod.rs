//! Decoder implementations.
//!
//! * `scalar` — Alg 1 + Alg 2 verbatim (the CPU baseline of refs [2,3]).
//! * `packed` — CPU execution of a tensor packing spec: the *same
//!   arithmetic* as the AOT artifact (matmul + add, round through the
//!   accumulator precision, max/argmax epilogue), so BER studies can run
//!   at CPU speed while staying faithful to the tensor formulation.
//! * `radix2` / `radix4` — named constructors over `packed`.
//! * `traceback` — the backward procedure (shared by every path; in the
//!   paper it runs on scalar CUDA cores because it cannot be a matmul).
//! * `tiled` — framed/overlapped decoding of long streams (§III).

pub mod types;
pub mod scalar;
pub mod packed;
pub mod traceback;
pub mod tiled;

pub use packed::PackedDecoder;
pub use scalar::ScalarDecoder;
pub use types::{AccPrecision, FrameDecoder, FrameJob, NEG};
