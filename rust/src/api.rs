//! The unified public API of `tcvd`: one builder-first facade from
//! configuration to the serving pipeline.
//!
//! Everything the CLI, the examples, the benches and downstream users
//! construct goes through [`DecoderBuilder`]. One-shot decoding
//! (offline / BER studies):
//!
//! ```
//! use tcvd::api::{BackendKind, DecoderBuilder};
//!
//! let llr = vec![0.0f32; 128 * 2]; // 128 trellis stages of rate-1/2 LLRs
//! let mut dec = DecoderBuilder::new()
//!     .backend(BackendKind::cpu("radix4"))
//!     .tile_dims(64, 32, 32)
//!     .build()?;
//! let bits = dec.decode_stream(&llr)?;
//! assert_eq!(bits.len(), 128);
//! # Ok::<(), tcvd::Error>(())
//! ```
//!
//! The streaming serving pipeline fans sessions out across engine
//! shards ([`DecoderBuilder::shards`], default: available parallelism)
//! and delivers each session's decoded payload strictly in order:
//!
//! ```
//! use tcvd::api::{BackendKind, DecoderBuilder};
//!
//! let coord = DecoderBuilder::new()
//!     .backend(BackendKind::cpu("radix4"))
//!     .tile_dims(32, 16, 16)
//!     .shards(2)
//!     .workers(2)
//!     .serve()?;
//! let mut session = coord.open_session()?;
//! session.push(&vec![0.5f32; 32 * 2])?; // one payload tile of LLRs
//! let bits = session.finish_and_collect()?;
//! assert_eq!(bits.len(), 32);
//! coord.shutdown()?;
//! # Ok::<(), tcvd::Error>(())
//! ```
//!
//! The production backend is the AOT PJRT artifact
//! ([`BackendKind::Artifact`], the default — needs `make artifacts`);
//! the CPU backends emulate the same tensor arithmetic and are used
//! throughout the tests. Memory-tight deployments select
//! [`BackendKind::Compact`], which stores survivors as bit-packed
//! decision words (1/32 the survivor memory of the scalar layout,
//! bit-identical output — see `docs/MEMORY.md`); CPU-serving
//! deployments select [`BackendKind::Simd`], the quantized
//! lane-parallel forward pass (scalar-identical bits at a multiple of
//! the scalar throughput — see `docs/PERFORMANCE.md`). The builder validates at
//! [`DecoderBuilder::build`]/[`DecoderBuilder::serve`] and reports
//! failures as the typed [`tcvd::Error`](crate::Error); `anyhow` never
//! crosses this boundary. The pipeline architecture behind `serve()` is
//! documented in `docs/ARCHITECTURE.md`.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::cli::{Args, FlagSpec};
use crate::coding::registry;
use crate::coding::trellis::Trellis;
use crate::config::Config;
use crate::coordinator::server::CoordinatorConfig;
use crate::coordinator::{BackendSpec, Coordinator};
use crate::defaults;
use crate::runtime::Manifest;
use crate::viterbi::tiled;
use crate::viterbi::types::{FrameDecoder, FrameJob};

pub use crate::channel::quantize::ChannelPrecision;
pub use crate::coding::TerminationMode;
pub use crate::viterbi::tiled::TileConfig;
pub use crate::coordinator::{MetricsSnapshot, Session, SessionHandle, ShardSnapshot};
pub use crate::error::{Error, Result};
pub use crate::util::half::HalfKind;
pub use crate::viterbi::types::AccPrecision;

/// Backend names accepted by [`DecoderBuilder::backend_name`] (the CLI
/// `--backend` values).
pub const BACKEND_NAMES: &[&str] = &[
    "artifact",
    "scalar",
    "compact",
    "simd",
    "cpu-radix2",
    "cpu-radix4",
    "cpu-radix4-noperm",
    "cpu-radix4-half",
    "cpu-radix4-half-f16",
];

/// CPU packing schemes accepted by [`BackendKind::Cpu`].
pub const CPU_SCHEMES: &[&str] = &["radix2", "radix4", "radix4_noperm"];

/// Which decoder implementation the builder lowers to.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT PJRT artifact (the production path; needs `make artifacts`).
    Artifact,
    /// CPU tensor-form emulation of a packing scheme (same arithmetic
    /// as the artifact, no PJRT).
    Cpu {
        /// Packing scheme, one of [`CPU_SCHEMES`].
        scheme: String,
    },
    /// Scalar Alg-1/Alg-2 baseline (the correctness oracle).
    Scalar,
    /// Memory-efficient survivor storage: scalar arithmetic with
    /// bit-packed per-stage decision words (1/32 the survivor memory of
    /// [`BackendKind::Scalar`], bit-identical output; arXiv
    /// 2011.09337). Pick this when per-shard memory — survivor bytes
    /// scale with `shards * queue_depth * frame_stages` — caps the
    /// deployment before compute does; `docs/MEMORY.md` has the worked
    /// budgets and the backend-selection table.
    Compact,
    /// Quantized lane-parallel ACS fast path: i16 path metrics
    /// (saturating adds, periodic renormalization), per-symbol
    /// branch-metric dedup and a structure-of-arrays butterfly update
    /// that runs many states per instruction (AVX2 kernel behind a
    /// runtime check, portable autovectorized loop elsewhere), with
    /// decisions bit-packed into the same survivor ring as
    /// [`BackendKind::Compact`]. The fastest CPU forward pass —
    /// bit-identical to [`BackendKind::Scalar`] on quantized inputs
    /// (pinned by `rust/tests/simd_equivalence.rs`); hot-path anatomy
    /// and the quantization model are in `docs/PERFORMANCE.md`.
    /// [`DecoderBuilder::renorm_every`] sets the renormalization
    /// period (clamped to the i16 headroom; 0 = widest safe period).
    Simd,
}

impl BackendKind {
    /// Convenience constructor for [`BackendKind::Cpu`].
    pub fn cpu(scheme: impl Into<String>) -> BackendKind {
        BackendKind::Cpu { scheme: scheme.into() }
    }

    /// The CLI name of this backend (the inverse of
    /// [`DecoderBuilder::backend_name`], modulo the precision-suffixed
    /// `cpu-radix4-half*` aliases, which also name an accumulator
    /// precision and therefore round-trip to plain `"cpu-radix4"`).
    pub fn name(&self) -> String {
        match self {
            BackendKind::Artifact => "artifact".to_string(),
            BackendKind::Scalar => "scalar".to_string(),
            BackendKind::Compact => "compact".to_string(),
            BackendKind::Simd => "simd".to_string(),
            BackendKind::Cpu { scheme } => match scheme.as_str() {
                "radix2" => "cpu-radix2".to_string(),
                "radix4_noperm" => "cpu-radix4-noperm".to_string(),
                _ => "cpu-radix4".to_string(),
            },
        }
    }
}

/// Builder for every `tcvd` decode surface: one-shot ([`Decoder`]) and
/// serving ([`Coordinator`]).
///
/// Defaults come from [`crate::defaults`]; file-based setup comes from
/// [`DecoderBuilder::from_toml`]; CLI overrides from
/// [`DecoderBuilder::apply_flags`]. All parameters are validated at
/// [`build`](Self::build)/[`serve`](Self::serve).
#[derive(Clone, Debug)]
pub struct DecoderBuilder {
    code: String,
    backend: BackendKind,
    artifacts_dir: PathBuf,
    variant: String,
    tile: TileConfig,
    acc: AccPrecision,
    chan: ChannelPrecision,
    renorm_every: usize,
    radix: usize,
    max_batch: usize,
    batch_deadline: Duration,
    workers: usize,
    queue_depth: usize,
    shards: usize,
    termination: TerminationMode,
    failpoints: Option<String>,
    max_restarts: usize,
}

impl Default for DecoderBuilder {
    fn default() -> Self {
        DecoderBuilder {
            code: defaults::CODE.to_string(),
            backend: BackendKind::Artifact,
            artifacts_dir: PathBuf::from(defaults::ARTIFACTS_DIR),
            variant: defaults::VARIANT.to_string(),
            tile: defaults::TILE,
            acc: AccPrecision::Single,
            chan: ChannelPrecision::Single,
            renorm_every: defaults::RENORM_EVERY,
            radix: defaults::RADIX,
            max_batch: defaults::MAX_BATCH,
            batch_deadline: Duration::from_micros(defaults::BATCH_DEADLINE_US),
            workers: defaults::WORKERS,
            queue_depth: defaults::QUEUE_DEPTH,
            shards: defaults::default_shards(),
            termination: defaults::TERMINATION,
            failpoints: None,
            max_restarts: defaults::MAX_SHARD_RESTARTS,
        }
    }
}

impl DecoderBuilder {
    /// A builder loaded with the canonical defaults.
    pub fn new() -> DecoderBuilder {
        DecoderBuilder::default()
    }

    /// Standard code name (registry key, e.g. `"ccsds"`).
    pub fn code(mut self, name: impl Into<String>) -> Self {
        self.code = name.into();
        self
    }

    /// Select the backend implementation.
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Select the backend by CLI name (see [`BACKEND_NAMES`]). Each
    /// name pins the accumulator precision (`-half`/`-half-f16` select
    /// a half accumulator, every other name single precision), so call
    /// [`precision`](Self::precision) *after* this to override.
    pub fn backend_name(mut self, name: &str) -> Result<Self> {
        self.acc = AccPrecision::Single;
        match name {
            "artifact" | "pjrt" => self.backend = BackendKind::Artifact,
            "scalar" => self.backend = BackendKind::Scalar,
            "compact" => self.backend = BackendKind::Compact,
            "simd" => self.backend = BackendKind::Simd,
            "cpu-radix2" => self.backend = BackendKind::cpu("radix2"),
            "cpu-radix4" => self.backend = BackendKind::cpu("radix4"),
            "cpu-radix4-noperm" => self.backend = BackendKind::cpu("radix4_noperm"),
            "cpu-radix4-half" => {
                self.backend = BackendKind::cpu("radix4");
                self.acc = AccPrecision::Half(HalfKind::Bf16);
            }
            "cpu-radix4-half-f16" => {
                self.backend = BackendKind::cpu("radix4");
                self.acc = AccPrecision::Half(HalfKind::F16);
            }
            other => {
                return Err(Error::config(format!(
                    "unknown backend {other:?}; known: {}",
                    BACKEND_NAMES.join(" ")
                )))
            }
        }
        Ok(self)
    }

    /// Artifact directory (artifact backend only).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Artifact variant name or unique substring (artifact backend only).
    pub fn variant(mut self, variant: impl Into<String>) -> Self {
        self.variant = variant.into();
        self
    }

    /// Tile geometry for stream decoding (paper §III).
    pub fn tile(mut self, tile: TileConfig) -> Self {
        self.tile = tile;
        self
    }

    /// Tile geometry as `(payload, head, tail)` stages.
    pub fn tile_dims(self, payload: usize, head: usize, tail: usize) -> Self {
        self.tile(TileConfig { payload, head, tail })
    }

    /// Accumulator (C/D fragment) precision for CPU backends — the
    /// paper's Table I axis.
    pub fn precision(mut self, acc: AccPrecision) -> Self {
        self.acc = acc;
        self
    }

    /// Channel-array storage precision for CPU backends.
    pub fn channel_precision(mut self, chan: ChannelPrecision) -> Self {
        self.chan = chan;
        self
    }

    /// Path-metric renormalization period in stages (CPU packed
    /// backends: 0 = off; `simd` backend: 0 = the widest period the
    /// i16 headroom allows, larger values are clamped to it).
    pub fn renorm_every(mut self, stages: usize) -> Self {
        self.renorm_every = stages;
        self
    }

    /// Trellis stages folded per ACS pass on the `simd` backend
    /// (radix-2^rho super-branches, rho in {1, 2}; default 1). rho = 2
    /// halves the serial stage-loop trip count and stays bit-identical
    /// to the scalar oracle; it requires an even frame stage count and
    /// `rho < k` ([`validate`](Self::validate) enforces both). Other
    /// backends ignore the knob (`cpu-radix*` carry their radix in the
    /// scheme name).
    pub fn radix(mut self, rho: usize) -> Self {
        self.radix = rho;
        self
    }

    /// Dynamic batcher: max frames per execution.
    pub fn max_batch(mut self, frames: usize) -> Self {
        self.max_batch = frames;
        self
    }

    /// Dynamic batcher: flush deadline.
    pub fn batch_deadline(mut self, deadline: Duration) -> Self {
        self.batch_deadline = deadline;
        self
    }

    /// Dynamic batcher: flush deadline in microseconds.
    pub fn batch_deadline_us(self, us: u64) -> Self {
        self.batch_deadline(Duration::from_micros(us))
    }

    /// Traceback worker threads (serving pipeline).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Bounded input queue depth in frames (serving pipeline
    /// backpressure).
    pub fn queue_depth(mut self, frames: usize) -> Self {
        self.queue_depth = frames;
        self
    }

    /// Engine shards: how many backend instances the pipeline runs,
    /// each on its own thread with its own work queue. The dispatcher
    /// routes frames to a session's home shard by affinity hash and
    /// idle shards steal work, so aggregate `serve()` throughput scales
    /// with the shard count until the machine saturates. The one-shot
    /// [`Decoder::decode_stream`] also fans frames out across this many
    /// lanes. Default: available parallelism
    /// ([`crate::defaults::default_shards`]).
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Stream termination mode — the workload axis deciding what the
    /// decoder may assume about the trellis ends
    /// (`docs/DECODING-MODES.md` is the guide):
    /// [`TerminationMode::Flushed`] pins both ends (the default),
    /// [`TerminationMode::TailBiting`] pins neither and frames the
    /// stream *circularly* (LTE-style blocks, no flush-bit rate loss),
    /// [`TerminationMode::Truncated`] pins only the start. Applies to
    /// [`Decoder::decode_stream`] and to every session of
    /// [`serve`](Self::serve).
    pub fn termination(mut self, termination: TerminationMode) -> Self {
        self.termination = termination;
        self
    }

    /// Select the termination mode by CLI/TOML name (see
    /// [`TerminationMode::NAMES`]).
    pub fn termination_name(self, name: &str) -> Result<Self> {
        Ok(self.termination(TerminationMode::parse_named(name)?))
    }

    /// Arm deterministic failpoints for fault-injection testing: a
    /// comma-separated `site=trigger` spec (see
    /// [`fault`](crate::fault) and `docs/RELIABILITY.md`). The spec is
    /// validated at [`serve`](Self::serve); it is **rejected** unless
    /// the crate was compiled with `--features failpoints`, so a spec
    /// can never silently no-op in a production binary. The
    /// `TCVD_FAILPOINTS` environment variable takes precedence over
    /// this value.
    pub fn failpoints(mut self, spec: impl Into<String>) -> Self {
        self.failpoints = Some(spec.into());
        self
    }

    /// Restart budget per engine shard: after this many supervised
    /// restarts a shard is declared dead and its queued work is failed
    /// with typed errors (default
    /// [`defaults::MAX_SHARD_RESTARTS`]). See `docs/RELIABILITY.md`.
    pub fn max_restarts(mut self, max_restarts: usize) -> Self {
        self.max_restarts = max_restarts;
        self
    }

    /// Build a builder from a parsed [`Config`] (the TOML view).
    pub fn from_config(cfg: &Config) -> Result<DecoderBuilder> {
        let b = DecoderBuilder {
            code: cfg.code.clone(),
            artifacts_dir: PathBuf::from(&cfg.artifacts_dir),
            variant: cfg.variant.clone(),
            tile: cfg.tile,
            max_batch: cfg.max_batch,
            batch_deadline: Duration::from_micros(cfg.batch_deadline_us),
            workers: cfg.workers,
            queue_depth: cfg.queue_depth,
            shards: cfg.shards,
            radix: cfg.radix,
            failpoints: cfg.fault_points.clone(),
            max_restarts: cfg.max_restarts,
            ..DecoderBuilder::new()
        };
        b.backend_name(&cfg.backend)?.termination_name(&cfg.termination)
    }

    /// Build a builder from TOML text (`tcvd.toml` schema).
    pub fn from_toml(text: &str) -> Result<DecoderBuilder> {
        Self::from_config(&Config::from_toml(text)?)
    }

    /// Build a builder from a TOML file.
    pub fn from_toml_file(path: &Path) -> Result<DecoderBuilder> {
        Self::from_config(&Config::from_file(path)?)
    }

    /// Apply CLI `--flag` overrides (the flags listed by
    /// [`builder_flags`]) on top of the current values.
    pub fn apply_flags(mut self, args: &Args) -> Result<Self> {
        if let Some(v) = args.get("code") {
            self.code = v.to_string();
        }
        if let Some(v) = args.get("backend") {
            let name = v.to_string();
            self = self.backend_name(&name)?;
        }
        if let Some(v) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("variant") {
            self.variant = v.to_string();
        }
        self.tile.payload = args.get_usize("payload", self.tile.payload)?;
        self.tile.head = args.get_usize("head", self.tile.head)?;
        self.tile.tail = args.get_usize("tail", self.tile.tail)?;
        self.workers = args.get_usize("workers", self.workers)?;
        self.max_batch = args.get_usize("max-batch", self.max_batch)?;
        self.batch_deadline = Duration::from_micros(
            args.get_u64("batch-deadline-us", self.batch_deadline.as_micros() as u64)?,
        );
        self.queue_depth = args.get_usize("queue-depth", self.queue_depth)?;
        self.shards = args.get_usize("shards", self.shards)?;
        self.renorm_every = args.get_usize("renorm-every", self.renorm_every)?;
        self.radix = args.get_usize("radix", self.radix)?;
        if let Some(v) = args.get("termination") {
            let name = v.to_string();
            self = self.termination_name(&name)?;
        }
        if let Some(v) = args.get("failpoints") {
            self.failpoints = Some(v.to_string());
        }
        self.max_restarts = args.get_usize("max-restarts", self.max_restarts)?;
        Ok(self)
    }

    /// Trellis stages per frame under the current tile geometry.
    pub fn frame_stages(&self) -> usize {
        self.tile.frame_stages()
    }

    /// The standard-code name currently configured.
    pub fn code_name(&self) -> &str {
        &self.code
    }

    /// The backend currently configured.
    pub fn backend_kind(&self) -> &BackendKind {
        &self.backend
    }

    /// The tile geometry currently configured.
    pub fn tile_config(&self) -> TileConfig {
        self.tile
    }

    /// The termination mode currently configured.
    pub fn termination_mode(&self) -> TerminationMode {
        self.termination
    }

    /// Validate the full parameter set (also called by
    /// [`build`](Self::build)/[`serve`](Self::serve)).
    pub fn validate(&self) -> Result<()> {
        let code = registry::lookup(&self.code).map_err(|e| Error::config(e))?;
        if self.radix != 1 && self.radix != 2 {
            return Err(Error::config(format!(
                "radix must be 1 or 2, got {}",
                self.radix
            )));
        }
        if self.backend == BackendKind::Simd && self.radix == 2 {
            // the radix-4 super-stage kernel folds stage pairs, so the
            // frame must split into whole super-stages and the code
            // must have dragonflies at rho = 2 (Thm 3: rho < k)
            if self.tile.frame_stages() % 2 != 0 {
                return Err(Error::config(format!(
                    "radix 2 needs an even frame stage count, got {} \
                     (payload {} + head {} + tail {})",
                    self.tile.frame_stages(),
                    self.tile.payload,
                    self.tile.head,
                    self.tile.tail
                )));
            }
            if code.k() <= 2 {
                return Err(Error::config(format!(
                    "radix 2 invalid for constraint length k={}",
                    code.k()
                )));
            }
        }
        if self.tile.payload == 0 {
            return Err(Error::config("tile payload must be positive"));
        }
        if self.workers == 0 {
            return Err(Error::config("workers must be positive"));
        }
        if self.shards == 0 {
            return Err(Error::config("shards must be positive"));
        }
        if self.max_batch == 0 {
            return Err(Error::config("max_batch must be positive"));
        }
        if self.queue_depth < self.max_batch {
            return Err(Error::config(format!(
                "queue_depth ({}) must be >= max_batch ({})",
                self.queue_depth, self.max_batch
            )));
        }
        match &self.backend {
            BackendKind::Cpu { scheme } => {
                if !CPU_SCHEMES.contains(&scheme.as_str()) {
                    return Err(Error::config(format!(
                        "unknown packing scheme {scheme:?}; known: {}",
                        CPU_SCHEMES.join(" ")
                    )));
                }
            }
            BackendKind::Artifact => {
                if self.variant.is_empty() {
                    return Err(Error::config("artifact backend needs a variant name"));
                }
            }
            BackendKind::Scalar | BackendKind::Compact | BackendKind::Simd => {}
        }
        Ok(())
    }

    /// Lower to the engine-facing backend spec. This is the only place
    /// in the crate where user parameters become a [`BackendSpec`].
    pub fn to_backend_spec(&self) -> BackendSpec {
        match &self.backend {
            BackendKind::Artifact => BackendSpec::Artifact {
                dir: self.artifacts_dir.clone(),
                variant: self.variant.clone(),
            },
            BackendKind::Scalar => BackendSpec::Scalar {
                code: self.code.clone(),
                stages: self.tile.frame_stages(),
            },
            BackendKind::Compact => BackendSpec::Compact {
                code: self.code.clone(),
                stages: self.tile.frame_stages(),
            },
            BackendKind::Simd => BackendSpec::Simd {
                code: self.code.clone(),
                stages: self.tile.frame_stages(),
                renorm_every: self.renorm_every,
                radix: self.radix,
            },
            BackendKind::Cpu { scheme } => BackendSpec::CpuPacked {
                code: self.code.clone(),
                scheme: scheme.clone(),
                stages: self.tile.frame_stages(),
                acc: self.acc,
                chan: self.chan,
                renorm_every: self.renorm_every,
            },
        }
    }

    /// Lower to the full pipeline configuration.
    pub fn to_coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            backend: self.to_backend_spec(),
            tile: self.tile,
            max_batch: self.max_batch,
            batch_deadline: self.batch_deadline,
            workers: self.workers,
            queue_depth: self.queue_depth,
            shards: self.shards,
            termination: self.termination,
            fault_spec: std::env::var("TCVD_FAILPOINTS")
                .ok()
                .filter(|s| !s.is_empty())
                .or_else(|| self.failpoints.clone()),
            max_restarts: self.max_restarts,
        }
    }

    /// For the artifact backend: if the manifest is readable and names
    /// the variant, reject a tile geometry that does not match the
    /// artifact's frame length *before* compiling anything. (A missing
    /// manifest is not an error here — backend construction reports it
    /// with full context.)
    fn check_artifact_geometry(&self) -> Result<()> {
        if self.backend != BackendKind::Artifact {
            return Ok(());
        }
        if let Ok(m) = Manifest::load(&self.artifacts_dir) {
            if let Ok(meta) = m.find(&self.variant) {
                let want = self.tile.frame_stages();
                if meta.stages_per_frame != want {
                    return Err(Error::config(format!(
                        "tile geometry ({want} stages = head {} + payload {} + tail {}) \
                         does not match artifact {} ({} stages per frame)",
                        self.tile.head,
                        self.tile.payload,
                        self.tile.tail,
                        meta.name,
                        meta.stages_per_frame
                    )));
                }
            }
        }
        Ok(())
    }

    /// Build a one-shot in-process [`Decoder`] (offline decoding, BER
    /// studies). No threads are spawned.
    pub fn build(self) -> Result<Decoder> {
        self.validate()?;
        self.check_artifact_geometry()?;
        let tile = self.tile;
        let spec = self.to_backend_spec();
        let inner = spec.build()?;
        if inner.frame_stages() != tile.frame_stages() {
            return Err(Error::config(format!(
                "backend frame ({} stages) does not match tile geometry ({} stages)",
                inner.frame_stages(),
                tile.frame_stages()
            )));
        }
        let beta = inner.trellis().code().beta();
        Ok(Decoder {
            inner,
            spec,
            tile,
            beta,
            shards: self.shards,
            termination: self.termination,
        })
    }

    /// Start the streaming serving pipeline and return the running
    /// [`Coordinator`] (engine thread + traceback workers +
    /// reassembler). Blocks until the backend is ready.
    pub fn serve(self) -> Result<Coordinator> {
        self.validate()?;
        self.check_artifact_geometry()?;
        Coordinator::start(self.to_coordinator_config())
    }
}

/// Flag specs for every builder option — the shared vocabulary of the
/// `tcvd` subcommands (single source for parsing *and* `--help`).
pub fn builder_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec::new("config", "PATH", "TOML config file (tcvd.toml schema), applied first"),
        FlagSpec::new("code", "NAME", format!("standard code (default {:?})", defaults::CODE)),
        FlagSpec::new(
            "backend",
            "NAME",
            format!("one of: {} (default {:?})", BACKEND_NAMES.join(" "), defaults::BACKEND),
        ),
        FlagSpec::new(
            "artifacts",
            "DIR",
            format!("artifact directory (default {:?})", defaults::ARTIFACTS_DIR),
        ),
        FlagSpec::new(
            "variant",
            "NAME",
            format!("artifact variant substring (default {:?})", defaults::VARIANT),
        ),
        FlagSpec::new(
            "payload",
            "N",
            format!("tile payload stages per frame (default {})", defaults::TILE.payload),
        ),
        FlagSpec::new(
            "head",
            "N",
            format!("tile head overlap stages (default {})", defaults::TILE.head),
        ),
        FlagSpec::new(
            "tail",
            "N",
            format!("tile tail overlap stages (default {})", defaults::TILE.tail),
        ),
        FlagSpec::new(
            "workers",
            "N",
            format!("traceback worker threads (default {})", defaults::WORKERS),
        ),
        FlagSpec::new(
            "max-batch",
            "N",
            format!("max frames per execution (default {})", defaults::MAX_BATCH),
        ),
        FlagSpec::new(
            "batch-deadline-us",
            "US",
            format!("batch flush deadline (default {})", defaults::BATCH_DEADLINE_US),
        ),
        FlagSpec::new(
            "queue-depth",
            "N",
            format!("input queue depth in frames (default {})", defaults::QUEUE_DEPTH),
        ),
        FlagSpec::new(
            "shards",
            "N",
            format!(
                "engine shards, one backend instance each (default: available \
                 parallelism, {} here)",
                defaults::default_shards()
            ),
        ),
        FlagSpec::new(
            "radix",
            "RHO",
            format!(
                "trellis stages folded per simd ACS pass, 1 or 2 (default {})",
                defaults::RADIX
            ),
        ),
        FlagSpec::new(
            "renorm-every",
            "N",
            format!(
                "metric renormalization period, cpu-*/simd backends (default {})",
                defaults::RENORM_EVERY
            ),
        ),
        FlagSpec::new(
            "termination",
            "MODE",
            format!(
                "stream termination, one of: {} (default {:?}; see docs/DECODING-MODES.md)",
                TerminationMode::NAMES.join(" "),
                defaults::TERMINATION.as_str()
            ),
        ),
        FlagSpec::new(
            "failpoints",
            "SPEC",
            "arm deterministic failpoints, comma-separated site=trigger \
             (needs --features failpoints; see docs/RELIABILITY.md)",
        ),
        FlagSpec::new(
            "max-restarts",
            "N",
            format!(
                "restart budget per engine shard before it is declared dead \
                 (default {})",
                defaults::MAX_SHARD_RESTARTS
            ),
        ),
    ]
}

/// Minimum frames a [`Decoder::decode_stream`] fan-out lane must
/// receive before spawning it is worth the lane's backend
/// construction.
pub const MIN_FRAMES_PER_LANE: usize = 4;

/// Decode `jobs` through `dec` in backend-sized batches, one emitted
/// bit vector per frame.
fn decode_jobs(dec: &mut dyn FrameDecoder, jobs: &[FrameJob]) -> Vec<Vec<u8>> {
    let mut out = Vec::with_capacity(jobs.len());
    for batch in jobs.chunks(dec.max_batch().max(1)) {
        out.extend(dec.decode_batch(batch));
    }
    out
}

/// A one-shot decoder built by [`DecoderBuilder::build`]: wraps the
/// scalar / packed / artifact frame decoders behind one interface for
/// offline decoding and BER measurement.
///
/// [`decode_stream`](Decoder::decode_stream) fans frames out across
/// [`DecoderBuilder::shards`] parallel lanes (each lane builds its own
/// backend instance from the same spec), so offline decoding of long
/// streams scales with the core count while staying bit-identical to
/// the single-lane result.
pub struct Decoder {
    inner: Box<dyn FrameDecoder>,
    /// The lowered spec, recloned per fan-out lane (backends are not
    /// `Send`, so each lane builds its own instance in-thread).
    spec: BackendSpec,
    tile: TileConfig,
    beta: usize,
    shards: usize,
    termination: TerminationMode,
}

impl Decoder {
    /// Decode a single frame of exactly
    /// [`frame_stages`](Self::frame_stages)` * beta` LLRs, emitting all
    /// of its stages. `start_state`/`end_state` pin the trellis ends
    /// when known (stream head / flushed tail).
    pub fn decode_frame(
        &mut self,
        llr: &[f32],
        start_state: Option<u32>,
        end_state: Option<u32>,
    ) -> Result<Vec<u8>> {
        let stages = self.inner.frame_stages();
        if llr.len() != stages * self.beta {
            return Err(Error::pipeline(format!(
                "frame expects {} LLRs ({} stages x beta {}), got {}",
                stages * self.beta,
                stages,
                self.beta,
                llr.len()
            )));
        }
        let job = FrameJob {
            llr: llr.to_vec(),
            start_state,
            end_state,
            emit_from: 0,
            emit_len: stages,
        };
        let mut out = self.inner.decode_batch(std::slice::from_ref(&job));
        Ok(out.remove(0))
    }

    /// Decode a whole LLR stream (frames cut per the builder's tile
    /// geometry, payload bits reassembled in order). The stream must
    /// cover a whole number of payload tiles, and it is terminated per
    /// the builder's [`termination`](DecoderBuilder::termination) mode
    /// (a tail-biting stream is framed circularly, a flushed stream
    /// pins both trellis ends).
    ///
    /// With [`DecoderBuilder::shards`] > 1 the frames are decoded on up
    /// to that many parallel lanes (frame decoding is independent
    /// across frames — the paper's parallelism source), each lane
    /// building its own backend instance from the spec; the output is
    /// bit-identical to the single-lane reference tiler for every lane
    /// count. Because lane backends cannot outlive the call (they are
    /// not `Send`, so they live on the transient lane threads), a lane
    /// is only opened when it has at least [`MIN_FRAMES_PER_LANE`]
    /// frames to amortize its backend construction; short streams
    /// decode on the caller thread with the already-built backend.
    pub fn decode_stream(&mut self, llr: &[f32]) -> Result<Vec<u8>> {
        let jobs = tiled::make_frames(llr, self.beta, &self.tile, self.termination)?;
        let lanes = self.shards.min(jobs.len() / MIN_FRAMES_PER_LANE).max(1);
        if lanes == 1 {
            // single lane: reuse the already-built backend directly
            return Ok(decode_jobs(self.inner.as_mut(), &jobs).concat());
        }
        let per_lane = jobs.len().div_ceil(lanes);
        let chunks: Vec<&[FrameJob]> = jobs.chunks(per_lane).collect();
        let spec = &self.spec;
        let inner = self.inner.as_mut();
        let mut parts: Vec<Result<Vec<Vec<u8>>>> = Vec::with_capacity(chunks.len());
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for chunk in &chunks[1..] {
                handles.push(scope.spawn(move || -> Result<Vec<Vec<u8>>> {
                    let mut dec = spec.build()?;
                    Ok(decode_jobs(dec.as_mut(), chunk))
                }));
            }
            // lane 0 runs on the caller thread with the existing backend
            parts.push(Ok(decode_jobs(inner, chunks[0])));
            for h in handles {
                parts.push(h.join().expect("decode lane panicked"));
            }
        });
        let mut out = Vec::with_capacity(llr.len() / self.beta);
        for part in parts {
            for bits in part? {
                out.extend_from_slice(&bits);
            }
        }
        Ok(out)
    }

    /// Trellis stages per frame.
    pub fn frame_stages(&self) -> usize {
        self.inner.frame_stages()
    }

    /// The tile geometry this decoder streams with.
    pub fn tile(&self) -> &TileConfig {
        &self.tile
    }

    /// The termination mode this decoder frames streams under.
    pub fn termination(&self) -> TerminationMode {
        self.termination
    }

    /// The trellis the decoder was built over.
    pub fn trellis(&self) -> &Arc<Trellis> {
        self.inner.trellis()
    }

    /// Short backend label for logs and benches.
    pub fn label(&self) -> String {
        self.inner.label()
    }

    /// Escape hatch to the frame-decoder trait object (e.g. for
    /// [`crate::ber::measure_ber`]).
    pub fn as_frame_decoder(&mut self) -> &mut dyn FrameDecoder {
        self.inner.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        DecoderBuilder::new().validate().unwrap();
    }

    #[test]
    fn bad_code_rejected() {
        let e = DecoderBuilder::new().code("nope").validate().unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn zero_workers_rejected() {
        let e = DecoderBuilder::new().workers(0).validate().unwrap_err();
        assert!(e.to_string().contains("workers"), "{e}");
    }

    #[test]
    fn zero_shards_rejected() {
        let e = DecoderBuilder::new().shards(0).validate().unwrap_err();
        assert!(e.to_string().contains("shards"), "{e}");
    }

    #[test]
    fn shards_flow_into_coordinator_config() {
        let cfg = DecoderBuilder::new().shards(5).to_coordinator_config();
        assert_eq!(cfg.shards, 5);
        let argv: Vec<String> =
            ["serve", "--shards", "3"].iter().map(|s| s.to_string()).collect();
        let b = DecoderBuilder::new()
            .apply_flags(&crate::cli::Args::parse(&argv).unwrap())
            .unwrap();
        assert_eq!(b.to_coordinator_config().shards, 3);
    }

    #[test]
    fn fault_knobs_flow_into_coordinator_config() {
        // builder setters
        let cfg = DecoderBuilder::new()
            .failpoints("engine.exec=hit:3")
            .max_restarts(2)
            .to_coordinator_config();
        // env may override fault_spec in CI, so only assert when unset
        if std::env::var("TCVD_FAILPOINTS").is_err() {
            assert_eq!(cfg.fault_spec.as_deref(), Some("engine.exec=hit:3"));
        }
        assert_eq!(cfg.max_restarts, 2);

        // CLI flags
        let argv: Vec<String> =
            ["serve", "--failpoints", "framer.push=every:4", "--max-restarts", "7"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let b = DecoderBuilder::new()
            .apply_flags(&crate::cli::Args::parse(&argv).unwrap())
            .unwrap();
        let cfg = b.to_coordinator_config();
        if std::env::var("TCVD_FAILPOINTS").is_err() {
            assert_eq!(cfg.fault_spec.as_deref(), Some("framer.push=every:4"));
        }
        assert_eq!(cfg.max_restarts, 7);

        // defaults: no spec armed, stock restart budget
        let cfg = DecoderBuilder::new().to_coordinator_config();
        if std::env::var("TCVD_FAILPOINTS").is_err() {
            assert!(cfg.fault_spec.is_none());
        }
        assert_eq!(cfg.max_restarts, defaults::MAX_SHARD_RESTARTS);
    }

    #[test]
    fn backend_names_all_parse() {
        for name in BACKEND_NAMES {
            DecoderBuilder::new().backend_name(name).unwrap();
        }
        assert!(DecoderBuilder::new().backend_name("gpu-magic").is_err());
    }

    #[test]
    fn backend_name_pins_precision() {
        // switching away from a -half name must not keep half precision
        let b = DecoderBuilder::new()
            .backend_name("cpu-radix4-half")
            .unwrap()
            .backend_name("cpu-radix4")
            .unwrap();
        match b.to_backend_spec() {
            BackendSpec::CpuPacked { acc, .. } => assert_eq!(acc, AccPrecision::Single),
            other => panic!("expected CpuPacked, got {other:?}"),
        }
    }

    #[test]
    fn compact_backend_builds_and_matches_scalar() {
        let llr = vec![1.0f32; 64 * 2]; // positive LLR ⇒ all-zero stream
        let mut s = DecoderBuilder::new()
            .backend(BackendKind::Scalar)
            .tile_dims(32, 8, 8)
            .build()
            .unwrap();
        let mut c = DecoderBuilder::new()
            .backend(BackendKind::Compact)
            .tile_dims(32, 8, 8)
            .build()
            .unwrap();
        assert_eq!(c.label(), "compact");
        assert_eq!(c.frame_stages(), 48);
        let a = s.decode_stream(&llr).unwrap();
        let b = c.decode_stream(&llr).unwrap();
        assert_eq!(a, b);
        assert_eq!(b, vec![0u8; 64]);
    }

    #[test]
    fn simd_backend_builds_and_matches_scalar() {
        let llr = vec![1.0f32; 64 * 2]; // positive LLR ⇒ all-zero stream
        let mut s = DecoderBuilder::new()
            .backend(BackendKind::Scalar)
            .tile_dims(32, 8, 8)
            .build()
            .unwrap();
        let mut c = DecoderBuilder::new()
            .backend_name("simd")
            .unwrap()
            .tile_dims(32, 8, 8)
            .build()
            .unwrap();
        assert_eq!(c.label(), "simd");
        assert_eq!(c.frame_stages(), 48);
        let a = s.decode_stream(&llr).unwrap();
        let b = c.decode_stream(&llr).unwrap();
        assert_eq!(a, b);
        assert_eq!(b, vec![0u8; 64]);
    }

    #[test]
    fn termination_flows_into_coordinator_config() {
        let cfg = DecoderBuilder::new()
            .termination(TerminationMode::TailBiting)
            .to_coordinator_config();
        assert_eq!(cfg.termination, TerminationMode::TailBiting);
        // CLI spelling (and the tail_biting alias) both parse
        let argv: Vec<String> =
            ["serve", "--termination", "tail-biting"].iter().map(|s| s.to_string()).collect();
        let b = DecoderBuilder::new()
            .apply_flags(&crate::cli::Args::parse(&argv).unwrap())
            .unwrap();
        assert_eq!(b.termination_mode(), TerminationMode::TailBiting);
        assert!(DecoderBuilder::new().termination_name("nope").is_err());
        for &name in TerminationMode::NAMES {
            DecoderBuilder::new().termination_name(name).unwrap();
        }
    }

    #[test]
    fn tail_biting_one_shot_decodes_circular_block() {
        use crate::channel::bpsk;
        use crate::coding::{registry, Encoder};

        // 64-bit tail-biting block through the one-shot facade: the
        // decoder must recover the payload with no pinned states
        let bits = crate::util::rng::Rng::new(11).bits(64);
        let mut enc = Encoder::new(registry::paper_code());
        let (coded, n) = enc.encode_terminated(&bits, TerminationMode::TailBiting);
        assert_eq!(n, 64);
        let llr: Vec<f32> = bpsk::modulate(&coded).iter().map(|&x| x as f32).collect();
        let mut dec = DecoderBuilder::new()
            .backend(BackendKind::Scalar)
            .tile_dims(32, 32, 32)
            .termination(TerminationMode::TailBiting)
            .build()
            .unwrap();
        assert_eq!(dec.termination(), TerminationMode::TailBiting);
        assert_eq!(dec.decode_stream(&llr).unwrap(), bits);
    }

    #[test]
    fn renorm_every_flows_into_simd_spec() {
        let b = DecoderBuilder::new().backend(BackendKind::Simd).renorm_every(4);
        match b.to_backend_spec() {
            BackendSpec::Simd { renorm_every, .. } => assert_eq!(renorm_every, 4),
            other => panic!("expected Simd spec, got {other:?}"),
        }
    }

    #[test]
    fn radix_flows_into_simd_spec_and_validates() {
        let b = DecoderBuilder::new().backend(BackendKind::Simd).radix(2);
        assert!(b.validate().is_ok(), "default geometry is radix-2 clean");
        match b.to_backend_spec() {
            BackendSpec::Simd { radix, .. } => assert_eq!(radix, 2),
            other => panic!("expected Simd spec, got {other:?}"),
        }
        // rho outside {1, 2} is a config error on any backend
        let err = DecoderBuilder::new().radix(3).validate().unwrap_err();
        assert!(err.to_string().contains("radix"), "{err}");
        // an odd frame stage count cannot split into super-stages
        let err = DecoderBuilder::new()
            .backend(BackendKind::Simd)
            .radix(2)
            .tile_dims(33, 0, 0)
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("even frame stage count"), "{err}");
        // non-simd backends ignore the knob entirely
        assert!(DecoderBuilder::new()
            .backend(BackendKind::Scalar)
            .radix(2)
            .tile_dims(33, 0, 0)
            .validate()
            .is_ok());
    }

    #[test]
    fn scalar_decoder_builds_and_decodes_frames() {
        let mut dec = DecoderBuilder::new()
            .backend(BackendKind::Scalar)
            .tile_dims(16, 0, 0)
            .build()
            .unwrap();
        assert_eq!(dec.frame_stages(), 16);
        // wrong-length frame is rejected with a typed error
        let e = dec.decode_frame(&[0.0; 10], Some(0), None).unwrap_err();
        assert!(matches!(e, Error::Pipeline(_)), "{e}");
    }
}
