//! LLR formation (paper §II-C): for BPSK over AWGN the exact LLR is
//! `2*y/sigma^2`. The Viterbi max-metric is invariant under positive
//! scaling, so the decoder may consume raw `y`; the scale only matters
//! once values are quantized to half precision (saturation / resolution),
//! which is exactly the §IX-B study.

/// Exact LLR scale factor for AWGN: 2 / sigma^2.
pub fn llr_scale(sigma: f64) -> f64 {
    2.0 / (sigma * sigma)
}

/// Form LLRs from received symbols (scale = llr_scale(sigma) for exact
/// LLRs, or 1.0 to feed raw symbols as the paper does).
pub fn form_llrs(received: &[f64], scale: f64) -> Vec<f32> {
    received.iter().map(|&y| (y * scale) as f32).collect()
}

/// Saturating fixed-range clamp sometimes used before half conversion.
pub fn clamp_llrs(llrs: &mut [f32], limit: f32) {
    for v in llrs.iter_mut() {
        *v = v.clamp(-limit, limit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_formula() {
        assert!((llr_scale(1.0) - 2.0).abs() < 1e-12);
        assert!((llr_scale(0.5) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn form_scales() {
        let l = form_llrs(&[0.5, -1.0], 2.0);
        assert_eq!(l, vec![1.0, -2.0]);
    }

    #[test]
    fn clamp_saturates_symmetrically() {
        let mut l = vec![10.0, -10.0, 0.5];
        clamp_llrs(&mut l, 4.0);
        assert_eq!(l, vec![4.0, -4.0, 0.5]);
    }
}
