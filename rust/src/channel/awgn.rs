//! AWGN channel at a given Eb/N0 (paper Fig 12 step 3).
//!
//! For BPSK with unit symbol energy and code rate R, the noise standard
//! deviation is `sigma = sqrt(1 / (2 * R * 10^(EbN0_dB/10)))`.
//!
//! NOTE: the paper's §IX-B text gives `sigma = 2^{-(Eb/N0)/20}`, which is
//! dimensionally a typo (base-2 instead of base-10 and missing the rate
//! term); we implement the standard formula and record the substitution
//! in EXPERIMENTS.md. The *shape* of every BER comparison is unaffected
//! because all decoders see the same channel.

use crate::util::rng::Rng;

/// Seedable AWGN channel for a fixed Eb/N0 and code rate.
#[derive(Clone, Debug)]
pub struct AwgnChannel {
    sigma: f64,
    rng: Rng,
}

impl AwgnChannel {
    /// Construct from Eb/N0 in dB and code rate R (= 1/beta).
    pub fn new(ebn0_db: f64, rate: f64, seed: u64) -> Self {
        AwgnChannel { sigma: sigma_for(ebn0_db, rate), rng: Rng::new(seed) }
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Add white Gaussian noise to BPSK symbols.
    pub fn transmit(&mut self, symbols: &[f64]) -> Vec<f64> {
        symbols.iter().map(|&s| s + self.sigma * self.rng.next_gaussian()).collect()
    }

    /// In-place variant for the streaming path (no allocation).
    pub fn transmit_into(&mut self, symbols: &[f64], out: &mut [f64]) {
        for (o, &s) in out.iter_mut().zip(symbols) {
            *o = s + self.sigma * self.rng.next_gaussian();
        }
    }
}

/// Noise sigma for BPSK at Eb/N0 (dB) and code rate R.
pub fn sigma_for(ebn0_db: f64, rate: f64) -> f64 {
    let ebn0 = 10f64.powf(ebn0_db / 10.0);
    (1.0 / (2.0 * rate * ebn0)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_reference_values() {
        // rate 1/2, 0 dB: sigma = 1.0; 10 dB: sigma = sqrt(1/10)
        assert!((sigma_for(0.0, 0.5) - 1.0).abs() < 1e-12);
        assert!((sigma_for(10.0, 0.5) - (0.1f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn noise_statistics() {
        let mut ch = AwgnChannel::new(0.0, 0.5, 7);
        let tx = vec![1.0; 100_000];
        let rx = ch.transmit(&tx);
        let mean = rx.iter().sum::<f64>() / rx.len() as f64;
        let var = rx.iter().map(|y| (y - mean).powi(2)).sum::<f64>() / rx.len() as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn deterministic_with_seed() {
        let mut a = AwgnChannel::new(3.0, 0.5, 1);
        let mut b = AwgnChannel::new(3.0, 0.5, 1);
        assert_eq!(a.transmit(&[1.0, -1.0]), b.transmit(&[1.0, -1.0]));
    }

    #[test]
    fn transmit_into_matches() {
        let mut a = AwgnChannel::new(3.0, 0.5, 9);
        let mut b = AwgnChannel::new(3.0, 0.5, 9);
        let tx = [1.0, -1.0, 1.0];
        let v = a.transmit(&tx);
        let mut buf = [0.0; 3];
        b.transmit_into(&tx, &mut buf);
        assert_eq!(v.as_slice(), buf.as_slice());
    }
}
