//! Channel-array precision quantization (paper §IX-B: "the data received
//! from channel can be half-precision"). Emulates storing the LLR array
//! in a 16-bit float format before it enters the B matrix.

use crate::util::half::HalfKind;

/// Channel storage precision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelPrecision {
    /// f32 storage ("single" in Table I).
    Single,
    /// 16-bit storage ("half"); the format depends on the platform
    /// mapping — f16 on V100, bf16 on TPU.
    Half(HalfKind),
}

impl ChannelPrecision {
    /// Quantize an LLR buffer through the channel storage format.
    pub fn quantize(self, llrs: &mut [f32]) {
        if let ChannelPrecision::Half(kind) = self {
            for v in llrs.iter_mut() {
                *v = kind.round(*v);
            }
        }
    }

    /// Bytes per stored LLR (drives the throughput difference the paper
    /// attributes to channel=half: smaller transfers).
    pub fn bytes_per_llr(self) -> usize {
        match self {
            ChannelPrecision::Single => 4,
            ChannelPrecision::Half(_) => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_is_identity() {
        let mut v = vec![1.2345678f32, -0.000123];
        let orig = v.clone();
        ChannelPrecision::Single.quantize(&mut v);
        assert_eq!(v, orig);
    }

    #[test]
    fn half_rounds() {
        let mut v = vec![1.0 + 1.0 / 4096.0];
        ChannelPrecision::Half(HalfKind::Bf16).quantize(&mut v);
        assert_eq!(v[0], 1.0); // bf16 drops the tiny fraction
        let mut w = vec![1.0 + 1.0 / 4096.0];
        ChannelPrecision::Half(HalfKind::F16).quantize(&mut w);
        assert_eq!(w[0], 1.0); // f16 (11-bit significand) drops 2^-12 too
    }

    #[test]
    fn sizes() {
        assert_eq!(ChannelPrecision::Single.bytes_per_llr(), 4);
        assert_eq!(ChannelPrecision::Half(HalfKind::F16).bytes_per_llr(), 2);
    }
}
