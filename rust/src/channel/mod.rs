//! Channel simulation substrate (paper Fig 12, steps 3-4): BPSK
//! modulation, AWGN, LLR formation and precision quantization. Replaces
//! the authors' MATLAB-side channel with a deterministic, seedable Rust
//! implementation.

pub mod bpsk;
pub mod awgn;
pub mod llr;
pub mod quantize;

pub use awgn::AwgnChannel;
pub use bpsk::{demod_hard, modulate};
pub use llr::llr_scale;
