//! BPSK mapping: bit 0 -> +1.0, bit 1 -> -1.0 (so a positive received
//! value / LLR indicates "bit 0 more likely", matching the branch-metric
//! sign convention in Eq 2).

/// Modulate coded bits onto BPSK symbols.
pub fn modulate(bits: &[u8]) -> Vec<f64> {
    bits.iter().map(|&b| 1.0 - 2.0 * b as f64).collect()
}

/// Hard-decision demodulation: sign slicer back to bits.
pub fn demod_hard(symbols: &[f64]) -> Vec<u8> {
    symbols.iter().map(|&y| u8::from(y < 0.0)).collect()
}

/// Hard-decision "LLRs": ±1 per bit, for the soft-vs-hard study (§II-C).
pub fn hard_llrs(symbols: &[f64]) -> Vec<f64> {
    symbols.iter().map(|&y| if y < 0.0 { -1.0 } else { 1.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_convention() {
        assert_eq!(modulate(&[0, 1, 0]), vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn demod_inverts_clean_mod() {
        let bits = [0u8, 1, 1, 0, 1];
        assert_eq!(demod_hard(&modulate(&bits)), bits);
    }

    #[test]
    fn hard_llr_saturates() {
        assert_eq!(hard_llrs(&[0.3, -2.7, 0.0]), vec![1.0, -1.0, 1.0]);
    }
}
