//! Canonical default parameters, in one place.
//!
//! Historically `config::Config::default`, `main.rs` and every bench
//! each carried their own copy of the tile geometry and artifact
//! variant strings; they drifted (e.g. `..._b64` vs `..._b64_s48`).
//! Everything now reads from here: `api::DecoderBuilder::new` starts
//! from these values, `config::Config::default` mirrors them, and the
//! benches/examples pull the variant names below.

use crate::coding::TerminationMode;
use crate::viterbi::tiled::TileConfig;

/// Default standard code (registry key): the paper's (2,1,7) 171/133.
pub const CODE: &str = "ccsds";

/// Default backend name (one of `api::BACKEND_NAMES`): the AOT PJRT
/// artifact. Memory-tight deployments switch to `"compact"` — the
/// bit-packed survivor store (see `docs/MEMORY.md` for the selection
/// table and per-shard budget math).
pub const BACKEND: &str = "artifact";

/// Default artifact directory (relative to the working directory).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Default AOT artifact variant: radix-4 + dragonfly-group permutation,
/// single-precision accumulator and channel, batch 64, 48 steps
/// (= 96 trellis stages per frame).
pub const VARIANT: &str = "radix4_jnp_acc-single_ch-single_b64_s48";

/// Tile geometry matching [`VARIANT`]: 64 payload + 16/16 overlap = 96
/// stages per frame.
pub const TILE: TileConfig = TileConfig { payload: 64, head: 16, tail: 16 };

/// Generous-overlap tile for CPU backends (whose frame length is free):
/// 64 payload + 32/32 overlap, the BER-safe geometry used by selftest
/// and the BER harness.
pub const CPU_TILE: TileConfig = TileConfig { payload: 64, head: 32, tail: 32 };

/// Dynamic batcher: max frames per execution.
pub const MAX_BATCH: usize = 64;

/// Dynamic batcher: flush deadline in microseconds.
pub const BATCH_DEADLINE_US: u64 = 2000;

/// Traceback worker threads.
pub const WORKERS: usize = 2;

/// Default engine shard count: one independent backend instance (and
/// engine thread) per available hardware thread, so `serve()` scales
/// across the machine out of the box. Falls back to 1 when the
/// parallelism cannot be queried.
pub fn default_shards() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Bounded input queue depth (frames) before backpressure.
pub const QUEUE_DEPTH: usize = 1024;

/// Per-session output channel depth (decoded chunks buffered between
/// the reassembler and a slow consumer before delivery blocks).
pub const SESSION_OUTPUT_DEPTH: usize = 1024;

/// Session-affinity hash multiplier (Fibonacci hashing on the golden
/// ratio, `2^64 / phi`): `coordinator::home_shard` mixes the session id
/// with this constant so consecutive ids spread evenly across engine
/// shards while every frame of one session keeps the same home shard.
pub const SESSION_AFFINITY_MULTIPLIER: u64 = 0x9E37_79B9_7F4A_7C15;

/// How long an idle engine shard waits on its own queue before
/// attempting to steal from siblings (microseconds).
pub const STEAL_POLL_US: u64 = 200;

// --- shard supervision (`coordinator::engine`, docs/RELIABILITY.md) ----

/// Restart budget per engine shard: how many panic-and-restart cycles
/// the supervisor allows over a shard's lifetime before declaring it
/// dead. A dead shard keeps draining its queue but answers every frame
/// with a typed (non-retryable) pipeline error, so the dispatcher and
/// its sessions never wedge.
pub const MAX_SHARD_RESTARTS: usize = 8;

/// After this many *consecutive* faults with no successful execution in
/// between, the supervisor rebuilds the shard's backend one step down
/// the degradation chain (simd radix-2 → simd → compact → scalar).
pub const DEGRADE_AFTER_FAULTS: usize = 2;

/// First restart backoff (milliseconds); doubles per consecutive
/// restart up to [`RESTART_BACKOFF_MAX_MS`].
pub const RESTART_BACKOFF_BASE_MS: u64 = 10;

/// Restart backoff ceiling (milliseconds).
pub const RESTART_BACKOFF_MAX_MS: u64 = 2_000;

// --- net: socket serving front-end (`tcvd::net`) -----------------------

/// Hard cap on concurrent network sessions (TCP connections + live UDP
/// flows). Admissions beyond the cap are load-shed with a typed reject.
pub const NET_MAX_SESSIONS: usize = 1024;

/// Idle eviction timeout for network sessions, in milliseconds: a TCP
/// connection that sends nothing for this long is evicted (the session
/// is closed through the normal `finish` path); a UDP flow with no
/// datagrams for this long is swept from the flow table.
pub const NET_IDLE_TIMEOUT_MS: u64 = 30_000;

/// Upper bound on one length-prefixed wire frame's payload (bytes).
/// Guards the server against allocating unbounded buffers from a
/// malformed or hostile length prefix.
pub const NET_MAX_FRAME_BYTES: usize = 1 << 22;

/// Per-connection outbound buffer high-water mark (bytes). Once a slow
/// reader lets this many undelivered bytes pile up, the reactor stops
/// draining that session's decoded output; the bounded session channel
/// then backpressures the pipeline instead of the server buffering
/// without limit. One connection buffers at most this plus one frame.
pub const NET_WRITE_HIGH_WATER: usize = 1 << 20;

/// UDP client pipelining: blocks in flight (sent, not yet acked) per
/// flow in `UdpClient::decode_blocks`.
pub const NET_UDP_WINDOW: usize = 4;

/// Reactor poller backend (`net.poller`): `"auto"` picks `epoll` on
/// Linux and `poll(2)` elsewhere; `"poll"`/`"epoll"` force a backend
/// (epoll degrades to poll off Linux). Both stay reachable so the
/// cross-backend conformance suite keeps them semantically identical.
pub const NET_POLLER: &str = "auto";

/// Server-side UDP reply batching (`net.udp_batch`): replies
/// accumulate up to this many datagrams before one `sendmmsg`-style
/// flush (the batch also flushes whenever the socket has no more
/// pending datagrams, so an isolated reply is never delayed). 1
/// disables batching; the syscall is runtime-gated and degrades to
/// per-datagram `send_to` where unavailable.
pub const NET_UDP_BATCH: usize = 8;

/// Default stream termination mode: zero-flushed blocks (both trellis
/// ends pinned to state 0 — the classic deep-space convention). SDR /
/// cellular block traffic (LTE PBCH/PDCCH style) switches to
/// `"tail-biting"`; `docs/DECODING-MODES.md` has the selection table.
pub const TERMINATION: TerminationMode = TerminationMode::Flushed;

/// Path-metric renormalization period (stages) for the CPU packed and
/// quantized SIMD backends.
pub const RENORM_EVERY: usize = 16;

/// Quantized SIMD backend: trellis stages folded per ACS pass
/// (radix-2^RADIX super-branches). 1 keeps the classic butterfly
/// kernel; 2 halves the serial stage-loop trip count
/// (`DecoderBuilder::radix`, `--radix`, bit-identical either way —
/// see `docs/PERFORMANCE.md`).
pub const RADIX: usize = 1;

/// Quantized SIMD backend: LLRs land on a grid with step
/// `1 / SIMD_LLR_SCALE` (i.e. `q = round(llr * SIMD_LLR_SCALE)`); the
/// quantization/renormalization model is documented in
/// `docs/PERFORMANCE.md`.
pub const SIMD_LLR_SCALE: f32 = 8.0;

/// Quantized SIMD backend: per-LLR clamp magnitude on the grid (so one
/// branch metric is at most `beta * SIMD_QMAX` and i16 path metrics
/// keep exact headroom between renormalizations; see
/// `viterbi::simd::Quantizer`, which shrinks this only for extreme
/// `k * beta` codes).
pub const SIMD_QMAX: i16 = 512;

/// Artifact variant names used by the precision benches (Table I rows).
pub const VARIANT_SINGLE_HALF: &str = "radix4_jnp_acc-single_ch-half_b64_s48";
pub const VARIANT_HALF_SINGLE: &str = "radix4_jnp_acc-half_ch-single_b64_s48";
pub const VARIANT_HALF_HALF: &str = "radix4_jnp_acc-half_ch-half_b64_s48";

/// Radix-ablation artifact variants (E4).
pub const VARIANT_RADIX2: &str = "radix2_jnp_acc-single_ch-single_b64_s96";
pub const VARIANT_RADIX4_NOPERM: &str = "radix4_noperm_jnp_acc-single_ch-single_b64_s48";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_matches_default_variant_frame() {
        // the b64_s48 artifact decodes 48 radix-4 steps = 96 stages
        assert_eq!(TILE.frame_stages(), 96);
        assert_eq!(CPU_TILE.frame_stages(), 128);
    }

    #[test]
    fn queue_covers_batch() {
        assert!(QUEUE_DEPTH >= MAX_BATCH);
    }

    #[test]
    fn default_shards_is_positive() {
        assert!(default_shards() >= 1);
    }
}
