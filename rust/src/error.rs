//! The typed public error of the `tcvd` crate.
//!
//! Every `tcvd::api` entry point (and the layers it lowers to — config,
//! CLI, coordinator, tiled decoding, BER harness) reports failures as
//! [`Error`], classified by which part of the stack rejected the
//! request. `anyhow` remains an *internal* tool of the lower layers
//! (runtime, coding, util); it never crosses the public API boundary —
//! internal errors are folded into a typed variant with context at the
//! layer border (see [`ResultExt`]).

use std::fmt;

/// What went wrong, by subsystem.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Error {
    /// Invalid configuration: unknown code or backend name, bad tile
    /// geometry, malformed TOML, unparseable or unknown CLI flags.
    Config(String),
    /// Artifact problems: missing manifest, unknown variant, HLO
    /// parse/compile failure, PJRT runtime unavailability.
    Artifact(String),
    /// Backend construction failures (packing build, decoder setup).
    Backend(String),
    /// Streaming pipeline failures: geometry mismatch at startup,
    /// pushes into a shut-down pipeline, worker panics.
    Pipeline(String),
    /// Network serving failures (`tcvd::net`): socket I/O, malformed
    /// wire frames, handshake rejects, evicted or load-shed sessions.
    Net(String),
}

impl Error {
    /// Build a [`Error::Config`] from anything displayable.
    pub fn config(msg: impl fmt::Display) -> Error {
        Error::Config(msg.to_string())
    }

    /// Build a [`Error::Artifact`] from anything displayable.
    pub fn artifact(msg: impl fmt::Display) -> Error {
        Error::Artifact(msg.to_string())
    }

    /// Build a [`Error::Backend`] from anything displayable.
    pub fn backend(msg: impl fmt::Display) -> Error {
        Error::Backend(msg.to_string())
    }

    /// Build a [`Error::Pipeline`] from anything displayable.
    pub fn pipeline(msg: impl fmt::Display) -> Error {
        Error::Pipeline(msg.to_string())
    }

    /// Build a [`Error::Net`] from anything displayable.
    pub fn net(msg: impl fmt::Display) -> Error {
        Error::Net(msg.to_string())
    }

    /// The subsystem label this error is classified under.
    pub fn kind(&self) -> &'static str {
        match self {
            Error::Config(_) => "config",
            Error::Artifact(_) => "artifact",
            Error::Backend(_) => "backend",
            Error::Pipeline(_) => "pipeline",
            Error::Net(_) => "net",
        }
    }

    /// The human-readable message (without the kind prefix).
    pub fn message(&self) -> &str {
        match self {
            Error::Config(m)
            | Error::Artifact(m)
            | Error::Backend(m)
            | Error::Pipeline(m)
            | Error::Net(m) => m,
        }
    }

    /// Is this failure worth retrying against the same pipeline?
    ///
    /// True for sessions poisoned because their home shard panicked and
    /// was restarted (the error message carries the stable
    /// `shard-restart` token): by the time the caller retries, the
    /// supervisor has the shard back up (possibly on a degraded
    /// backend), so a fresh session is expected to succeed. The net
    /// layer maps these onto the retryable REJECT/SHED wire path that
    /// `loadgen`'s shed-aware clients already honor.
    pub fn is_retryable(&self) -> bool {
        matches!(self, Error::Pipeline(m) | Error::Net(m) if m.contains("shard-restart"))
    }

    /// Prepend context, preserving the variant: `context: message`.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        match self {
            Error::Config(m) => Error::Config(format!("{ctx}: {m}")),
            Error::Artifact(m) => Error::Artifact(format!("{ctx}: {m}")),
            Error::Backend(m) => Error::Backend(format!("{ctx}: {m}")),
            Error::Pipeline(m) => Error::Pipeline(format!("{ctx}: {m}")),
            Error::Net(m) => Error::Net(format!("{ctx}: {m}")),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.kind(), self.message())
    }
}

impl std::error::Error for Error {}

/// `tcvd::Result<T>`: `Result` defaulted to the typed [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Fold any displayable error (`anyhow::Error`, `std::io::Error`,
/// parse errors, channel errors, ...) into a typed [`Error`] with
/// context — the conversion used at the boundary between tcvd's
/// anyhow-based internals and its typed public surface.
pub trait ResultExt<T> {
    /// Map the error into [`Error::Config`] as `ctx: cause`.
    fn or_config(self, ctx: impl fmt::Display) -> Result<T>;
    /// Map the error into [`Error::Artifact`] as `ctx: cause`.
    fn or_artifact(self, ctx: impl fmt::Display) -> Result<T>;
    /// Map the error into [`Error::Backend`] as `ctx: cause`.
    fn or_backend(self, ctx: impl fmt::Display) -> Result<T>;
    /// Map the error into [`Error::Pipeline`] as `ctx: cause`.
    fn or_pipeline(self, ctx: impl fmt::Display) -> Result<T>;
    /// Map the error into [`Error::Net`] as `ctx: cause`.
    fn or_net(self, ctx: impl fmt::Display) -> Result<T>;
}

impl<T, E: fmt::Display> ResultExt<T> for std::result::Result<T, E> {
    fn or_config(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::Config(format!("{ctx}: {e}")))
    }

    fn or_artifact(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::Artifact(format!("{ctx}: {e}")))
    }

    fn or_backend(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::Backend(format!("{ctx}: {e}")))
    }

    fn or_pipeline(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::Pipeline(format!("{ctx}: {e}")))
    }

    fn or_net(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::Net(format!("{ctx}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_kind() {
        let e = Error::config("unknown code \"x\"");
        assert_eq!(e.to_string(), "config: unknown code \"x\"");
        assert_eq!(e.kind(), "config");
        assert_eq!(e.message(), "unknown code \"x\"");
    }

    #[test]
    fn context_preserves_variant() {
        let e = Error::artifact("no manifest").context("starting backend");
        assert_eq!(e, Error::Artifact("starting backend: no manifest".into()));
    }

    #[test]
    fn result_ext_classifies_foreign_errors() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.or_pipeline("reading stream").unwrap_err();
        assert_eq!(e, Error::Pipeline("reading stream: gone".into()));
    }

    #[test]
    fn retryable_is_keyed_on_the_shard_restart_token() {
        assert!(Error::pipeline("shard-restart: shard 3 panicked mid-batch").is_retryable());
        assert!(Error::net("session rejected (shard-restart): retry").is_retryable());
        assert!(!Error::pipeline("decoder shut down").is_retryable());
        assert!(!Error::config("shard-restart").is_retryable(), "config errors never retry");
    }

    #[test]
    fn interops_with_std_error() {
        fn takes_std(_: &dyn std::error::Error) {}
        let e = Error::backend("boom");
        takes_std(&e);
    }
}
