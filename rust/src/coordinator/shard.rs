//! Sharding primitives: the per-shard bounded work queue and the
//! dispatcher that routes frames from the session-facing input channel
//! onto engine shards.
//!
//! Routing is **session-affinity hashed** ([`home_shard`]): every frame
//! of a session lands on the same home shard, which keeps a shard's
//! dynamic batcher warm with frames from a stable session set and
//! bounds the survivor state any one shard holds (the memory argument
//! of arXiv 2011.09337). Because overlapped frames decode independently
//! (the block-parallel property of Peng et al., arXiv 1608.00066), any
//! shard may decode any frame — so an **idle shard steals** from the
//! deepest sibling queue instead of sleeping, and the reassembly stage
//! restores per-session order by sequence number afterwards.
//!
//! See `docs/ARCHITECTURE.md` for the full data-flow and threading
//! model.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::FrameTask;

/// Result of a bounded-wait pop from a [`ShardQueue`].
pub enum Pop {
    /// A frame was dequeued.
    Item(FrameTask),
    /// The wait elapsed with the queue still empty (and open).
    Timeout,
    /// The queue is closed *and* fully drained.
    Closed,
}

/// A bounded blocking FIFO owned by one engine shard.
///
/// Three parties touch it: the dispatcher pushes (blocking when full —
/// the backpressure link between the session input channel and the
/// shard), the owning engine pops with a deadline (the batching wait),
/// and sibling engines [`try_pop`](ShardQueue::try_pop) to steal work
/// when idle. Items still drain after [`close`](ShardQueue::close);
/// only a closed *and* empty queue reports [`Pop::Closed`].
pub struct ShardQueue {
    inner: Mutex<Inner>,
    /// Wakes consumers (the owner's pop and stealers) on arrival/close.
    cv_items: Condvar,
    /// Wakes the dispatcher when space frees up or the queue closes.
    cv_space: Condvar,
    cap: usize,
}

struct Inner {
    q: VecDeque<FrameTask>,
    closed: bool,
}

impl ShardQueue {
    /// A queue holding at most `cap` frames (clamped to at least 1).
    pub fn new(cap: usize) -> ShardQueue {
        ShardQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            cv_items: Condvar::new(),
            cv_space: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Bounded blocking push; returns false (dropping the frame) once
    /// the queue is closed.
    pub fn push(&self, task: FrameTask) -> bool {
        let mut g = self.inner.lock().unwrap();
        while !g.closed && g.q.len() >= self.cap {
            g = self.cv_space.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.q.push_back(task);
        drop(g);
        self.cv_items.notify_one();
        true
    }

    /// Non-blocking pop — the steal path.
    pub fn try_pop(&self) -> Option<FrameTask> {
        let mut g = self.inner.lock().unwrap();
        let item = g.q.pop_front();
        if item.is_some() {
            drop(g);
            self.cv_space.notify_one();
        }
        item
    }

    /// Pop, waiting up to `wait` for an item. The wait is measured
    /// against a deadline fixed on entry, so wakeups that lose the race
    /// to a stealer (item gone again by the time the lock is held) do
    /// not extend the total wait beyond `wait`.
    pub fn pop_timeout(&self, wait: Duration) -> Pop {
        // None = effectively unbounded (absurdly large `wait`)
        let deadline = Instant::now().checked_add(wait);
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                drop(g);
                self.cv_space.notify_one();
                return Pop::Item(item);
            }
            if g.closed {
                return Pop::Closed;
            }
            let remaining = match deadline {
                Some(d) => {
                    let r = d.saturating_duration_since(Instant::now());
                    if r.is_zero() {
                        return Pop::Timeout;
                    }
                    r
                }
                None => Duration::from_secs(3600),
            };
            let (guard, _res) = self.cv_items.wait_timeout(g, remaining).unwrap();
            g = guard;
        }
    }

    /// Close the queue: wakes the dispatcher and every consumer.
    /// Remaining items still drain through pops.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv_items.notify_all();
        self.cv_space.notify_all();
    }

    /// Current queue depth in frames.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Session-affinity routing: the home shard for a session id. A
/// Fibonacci multiplicative hash spreads sequentially-allocated session
/// ids across shards without correlating with the allocation order.
pub fn home_shard(session: u64, n_shards: usize) -> usize {
    debug_assert!(n_shards > 0);
    ((session.wrapping_mul(crate::defaults::SESSION_AFFINITY_MULTIPLIER) >> 32)
        % n_shards.max(1) as u64) as usize
}

/// Steal one frame on behalf of shard `me`: scan the sibling queues,
/// take the oldest frame of the deepest one. Returns `None` when every
/// sibling is empty.
pub fn steal(queues: &[ShardQueue], me: usize) -> Option<FrameTask> {
    let mut best: Option<usize> = None;
    let mut best_len = 0usize;
    for (j, q) in queues.iter().enumerate() {
        if j == me {
            continue;
        }
        let len = q.len();
        if len > best_len {
            best_len = len;
            best = Some(j);
        }
    }
    best.and_then(|j| queues[j].try_pop())
}

/// Run the dispatcher loop (one thread): route every frame arriving on
/// the session input channel to its session's home shard, maintaining
/// the per-shard queue-depth gauge. Exits — closing every shard queue
/// so the engines wind down — when the input channel closes, i.e. when
/// the coordinator and every session handle dropped their senders.
pub fn run_dispatcher(
    rx: Receiver<FrameTask>,
    shards: Arc<Vec<ShardQueue>>,
    metrics: Arc<Metrics>,
) {
    let n = shards.len();
    for task in rx {
        let s = home_shard(task.session, n);
        if !shards[s].push(task) {
            break; // queues force-closed under us: shutting down
        }
        metrics.shard(s).queue_depth.store(shards[s].len() as u64, Ordering::Relaxed);
    }
    for q in shards.iter() {
        q.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::viterbi::types::FrameJob;
    use std::time::Instant;

    fn task(session: u64, seq: u64) -> FrameTask {
        FrameTask {
            session,
            seq,
            job: FrameJob {
                llr: vec![0.0; 4],
                start_state: None,
                end_state: None,
                emit_from: 0,
                emit_len: 2,
            },
            t_enq: Instant::now(),
        }
    }

    #[test]
    fn fifo_and_close_semantics() {
        let q = ShardQueue::new(8);
        assert!(q.push(task(1, 0)));
        assert!(q.push(task(1, 1)));
        assert_eq!(q.len(), 2);
        match q.pop_timeout(Duration::from_millis(1)) {
            Pop::Item(t) => assert_eq!(t.seq, 0),
            _ => panic!("expected item"),
        }
        q.close();
        assert!(!q.push(task(1, 2)), "push after close must be rejected");
        // remaining item drains, then Closed
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Item(_)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed));
    }

    #[test]
    fn bounded_push_blocks_until_space() {
        let q = Arc::new(ShardQueue::new(1));
        assert!(q.push(task(0, 0)));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(task(0, 1)));
        std::thread::sleep(Duration::from_millis(20));
        assert!(!h.is_finished(), "push into a full queue must block");
        assert!(q.try_pop().is_some());
        assert!(h.join().unwrap());
    }

    #[test]
    fn pop_times_out_on_empty_queue() {
        let q = ShardQueue::new(4);
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Pop::Timeout));
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn home_shard_is_stable_and_in_range() {
        for n in 1..=9usize {
            for session in 0..200u64 {
                let s = home_shard(session, n);
                assert!(s < n);
                assert_eq!(s, home_shard(session, n), "routing must be deterministic");
            }
        }
        // sequential ids must not all collapse onto one shard
        let hits: std::collections::HashSet<usize> =
            (0..32u64).map(|s| home_shard(s, 8)).collect();
        assert!(hits.len() > 2, "hash spreads sessions: {hits:?}");
    }

    #[test]
    fn steal_takes_from_deepest_sibling() {
        let queues: Vec<ShardQueue> = (0..3).map(|_| ShardQueue::new(16)).collect();
        queues[0].push(task(0, 0)); // own work: must never be "stolen"
        queues[1].push(task(1, 0));
        queues[2].push(task(2, 0));
        queues[2].push(task(2, 1));
        let got = steal(&queues, 0).expect("work available");
        assert_eq!(got.session, 2, "deepest queue is shard 2");
        assert!(steal(&queues, 0).is_some());
        assert!(steal(&queues, 0).is_some());
        assert!(steal(&queues, 0).is_none(), "own queue is never stolen from");
        assert_eq!(queues[0].len(), 1);
    }
}
