//! Backend construction for the engine shards. PJRT executables are
//! not `Send`, so the spec (plain data) crosses the thread boundary and
//! each engine shard builds its *own* backend instance inside its
//! thread — N shards means N independent executables, which is exactly
//! what lets their forward passes run concurrently.
//!
//! `BackendSpec` is an internal lowering target: user-facing code
//! configures backends through `api::DecoderBuilder`, which is the only
//! place specs are constructed from user parameters. The recipe for
//! adding a new backend lives in `docs/ARCHITECTURE.md`.

use std::path::PathBuf;
use std::sync::Arc;

use crate::channel::quantize::ChannelPrecision;
use crate::coding::packing::build_packing;
use crate::coding::registry;
use crate::coding::trellis::Trellis;
use crate::error::{Result, ResultExt};
use crate::runtime::{client, Artifact, ArtifactDecoder, Manifest};
use crate::util::half::HalfKind;
use crate::viterbi::compact::CompactDecoder;
use crate::viterbi::packed::PackedDecoder;
use crate::viterbi::scalar::ScalarDecoder;
use crate::viterbi::simd::SimdDecoder;
use crate::viterbi::types::{AccPrecision, FrameDecoder};

/// What decoder the engine should run.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// AOT artifact via PJRT (the production path).
    Artifact { dir: PathBuf, variant: String },
    /// CPU tensor-form emulation (same arithmetic, no PJRT).
    CpuPacked {
        code: String,
        scheme: String,
        stages: usize,
        acc: AccPrecision,
        chan: ChannelPrecision,
        renorm_every: usize,
    },
    /// Scalar Alg-1/Alg-2 baseline.
    Scalar { code: String, stages: usize },
    /// Memory-efficient survivor storage (arXiv 2011.09337): scalar
    /// Alg-1 arithmetic with bit-packed per-stage decision words in a
    /// frame-sized ring — 1/32 the survivor memory of `Scalar`,
    /// bit-identical output. Memory model: `docs/MEMORY.md`.
    Compact { code: String, stages: usize },
    /// Quantized lane-parallel ACS fast path: i16 path metrics with
    /// saturating adds and periodic renormalization, per-symbol
    /// branch-metric dedup, structure-of-arrays butterfly update
    /// (autovectorized, AVX2 kernel behind a runtime check), decisions
    /// bit-packed into the `Compact` ring. `radix` (1 or 2) sets the
    /// trellis stages folded per pass: 2 runs radix-4 super-branch
    /// tournaments over precomputed `(y_left, y_right)` metric planes
    /// and stores 2-bit winners. Decodes bit-identically to `Scalar`
    /// on grid LLRs at either radix; model in `docs/PERFORMANCE.md`.
    Simd { code: String, stages: usize, renorm_every: usize, radix: usize },
}

impl BackendSpec {
    /// Convenience: the default artifact backend.
    pub fn artifact(dir: impl Into<PathBuf>, variant: impl Into<String>) -> Self {
        BackendSpec::Artifact { dir: dir.into(), variant: variant.into() }
    }

    /// A short name for logs and the supervisor's degradation records.
    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Artifact { .. } => "artifact",
            BackendSpec::CpuPacked { .. } => "cpu",
            BackendSpec::Scalar { .. } => "scalar",
            BackendSpec::Compact { .. } => "compact",
            BackendSpec::Simd { radix, .. } => {
                if *radix > 1 { "simd-r2" } else { "simd" }
            }
        }
    }

    /// One step down the graceful-degradation chain the shard
    /// supervisor walks when a backend keeps faulting:
    ///
    /// ```text
    /// simd radix-2 → simd radix-1 → compact → scalar → (dead)
    /// cpu / artifact ──────────────→ compact → scalar → (dead)
    /// ```
    ///
    /// Every step preserves the frame geometry and decodes
    /// bit-identically (the repo's equivalence corpora pin this), so a
    /// degraded shard serves the same traffic, just slower. `None`
    /// means the chain is exhausted: the scalar oracle is the last
    /// resort, and an `Artifact` spec with an unknown code geometry
    /// also cannot be rebuilt (its stages live in the artifact, not the
    /// spec — the supervisor declares such a shard dead instead).
    pub fn degraded(&self) -> Option<BackendSpec> {
        match self {
            BackendSpec::Simd { code, stages, renorm_every, radix } if *radix > 1 => {
                Some(BackendSpec::Simd {
                    code: code.clone(),
                    stages: *stages,
                    renorm_every: *renorm_every,
                    radix: 1,
                })
            }
            BackendSpec::Simd { code, stages, .. }
            | BackendSpec::CpuPacked { code, stages, .. } => {
                Some(BackendSpec::Compact { code: code.clone(), stages: *stages })
            }
            BackendSpec::Compact { code, stages } => {
                Some(BackendSpec::Scalar { code: code.clone(), stages: *stages })
            }
            BackendSpec::Scalar { .. } | BackendSpec::Artifact { .. } => None,
        }
    }

    /// Build the decoder (call on the owning thread).
    pub fn build(&self) -> Result<Box<dyn FrameDecoder>> {
        match self {
            BackendSpec::Artifact { dir, variant } => {
                let manifest = Manifest::load(dir)
                    .or_artifact(format!("loading manifest from {}", dir.display()))?;
                let meta = manifest.find(variant).or_artifact("selecting variant")?.clone();
                let cl = client::cpu_client().or_artifact("creating PJRT client")?;
                let artifact = Artifact::load(&cl, &manifest, &meta)
                    .or_artifact(format!("loading artifact {}", meta.name))?;
                let code = artifact.code().or_artifact("decoding artifact code")?;
                let trellis = Arc::new(Trellis::new(code));
                Ok(Box::new(ArtifactDecoder::new(Arc::new(artifact), trellis)))
            }
            BackendSpec::CpuPacked { code, scheme, stages, acc, chan, renorm_every } => {
                let code = registry::lookup(code).or_backend("cpu backend")?;
                let trellis = Arc::new(Trellis::new(code));
                let pk = build_packing(&trellis, scheme)
                    .or_backend(format!("building {scheme} packing"))?;
                Ok(Box::new(PackedDecoder::new(
                    trellis, pk, *stages, *acc, HalfKind::Bf16, *chan, *renorm_every,
                )))
            }
            BackendSpec::Scalar { code, stages } => {
                let code = registry::lookup(code).or_backend("scalar backend")?;
                let trellis = Arc::new(Trellis::new(code));
                Ok(Box::new(ScalarDecoder::new(trellis, *stages)))
            }
            BackendSpec::Compact { code, stages } => {
                let code = registry::lookup(code).or_backend("compact backend")?;
                let trellis = Arc::new(Trellis::new(code));
                Ok(Box::new(CompactDecoder::new(trellis, *stages)))
            }
            BackendSpec::Simd { code, stages, renorm_every, radix } => {
                let code = registry::lookup(code).or_backend("simd backend")?;
                let trellis = Arc::new(Trellis::new(code));
                Ok(Box::new(SimdDecoder::with_radix(trellis, *stages, *renorm_every,
                                                    *radix)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn cpu_backends_build() {
        let spec = BackendSpec::CpuPacked {
            code: "ccsds".into(),
            scheme: "radix4".into(),
            stages: 64,
            acc: AccPrecision::Single,
            chan: ChannelPrecision::Single,
            renorm_every: 16,
        };
        let dec = spec.build().unwrap();
        assert_eq!(dec.frame_stages(), 64);

        let dec2 = BackendSpec::Scalar { code: "ccsds".into(), stages: 32 }.build().unwrap();
        assert_eq!(dec2.frame_stages(), 32);

        let dec3 = BackendSpec::Compact { code: "ccsds".into(), stages: 32 }.build().unwrap();
        assert_eq!(dec3.frame_stages(), 32);
        assert_eq!(dec3.label(), "compact");

        let dec4 = BackendSpec::Simd {
            code: "ccsds".into(),
            stages: 32,
            renorm_every: 16,
            radix: 1,
        }
        .build()
        .unwrap();
        assert_eq!(dec4.frame_stages(), 32);
        assert_eq!(dec4.label(), "simd");

        let dec5 = BackendSpec::Simd {
            code: "ccsds".into(),
            stages: 32,
            renorm_every: 16,
            radix: 2,
        }
        .build()
        .unwrap();
        assert_eq!(dec5.frame_stages(), 32);
        assert_eq!(dec5.label(), "simd");
    }

    #[test]
    fn degradation_chain_walks_to_scalar_and_stops() {
        let mut spec = BackendSpec::Simd {
            code: "ccsds".into(),
            stages: 32,
            renorm_every: 16,
            radix: 2,
        };
        let mut walk = vec![spec.name()];
        while let Some(next) = spec.degraded() {
            // every step keeps the frame geometry and stays buildable
            assert_eq!(next.build().unwrap().frame_stages(), 32);
            walk.push(next.name());
            spec = next;
        }
        assert_eq!(walk, vec!["simd-r2", "simd", "compact", "scalar"]);

        let cpu = BackendSpec::CpuPacked {
            code: "ccsds".into(),
            scheme: "radix4".into(),
            stages: 64,
            acc: AccPrecision::Single,
            chan: ChannelPrecision::Single,
            renorm_every: 16,
        };
        assert_eq!(cpu.degraded().unwrap().name(), "compact");
        assert!(BackendSpec::artifact("artifacts", "radix4").degraded().is_none());
    }

    #[test]
    fn missing_artifact_dir_errors() {
        let spec = BackendSpec::artifact("/nonexistent-dir", "radix4");
        let e = spec.build().unwrap_err();
        assert!(matches!(e, Error::Artifact(_)), "{e}");
    }

    #[test]
    fn unknown_code_is_backend_error() {
        let e = BackendSpec::Scalar { code: "nope".into(), stages: 32 }.build().unwrap_err();
        assert!(matches!(e, Error::Backend(_)), "{e}");
    }
}
