//! Streaming framer: the incremental version of `viterbi::tiled::
//! make_frames`, producing identical frames from chunked input (verified
//! against it in tests). Frames carry monotonically increasing
//! per-session sequence numbers, which is all the downstream pipeline
//! (dispatcher, shards, reassembly) needs to restore order — the framer
//! is the single point where a stream's framing is decided.

use crate::viterbi::tiled::TileConfig;
use crate::viterbi::types::FrameJob;

/// Cuts a pushed LLR stream into fixed-geometry overlapped frames.
#[derive(Debug)]
pub struct Framer {
    cfg: TileConfig,
    beta: usize,
    /// Buffered LLRs starting at stage `buf_start`.
    buf: Vec<f32>,
    buf_start: usize,
    /// Next frame index to emit.
    next_frame: usize,
    /// Total stages pushed so far.
    stages_in: usize,
    finished: bool,
}

impl Framer {
    pub fn new(cfg: TileConfig, beta: usize) -> Self {
        Framer {
            cfg,
            beta,
            buf: Vec::new(),
            buf_start: 0,
            next_frame: 0,
            stages_in: 0,
            finished: false,
        }
    }

    pub fn frames_emitted(&self) -> usize {
        self.next_frame
    }

    /// Coded symbols per trellis stage (chunk alignment unit).
    pub fn beta(&self) -> usize {
        self.beta
    }

    /// Stage index where frame `fi`'s buffer begins.
    fn frame_start(&self, fi: usize) -> usize {
        (fi * self.cfg.payload).saturating_sub(self.cfg.head)
    }

    /// Push an LLR chunk (`len % beta == 0`); returns all frames that
    /// became complete.
    pub fn push(&mut self, llr: &[f32]) -> Vec<FrameJob> {
        assert!(!self.finished, "push after finish");
        assert_eq!(llr.len() % self.beta, 0, "chunk not stage-aligned");
        self.buf.extend_from_slice(llr);
        self.stages_in += llr.len() / self.beta;

        let stages = self.cfg.frame_stages();
        let mut out = Vec::new();
        while self.frame_start(self.next_frame) + stages <= self.stages_in {
            out.push(self.emit(self.next_frame, stages, false, false));
        }
        self.gc();
        out
    }

    /// Flush: pad the stream tail with zero LLRs and emit the remaining
    /// frames. `flushed_end` marks whether the encoder was flushed to
    /// state 0 at the true stream end.
    pub fn finish(&mut self, flushed_end: bool) -> Vec<FrameJob> {
        assert!(!self.finished, "finish twice");
        self.finished = true;
        let stages = self.cfg.frame_stages();
        let n_frames = self.stages_in.div_ceil(self.cfg.payload);
        let mut out = Vec::new();
        while self.next_frame < n_frames {
            let is_last = self.next_frame + 1 == n_frames;
            out.push(self.emit(self.next_frame, stages, true, is_last && flushed_end));
        }
        out
    }

    fn emit(&mut self, fi: usize, stages: usize, pad: bool, flushed_last: bool) -> FrameJob {
        let pay_start = fi * self.cfg.payload;
        let start = self.frame_start(fi);
        let head = pay_start - start;
        let mut frame = vec![0f32; stages * self.beta];
        let rel = (start - self.buf_start) * self.beta;
        let avail_stages = (self.stages_in - start).min(stages);
        let take = avail_stages * self.beta;
        assert!(pad || take == stages * self.beta);
        frame[..take].copy_from_slice(&self.buf[rel..rel + take]);
        self.next_frame = fi + 1;
        FrameJob {
            llr: frame,
            start_state: if fi == 0 { Some(0) } else { None },
            // only claim the flushed end state when the frame ends
            // exactly at the true stream end (no padding desync)
            end_state: if flushed_last && start + stages == self.stages_in {
                Some(0)
            } else {
                None
            },
            emit_from: head,
            emit_len: self.cfg.payload.min(self.stages_in - pay_start),
        }
    }

    /// Drop buffered stages no future frame needs.
    fn gc(&mut self) {
        let keep_from = self.frame_start(self.next_frame);
        if keep_from > self.buf_start {
            self.buf.drain(..(keep_from - self.buf_start) * self.beta);
            self.buf_start = keep_from;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::viterbi::tiled::make_frames;

    fn cfg() -> TileConfig {
        TileConfig { payload: 32, head: 8, tail: 12 }
    }

    fn random_llrs(n_stages: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n_stages * 2).map(|_| r.next_gaussian() as f32).collect()
    }

    fn assert_jobs_eq(a: &[FrameJob], b: &[FrameJob]) {
        assert_eq!(a.len(), b.len(), "frame count");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.llr, y.llr, "frame {i} llr");
            assert_eq!(x.start_state, y.start_state, "frame {i} start");
            assert_eq!(x.end_state, y.end_state, "frame {i} end");
            assert_eq!(x.emit_from, y.emit_from, "frame {i} emit_from");
            assert_eq!(x.emit_len, y.emit_len, "frame {i} emit_len");
        }
    }

    #[test]
    fn matches_make_frames_whole_push() {
        let llr = random_llrs(128, 1);
        let want = make_frames(&llr, 2, &cfg(), true).unwrap();
        let mut fr = Framer::new(cfg(), 2);
        let mut got = fr.push(&llr);
        got.extend(fr.finish(true));
        assert_jobs_eq(&got, &want);
    }

    #[test]
    fn matches_make_frames_chunked() {
        let llr = random_llrs(256, 2);
        let want = make_frames(&llr, 2, &cfg(), true).unwrap();
        for chunk_stages in [1usize, 7, 31, 64] {
            let mut fr = Framer::new(cfg(), 2);
            let mut got = Vec::new();
            for chunk in llr.chunks(chunk_stages * 2) {
                got.extend(fr.push(chunk));
            }
            got.extend(fr.finish(true));
            assert_jobs_eq(&got, &want);
        }
    }

    #[test]
    fn partial_tail_padded() {
        // 100 stages with payload 32 -> 4 frames, last emits 4 bits
        let llr = random_llrs(100, 3);
        let mut fr = Framer::new(cfg(), 2);
        let mut jobs = fr.push(&llr);
        jobs.extend(fr.finish(false));
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[3].emit_len, 4);
        let total: usize = jobs.iter().map(|j| j.emit_len).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn gc_bounds_memory() {
        let mut fr = Framer::new(cfg(), 2);
        for i in 0..100 {
            fr.push(&random_llrs(64, i));
        }
        // buffer must hold at most ~frame_stages + chunk worth of stages
        assert!(fr.buf.len() <= (fr.cfg.frame_stages() + 64 + fr.cfg.head) * 2,
                "buf len {}", fr.buf.len());
    }

    #[test]
    #[should_panic(expected = "push after finish")]
    fn push_after_finish_panics() {
        let mut fr = Framer::new(cfg(), 2);
        fr.finish(false);
        fr.push(&[0.0, 0.0]);
    }
}
