//! Streaming framer: the incremental version of `viterbi::tiled::
//! make_frames`, producing identical frames from chunked input (verified
//! against it in tests). Frames carry monotonically increasing
//! per-session sequence numbers, which is all the downstream pipeline
//! (dispatcher, shards, reassembly) needs to restore order — the framer
//! is the single point where a stream's framing is decided.
//!
//! The framer owns the stream's [`TerminationMode`]
//! (`docs/DECODING-MODES.md`):
//!
//! * **Flushed / truncated** streams emit frames incrementally as their
//!   windows complete, with a rolling buffer bounded by one frame
//!   geometry plus the chunk size (see `gc`).
//! * **Tail-biting** blocks are circular: frame 0's head context is the
//!   *end* of the block, so no frame can be cut before
//!   [`finish`](Framer::finish). The framer buffers the whole block (tail-biting
//!   traffic is short blocks — that is the point of the mode) and emits
//!   every circularly-extended frame at finish time, still in order.
//!
//! One frame per mode through the streaming interface:
//!
//! ```
//! use tcvd::coding::TerminationMode;
//! use tcvd::coordinator::framer::Framer;
//! use tcvd::viterbi::tiled::TileConfig;
//!
//! let cfg = TileConfig { payload: 32, head: 8, tail: 8 };
//! let llr = vec![0.5f32; 32 * 2]; // one payload tile of rate-1/2 LLRs
//!
//! // Flushed: the stream head is pinned to state 0. (The flushed *end*
//! // state is only claimed when a frame's window lands exactly on the
//! // stream end — here the tail overlap reaches past it, so the frame
//! // is zero-padded and traceback starts from the best-metric state;
//! // `viterbi::tiled::make_frames` documents the claim rule.)
//! let mut fr = Framer::new(cfg, 2, TerminationMode::Flushed);
//! let mut jobs = fr.push(&llr);
//! jobs.extend(fr.finish()?);
//! assert_eq!(jobs.len(), 1);
//! assert_eq!((jobs[0].start_state, jobs[0].end_state), (Some(0), None));
//!
//! // Truncated: known start, and *never* a pinned end
//! let mut fr = Framer::new(cfg, 2, TerminationMode::Truncated);
//! let mut jobs = fr.push(&llr);
//! jobs.extend(fr.finish()?);
//! assert_eq!((jobs[0].start_state, jobs[0].end_state), (Some(0), None));
//!
//! // Tail-biting: nothing can be emitted before the block end arrives
//! // (frame 0 wraps its head context around from the block tail) ...
//! let mut fr = Framer::new(cfg, 2, TerminationMode::TailBiting);
//! assert!(fr.push(&llr).is_empty());
//! let jobs = fr.finish()?;
//! // ... and no frame pins a state; the circular context replaces both
//! assert_eq!((jobs[0].start_state, jobs[0].end_state), (None, None));
//! assert_eq!(jobs[0].emit_from, cfg.head);
//! # Ok::<(), tcvd::Error>(())
//! ```

use crate::coding::TerminationMode;
use crate::error::{Error, Result};
use crate::viterbi::tiled::{self, TileConfig};
use crate::viterbi::types::FrameJob;

/// Cuts a pushed LLR stream into fixed-geometry overlapped frames.
#[derive(Debug)]
pub struct Framer {
    cfg: TileConfig,
    beta: usize,
    termination: TerminationMode,
    /// Buffered LLRs starting at stage `buf_start`.
    buf: Vec<f32>,
    buf_start: usize,
    /// Next frame index to emit.
    next_frame: usize,
    /// Total stages pushed so far.
    stages_in: usize,
    finished: bool,
}

impl Framer {
    pub fn new(cfg: TileConfig, beta: usize, termination: TerminationMode) -> Self {
        Framer {
            cfg,
            beta,
            termination,
            buf: Vec::new(),
            buf_start: 0,
            next_frame: 0,
            stages_in: 0,
            finished: false,
        }
    }

    pub fn frames_emitted(&self) -> usize {
        self.next_frame
    }

    /// Coded symbols per trellis stage (chunk alignment unit).
    pub fn beta(&self) -> usize {
        self.beta
    }

    /// The termination mode this framer cuts frames for.
    pub fn termination(&self) -> TerminationMode {
        self.termination
    }

    /// Stage index where frame `fi`'s buffer begins.
    fn frame_start(&self, fi: usize) -> usize {
        (fi * self.cfg.payload).saturating_sub(self.cfg.head)
    }

    /// Push an LLR chunk (`len % beta == 0`); returns all frames that
    /// became complete. Tail-biting streams always return an empty
    /// vector here — their frames wrap around the block end and are all
    /// emitted by [`finish`](Self::finish).
    pub fn push(&mut self, llr: &[f32]) -> Vec<FrameJob> {
        assert!(!self.finished, "push after finish");
        assert_eq!(llr.len() % self.beta, 0, "chunk not stage-aligned");
        self.buf.extend_from_slice(llr);
        self.stages_in += llr.len() / self.beta;
        if self.termination == TerminationMode::TailBiting {
            // circular block: every frame needs the (unknown) block end
            return Vec::new();
        }

        let stages = self.cfg.frame_stages();
        let mut out = Vec::new();
        while self.frame_start(self.next_frame) + stages <= self.stages_in {
            out.push(self.emit(self.next_frame, stages, false, false));
        }
        self.gc();
        out
    }

    /// End of stream: emit the remaining frames. For flushed/truncated
    /// streams the tail is padded with zero (uninformative) LLRs; for a
    /// tail-biting block *all* frames are cut here, circularly extended
    /// around the block, which therefore must contain a whole number of
    /// payload tiles (typed error otherwise).
    pub fn finish(&mut self) -> Result<Vec<FrameJob>> {
        assert!(!self.finished, "finish twice");
        self.finished = true;
        if self.termination == TerminationMode::TailBiting {
            if self.stages_in % self.cfg.payload != 0 {
                return Err(Error::pipeline(format!(
                    "tail-biting block of {} stages is not a multiple of the tile \
                     payload {} (circular framing cannot pad)",
                    self.stages_in, self.cfg.payload
                )));
            }
            debug_assert_eq!(self.buf_start, 0, "tail-biting framer never gcs");
            let jobs = tiled::tail_biting_frames(&self.buf, self.beta, &self.cfg);
            self.next_frame = jobs.len();
            return Ok(jobs);
        }
        let stages = self.cfg.frame_stages();
        let n_frames = self.stages_in.div_ceil(self.cfg.payload);
        let mut out = Vec::new();
        while self.next_frame < n_frames {
            let is_last = self.next_frame + 1 == n_frames;
            let flushed = self.termination == TerminationMode::Flushed;
            out.push(self.emit(self.next_frame, stages, true, is_last && flushed));
        }
        Ok(out)
    }

    fn emit(&mut self, fi: usize, stages: usize, pad: bool, flushed_last: bool) -> FrameJob {
        let pay_start = fi * self.cfg.payload;
        let start = self.frame_start(fi);
        let head = pay_start - start;
        let mut frame = vec![0f32; stages * self.beta];
        let rel = (start - self.buf_start) * self.beta;
        let avail_stages = (self.stages_in - start).min(stages);
        let take = avail_stages * self.beta;
        assert!(pad || take == stages * self.beta);
        frame[..take].copy_from_slice(&self.buf[rel..rel + take]);
        self.next_frame = fi + 1;
        FrameJob {
            llr: frame,
            start_state: if fi == 0 { Some(0) } else { None },
            // only claim the flushed end state when the frame ends
            // exactly at the true stream end (no padding desync)
            end_state: if flushed_last && start + stages == self.stages_in {
                Some(0)
            } else {
                None
            },
            emit_from: head,
            emit_len: self.cfg.payload.min(self.stages_in - pay_start),
        }
    }

    /// Drop buffered stages no future frame needs (never called for
    /// tail-biting streams, whose every frame needs the whole block).
    fn gc(&mut self) {
        let keep_from = self.frame_start(self.next_frame);
        if keep_from > self.buf_start {
            self.buf.drain(..(keep_from - self.buf_start) * self.beta);
            self.buf_start = keep_from;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::viterbi::tiled::make_frames;

    fn cfg() -> TileConfig {
        TileConfig { payload: 32, head: 8, tail: 12 }
    }

    fn random_llrs(n_stages: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n_stages * 2).map(|_| r.next_gaussian() as f32).collect()
    }

    fn assert_jobs_eq(a: &[FrameJob], b: &[FrameJob]) {
        assert_eq!(a.len(), b.len(), "frame count");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.llr, y.llr, "frame {i} llr");
            assert_eq!(x.start_state, y.start_state, "frame {i} start");
            assert_eq!(x.end_state, y.end_state, "frame {i} end");
            assert_eq!(x.emit_from, y.emit_from, "frame {i} emit_from");
            assert_eq!(x.emit_len, y.emit_len, "frame {i} emit_len");
        }
    }

    #[test]
    fn matches_make_frames_whole_push() {
        let llr = random_llrs(128, 1);
        let want = make_frames(&llr, 2, &cfg(), TerminationMode::Flushed).unwrap();
        let mut fr = Framer::new(cfg(), 2, TerminationMode::Flushed);
        let mut got = fr.push(&llr);
        got.extend(fr.finish().unwrap());
        assert_jobs_eq(&got, &want);
    }

    #[test]
    fn matches_make_frames_chunked() {
        let llr = random_llrs(256, 2);
        let want = make_frames(&llr, 2, &cfg(), TerminationMode::Flushed).unwrap();
        for chunk_stages in [1usize, 7, 31, 64] {
            let mut fr = Framer::new(cfg(), 2, TerminationMode::Flushed);
            let mut got = Vec::new();
            for chunk in llr.chunks(chunk_stages * 2) {
                got.extend(fr.push(chunk));
            }
            got.extend(fr.finish().unwrap());
            assert_jobs_eq(&got, &want);
        }
    }

    #[test]
    fn matches_make_frames_truncated() {
        let llr = random_llrs(128, 6);
        let want = make_frames(&llr, 2, &cfg(), TerminationMode::Truncated).unwrap();
        let mut fr = Framer::new(cfg(), 2, TerminationMode::Truncated);
        let mut got = fr.push(&llr);
        got.extend(fr.finish().unwrap());
        assert_jobs_eq(&got, &want);
        assert!(got.iter().all(|j| j.end_state.is_none()));
    }

    #[test]
    fn matches_make_frames_tail_biting_chunked() {
        let llr = random_llrs(128, 9);
        let want = make_frames(&llr, 2, &cfg(), TerminationMode::TailBiting).unwrap();
        for chunk_stages in [1usize, 7, 32, 128] {
            let mut fr = Framer::new(cfg(), 2, TerminationMode::TailBiting);
            for chunk in llr.chunks(chunk_stages * 2) {
                assert!(fr.push(chunk).is_empty(), "tail-biting must defer to finish");
            }
            let got = fr.finish().unwrap();
            assert_jobs_eq(&got, &want);
            assert_eq!(fr.frames_emitted(), want.len());
        }
    }

    #[test]
    fn tail_biting_rejects_partial_tile() {
        let mut fr = Framer::new(cfg(), 2, TerminationMode::TailBiting);
        fr.push(&random_llrs(33, 4)); // 33 stages: not a multiple of 32
        let e = fr.finish().unwrap_err();
        assert!(matches!(e, Error::Pipeline(_)), "{e}");
        assert!(e.to_string().contains("tail-biting"), "{e}");
    }

    #[test]
    fn partial_tail_padded() {
        // 100 stages with payload 32 -> 4 frames, last emits 4 bits
        let llr = random_llrs(100, 3);
        let mut fr = Framer::new(cfg(), 2, TerminationMode::Truncated);
        let mut jobs = fr.push(&llr);
        jobs.extend(fr.finish().unwrap());
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[3].emit_len, 4);
        let total: usize = jobs.iter().map(|j| j.emit_len).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn gc_bounds_memory() {
        let mut fr = Framer::new(cfg(), 2, TerminationMode::Flushed);
        for i in 0..100 {
            fr.push(&random_llrs(64, i));
        }
        // buffer must hold at most ~frame_stages + chunk worth of stages
        assert!(fr.buf.len() <= (fr.cfg.frame_stages() + 64 + fr.cfg.head) * 2,
                "buf len {}", fr.buf.len());
    }

    #[test]
    #[should_panic(expected = "push after finish")]
    fn push_after_finish_panics() {
        let mut fr = Framer::new(cfg(), 2, TerminationMode::Truncated);
        fr.finish().unwrap();
        fr.push(&[0.0, 0.0]);
    }
}
