//! Pipeline metrics: throughput, latency percentiles, batch occupancy,
//! and per-shard counters (queue depth, frames decoded, steal count,
//! survivor-byte high-water mark, forward-throughput EWMA).
//!
//! One [`Metrics`] hub is shared by every pipeline stage; sessions read
//! point-in-time [`MetricsSnapshot`]s through
//! [`Session::metrics`](super::Session::metrics). The global counters
//! aggregate across shards; `shards[i]` isolates engine shard `i`, and
//! the per-shard `frames`/`execs` counters always sum to the global
//! `frames_out`/`execs` once a workload has drained.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::stats::LogHistogram;

/// Counters for one engine shard.
#[derive(Default)]
pub struct ShardStats {
    /// Frames this shard ran the forward pass for.
    pub frames: AtomicU64,
    /// Batched executions this shard launched.
    pub execs: AtomicU64,
    /// Frames this shard stole from sibling queues while idle.
    pub steals: AtomicU64,
    /// Last observed depth of this shard's work queue (gauge).
    pub queue_depth: AtomicU64,
    /// High-water mark of resident survivor bytes this shard
    /// materialized in a single batched execution (gauge; the
    /// memory-model quantity of `docs/MEMORY.md` — depends on the
    /// backend's survivor layout and the frame geometry).
    pub survivor_bytes: AtomicU64,
    /// EWMA of this shard's forward-pass throughput in Mb/s of emitted
    /// payload bits (gauge; f64 stored as bits, smoothing factor
    /// [`THROUGHPUT_EWMA_ALPHA`]). Written only by the owning engine
    /// thread via [`Metrics::record_exec`], so the read-modify-write
    /// needs no CAS loop.
    pub throughput_mbps: AtomicU64,
    /// Panics this shard's exec loop was caught and recovered from
    /// (see `docs/RELIABILITY.md`).
    pub panics: AtomicU64,
    /// Supervisor restarts of this shard (each panic within the
    /// restart budget costs one).
    pub restarts: AtomicU64,
    /// Degradation steps this shard's backend has taken down the
    /// fallback chain (simd radix-2 → simd → compact → scalar).
    pub degraded: AtomicU64,
    /// Restart backoff this shard is currently sleeping, in
    /// milliseconds (gauge; 0 while serving).
    pub backoff_ms: AtomicU64,
}

/// Smoothing factor of the per-shard `throughput_mbps` EWMA gauge: the
/// weight of the newest batched execution.
pub const THROUGHPUT_EWMA_ALPHA: f64 = 0.2;

/// Counters for the socket serving front-end (`tcvd::net`). All zero
/// for pipelines that never attach a network server.
#[derive(Default)]
pub struct NetStats {
    /// Network sessions admitted (TCP handshakes accepted + new UDP
    /// flows observed).
    pub sessions_accepted: AtomicU64,
    /// Sessions evicted: idle timeouts, dirty disconnects, per-session
    /// protocol errors.
    pub sessions_evicted: AtomicU64,
    /// Sessions load-shed at admission (session cap reached or shard
    /// queues saturated).
    pub sessions_shed: AtomicU64,
    /// Individual UDP blocks shed on an already-admitted flow because
    /// the shard queues were saturated when the datagram arrived.
    pub blocks_shed: AtomicU64,
    /// TCP handshakes rejected for a config mismatch (client asked for
    /// a code/backend/termination/tile the server does not run).
    pub handshake_rejects: AtomicU64,
    /// Wire bytes received (frame headers + payloads, UDP datagrams).
    pub bytes_in: AtomicU64,
    /// Wire bytes sent.
    pub bytes_out: AtomicU64,
    /// Fds the TCP reactor registered on its last poll tick (gauge:
    /// listener + live connections).
    pub reactor_fds: AtomicU64,
    /// Poll wakeups of the TCP reactor (readiness or tick timeout).
    pub reactor_wakeups: AtomicU64,
    /// High-water mark of one connection's buffered outbound bytes
    /// (gauge; bounded by `net.write_high_water` plus one frame).
    pub write_buf_hwm: AtomicU64,
    /// Transient `accept()` failures on the TCP listener (EMFILE,
    /// ECONNABORTED, ...). The reactor retries on its next tick; this
    /// counter is how operators see it happening.
    pub accept_errors: AtomicU64,
    /// The reactor's live poller backend (gauge; a [`poller_code`]
    /// value, `NONE` until a reactor attaches).
    pub poller: AtomicU64,
    /// Ready fds delivered across all reactor wakeups. On the epoll
    /// backend a wakeup costs O(ready), so `reactor_ready_events /
    /// reactor_wakeups` staying far below `reactor_fds` is the
    /// kernel-event headroom made visible.
    pub reactor_ready_events: AtomicU64,
    /// Batched UDP reply flushes (one `sendmmsg`-style syscall each).
    pub udp_batched_sends: AtomicU64,
    /// Reply datagrams that left through a batched flush.
    pub udp_batch_datagrams: AtomicU64,
    /// Reply datagrams sent one `send_to` at a time because the batched
    /// syscall is unavailable on this platform/kernel (the runtime
    /// gate latched off).
    pub udp_send_fallbacks: AtomicU64,
}

/// Wire codes of the [`NetStats::poller`] gauge. The metrics JSON
/// reports the name ([`poller_code::name`]), not the raw code.
pub mod poller_code {
    /// No reactor has attached (or the server is UDP-only).
    pub const NONE: u64 = 0;
    /// The portable `poll(2)` backend.
    pub const POLL: u64 = 1;
    /// The Linux `epoll` backend.
    pub const EPOLL: u64 = 2;
    /// The non-unix degraded backend (everything ready every tick).
    pub const FALLBACK: u64 = 3;

    /// The knob-style name of a poller code.
    pub fn name(code: u64) -> &'static str {
        match code {
            POLL => "poll",
            EPOLL => "epoll",
            FALLBACK => "fallback",
            _ => "none",
        }
    }
}

/// Shared metrics hub (updated by every pipeline stage).
pub struct Metrics {
    start: Instant,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub bits_out: AtomicU64,
    pub execs: AtomicU64,
    pub exec_frames: AtomicU64,
    pub forward_ns: AtomicU64,
    pub traceback_ns: AtomicU64,
    shards: Vec<ShardStats>,
    /// Engine shard panics caught by the supervisor, across all shards.
    pub shard_panics: AtomicU64,
    /// Supervisor shard restarts, across all shards.
    pub shard_restarts: AtomicU64,
    /// Backend degradation steps taken, across all shards.
    pub degradations: AtomicU64,
    /// Sessions poisoned by a shard fault: each received its gapless
    /// decoded prefix followed by exactly one typed error.
    pub sessions_poisoned: AtomicU64,
    /// Socket front-end counters (see [`NetStats`]).
    pub net: NetStats,
    latency: Mutex<LogHistogram>,
    occupancy: Mutex<LogHistogram>,
    net_latency: Mutex<LogHistogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new(1)
    }
}

impl Metrics {
    /// A metrics hub for a pipeline with `n_shards` engine shards.
    pub fn new(n_shards: usize) -> Self {
        Metrics {
            start: Instant::now(),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            bits_out: AtomicU64::new(0),
            execs: AtomicU64::new(0),
            exec_frames: AtomicU64::new(0),
            forward_ns: AtomicU64::new(0),
            traceback_ns: AtomicU64::new(0),
            shards: (0..n_shards.max(1)).map(|_| ShardStats::default()).collect(),
            shard_panics: AtomicU64::new(0),
            shard_restarts: AtomicU64::new(0),
            degradations: AtomicU64::new(0),
            sessions_poisoned: AtomicU64::new(0),
            net: NetStats::default(),
            latency: Mutex::new(LogHistogram::new()),
            occupancy: Mutex::new(LogHistogram::new()),
            net_latency: Mutex::new(LogHistogram::new()),
        }
    }

    /// The counters of engine shard `i`.
    pub fn shard(&self, i: usize) -> &ShardStats {
        &self.shards[i]
    }

    /// Record one batched execution by shard `shard` covering `frames`
    /// frames whose forward pass materialized `survivor_bytes` of
    /// survivor storage and will emit `bits` payload bits.
    pub fn record_exec(&self, shard: usize, frames: usize, forward_ns: u64,
                       survivor_bytes: usize, bits: usize) {
        self.execs.fetch_add(1, Ordering::Relaxed);
        self.exec_frames.fetch_add(frames as u64, Ordering::Relaxed);
        self.forward_ns.fetch_add(forward_ns, Ordering::Relaxed);
        let s = &self.shards[shard];
        s.execs.fetch_add(1, Ordering::Relaxed);
        s.frames.fetch_add(frames as u64, Ordering::Relaxed);
        s.survivor_bytes.fetch_max(survivor_bytes as u64, Ordering::Relaxed);
        if forward_ns > 0 && bits > 0 {
            // Mb/s = bits / (ns * 1e-9) / 1e6 = bits * 1000 / ns
            let inst = bits as f64 * 1000.0 / forward_ns as f64;
            let prev = f64::from_bits(s.throughput_mbps.load(Ordering::Relaxed));
            let next = if prev == 0.0 {
                inst
            } else {
                THROUGHPUT_EWMA_ALPHA * inst + (1.0 - THROUGHPUT_EWMA_ALPHA) * prev
            };
            s.throughput_mbps.store(next.to_bits(), Ordering::Relaxed);
        }
        self.occupancy.lock().unwrap().record(frames as u64);
    }

    /// Record one completed network block/stream decode: the wall time
    /// from the client's end-of-stream to the last decoded byte on the
    /// wire (the per-session latency quantity of `docs/NETWORKING.md`).
    pub fn record_net_block(&self, latency: std::time::Duration) {
        self.net_latency.lock().unwrap().record(latency.as_nanos() as u64);
    }

    /// Sum of the per-shard queue-depth gauges: the admission signal
    /// the net front-end sheds load on when it exceeds the configured
    /// threshold.
    pub fn queue_depth_total(&self) -> u64 {
        self.shards.iter().map(|s| s.queue_depth.load(Ordering::Relaxed)).sum()
    }

    /// Record one decoded frame delivered to the reassembler.
    pub fn record_delivery(&self, bits: usize, enq: Instant, traceback_ns: u64) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        self.bits_out.fetch_add(bits as u64, Ordering::Relaxed);
        self.traceback_ns.fetch_add(traceback_ns, Ordering::Relaxed);
        self.latency.lock().unwrap().record(enq.elapsed().as_nanos() as u64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let elapsed = self.start.elapsed().as_secs_f64();
        let bits = self.bits_out.load(Ordering::Relaxed);
        let execs = self.execs.load(Ordering::Relaxed).max(1);
        let lat = self.latency.lock().unwrap();
        let net_lat = self.net_latency.lock().unwrap();
        MetricsSnapshot {
            elapsed_s: elapsed,
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            bits_out: bits,
            throughput_bps: bits as f64 / elapsed.max(1e-9),
            execs,
            mean_batch: self.exec_frames.load(Ordering::Relaxed) as f64 / execs as f64,
            forward_ns_total: self.forward_ns.load(Ordering::Relaxed),
            traceback_ns_total: self.traceback_ns.load(Ordering::Relaxed),
            latency_p50_us: lat.percentile(50.0) as f64 / 1e3,
            latency_p99_us: lat.percentile(99.0) as f64 / 1e3,
            shards: self
                .shards
                .iter()
                .map(|s| ShardSnapshot {
                    frames: s.frames.load(Ordering::Relaxed),
                    execs: s.execs.load(Ordering::Relaxed),
                    steals: s.steals.load(Ordering::Relaxed),
                    queue_depth: s.queue_depth.load(Ordering::Relaxed),
                    survivor_bytes: s.survivor_bytes.load(Ordering::Relaxed),
                    throughput_mbps: f64::from_bits(s.throughput_mbps.load(Ordering::Relaxed)),
                    panics: s.panics.load(Ordering::Relaxed),
                    restarts: s.restarts.load(Ordering::Relaxed),
                    degraded: s.degraded.load(Ordering::Relaxed),
                    backoff_ms: s.backoff_ms.load(Ordering::Relaxed),
                })
                .collect(),
            shard_panics: self.shard_panics.load(Ordering::Relaxed),
            shard_restarts: self.shard_restarts.load(Ordering::Relaxed),
            degradations: self.degradations.load(Ordering::Relaxed),
            sessions_poisoned: self.sessions_poisoned.load(Ordering::Relaxed),
            net: NetSnapshot {
                sessions_accepted: self.net.sessions_accepted.load(Ordering::Relaxed),
                sessions_evicted: self.net.sessions_evicted.load(Ordering::Relaxed),
                sessions_shed: self.net.sessions_shed.load(Ordering::Relaxed),
                blocks_shed: self.net.blocks_shed.load(Ordering::Relaxed),
                handshake_rejects: self.net.handshake_rejects.load(Ordering::Relaxed),
                bytes_in: self.net.bytes_in.load(Ordering::Relaxed),
                bytes_out: self.net.bytes_out.load(Ordering::Relaxed),
                reactor_fds: self.net.reactor_fds.load(Ordering::Relaxed),
                reactor_wakeups: self.net.reactor_wakeups.load(Ordering::Relaxed),
                write_buf_hwm: self.net.write_buf_hwm.load(Ordering::Relaxed),
                accept_errors: self.net.accept_errors.load(Ordering::Relaxed),
                poller: poller_code::name(self.net.poller.load(Ordering::Relaxed)),
                reactor_ready_events: self.net.reactor_ready_events.load(Ordering::Relaxed),
                udp_batched_sends: self.net.udp_batched_sends.load(Ordering::Relaxed),
                udp_batch_datagrams: self.net.udp_batch_datagrams.load(Ordering::Relaxed),
                udp_send_fallbacks: self.net.udp_send_fallbacks.load(Ordering::Relaxed),
                blocks: net_lat.count(),
                block_p50_us: net_lat.percentile(50.0) as f64 / 1e3,
                block_p99_us: net_lat.percentile(99.0) as f64 / 1e3,
            },
        }
    }
}

/// Point-in-time view of one engine shard's counters.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    /// Frames this shard ran the forward pass for.
    pub frames: u64,
    /// Batched executions this shard launched.
    pub execs: u64,
    /// Frames this shard stole from sibling queues while idle.
    pub steals: u64,
    /// Last observed depth of this shard's work queue.
    pub queue_depth: u64,
    /// High-water mark of resident survivor bytes from one batched
    /// execution (see `docs/MEMORY.md` for the per-layout formulas).
    pub survivor_bytes: u64,
    /// EWMA of this shard's forward-pass throughput in Mb/s of payload
    /// bits (0 until the shard has executed; see
    /// [`THROUGHPUT_EWMA_ALPHA`]).
    pub throughput_mbps: f64,
    /// Panics this shard's exec loop recovered from.
    pub panics: u64,
    /// Supervisor restarts of this shard.
    pub restarts: u64,
    /// Degradation steps this shard's backend has taken.
    pub degraded: u64,
    /// Restart backoff currently being slept (ms; 0 while serving).
    pub backoff_ms: u64,
}

/// A point-in-time view of the metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub elapsed_s: f64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub bits_out: u64,
    pub throughput_bps: f64,
    pub execs: u64,
    pub mean_batch: f64,
    pub forward_ns_total: u64,
    pub traceback_ns_total: u64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    /// Per-shard counters, indexed by shard id.
    pub shards: Vec<ShardSnapshot>,
    /// Engine shard panics caught by the supervisor (all shards).
    pub shard_panics: u64,
    /// Supervisor shard restarts (all shards).
    pub shard_restarts: u64,
    /// Backend degradation steps taken (all shards).
    pub degradations: u64,
    /// Sessions poisoned by shard faults (gapless prefix + one typed
    /// error each).
    pub sessions_poisoned: u64,
    /// Socket front-end counters (all zero without a network server).
    pub net: NetSnapshot,
}

/// Point-in-time view of the socket front-end counters.
#[derive(Clone, Debug, Default)]
pub struct NetSnapshot {
    /// Network sessions admitted (TCP + new UDP flows).
    pub sessions_accepted: u64,
    /// Sessions evicted (idle timeout, dirty disconnect, protocol error).
    pub sessions_evicted: u64,
    /// Sessions load-shed at admission (cap or queue saturation).
    pub sessions_shed: u64,
    /// UDP blocks shed on admitted flows under queue saturation.
    pub blocks_shed: u64,
    /// TCP handshakes rejected for a config mismatch.
    pub handshake_rejects: u64,
    /// Wire bytes received.
    pub bytes_in: u64,
    /// Wire bytes sent.
    pub bytes_out: u64,
    /// Fds registered on the TCP reactor's last poll tick.
    pub reactor_fds: u64,
    /// TCP reactor poll wakeups.
    pub reactor_wakeups: u64,
    /// Peak buffered outbound bytes of any one connection.
    pub write_buf_hwm: u64,
    /// Transient TCP `accept()` failures (retried next tick).
    pub accept_errors: u64,
    /// The reactor's live poller backend name (`"none"` until a
    /// reactor attaches; see [`poller_code`]).
    pub poller: &'static str,
    /// Ready fds delivered across all reactor wakeups.
    pub reactor_ready_events: u64,
    /// Batched UDP reply flushes (syscalls).
    pub udp_batched_sends: u64,
    /// Reply datagrams sent through batched flushes.
    pub udp_batch_datagrams: u64,
    /// Reply datagrams that fell back to one `send_to` each.
    pub udp_send_fallbacks: u64,
    /// Completed network block/stream decodes measured for latency.
    pub blocks: u64,
    /// p50 of end-of-stream -> last-byte-delivered latency (us).
    pub block_p50_us: f64,
    /// p99 of end-of-stream -> last-byte-delivered latency (us).
    pub block_p99_us: f64,
}

impl NetSnapshot {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("sessions_accepted", json::num(self.sessions_accepted as f64)),
            ("sessions_evicted", json::num(self.sessions_evicted as f64)),
            ("sessions_shed", json::num(self.sessions_shed as f64)),
            ("blocks_shed", json::num(self.blocks_shed as f64)),
            ("handshake_rejects", json::num(self.handshake_rejects as f64)),
            ("bytes_in", json::num(self.bytes_in as f64)),
            ("bytes_out", json::num(self.bytes_out as f64)),
            ("reactor_fds", json::num(self.reactor_fds as f64)),
            ("reactor_wakeups", json::num(self.reactor_wakeups as f64)),
            ("write_buf_hwm", json::num(self.write_buf_hwm as f64)),
            ("accept_errors", json::num(self.accept_errors as f64)),
            ("poller", Json::Str(self.poller.to_string())),
            ("reactor_ready_events", json::num(self.reactor_ready_events as f64)),
            ("udp_batched_sends", json::num(self.udp_batched_sends as f64)),
            ("udp_batch_datagrams", json::num(self.udp_batch_datagrams as f64)),
            ("udp_send_fallbacks", json::num(self.udp_send_fallbacks as f64)),
            ("blocks", json::num(self.blocks as f64)),
            ("block_p50_us", json::num(self.block_p50_us)),
            ("block_p99_us", json::num(self.block_p99_us)),
        ])
    }
}

impl MetricsSnapshot {
    /// Total frames stolen across all shards.
    pub fn steals_total(&self) -> u64 {
        self.shards.iter().map(|s| s.steals).sum()
    }

    /// Peak single-batch survivor bytes across all shards (the
    /// `docs/MEMORY.md` budget quantity, as actually observed).
    pub fn survivor_bytes_peak(&self) -> u64 {
        self.shards.iter().map(|s| s.survivor_bytes).max().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("elapsed_s", json::num(self.elapsed_s)),
            ("frames_in", json::num(self.frames_in as f64)),
            ("frames_out", json::num(self.frames_out as f64)),
            ("bits_out", json::num(self.bits_out as f64)),
            ("throughput_bps", json::num(self.throughput_bps)),
            ("execs", json::num(self.execs as f64)),
            ("mean_batch", json::num(self.mean_batch)),
            ("forward_ns_total", json::num(self.forward_ns_total as f64)),
            ("traceback_ns_total", json::num(self.traceback_ns_total as f64)),
            ("latency_p50_us", json::num(self.latency_p50_us)),
            ("latency_p99_us", json::num(self.latency_p99_us)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            json::obj(vec![
                                ("frames", json::num(s.frames as f64)),
                                ("execs", json::num(s.execs as f64)),
                                ("steals", json::num(s.steals as f64)),
                                ("queue_depth", json::num(s.queue_depth as f64)),
                                ("survivor_bytes", json::num(s.survivor_bytes as f64)),
                                ("throughput_mbps", json::num(s.throughput_mbps)),
                                ("panics", json::num(s.panics as f64)),
                                ("restarts", json::num(s.restarts as f64)),
                                ("degraded", json::num(s.degraded as f64)),
                                ("backoff_ms", json::num(s.backoff_ms as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("shard_panics", json::num(self.shard_panics as f64)),
            ("shard_restarts", json::num(self.shard_restarts as f64)),
            ("degradations", json::num(self.degradations as f64)),
            ("sessions_poisoned", json::num(self.sessions_poisoned as f64)),
            ("net", self.net.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = Metrics::new(2);
        m.record_exec(0, 8, 1000, 8192, 512);
        m.record_exec(1, 4, 1000, 4096, 256);
        let t = Instant::now();
        m.record_delivery(64, t, 500);
        m.record_delivery(64, t, 500);
        let s = m.snapshot();
        assert_eq!(s.execs, 2);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert_eq!(s.bits_out, 128);
        assert_eq!(s.frames_out, 2);
        assert!(s.throughput_bps > 0.0);
        let j = s.to_json().to_string_pretty();
        assert!(j.contains("throughput_bps"));
        assert!(j.contains("steals"));
        assert!(j.contains("survivor_bytes"));
        assert!(j.contains("throughput_mbps"));
    }

    #[test]
    fn survivor_bytes_gauge_is_a_high_water_mark() {
        let m = Metrics::new(2);
        m.record_exec(0, 4, 10, 4096, 64);
        m.record_exec(0, 8, 10, 8192, 128);
        m.record_exec(0, 2, 10, 2048, 32); // smaller batch must not lower the peak
        m.record_exec(1, 1, 10, 1024, 16);
        let s = m.snapshot();
        assert_eq!(s.shards[0].survivor_bytes, 8192);
        assert_eq!(s.shards[1].survivor_bytes, 1024);
        assert_eq!(s.survivor_bytes_peak(), 8192);
    }

    #[test]
    fn throughput_gauge_is_an_ewma_of_exec_rates() {
        let m = Metrics::new(2);
        // 1000 bits in 1000 ns = 1000 Mb/s exactly
        m.record_exec(0, 1, 1000, 0, 1000);
        let s = m.snapshot();
        assert!((s.shards[0].throughput_mbps - 1000.0).abs() < 1e-9, "first exec seeds the EWMA");
        assert_eq!(s.shards[1].throughput_mbps, 0.0, "idle shard reports 0");
        // second exec at 2000 Mb/s blends in with weight alpha
        m.record_exec(0, 1, 1000, 0, 2000);
        let want = THROUGHPUT_EWMA_ALPHA * 2000.0 + (1.0 - THROUGHPUT_EWMA_ALPHA) * 1000.0;
        let s = m.snapshot();
        assert!((s.shards[0].throughput_mbps - want).abs() < 1e-9, "EWMA blend");
        // zero-duration / zero-bit execs must not poison the gauge
        m.record_exec(0, 1, 0, 0, 100);
        m.record_exec(0, 1, 100, 0, 0);
        assert!((m.snapshot().shards[0].throughput_mbps - want).abs() < 1e-9);
    }

    #[test]
    fn shard_counters_isolate_and_sum() {
        let m = Metrics::new(3);
        m.record_exec(0, 5, 10, 0, 0);
        m.record_exec(2, 3, 10, 0, 0);
        m.shard(2).steals.fetch_add(2, Ordering::Relaxed);
        m.shard(1).queue_depth.store(7, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shards.len(), 3);
        assert_eq!(s.shards[0].frames, 5);
        assert_eq!(s.shards[1].frames, 0);
        assert_eq!(s.shards[2].frames, 3);
        assert_eq!(s.shards[1].queue_depth, 7);
        assert_eq!(s.steals_total(), 2);
        let shard_frames: u64 = s.shards.iter().map(|sh| sh.frames).sum();
        assert_eq!(shard_frames, 8);
        let shard_execs: u64 = s.shards.iter().map(|sh| sh.execs).sum();
        assert_eq!(shard_execs, s.execs);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let m = Metrics::new(0);
        assert_eq!(m.snapshot().shards.len(), 1);
    }

    #[test]
    fn net_counters_snapshot_and_serialize() {
        let m = Metrics::new(2);
        m.net.sessions_accepted.fetch_add(3, Ordering::Relaxed);
        m.net.sessions_evicted.fetch_add(1, Ordering::Relaxed);
        m.net.sessions_shed.fetch_add(2, Ordering::Relaxed);
        m.net.bytes_in.fetch_add(100, Ordering::Relaxed);
        m.net.reactor_fds.store(5, Ordering::Relaxed);
        m.net.reactor_wakeups.fetch_add(12, Ordering::Relaxed);
        m.net.write_buf_hwm.fetch_max(4096, Ordering::Relaxed);
        m.net.write_buf_hwm.fetch_max(1024, Ordering::Relaxed); // hwm never lowers
        m.net.poller.store(poller_code::EPOLL, Ordering::Relaxed);
        m.net.reactor_ready_events.fetch_add(9, Ordering::Relaxed);
        m.net.udp_batched_sends.fetch_add(4, Ordering::Relaxed);
        m.net.udp_batch_datagrams.fetch_add(17, Ordering::Relaxed);
        m.net.udp_send_fallbacks.fetch_add(2, Ordering::Relaxed);
        m.record_net_block(std::time::Duration::from_micros(500));
        m.record_net_block(std::time::Duration::from_micros(700));
        let s = m.snapshot();
        assert_eq!(s.net.sessions_accepted, 3);
        assert_eq!(s.net.sessions_evicted, 1);
        assert_eq!(s.net.sessions_shed, 2);
        assert_eq!(s.net.reactor_fds, 5);
        assert_eq!(s.net.reactor_wakeups, 12);
        assert_eq!(s.net.write_buf_hwm, 4096);
        assert_eq!(s.net.poller, "epoll");
        assert_eq!(s.net.reactor_ready_events, 9);
        assert_eq!(s.net.udp_batched_sends, 4);
        assert_eq!(s.net.udp_batch_datagrams, 17);
        assert_eq!(s.net.udp_send_fallbacks, 2);
        assert_eq!(s.net.blocks, 2);
        assert!(s.net.block_p50_us >= 400.0 && s.net.block_p99_us <= 800.0,
                "p50={} p99={}", s.net.block_p50_us, s.net.block_p99_us);
        let j = s.to_json().to_string_pretty();
        assert!(j.contains("sessions_accepted"));
        assert!(j.contains("reactor_wakeups"));
        assert!(j.contains("write_buf_hwm"));
        assert!(j.contains("block_p99_us"));
        for key in ["poller", "reactor_ready_events", "udp_batched_sends",
                    "udp_batch_datagrams", "udp_send_fallbacks"] {
            assert!(j.contains(key), "snapshot JSON is missing {key}");
        }
        assert!(j.contains("\"epoll\""), "poller gauge serializes by name");
    }

    #[test]
    fn poller_codes_name_every_backend() {
        assert_eq!(poller_code::name(poller_code::NONE), "none");
        assert_eq!(poller_code::name(poller_code::POLL), "poll");
        assert_eq!(poller_code::name(poller_code::EPOLL), "epoll");
        assert_eq!(poller_code::name(poller_code::FALLBACK), "fallback");
        assert_eq!(poller_code::name(99), "none", "unknown codes read as none");
    }

    #[test]
    fn supervision_counters_snapshot_and_serialize() {
        let m = Metrics::new(2);
        m.shard_panics.fetch_add(3, Ordering::Relaxed);
        m.shard_restarts.fetch_add(2, Ordering::Relaxed);
        m.degradations.fetch_add(1, Ordering::Relaxed);
        m.sessions_poisoned.fetch_add(4, Ordering::Relaxed);
        m.shard(1).panics.fetch_add(3, Ordering::Relaxed);
        m.shard(1).restarts.fetch_add(2, Ordering::Relaxed);
        m.shard(1).degraded.fetch_add(1, Ordering::Relaxed);
        m.shard(1).backoff_ms.store(40, Ordering::Relaxed);
        m.net.accept_errors.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shard_panics, 3);
        assert_eq!(s.shard_restarts, 2);
        assert_eq!(s.degradations, 1);
        assert_eq!(s.sessions_poisoned, 4);
        assert_eq!(s.shards[0].panics, 0);
        assert_eq!(s.shards[1].panics, 3);
        assert_eq!(s.shards[1].restarts, 2);
        assert_eq!(s.shards[1].degraded, 1);
        assert_eq!(s.shards[1].backoff_ms, 40);
        assert_eq!(s.net.accept_errors, 5);
        let j = s.to_json().to_string_pretty();
        for key in ["shard_panics", "shard_restarts", "degradations", "sessions_poisoned",
                    "backoff_ms", "accept_errors"] {
            assert!(j.contains(key), "snapshot JSON is missing {key}");
        }
    }

    #[test]
    fn queue_depth_total_sums_gauges() {
        let m = Metrics::new(3);
        m.shard(0).queue_depth.store(4, Ordering::Relaxed);
        m.shard(2).queue_depth.store(6, Ordering::Relaxed);
        assert_eq!(m.queue_depth_total(), 10);
    }
}
