//! Pipeline metrics: throughput, latency percentiles, batch occupancy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::stats::LogHistogram;

/// Shared metrics hub (updated by every pipeline stage).
pub struct Metrics {
    start: Instant,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub bits_out: AtomicU64,
    pub execs: AtomicU64,
    pub exec_frames: AtomicU64,
    pub forward_ns: AtomicU64,
    pub traceback_ns: AtomicU64,
    latency: Mutex<LogHistogram>,
    occupancy: Mutex<LogHistogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            start: Instant::now(),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            bits_out: AtomicU64::new(0),
            execs: AtomicU64::new(0),
            exec_frames: AtomicU64::new(0),
            forward_ns: AtomicU64::new(0),
            traceback_ns: AtomicU64::new(0),
            latency: Mutex::new(LogHistogram::new()),
            occupancy: Mutex::new(LogHistogram::new()),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_exec(&self, frames: usize, forward_ns: u64) {
        self.execs.fetch_add(1, Ordering::Relaxed);
        self.exec_frames.fetch_add(frames as u64, Ordering::Relaxed);
        self.forward_ns.fetch_add(forward_ns, Ordering::Relaxed);
        self.occupancy.lock().unwrap().record(frames as u64);
    }

    pub fn record_delivery(&self, bits: usize, enq: Instant, traceback_ns: u64) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        self.bits_out.fetch_add(bits as u64, Ordering::Relaxed);
        self.traceback_ns.fetch_add(traceback_ns, Ordering::Relaxed);
        self.latency.lock().unwrap().record(enq.elapsed().as_nanos() as u64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let elapsed = self.start.elapsed().as_secs_f64();
        let bits = self.bits_out.load(Ordering::Relaxed);
        let execs = self.execs.load(Ordering::Relaxed).max(1);
        let lat = self.latency.lock().unwrap();
        MetricsSnapshot {
            elapsed_s: elapsed,
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            bits_out: bits,
            throughput_bps: bits as f64 / elapsed.max(1e-9),
            execs,
            mean_batch: self.exec_frames.load(Ordering::Relaxed) as f64 / execs as f64,
            forward_ns_total: self.forward_ns.load(Ordering::Relaxed),
            traceback_ns_total: self.traceback_ns.load(Ordering::Relaxed),
            latency_p50_us: lat.percentile(50.0) as f64 / 1e3,
            latency_p99_us: lat.percentile(99.0) as f64 / 1e3,
        }
    }
}

/// A point-in-time view of the metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub elapsed_s: f64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub bits_out: u64,
    pub throughput_bps: f64,
    pub execs: u64,
    pub mean_batch: f64,
    pub forward_ns_total: u64,
    pub traceback_ns_total: u64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("elapsed_s", json::num(self.elapsed_s)),
            ("frames_in", json::num(self.frames_in as f64)),
            ("frames_out", json::num(self.frames_out as f64)),
            ("bits_out", json::num(self.bits_out as f64)),
            ("throughput_bps", json::num(self.throughput_bps)),
            ("execs", json::num(self.execs as f64)),
            ("mean_batch", json::num(self.mean_batch)),
            ("forward_ns_total", json::num(self.forward_ns_total as f64)),
            ("traceback_ns_total", json::num(self.traceback_ns_total as f64)),
            ("latency_p50_us", json::num(self.latency_p50_us)),
            ("latency_p99_us", json::num(self.latency_p99_us)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = Metrics::new();
        m.record_exec(8, 1000);
        m.record_exec(4, 1000);
        let t = Instant::now();
        m.record_delivery(64, t, 500);
        m.record_delivery(64, t, 500);
        let s = m.snapshot();
        assert_eq!(s.execs, 2);
        assert!((s.mean_batch - 6.0).abs() < 1e-9);
        assert_eq!(s.bits_out, 128);
        assert_eq!(s.frames_out, 2);
        assert!(s.throughput_bps > 0.0);
        let j = s.to_json().to_string_pretty();
        assert!(j.contains("throughput_bps"));
    }
}
