//! The execution engine shards: dynamic batching + the tensor forward
//! pass. Each shard thread owns one (non-`Send`) backend instance —
//! serializing launches exactly like a CUDA stream — pulls frames from
//! its own work queue (stealing from siblings when idle), and ships raw
//! survivors to the shared traceback worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::trellis::Trellis;
use crate::error::Result;
use crate::util::queue::Queue;
use crate::viterbi::types::RawFrame;

use super::backend::BackendSpec;
use super::metrics::Metrics;
use super::shard::{self, Pop, ShardQueue};
use super::{DecodedFrame, FrameTask};

/// How often an idle shard re-scans sibling queues for stealable work
/// (tuned in one place: [`crate::defaults::STEAL_POLL_US`]).
pub const STEAL_POLL: Duration = Duration::from_micros(crate::defaults::STEAL_POLL_US);

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max frames per execution (clamped to the backend's max batch).
    pub max_batch: usize,
    /// How long to wait for more frames after the first arrives.
    pub deadline: Duration,
}

/// A forwarded frame awaiting traceback.
pub struct RawTask {
    pub task: FrameTask,
    pub raw: RawFrame,
}

/// Run one engine shard loop (call from a dedicated thread).
///
/// Builds the backend *inside* the thread (PJRT executables are not
/// `Send`), signals readiness — or a startup error — through `ready`,
/// then batches its queue (`queues[shard_idx]`) into executions until
/// the dispatcher closes every shard queue. An idle shard steals the
/// oldest frame from the deepest sibling queue rather than sleeping.
/// The last shard to exit closes the raw-survivor queue so the shared
/// traceback pool winds down; `live` counts the shards still running.
pub fn run_engine_shard(
    shard_idx: usize,
    spec: BackendSpec,
    policy: BatchPolicy,
    queues: Arc<Vec<ShardQueue>>,
    out: Arc<Queue<RawTask>>,
    live: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
    ready: SyncSender<Result<(usize, Arc<Trellis>)>>, // (frame_stages, trellis)
) {
    let mut dec = match spec.build() {
        Ok(d) => {
            let _ = ready.send(Ok((d.frame_stages(), d.trellis().clone())));
            d
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            if live.fetch_sub(1, Ordering::AcqRel) == 1 {
                out.close();
            }
            return;
        }
    };
    let own = &queues[shard_idx];
    let stats = metrics.shard(shard_idx);
    let max_batch = policy.max_batch.min(dec.max_batch()).max(1);
    let mut batch: Vec<FrameTask> = Vec::with_capacity(max_batch);

    'serve: loop {
        // acquire the first frame of the batch: own queue first, else
        // steal from the deepest sibling (work-stealing for idle shards)
        let first = loop {
            match own.pop_timeout(STEAL_POLL) {
                Pop::Item(t) => break t,
                Pop::Closed => break 'serve, // shutdown: all queues drain
                Pop::Timeout => {
                    if let Some(t) = shard::steal(&queues, shard_idx) {
                        stats.steals.fetch_add(1, Ordering::Relaxed);
                        break t;
                    }
                }
            }
        };
        let t0 = Instant::now();
        batch.push(first);
        // fill from the own queue until full or deadline
        while batch.len() < max_batch {
            match policy.deadline.checked_sub(t0.elapsed()) {
                None => break,
                Some(left) => match own.pop_timeout(left) {
                    Pop::Item(t) => batch.push(t),
                    Pop::Timeout | Pop::Closed => break,
                },
            }
        }
        // execute the forward pass
        let jobs: Vec<_> = batch.iter().map(|t| t.job.clone()).collect();
        let bits: usize = jobs.iter().map(|j| j.emit_len).sum();
        let fwd_start = Instant::now();
        let raws = dec.forward_batch(&jobs);
        let surv_bytes: usize = raws.iter().map(|r| r.surv.bytes()).sum();
        metrics.record_exec(shard_idx, batch.len(), fwd_start.elapsed().as_nanos() as u64,
                            surv_bytes, bits);
        stats.queue_depth.store(own.len() as u64, Ordering::Relaxed);
        for (task, raw) in batch.drain(..).zip(raws) {
            if !out.push(RawTask { task, raw }) {
                break 'serve; // downstream gone
            }
        }
    }
    if live.fetch_sub(1, Ordering::AcqRel) == 1 {
        out.close(); // every shard drained: let the traceback pool wind down
    }
}

/// Run a traceback worker loop (call from worker threads). Pulls raw
/// frames from the queue shared by all engine shards, runs Alg 2, and
/// emits decoded frames to the reassembler.
pub fn run_traceback_worker(
    trellis: Arc<Trellis>,
    rx: Arc<Queue<RawTask>>,
    out: Sender<super::reassembly::Msg>,
    metrics: Arc<Metrics>,
) {
    loop {
        let RawTask { task, raw } = match rx.pop() {
            Some(x) => x,
            None => return,
        };
        let t0 = Instant::now();
        let bits = raw.traceback(&trellis, &task.job);
        let tb_ns = t0.elapsed().as_nanos() as u64;
        metrics.record_delivery(bits.len(), task.t_enq, tb_ns);
        let df = DecodedFrame { session: task.session, seq: task.seq, bits, t_enq: task.t_enq };
        if out.send(super::reassembly::Msg::Decoded(df)).is_err() {
            return;
        }
    }
}
