//! The execution engine shards: dynamic batching + the tensor forward
//! pass. Each shard thread owns one (non-`Send`) backend instance —
//! serializing launches exactly like a CUDA stream — pulls frames from
//! its own work queue (stealing from siblings when idle), and ships raw
//! survivors to the shared traceback worker pool.
//!
//! Shard threads are *supervised* (`docs/RELIABILITY.md`): the exec
//! loop runs under `catch_unwind`, so a panic in one backend poisons
//! only the sessions whose frames were in the panicking batch (each
//! gets its gapless prefix plus one typed, retryable error through
//! reassembly) and the shard restarts with exponential backoff. After
//! [`Supervision::degrade_after`] consecutive no-progress faults the
//! shard's backend is rebuilt one step down the degradation chain
//! ([`BackendSpec::degraded`]); after [`Supervision::max_restarts`]
//! restarts the shard is declared dead and keeps draining its queue
//! with typed errors so the dispatcher and its sessions never wedge.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::trellis::Trellis;
use crate::error::{Error, Result};
use crate::fault::{self, FaultMap};
use crate::util::queue::Queue;
use crate::viterbi::types::{FrameDecoder, RawFrame};

use super::backend::BackendSpec;
use super::metrics::Metrics;
use super::reassembly::Msg;
use super::shard::{self, Pop, ShardQueue};
use super::{DecodedFrame, FrameTask};

/// How often an idle shard re-scans sibling queues for stealable work
/// (tuned in one place: [`crate::defaults::STEAL_POLL_US`]).
pub const STEAL_POLL: Duration = Duration::from_micros(crate::defaults::STEAL_POLL_US);

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max frames per execution (clamped to the backend's max batch).
    pub max_batch: usize,
    /// How long to wait for more frames after the first arrives.
    pub deadline: Duration,
}

/// Shard supervision policy (see `docs/RELIABILITY.md` for the state
/// machine and the backoff/budget math).
#[derive(Clone, Copy, Debug)]
pub struct Supervision {
    /// Panic-and-restart cycles allowed per shard before it is
    /// declared dead.
    pub max_restarts: usize,
    /// Consecutive no-progress faults before the backend degrades one
    /// chain step.
    pub degrade_after: usize,
    /// First restart backoff; doubles per consecutive restart.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_max: Duration,
}

impl Default for Supervision {
    fn default() -> Self {
        Supervision {
            max_restarts: crate::defaults::MAX_SHARD_RESTARTS,
            degrade_after: crate::defaults::DEGRADE_AFTER_FAULTS,
            backoff_base: Duration::from_millis(crate::defaults::RESTART_BACKOFF_BASE_MS),
            backoff_max: Duration::from_millis(crate::defaults::RESTART_BACKOFF_MAX_MS),
        }
    }
}

/// Backoff before restart number `restarts` (1-based):
/// `base * 2^(restarts-1)`, capped at `backoff_max`.
fn backoff_for(restarts: usize, sup: &Supervision) -> Duration {
    let doublings = (restarts.saturating_sub(1)).min(20) as u32;
    sup.backoff_base
        .saturating_mul(1u32 << doublings)
        .min(sup.backoff_max)
}

/// A forwarded frame awaiting traceback.
pub struct RawTask {
    pub task: FrameTask,
    pub raw: RawFrame,
}

/// Why one supervised serve pass returned (vs. unwinding).
enum ServeExit {
    /// Queues closed or downstream gone: orderly pipeline shutdown.
    Shutdown,
}

/// Run one engine shard loop (call from a dedicated thread).
///
/// Builds the backend *inside* the thread (PJRT executables are not
/// `Send`), signals readiness — or a startup error — through `ready`,
/// then batches its queue (`queues[shard_idx]`) into executions until
/// the dispatcher closes every shard queue. An idle shard steals the
/// oldest frame from the deepest sibling queue rather than sleeping.
/// The last shard to exit closes the raw-survivor queue so the shared
/// traceback pool winds down; `live` counts the shards still running.
///
/// A *startup* build failure is strict (reported through `ready`, so
/// `Coordinator::start` fails fast); once serving, panics are absorbed
/// by the supervisor as described in the module docs, with poisons
/// reported to reassembly through `ctrl`.
#[allow(clippy::too_many_arguments)]
pub fn run_engine_shard(
    shard_idx: usize,
    spec: BackendSpec,
    policy: BatchPolicy,
    queues: Arc<Vec<ShardQueue>>,
    out: Arc<Queue<RawTask>>,
    live: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
    ready: SyncSender<Result<(usize, Arc<Trellis>)>>, // (frame_stages, trellis)
    ctrl: Sender<Msg>,
    sup: Supervision,
    faults: Arc<FaultMap>,
) {
    let mut spec = spec;
    let mut dec = match spec.build() {
        Ok(d) => {
            let _ = ready.send(Ok((d.frame_stages(), d.trellis().clone())));
            d
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            if live.fetch_sub(1, Ordering::AcqRel) == 1 {
                out.close();
            }
            return;
        }
    };
    let frame_stages = dec.frame_stages();
    let own = &queues[shard_idx];
    let stats = metrics.shard(shard_idx);
    // the batch lives outside the unwind boundary so a panicking
    // forward pass leaves its in-flight tasks here for poisoning
    let mut batch: Vec<FrameTask> = Vec::with_capacity(policy.max_batch.max(1));
    let mut restarts = 0usize;
    let mut consecutive = 0usize;
    let mut execs_at_fault = stats.execs.load(Ordering::Relaxed);

    'supervise: loop {
        let pass = catch_unwind(AssertUnwindSafe(|| {
            serve_batches(shard_idx, dec.as_mut(), policy, &queues, &out, &metrics, &faults,
                          &mut batch)
        }));
        match pass {
            Ok(ServeExit::Shutdown) => break 'supervise,
            Err(_) => {
                stats.panics.fetch_add(1, Ordering::Relaxed);
                metrics.shard_panics.fetch_add(1, Ordering::Relaxed);
                // poison only the sessions whose frames were in flight
                // in the panicking batch: gapless prefix + one typed,
                // retryable error each (reassembly enforces both)
                poison_batch(&mut batch, &ctrl, || {
                    Error::pipeline(format!(
                        "shard-restart: engine shard {shard_idx} panicked with this session's \
                         frames in flight; the shard restarts — retry the session"
                    ))
                });
                // progress tracking: did any execution complete since
                // the last fault?
                let execs_now = stats.execs.load(Ordering::Relaxed);
                consecutive = if execs_now > execs_at_fault { 1 } else { consecutive + 1 };
                execs_at_fault = execs_now;
                if restarts >= sup.max_restarts {
                    drain_dead(own, &ctrl, || {
                        Error::pipeline(format!(
                            "engine shard {shard_idx} is dead (restart budget of {} exhausted); \
                             session aborted",
                            sup.max_restarts
                        ))
                    });
                    break 'supervise;
                }
                restarts += 1;
                stats.restarts.fetch_add(1, Ordering::Relaxed);
                metrics.shard_restarts.fetch_add(1, Ordering::Relaxed);
                // repeated faults with no progress: walk the
                // degradation chain before rebuilding
                if consecutive >= sup.degrade_after {
                    if let Some(next) = spec.degraded() {
                        spec = next;
                        consecutive = 0;
                        stats.degraded.fetch_add(1, Ordering::Relaxed);
                        metrics.degradations.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let backoff = backoff_for(restarts, &sup);
                stats.backoff_ms.store(backoff.as_millis() as u64, Ordering::Relaxed);
                std::thread::sleep(backoff);
                stats.backoff_ms.store(0, Ordering::Relaxed);
                // rebuild the backend; a failing rebuild keeps walking
                // the degradation chain until something builds
                match rebuild(&mut spec, frame_stages, shard_idx, stats, &metrics, &faults) {
                    Some(d) => dec = d,
                    None => {
                        drain_dead(own, &ctrl, || {
                            Error::pipeline(format!(
                                "engine shard {shard_idx} is dead (no backend left on the \
                                 degradation chain); session aborted"
                            ))
                        });
                        break 'supervise;
                    }
                }
            }
        }
    }
    if live.fetch_sub(1, Ordering::AcqRel) == 1 {
        out.close(); // every shard drained: let the traceback pool wind down
    }
}

/// The actual exec loop of one shard: batches frames into forward
/// passes until shutdown. Runs inside the supervisor's unwind boundary;
/// `batch` is owned by the caller so in-flight tasks survive a panic.
#[allow(clippy::too_many_arguments)]
fn serve_batches(
    shard_idx: usize,
    dec: &mut dyn FrameDecoder,
    policy: BatchPolicy,
    queues: &[ShardQueue],
    out: &Queue<RawTask>,
    metrics: &Metrics,
    faults: &FaultMap,
    batch: &mut Vec<FrameTask>,
) -> ServeExit {
    let own = &queues[shard_idx];
    let stats = metrics.shard(shard_idx);
    let max_batch = policy.max_batch.min(dec.max_batch()).max(1);
    batch.clear(); // tasks from a previous fault were already poisoned
    loop {
        // acquire the first frame of the batch: own queue first, else
        // steal from the deepest sibling (work-stealing for idle shards)
        let first = loop {
            match own.pop_timeout(STEAL_POLL) {
                Pop::Item(t) => break t,
                Pop::Closed => return ServeExit::Shutdown, // shutdown: all queues drain
                Pop::Timeout => {
                    if let Some(t) = shard::steal(queues, shard_idx) {
                        stats.steals.fetch_add(1, Ordering::Relaxed);
                        break t;
                    }
                }
            }
        };
        let t0 = Instant::now();
        batch.push(first);
        // fill from the own queue until full or deadline
        while batch.len() < max_batch {
            match policy.deadline.checked_sub(t0.elapsed()) {
                None => break,
                Some(left) => match own.pop_timeout(left) {
                    Pop::Item(t) => batch.push(t),
                    Pop::Timeout | Pop::Closed => break,
                },
            }
        }
        // injected fault: panic with the batch in flight, before any
        // execution is recorded (so degradation sees "no progress")
        if faults.fire(fault::site::ENGINE_EXEC) {
            panic!("failpoint engine.exec fired on shard {shard_idx}");
        }
        // execute the forward pass
        let jobs: Vec<_> = batch.iter().map(|t| t.job.clone()).collect();
        let bits: usize = jobs.iter().map(|j| j.emit_len).sum();
        let fwd_start = Instant::now();
        let raws = dec.forward_batch(&jobs);
        let surv_bytes: usize = raws.iter().map(|r| r.surv.bytes()).sum();
        metrics.record_exec(shard_idx, batch.len(), fwd_start.elapsed().as_nanos() as u64,
                            surv_bytes, bits);
        stats.queue_depth.store(own.len() as u64, Ordering::Relaxed);
        for (task, raw) in batch.drain(..).zip(raws) {
            if !out.push(RawTask { task, raw }) {
                return ServeExit::Shutdown; // downstream gone
            }
        }
    }
}

/// Poison every session with a frame in `batch` (once per distinct
/// session) and clear the batch.
fn poison_batch(batch: &mut Vec<FrameTask>, ctrl: &Sender<Msg>, error: impl Fn() -> Error) {
    let mut seen: Vec<u64> = Vec::new();
    for task in batch.drain(..) {
        if !seen.contains(&task.session) {
            seen.push(task.session);
            let _ = ctrl.send(Msg::Poison { session: task.session, error: error() });
        }
    }
}

/// A dead shard's duty loop: keep draining the own queue (so the
/// blocking dispatcher never wedges on a full queue) and poison every
/// session routed here, until the dispatcher closes the queue. Sibling
/// shards may still steal from this queue; frames they win decode
/// normally — either way no frame is silently dropped.
fn drain_dead(own: &ShardQueue, ctrl: &Sender<Msg>, error: impl Fn() -> Error) {
    loop {
        match own.pop_timeout(Duration::from_millis(50)) {
            Pop::Item(t) => {
                let _ = ctrl.send(Msg::Poison { session: t.session, error: error() });
            }
            Pop::Timeout => continue,
            Pop::Closed => return,
        }
    }
}

/// Rebuild a shard's backend after a restart, walking the degradation
/// chain past any spec that fails to build (or that the `engine.build`
/// failpoint fails for it). `None` means nothing on the chain builds:
/// the shard is dead.
fn rebuild(
    spec: &mut BackendSpec,
    frame_stages: usize,
    shard_idx: usize,
    stats: &super::metrics::ShardStats,
    metrics: &Metrics,
    faults: &FaultMap,
) -> Option<Box<dyn FrameDecoder>> {
    loop {
        let built = if faults.fire(fault::site::ENGINE_BUILD) {
            Err(Error::backend(format!("failpoint engine.build fired on shard {shard_idx}")))
        } else {
            spec.build()
        };
        match built {
            // the degradation chain preserves frame geometry; a
            // mismatch would corrupt framing, so treat it like a
            // failed build and keep walking
            Ok(d) if d.frame_stages() == frame_stages => return Some(d),
            Ok(_) | Err(_) => match spec.degraded() {
                Some(next) => {
                    *spec = next;
                    stats.degraded.fetch_add(1, Ordering::Relaxed);
                    metrics.degradations.fetch_add(1, Ordering::Relaxed);
                }
                None => return None,
            },
        }
    }
}

/// Run a traceback worker loop (call from worker threads). Pulls raw
/// frames from the queue shared by all engine shards, runs Alg 2, and
/// emits decoded frames to the reassembler.
pub fn run_traceback_worker(
    trellis: Arc<Trellis>,
    rx: Arc<Queue<RawTask>>,
    out: Sender<super::reassembly::Msg>,
    metrics: Arc<Metrics>,
) {
    loop {
        let RawTask { task, raw } = match rx.pop() {
            Some(x) => x,
            None => return,
        };
        let t0 = Instant::now();
        let bits = raw.traceback(&trellis, &task.job);
        let tb_ns = t0.elapsed().as_nanos() as u64;
        metrics.record_delivery(bits.len(), task.t_enq, tb_ns);
        let df = DecodedFrame { session: task.session, seq: task.seq, bits, t_enq: task.t_enq };
        if out.send(super::reassembly::Msg::Decoded(df)).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_from_base_and_caps() {
        let sup = Supervision {
            max_restarts: 8,
            degrade_after: 2,
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_millis(2000),
        };
        assert_eq!(backoff_for(1, &sup), Duration::from_millis(10));
        assert_eq!(backoff_for(2, &sup), Duration::from_millis(20));
        assert_eq!(backoff_for(3, &sup), Duration::from_millis(40));
        assert_eq!(backoff_for(8, &sup), Duration::from_millis(1280));
        assert_eq!(backoff_for(9, &sup), Duration::from_millis(2000), "capped");
        assert_eq!(backoff_for(1000, &sup), Duration::from_millis(2000), "no overflow");
    }

    #[test]
    fn default_supervision_mirrors_defaults() {
        let sup = Supervision::default();
        assert_eq!(sup.max_restarts, crate::defaults::MAX_SHARD_RESTARTS);
        assert_eq!(sup.degrade_after, crate::defaults::DEGRADE_AFTER_FAULTS);
        assert_eq!(sup.backoff_base.as_millis() as u64,
                   crate::defaults::RESTART_BACKOFF_BASE_MS);
        assert_eq!(sup.backoff_max.as_millis() as u64,
                   crate::defaults::RESTART_BACKOFF_MAX_MS);
    }
}
