//! The execution engine thread: dynamic batching + the tensor forward
//! pass. One engine thread owns the (non-`Send`) PJRT executable —
//! serializing launches exactly like a CUDA stream — and ships raw
//! survivors to the traceback worker pool.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::trellis::Trellis;
use crate::error::Result;
use crate::util::queue::Queue;
use crate::viterbi::types::RawFrame;

use super::backend::BackendSpec;
use super::metrics::Metrics;
use super::{DecodedFrame, FrameTask};

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max frames per execution (clamped to the backend's max batch).
    pub max_batch: usize,
    /// How long to wait for more frames after the first arrives.
    pub deadline: Duration,
}

/// A forwarded frame awaiting traceback.
pub struct RawTask {
    pub task: FrameTask,
    pub raw: RawFrame,
}

/// Run the engine loop (call from a dedicated thread). Signals readiness
/// (or a startup error) through `ready`, then batches `rx` into
/// executions until the channel closes.
pub fn run_engine(
    spec: BackendSpec,
    policy: BatchPolicy,
    rx: Receiver<FrameTask>,
    out: Arc<Queue<RawTask>>,
    metrics: Arc<Metrics>,
    ready: SyncSender<Result<(usize, Arc<Trellis>)>>, // (frame_stages, trellis)
) {
    let mut dec = match spec.build() {
        Ok(d) => {
            let _ = ready.send(Ok((d.frame_stages(), d.trellis().clone())));
            d
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            out.close();
            return;
        }
    };
    let max_batch = policy.max_batch.min(dec.max_batch()).max(1);
    let mut batch: Vec<FrameTask> = Vec::with_capacity(max_batch);

    loop {
        // block for the first frame of the batch
        match rx.recv() {
            Ok(t) => batch.push(t),
            Err(_) => break, // input closed, all work drained
        }
        let t0 = Instant::now();
        // fill until full or deadline
        while batch.len() < max_batch {
            let left = policy.deadline.checked_sub(t0.elapsed());
            match left {
                None => break,
                Some(d) => match rx.recv_timeout(d) {
                    Ok(t) => batch.push(t),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                },
            }
        }
        // execute
        let jobs: Vec<_> = batch.iter().map(|t| t.job.clone()).collect();
        let fwd_start = Instant::now();
        let raws = dec.forward_batch(&jobs);
        metrics.record_exec(batch.len(), fwd_start.elapsed().as_nanos() as u64);
        for (task, raw) in batch.drain(..).zip(raws) {
            if !out.push(RawTask { task, raw }) {
                out.close();
                return; // downstream gone
            }
        }
    }
    out.close(); // input drained: let workers wind down
}

/// Run a traceback worker loop (call from worker threads). Pulls raw
/// frames from the shared queue, runs Alg 2, emits decoded frames to the
/// reassembler.
pub fn run_traceback_worker(
    trellis: Arc<Trellis>,
    rx: Arc<Queue<RawTask>>,
    out: Sender<super::reassembly::Msg>,
    metrics: Arc<Metrics>,
) {
    loop {
        let RawTask { task, raw } = match rx.pop() {
            Some(x) => x,
            None => return,
        };
        let t0 = Instant::now();
        let bits = raw.traceback(&trellis, &task.job);
        let tb_ns = t0.elapsed().as_nanos() as u64;
        metrics.record_delivery(bits.len(), task.t_enq, tb_ns);
        let df = DecodedFrame { session: task.session, seq: task.seq, bits, t_enq: task.t_enq };
        if out.send(super::reassembly::Msg::Decoded(df)).is_err() {
            return;
        }
    }
}
