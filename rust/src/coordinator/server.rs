//! The coordinator facade: wires framer -> dispatcher -> engine shards
//! -> traceback workers -> reassembly into a running pipeline and
//! exposes the session API used by `api::DecoderBuilder::serve`, the
//! CLI, examples and benches.
//!
//! Threading model (see `docs/ARCHITECTURE.md` for the full picture):
//! one dispatcher thread routes frames to `shards` engine threads (each
//! owning a private backend instance and work queue, with work-stealing
//! between them), `workers` traceback threads drain the shared
//! raw-survivor queue, and one reassembly thread restores per-session
//! order. Per-session delivery is strictly in sequence regardless of
//! which shard decoded each frame.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coding::trellis::Trellis;
use crate::coding::TerminationMode;
use crate::error::{Error, Result, ResultExt};
use crate::fault::{self, FaultMap};
use crate::util::queue::Queue;
use crate::viterbi::tiled::TileConfig;

use super::backend::BackendSpec;
use super::engine::{run_engine_shard, run_traceback_worker, BatchPolicy, RawTask, Supervision};
use super::framer::Framer;
use super::metrics::{Metrics, MetricsSnapshot};
use super::reassembly::{run_reassembly, Msg};
use super::shard::{run_dispatcher, ShardQueue};
use super::FrameTask;

/// Coordinator configuration — the lowering target of
/// [`crate::api::DecoderBuilder::to_coordinator_config`], which is the
/// supported way to produce one.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub backend: BackendSpec,
    pub tile: TileConfig,
    pub max_batch: usize,
    pub batch_deadline: Duration,
    pub workers: usize,
    pub queue_depth: usize,
    /// Engine shards: independent backend instances, each on its own
    /// thread with its own work queue (clamped to at least 1).
    pub shards: usize,
    /// How session streams are terminated — decides what each frame may
    /// assume about the trellis ends and whether framing is linear
    /// (flushed/truncated) or circular (tail-biting); see
    /// `docs/DECODING-MODES.md`.
    pub termination: TerminationMode,
    /// Deterministic failpoint spec (`site=trigger,...`, see
    /// [`crate::fault`]). `None`/empty arms nothing. A non-empty spec is
    /// a typed [`Error::Config`] unless the crate was built with the
    /// `failpoints` feature — production binaries cannot silently carry
    /// armed faults.
    pub fault_spec: Option<String>,
    /// Restart budget per engine shard: after this many supervised
    /// restarts a shard is declared dead and its queue drained with
    /// typed errors (see `docs/RELIABILITY.md`).
    pub max_restarts: usize,
}

/// A running decode pipeline.
pub struct Coordinator {
    input: SyncSender<FrameTask>,
    ctrl: Sender<Msg>,
    metrics: Arc<Metrics>,
    tile: TileConfig,
    beta: usize,
    n_shards: usize,
    termination: TerminationMode,
    trellis: Arc<Trellis>,
    next_session: AtomicU64,
    faults: Arc<FaultMap>,
    threads: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the pipeline: spawns the engine shards (each builds its
    /// own backend instance in-thread), the dispatcher, the traceback
    /// workers and the reassembler. Blocks until every shard's backend
    /// is ready.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let faults = match cfg.fault_spec.as_deref() {
            Some(spec) if !spec.is_empty() => {
                if !fault::enabled() {
                    return Err(Error::config(format!(
                        "failpoint spec {spec:?} given but failpoints are not compiled in; \
                         rebuild with `--features failpoints`"
                    )));
                }
                Arc::new(FaultMap::parse(spec)?)
            }
            _ => Arc::new(FaultMap::default()),
        };
        let n_shards = cfg.shards.max(1);
        let metrics = Arc::new(Metrics::new(n_shards));
        let (input_tx, input_rx) = mpsc::sync_channel::<FrameTask>(cfg.queue_depth);
        // per-shard queues sized so the total frames buffered past the
        // input channel stay within ~one extra queue_depth
        let per_shard_cap = (cfg.queue_depth / n_shards).max(cfg.max_batch).max(1);
        let shard_qs: Arc<Vec<ShardQueue>> =
            Arc::new((0..n_shards).map(|_| ShardQueue::new(per_shard_cap)).collect());
        let raw_q: Arc<Queue<RawTask>> = Arc::new(Queue::new());
        let (msg_tx, msg_rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::sync_channel(n_shards);
        let live = Arc::new(AtomicUsize::new(n_shards));

        let mut threads = Vec::new();
        let policy = BatchPolicy { max_batch: cfg.max_batch, deadline: cfg.batch_deadline };
        let sup = Supervision { max_restarts: cfg.max_restarts, ..Supervision::default() };
        for i in 0..n_shards {
            let spec = cfg.backend.clone();
            let queues = shard_qs.clone();
            let out = raw_q.clone();
            let live = live.clone();
            let m = metrics.clone();
            let ready = ready_tx.clone();
            let ctrl = msg_tx.clone();
            let f = faults.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("tcvd-engine-{i}"))
                    .spawn(move || {
                        run_engine_shard(i, spec, policy, queues, out, live, m, ready, ctrl, sup, f)
                    })
                    .or_pipeline("spawning engine shard")?,
            );
        }
        drop(ready_tx); // shards hold the only senders now
        {
            let queues = shard_qs.clone();
            let m = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tcvd-dispatch".into())
                    .spawn(move || run_dispatcher(input_rx, queues, m))
                    .or_pipeline("spawning dispatcher")?,
            );
        }
        // every shard must come up, and all geometries must agree
        let mut trellis: Option<Arc<Trellis>> = None;
        for _ in 0..n_shards {
            let (frame_stages, t) = ready_rx
                .recv()
                .or_pipeline("engine shard died during startup")?
                .map_err(|e| e.context("backend startup failed"))?;
            if frame_stages != cfg.tile.frame_stages() {
                return Err(Error::config(format!(
                    "backend frame ({frame_stages} stages) does not match tile geometry \
                     ({} = head {} + payload {} + tail {})",
                    cfg.tile.frame_stages(),
                    cfg.tile.head,
                    cfg.tile.payload,
                    cfg.tile.tail
                )));
            }
            trellis.get_or_insert(t);
        }
        let trellis = trellis.expect("n_shards >= 1");

        for w in 0..cfg.workers.max(1) {
            let rx = raw_q.clone();
            let out = msg_tx.clone();
            let tr = trellis.clone();
            let m = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("tcvd-traceback-{w}"))
                    .spawn(move || run_traceback_worker(tr, rx, out, m))
                    .or_pipeline("spawning traceback worker")?,
            );
        }
        let ctrl = msg_tx; // remaining clone for session control
        {
            let m = metrics.clone();
            let f = faults.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tcvd-reassembly".into())
                    .spawn(move || run_reassembly(msg_rx, m, f))
                    .or_pipeline("spawning reassembler")?,
            );
        }

        let beta = trellis.code().beta();
        Ok(Coordinator {
            input: input_tx,
            ctrl,
            metrics,
            tile: cfg.tile,
            beta,
            n_shards,
            termination: cfg.termination,
            trellis,
            next_session: AtomicU64::new(0),
            faults,
            threads,
        })
    }

    pub fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    pub fn tile(&self) -> &TileConfig {
        &self.tile
    }

    /// Number of engine shards this pipeline runs.
    pub fn shards(&self) -> usize {
        self.n_shards
    }

    /// The termination mode every session of this pipeline decodes
    /// under (set via `DecoderBuilder::termination`).
    pub fn termination(&self) -> TerminationMode {
        self.termination
    }

    /// Open a streaming session: push LLR chunks in, iterate in-order
    /// decoded payload chunks out.
    pub fn open_session(&self) -> Result<Session> {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        let (out_tx, out_rx) =
            mpsc::sync_channel::<Result<Vec<u8>>>(crate::defaults::SESSION_OUTPUT_DEPTH);
        self.ctrl
            .send(Msg::Open { session: id, out: out_tx })
            .map_err(|_| Error::pipeline("pipeline is shut down"))?;
        let handle = SessionHandle {
            id,
            framer: Framer::new(self.tile, self.beta, self.termination),
            input: Some(self.input.clone()),
            ctrl: Some(self.ctrl.clone()),
            metrics: self.metrics.clone(),
            faults: self.faults.clone(),
            pending: VecDeque::new(),
            dispatched: 0,
            framing_done: false,
        };
        Ok(Session { handle, out: out_rx })
    }

    /// Convenience: decode one whole LLR stream through the pipeline
    /// (open session, push, finish, collect). The stream is terminated
    /// per the pipeline's [`termination`](Self::termination) mode.
    pub fn decode_stream_blocking(&self, llr: &[f32]) -> Result<Vec<u8>> {
        let mut session = self.open_session()?;
        session.push(llr)?;
        session.finish()?;
        let mut out = Vec::new();
        for chunk in session {
            out.extend_from_slice(&chunk?);
        }
        Ok(out)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The shared metrics hub itself (not a snapshot) — the counters
    /// the net front-end increments for accepted/evicted/shed sessions
    /// and reads for queue-saturation admission control.
    pub fn metrics_hub(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The pipeline's armed failpoint map (empty unless a spec was
    /// given and the `failpoints` feature is on). The net front-end
    /// shares it so `net.shed` / `net.admit` sites and the pipeline
    /// sites fire from one deterministic arming.
    pub fn faults(&self) -> Arc<FaultMap> {
        self.faults.clone()
    }

    /// Shut down: all sessions must be finished/dropped first. Joins
    /// every pipeline thread.
    pub fn shutdown(self) -> Result<()> {
        let Coordinator { input, ctrl, threads, .. } = self;
        drop(input);
        drop(ctrl);
        for t in threads {
            t.join().map_err(|_| Error::pipeline("pipeline thread panicked"))?;
        }
        Ok(())
    }
}

/// One decoding stream. Push LLR chunks; completed frames flow through
/// the pipeline with backpressure (push blocks when the queue is full).
/// `finish` releases the handle's hold on the pipeline, so a finished
/// handle never blocks `Coordinator::shutdown`.
pub struct SessionHandle {
    id: u64,
    framer: Framer,
    input: Option<SyncSender<FrameTask>>,
    ctrl: Option<Sender<Msg>>,
    metrics: Arc<Metrics>,
    faults: Arc<FaultMap>,
    /// Frames emitted by the framer but not yet handed to the pipeline
    /// (non-blocking driving only; the blocking `push` dispatches
    /// immediately and never populates this queue).
    pending: VecDeque<crate::viterbi::types::FrameJob>,
    /// Frames actually dispatched to the pipeline via `try_dispatch` —
    /// doubles as the next sequence number, since dispatch order is the
    /// framer's emission order.
    dispatched: u64,
    framing_done: bool,
}

impl SessionHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Point-in-time pipeline metrics (shared across sessions).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn send_jobs(&mut self, base: u64, jobs: Vec<crate::viterbi::types::FrameJob>) -> Result<()> {
        let input = self.input.as_ref().expect("checked by callers");
        for (i, job) in jobs.into_iter().enumerate() {
            self.metrics.frames_in.fetch_add(1, Ordering::Relaxed);
            input
                .send(FrameTask {
                    session: self.id,
                    seq: base + i as u64,
                    job,
                    t_enq: Instant::now(),
                })
                .map_err(|_| Error::pipeline("pipeline is shut down"))?;
        }
        Ok(())
    }

    /// Push an LLR chunk (length must be a multiple of beta).
    pub fn push(&mut self, llr: &[f32]) -> Result<()> {
        if self.input.is_none() {
            return Err(Error::pipeline("session already finished"));
        }
        if self.faults.fire(fault::site::FRAMER_PUSH) {
            return Err(Error::pipeline("failpoint framer.push fired: chunk dropped"));
        }
        if llr.len() % self.framer_beta() != 0 {
            return Err(Error::pipeline(format!(
                "chunk length {} is not a multiple of beta {}",
                llr.len(),
                self.framer_beta()
            )));
        }
        let base = self.framer.frames_emitted() as u64;
        let jobs = self.framer.push(llr);
        self.send_jobs(base, jobs)
    }

    fn framer_beta(&self) -> usize {
        self.framer.beta()
    }

    /// End the stream: emits the remaining frames (all of them, for a
    /// tail-biting block), tells the reassembler the total frame count
    /// so it can close the output, and drops this handle's pipeline
    /// senders. The termination semantics come from the pipeline
    /// configuration (`DecoderBuilder::termination`).
    pub fn finish(&mut self) -> Result<()> {
        if self.input.is_none() {
            return Err(Error::pipeline("session already finished"));
        }
        let base = self.framer.frames_emitted() as u64;
        let jobs = match self.framer.finish() {
            Ok(jobs) => jobs,
            Err(e) => {
                // the stream cannot be completed (e.g. a tail-biting
                // block that is not a whole number of payload tiles):
                // close the session with the frames already emitted so
                // the pipeline is not left holding an open output, and
                // surface the typed error to the caller
                let total = self.framer.frames_emitted() as u64;
                let ctrl = self.ctrl.take().expect("ctrl present until finish");
                self.input = None;
                let _ = ctrl.send(Msg::Finish { session: self.id, total_frames: total });
                return Err(e);
            }
        };
        self.send_jobs(base, jobs)?;
        let total = self.framer.frames_emitted() as u64;
        let ctrl = self.ctrl.take().expect("ctrl present until finish");
        self.input = None;
        ctrl.send(Msg::Finish { session: self.id, total_frames: total })
            .map_err(|_| Error::pipeline("pipeline is shut down"))?;
        Ok(())
    }

    // ---- non-blocking driving (the `tcvd::net` reactor) -------------
    //
    // The blocking `push`/`finish` pair above parks the calling thread
    // when the pipeline queue is full — fine with one thread per
    // session, fatal for a reactor multiplexing every socket on one
    // thread. The methods below split framing from dispatch: the framer
    // runs eagerly (it only buffers memory), dispatch goes through
    // `try_send`, and the session closes at the frames actually
    // dispatched. Sequence numbers are assigned in dispatch order,
    // which is the framer's emission order, so the dispatched frames
    // are always a gapless prefix and a dirty close at any point leaves
    // the reassembler consistent. Drive a handle through one API or the
    // other, never both.

    /// Frame an LLR chunk (length must be a multiple of beta) without
    /// dispatching. Never blocks.
    pub fn frame_chunk(&mut self, llr: &[f32]) -> Result<()> {
        if self.input.is_none() || self.framing_done {
            return Err(Error::pipeline("session already finished"));
        }
        if self.faults.fire(fault::site::FRAMER_PUSH) {
            return Err(Error::pipeline("failpoint framer.push fired: chunk dropped"));
        }
        if llr.len() % self.framer_beta() != 0 {
            return Err(Error::pipeline(format!(
                "chunk length {} is not a multiple of beta {}",
                llr.len(),
                self.framer_beta()
            )));
        }
        let jobs = self.framer.push(llr);
        self.pending.extend(jobs);
        Ok(())
    }

    /// End the stream on the framing side: flushes the framer into the
    /// pending queue (for tail-biting, this emits the whole block). The
    /// session stays open until the pending frames are dispatched and
    /// [`close_dispatched`](Self::close_dispatched) runs. On a framer
    /// error (e.g. a misaligned tail-biting block) the session is
    /// closed at the dispatched prefix and the typed error returned.
    pub fn frame_finish(&mut self) -> Result<()> {
        if self.input.is_none() || self.framing_done {
            return Err(Error::pipeline("session already finished"));
        }
        self.framing_done = true;
        match self.framer.finish() {
            Ok(jobs) => {
                self.pending.extend(jobs);
                Ok(())
            }
            Err(e) => {
                self.close_dispatched();
                Err(e)
            }
        }
    }

    /// Hand pending frames to the pipeline without blocking; stops at
    /// the first `try_send` refusal (shard queues full). Returns the
    /// number of frames still pending.
    pub fn try_dispatch(&mut self) -> Result<usize> {
        let Some(input) = self.input.as_ref() else { return Ok(0) };
        while let Some(job) = self.pending.pop_front() {
            match input.try_send(FrameTask {
                session: self.id,
                seq: self.dispatched,
                job,
                t_enq: Instant::now(),
            }) {
                Ok(()) => {
                    self.dispatched += 1;
                    self.metrics.frames_in.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Full(task)) => {
                    self.pending.push_front(task.job);
                    break;
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err(Error::pipeline("pipeline is shut down"));
                }
            }
        }
        Ok(self.pending.len())
    }

    /// Frames framed but not yet accepted by the pipeline. Non-zero
    /// means the pipeline is backpressuring — stop reading more input.
    pub fn pending_frames(&self) -> usize {
        self.pending.len()
    }

    /// Whether [`frame_finish`](Self::frame_finish) has run.
    pub fn framing_done(&self) -> bool {
        self.framing_done
    }

    /// Close the session at the frames dispatched so far: undispatched
    /// pending frames are dropped and the reassembler is told the final
    /// frame count, so the output stream completes (or, on a dirty
    /// close, the receiver can simply be dropped). Idempotent; used for
    /// both the clean path (after the pending queue drains) and every
    /// dirty-disconnect path.
    pub fn close_dispatched(&mut self) {
        self.pending.clear();
        self.input = None;
        if let Some(ctrl) = self.ctrl.take() {
            let _ = ctrl.send(Msg::Finish { session: self.id, total_frames: self.dispatched });
        }
    }
}

/// A full-duplex session: the push side ([`SessionHandle`]) plus the
/// in-order decoded output stream.
///
/// Output access is either non-blocking ([`poll`](Session::poll)),
/// blocking per chunk ([`next_chunk`](Session::next_chunk)), or through
/// the blocking [`Iterator`] impl, which yields in-order payload chunks
/// until the session's output is complete. Producer/consumer splits
/// (push from one thread, drain from another) use
/// [`split`](Session::split).
///
/// Each yielded item is a `Result`: `Ok` chunks are the in-order
/// payload bits; an `Err` means the session was poisoned by a pipeline
/// fault (e.g. its home shard panicked mid-decode) — the error arrives
/// at most once, after the gapless prefix, and closes the stream. A
/// retryable error ([`Error::is_retryable`]) means a fresh session
/// against the same pipeline is expected to succeed.
pub struct Session {
    handle: SessionHandle,
    out: Receiver<Result<Vec<u8>>>,
}

impl Session {
    pub fn id(&self) -> u64 {
        self.handle.id()
    }

    /// Push an LLR chunk (length must be a multiple of beta). Blocks
    /// when the pipeline queue is full (backpressure).
    pub fn push(&mut self, llr: &[f32]) -> Result<()> {
        self.handle.push(llr)
    }

    /// End the stream and release the push side; the output iterator
    /// terminates once all frames are delivered.
    pub fn finish(&mut self) -> Result<()> {
        self.handle.finish()
    }

    /// Non-blocking poll for the next in-order decoded chunk.
    /// `None` means "nothing ready yet *or* stream complete" — use the
    /// iterator / [`next_chunk`](Session::next_chunk) to distinguish.
    pub fn poll(&mut self) -> Option<Result<Vec<u8>>> {
        match self.out.try_recv() {
            Ok(chunk) => Some(chunk),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Blocking receive of the next in-order decoded chunk; `None` once
    /// the session output is complete.
    pub fn next_chunk(&mut self) -> Option<Result<Vec<u8>>> {
        self.out.recv().ok()
    }

    /// Point-in-time pipeline metrics (shared across sessions).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.handle.metrics()
    }

    /// Split into the push handle and the raw output receiver, for
    /// producer/consumer thread pairs.
    pub fn split(self) -> (SessionHandle, Receiver<Result<Vec<u8>>>) {
        (self.handle, self.out)
    }

    /// Finish the stream and block until every decoded payload bit has
    /// arrived. A poisoned session surfaces its typed error here.
    pub fn finish_and_collect(mut self) -> Result<Vec<u8>> {
        self.finish()?;
        let mut out = Vec::new();
        for chunk in self {
            out.extend_from_slice(&chunk?);
        }
        Ok(out)
    }
}

impl Iterator for Session {
    type Item = Result<Vec<u8>, Error>;

    /// Blocking, in-order iteration over decoded payload chunks.
    fn next(&mut self) -> Option<Result<Vec<u8>, Error>> {
        self.next_chunk()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::registry;
    use crate::coding::Encoder;
    use crate::util::rng::Rng;
    use crate::viterbi::scalar;

    fn cpu_config(tile: TileConfig) -> CoordinatorConfig {
        CoordinatorConfig {
            backend: BackendSpec::CpuPacked {
                code: "ccsds".into(),
                scheme: "radix4".into(),
                stages: tile.frame_stages(),
                acc: crate::viterbi::types::AccPrecision::Single,
                chan: crate::channel::quantize::ChannelPrecision::Single,
                renorm_every: 16,
            },
            tile,
            max_batch: 8,
            batch_deadline: Duration::from_micros(500),
            workers: 2,
            queue_depth: 64,
            shards: 2,
            termination: TerminationMode::Flushed,
            fault_spec: None,
            max_restarts: crate::defaults::MAX_SHARD_RESTARTS,
        }
    }

    fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
        let code = registry::paper_code();
        let mut enc = Encoder::new(code.clone());
        let mut bits = Rng::new(seed).bits(payload_bits - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0xFEED);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }

    #[test]
    fn pipeline_decodes_one_stream() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (bits, llr) = noisy_stream(42, 256, 5.0);
        let out = coord.decode_stream_blocking(&llr).unwrap();
        assert_eq!(out, bits);
        let snap = coord.metrics();
        assert_eq!(snap.frames_in, 8);
        assert_eq!(snap.frames_out, 8);
        assert_eq!(coord.shards(), 2);
        assert_eq!(snap.shards.len(), 2);
        let shard_frames: u64 = snap.shards.iter().map(|s| s.frames).sum();
        assert_eq!(shard_frames, snap.frames_out);
        coord.shutdown().unwrap();
    }

    #[test]
    fn pipeline_handles_concurrent_sessions() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let coord = Arc::new(Coordinator::start(cpu_config(tile)).unwrap());
        let mut joins = Vec::new();
        for s in 0..4u64 {
            let c = coord.clone();
            joins.push(std::thread::spawn(move || {
                let (bits, llr) = noisy_stream(100 + s, 128, 5.0);
                let out = c.decode_stream_blocking(&llr).unwrap();
                assert_eq!(out, bits, "session {s}");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let coord = Arc::try_unwrap(coord).ok().expect("all sessions done");
        let snap = coord.metrics();
        assert_eq!(snap.frames_out, 16);
        assert!(snap.mean_batch >= 1.0);
        coord.shutdown().unwrap();
    }

    #[test]
    fn chunked_push_matches_reference() {
        let tile = TileConfig { payload: 64, head: 24, tail: 24 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (bits, llr) = noisy_stream(7, 512, 5.0);
        let mut session = coord.open_session().unwrap();
        for chunk in llr.chunks(46) {
            // 23-stage odd chunks
            session.push(chunk).unwrap();
        }
        let out = session.finish_and_collect().unwrap();
        assert_eq!(out, bits);
        // scalar reference agrees (up to half rounding of B) at 5 dB
        let t = coord.trellis().clone();
        let lam0 = scalar::initial_metrics(64, Some(0));
        let llr_h: Vec<f32> =
            llr.iter().map(|&x| crate::util::half::HalfKind::Bf16.round(x)).collect();
        let whole = scalar::decode(&t, &llr_h, &lam0, Some(0));
        assert_eq!(out, whole);
        coord.shutdown().unwrap();
    }

    #[test]
    fn session_poll_and_metrics() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (bits, llr) = noisy_stream(9, 128, 6.0);
        let mut session = coord.open_session().unwrap();
        session.push(&llr).unwrap();
        session.finish().unwrap();
        let mut out = Vec::new();
        // drain via poll (non-blocking) + blocking fallback
        loop {
            match session.poll() {
                Some(c) => out.extend_from_slice(&c.unwrap()),
                None => match session.next_chunk() {
                    Some(c) => out.extend_from_slice(&c.unwrap()),
                    None => break,
                },
            }
        }
        assert_eq!(out, bits);
        assert!(session.metrics().frames_out >= 4);
        coord.shutdown().unwrap();
    }

    #[test]
    fn split_supports_producer_consumer() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (bits, llr) = noisy_stream(21, 256, 6.0);
        let session = coord.open_session().unwrap();
        let (mut handle, rx) = session.split();
        let consumer = std::thread::spawn(move || {
            let mut out = Vec::new();
            for c in rx {
                out.extend_from_slice(&c.unwrap());
            }
            out
        });
        for chunk in llr.chunks(64) {
            handle.push(chunk).unwrap();
        }
        handle.finish().unwrap();
        assert_eq!(consumer.join().unwrap(), bits);
        coord.shutdown().unwrap();
    }

    #[test]
    fn push_after_finish_is_typed_error() {
        let tile = TileConfig { payload: 32, head: 8, tail: 8 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (_, llr) = noisy_stream(3, 64, 6.0);
        let mut session = coord.open_session().unwrap();
        session.push(&llr).unwrap();
        session.finish().unwrap();
        let e = session.push(&llr).unwrap_err();
        assert!(matches!(e, Error::Pipeline(_)), "{e}");
        for _ in session {}
        coord.shutdown().unwrap();
    }

    #[test]
    fn tail_biting_misaligned_block_is_typed_error() {
        let tile = TileConfig { payload: 32, head: 8, tail: 8 };
        let mut cfg = cpu_config(tile);
        cfg.termination = TerminationMode::TailBiting;
        let coord = Coordinator::start(cfg).unwrap();
        assert_eq!(coord.termination(), TerminationMode::TailBiting);
        let mut session = coord.open_session().unwrap();
        session.push(&vec![0.0f32; 10 * 2]).unwrap(); // 10 stages: partial tile
        let e = session.finish().unwrap_err();
        assert!(matches!(e, Error::Pipeline(_)), "{e}");
        assert!(e.to_string().contains("tail-biting"), "{e}");
        // the session is poisoned but closed: a second finish is a typed
        // error, the output stream terminates, and shutdown still joins
        let e2 = session.finish().unwrap_err();
        assert!(matches!(e2, Error::Pipeline(_)), "{e2}");
        for _ in session {}
        coord.shutdown().unwrap();
    }

    #[test]
    fn nonblocking_drive_matches_blocking_push() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (bits, llr) = noisy_stream(11, 256, 5.0);
        let (mut handle, rx) = coord.open_session().unwrap().split();
        for chunk in llr.chunks(64) {
            handle.frame_chunk(chunk).unwrap();
        }
        handle.frame_finish().unwrap();
        // reactor-style loop: try_dispatch + non-blocking output drain
        let mut out = Vec::new();
        let mut closed = false;
        loop {
            if !closed {
                let left = handle.try_dispatch().unwrap();
                if left == 0 && handle.framing_done() {
                    handle.close_dispatched();
                    handle.close_dispatched(); // idempotent
                    closed = true;
                }
            }
            match rx.try_recv() {
                Ok(c) => out.extend_from_slice(&c.unwrap()),
                Err(TryRecvError::Empty) => std::thread::sleep(Duration::from_millis(1)),
                Err(TryRecvError::Disconnected) => break,
            }
        }
        assert_eq!(out, bits);
        coord.shutdown().unwrap();
    }

    #[test]
    fn nonblocking_misaligned_tail_biting_closes_session() {
        let tile = TileConfig { payload: 32, head: 8, tail: 8 };
        let mut cfg = cpu_config(tile);
        cfg.termination = TerminationMode::TailBiting;
        let coord = Coordinator::start(cfg).unwrap();
        let (mut handle, rx) = coord.open_session().unwrap().split();
        handle.frame_chunk(&vec![0.0f32; 10 * 2]).unwrap(); // partial tile
        let e = handle.frame_finish().unwrap_err();
        assert!(matches!(e, Error::Pipeline(_)), "{e}");
        // the session closed at the dispatched prefix: the output stream
        // terminates, further framing is a typed error, shutdown joins
        let e2 = handle.frame_chunk(&[0.0; 2]).unwrap_err();
        assert!(matches!(e2, Error::Pipeline(_)), "{e2}");
        assert_eq!(handle.try_dispatch().unwrap(), 0);
        for _ in rx {}
        coord.shutdown().unwrap();
    }

    #[test]
    fn fault_spec_is_gated_on_the_failpoints_feature() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let mut cfg = cpu_config(tile);
        // a spec that can parse but will never fire
        cfg.fault_spec = Some("engine.exec=hit:1000000".into());
        match Coordinator::start(cfg) {
            Ok(coord) => {
                assert!(crate::fault::enabled(), "start must reject specs without the feature");
                coord.shutdown().unwrap();
            }
            Err(e) => {
                assert!(!crate::fault::enabled(), "{e}");
                assert!(matches!(e, Error::Config(_)), "{e}");
                assert!(e.to_string().contains("failpoints"), "{e}");
            }
        }
        // an unparseable spec is a typed config error either way
        let mut bad = cpu_config(tile);
        bad.fault_spec = Some("no-such-site=hit:1".into());
        let e = Coordinator::start(bad).map(|_| ()).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn mismatched_tile_rejected() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let mut cfg = cpu_config(tile);
        // backend frame stages disagree with tile geometry
        if let BackendSpec::CpuPacked { ref mut stages, .. } = cfg.backend {
            *stages = 128;
        }
        let e = Coordinator::start(cfg).map(|_| ()).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        assert!(e.to_string().contains("does not match"), "{e}");
    }
}
