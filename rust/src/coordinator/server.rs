//! The coordinator facade: wires framer -> batcher/engine -> traceback
//! workers -> reassembly into a running pipeline and exposes the session
//! API used by the CLI, examples and benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coding::trellis::Trellis;
use crate::util::queue::Queue;
use crate::viterbi::tiled::TileConfig;

use super::backend::BackendSpec;
use super::engine::{run_engine, run_traceback_worker, BatchPolicy, RawTask};
use super::framer::Framer;
use super::metrics::{Metrics, MetricsSnapshot};
use super::reassembly::{run_reassembly, Msg};
use super::FrameTask;

/// Coordinator configuration (see `config::Config` for file-based setup).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub backend: BackendSpec,
    pub tile: TileConfig,
    pub max_batch: usize,
    pub batch_deadline: Duration,
    pub workers: usize,
    pub queue_depth: usize,
}

/// A running decode pipeline.
pub struct Coordinator {
    input: SyncSender<FrameTask>,
    ctrl: Sender<Msg>,
    metrics: Arc<Metrics>,
    tile: TileConfig,
    beta: usize,
    trellis: Arc<Trellis>,
    next_session: AtomicU64,
    threads: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the pipeline: spawns the engine thread (which builds the
    /// backend and compiles the artifact), the traceback workers and the
    /// reassembler. Blocks until the backend is ready.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::new());
        let (input_tx, input_rx) = mpsc::sync_channel::<FrameTask>(cfg.queue_depth);
        let raw_q: Arc<Queue<RawTask>> = Arc::new(Queue::new());
        let (msg_tx, msg_rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::sync_channel(1);

        let mut threads = Vec::new();
        let policy = BatchPolicy { max_batch: cfg.max_batch, deadline: cfg.batch_deadline };
        let spec = cfg.backend.clone();
        let m_engine = metrics.clone();
        let raw_q_engine = raw_q.clone();
        threads.push(
            std::thread::Builder::new()
                .name("tcvd-engine".into())
                .spawn(move || {
                    run_engine(spec, policy, input_rx, raw_q_engine, m_engine, ready_tx)
                })?,
        );
        let (frame_stages, trellis) = ready_rx
            .recv()
            .context("engine thread died during startup")?
            .context("backend startup failed")?;
        if frame_stages != cfg.tile.frame_stages() {
            bail!(
                "backend frame ({frame_stages} stages) does not match tile geometry \
                 ({} = head {} + payload {} + tail {})",
                cfg.tile.frame_stages(), cfg.tile.head, cfg.tile.payload, cfg.tile.tail
            );
        }

        for w in 0..cfg.workers.max(1) {
            let rx = raw_q.clone();
            let out = msg_tx.clone();
            let tr = trellis.clone();
            let m = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("tcvd-traceback-{w}"))
                    .spawn(move || run_traceback_worker(tr, rx, out, m))?,
            );
        }
        let ctrl = msg_tx; // remaining clone for session control
        threads.push(
            std::thread::Builder::new()
                .name("tcvd-reassembly".into())
                .spawn(move || run_reassembly(msg_rx))?,
        );

        let beta = trellis.code().beta();
        Ok(Coordinator {
            input: input_tx,
            ctrl,
            metrics,
            tile: cfg.tile,
            beta,
            trellis,
            next_session: AtomicU64::new(0),
            threads,
        })
    }

    pub fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    pub fn tile(&self) -> &TileConfig {
        &self.tile
    }

    /// Open a streaming session; returns the handle for pushing LLRs and
    /// the receiver of in-order decoded payload chunks.
    pub fn open_session(&self) -> Result<(SessionHandle, Receiver<Vec<u8>>)> {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        let (out_tx, out_rx) = mpsc::sync_channel(1024);
        self.ctrl
            .send(Msg::Open { session: id, out: out_tx })
            .map_err(|_| anyhow::anyhow!("pipeline is shut down"))?;
        let handle = SessionHandle {
            id,
            framer: Framer::new(self.tile, self.beta),
            input: Some(self.input.clone()),
            ctrl: Some(self.ctrl.clone()),
            metrics: self.metrics.clone(),
        };
        Ok((handle, out_rx))
    }

    /// Convenience: decode one whole LLR stream through the pipeline
    /// (open session, push, finish, collect).
    pub fn decode_stream_blocking(&self, llr: &[f32], flushed_end: bool) -> Result<Vec<u8>> {
        let (mut h, rx) = self.open_session()?;
        h.push(llr)?;
        h.finish(flushed_end)?;
        let mut out = Vec::new();
        for chunk in rx {
            out.extend_from_slice(&chunk);
        }
        Ok(out)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shut down: all session handles must be finished/dropped first.
    /// Joins every pipeline thread.
    pub fn shutdown(self) -> Result<()> {
        let Coordinator { input, ctrl, threads, .. } = self;
        drop(input);
        drop(ctrl);
        for t in threads {
            t.join().map_err(|_| anyhow::anyhow!("pipeline thread panicked"))?;
        }
        Ok(())
    }
}

/// One decoding stream. Push LLR chunks; completed frames flow through
/// the pipeline with backpressure (push blocks when the queue is full).
/// `finish` releases the handle's hold on the pipeline, so a finished
/// handle never blocks `Coordinator::shutdown`.
pub struct SessionHandle {
    id: u64,
    framer: Framer,
    input: Option<SyncSender<FrameTask>>,
    ctrl: Option<Sender<Msg>>,
    metrics: Arc<Metrics>,
}

impl SessionHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    fn send_jobs(&mut self, base: u64, jobs: Vec<crate::viterbi::types::FrameJob>) -> Result<()> {
        let input = self.input.as_ref().expect("checked by callers");
        for (i, job) in jobs.into_iter().enumerate() {
            self.metrics.frames_in.fetch_add(1, Ordering::Relaxed);
            input
                .send(FrameTask {
                    session: self.id,
                    seq: base + i as u64,
                    job,
                    t_enq: Instant::now(),
                })
                .map_err(|_| anyhow::anyhow!("pipeline is shut down"))?;
        }
        Ok(())
    }

    /// Push an LLR chunk (length must be a multiple of beta).
    pub fn push(&mut self, llr: &[f32]) -> Result<()> {
        anyhow::ensure!(self.input.is_some(), "session already finished");
        let base = self.framer.frames_emitted() as u64;
        let jobs = self.framer.push(llr);
        self.send_jobs(base, jobs)
    }

    /// Flush the stream: emits the remaining (padded) frames, tells the
    /// reassembler the total frame count so it can close the output, and
    /// drops this handle's pipeline senders.
    pub fn finish(&mut self, flushed_end: bool) -> Result<()> {
        anyhow::ensure!(self.input.is_some(), "session already finished");
        let base = self.framer.frames_emitted() as u64;
        let jobs = self.framer.finish(flushed_end);
        self.send_jobs(base, jobs)?;
        let total = self.framer.frames_emitted() as u64;
        let ctrl = self.ctrl.take().expect("ctrl present until finish");
        self.input = None;
        ctrl.send(Msg::Finish { session: self.id, total_frames: total })
            .map_err(|_| anyhow::anyhow!("pipeline is shut down"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::registry;
    use crate::coding::Encoder;
    use crate::util::rng::Rng;
    use crate::viterbi::scalar;

    fn cpu_config(tile: TileConfig) -> CoordinatorConfig {
        CoordinatorConfig {
            backend: BackendSpec::CpuPacked {
                code: "ccsds".into(),
                scheme: "radix4".into(),
                stages: tile.frame_stages(),
                acc: crate::viterbi::types::AccPrecision::Single,
                chan: crate::channel::quantize::ChannelPrecision::Single,
                renorm_every: 16,
            },
            tile,
            max_batch: 8,
            batch_deadline: Duration::from_micros(500),
            workers: 2,
            queue_depth: 64,
        }
    }

    fn noisy_stream(seed: u64, payload_bits: usize, ebn0: f64) -> (Vec<u8>, Vec<f32>) {
        let code = registry::paper_code();
        let mut enc = Encoder::new(code.clone());
        let mut bits = Rng::new(seed).bits(payload_bits - 6);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(ebn0, 0.5, seed ^ 0xFEED);
        let rx = ch.transmit(&tx);
        (bits, rx.iter().map(|&x| x as f32).collect())
    }

    #[test]
    fn pipeline_decodes_one_stream() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (bits, llr) = noisy_stream(42, 256, 5.0);
        let out = coord.decode_stream_blocking(&llr, true).unwrap();
        assert_eq!(out, bits);
        let snap = coord.metrics();
        assert_eq!(snap.frames_in, 8);
        assert_eq!(snap.frames_out, 8);
        coord.shutdown().unwrap();
    }

    #[test]
    fn pipeline_handles_concurrent_sessions() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let coord = Arc::new(Coordinator::start(cpu_config(tile)).unwrap());
        let mut joins = Vec::new();
        for s in 0..4u64 {
            let c = coord.clone();
            joins.push(std::thread::spawn(move || {
                let (bits, llr) = noisy_stream(100 + s, 128, 5.0);
                let out = c.decode_stream_blocking(&llr, true).unwrap();
                assert_eq!(out, bits, "session {s}");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let coord = Arc::try_unwrap(coord).ok().expect("all sessions done");
        let snap = coord.metrics();
        assert_eq!(snap.frames_out, 16);
        assert!(snap.mean_batch >= 1.0);
        coord.shutdown().unwrap();
    }

    #[test]
    fn chunked_push_matches_reference() {
        let tile = TileConfig { payload: 64, head: 24, tail: 24 };
        let coord = Coordinator::start(cpu_config(tile)).unwrap();
        let (bits, llr) = noisy_stream(7, 512, 5.0);
        let (mut h, rx) = coord.open_session().unwrap();
        for chunk in llr.chunks(46) {
            // 23-stage odd chunks
            h.push(chunk).unwrap();
        }
        h.finish(true).unwrap();
        let mut out = Vec::new();
        for c in rx {
            out.extend_from_slice(&c);
        }
        assert_eq!(out, bits);
        // scalar reference agrees (up to half rounding of B) at 5 dB
        let t = coord.trellis().clone();
        let lam0 = scalar::initial_metrics(64, Some(0));
        let llr_h: Vec<f32> =
            llr.iter().map(|&x| crate::util::half::HalfKind::Bf16.round(x)).collect();
        let whole = scalar::decode(&t, &llr_h, &lam0, Some(0));
        assert_eq!(out, whole);
        coord.shutdown().unwrap();
    }

    #[test]
    fn mismatched_tile_rejected() {
        let tile = TileConfig { payload: 32, head: 16, tail: 16 };
        let mut cfg = cpu_config(tile);
        // backend frame stages disagree with tile geometry
        if let BackendSpec::CpuPacked { ref mut stages, .. } = cfg.backend {
            *stages = 128;
        }
        assert!(Coordinator::start(cfg).is_err());
    }
}
