//! L3 — the streaming SDR coordinator (the serving layer around the
//! tensor-formulated decoder).
//!
//! Shape: a vLLM-router-like pipeline specialized for convolutional
//! decoding. Many concurrent *sessions* (radio streams) push LLR
//! chunks; a per-session **framer** cuts them into overlapped frames
//! (§III tiling); a **dispatcher** routes each frame to its session's
//! home **engine shard** by affinity hash — every shard owns a private
//! backend instance (the PJRT executable or its CPU emulation), a
//! bounded work queue and a **dynamic batcher**, and idle shards steal
//! from the deepest sibling queue; a shared **traceback worker pool**
//! runs the backward procedure (the paper's scalar-core stage); the
//! **reassembler** restores per-session bit order and delivers in-order
//! decoded payloads with backpressure end to end. Python is never on
//! this path.
//!
//! ```text
//! sessions ──framer──▶ input ──dispatcher──▶ shard queues ──engines──▶
//!   raw survivors ──traceback pool──▶ reassembly ──▶ per-session output
//! ```
//!
//! Guarantees (documented in full in `docs/ARCHITECTURE.md`):
//!
//! * **Ordering** — each session's decoded payload chunks arrive in
//!   stream order, regardless of which shard decoded which frame or in
//!   what order frames finished.
//! * **Determinism** — decoded bits are a pure function of the LLR
//!   stream and the decoder configuration; the shard count and thread
//!   scheduling never change the output.
//! * **Backpressure** — `Session::push` blocks once the input channel
//!   plus the shard queues are full; frames are never dropped.
//! * **Fault isolation** — a panicking engine shard is caught by its
//!   supervisor and restarted (degrading its backend down the chain in
//!   `BackendSpec::degraded` if it keeps faulting); only sessions with
//!   frames in flight on that shard see an error — exactly one, typed
//!   and retryable, after their gapless decoded prefix. See
//!   `docs/RELIABILITY.md`.
//!
//! Construction goes through [`crate::api::DecoderBuilder::serve`]; the
//! shard count comes from [`crate::api::DecoderBuilder::shards`]
//! (default: available parallelism).

pub mod framer;
pub mod metrics;
pub mod backend;
pub mod shard;
pub mod engine;
pub mod reassembly;
pub mod server;

use std::time::Instant;

use crate::viterbi::types::FrameJob;

pub use backend::BackendSpec;
pub use metrics::{poller_code, Metrics, MetricsSnapshot, NetSnapshot, NetStats, ShardSnapshot};
pub use server::{Coordinator, Session, SessionHandle};
pub use shard::home_shard;

/// A frame travelling through the pipeline.
#[derive(Clone, Debug)]
pub struct FrameTask {
    pub session: u64,
    pub seq: u64,
    pub job: FrameJob,
    pub t_enq: Instant,
}

/// A decoded frame heading back to its session.
#[derive(Debug)]
pub struct DecodedFrame {
    pub session: u64,
    pub seq: u64,
    pub bits: Vec<u8>,
    pub t_enq: Instant,
}
