//! L3 — the streaming SDR coordinator (the serving layer around the
//! tensor-formulated decoder).
//!
//! Shape: a vLLM-router-like pipeline specialized for convolutional
//! decoding. Many concurrent *sessions* (radio streams) push LLR chunks;
//! a per-session **framer** cuts them into overlapped frames (§III
//! tiling); a **dynamic batcher** packs frames from all sessions into
//! full artifact batches (size + deadline policy); the **engine thread**
//! owns the PJRT executable and runs the tensor forward pass; a
//! **traceback worker pool** runs the backward procedure (the paper's
//! scalar-core stage); the **reassembler** restores per-session bit
//! order and delivers in-order decoded payloads with backpressure end to
//! end. Python is never on this path.

pub mod framer;
pub mod metrics;
pub mod backend;
pub mod engine;
pub mod reassembly;
pub mod server;

use std::time::Instant;

use crate::viterbi::types::FrameJob;

pub use backend::BackendSpec;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Coordinator, Session, SessionHandle};

/// A frame travelling through the pipeline.
#[derive(Clone, Debug)]
pub struct FrameTask {
    pub session: u64,
    pub seq: u64,
    pub job: FrameJob,
    pub t_enq: Instant,
}

/// A decoded frame heading back to its session.
#[derive(Debug)]
pub struct DecodedFrame {
    pub session: u64,
    pub seq: u64,
    pub bits: Vec<u8>,
    pub t_enq: Instant,
}
