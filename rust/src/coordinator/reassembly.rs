//! Per-session in-order reassembly: decoded frames arrive out of order
//! from the worker pool — and, with a sharded coordinator, from frames
//! decoded on different engine shards in any interleaving — yet each
//! session's payload bits are delivered to its consumer strictly in
//! sequence.
//!
//! This stage is what makes shard routing and work-stealing invisible
//! to sessions: frames are buffered per session keyed by their sequence
//! number and released only when contiguous, so the delivery order is a
//! pure function of the framing, never of scheduling. A session's
//! output channel closes once `total_frames` (announced by
//! `Session::finish`) have been delivered, which terminates the
//! consumer-side iterator.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::{Receiver, SyncSender};

use super::DecodedFrame;

/// Control + data messages for the reassembly thread.
pub enum Msg {
    Open { session: u64, out: SyncSender<Vec<u8>> },
    /// Total frames the session will produce (sent at session finish).
    Finish { session: u64, total_frames: u64 },
    Decoded(DecodedFrame),
}

struct SessionState {
    out: SyncSender<Vec<u8>>,
    next_seq: u64,
    pending: BTreeMap<u64, Vec<u8>>,
    total_frames: Option<u64>,
}

impl SessionState {
    /// Deliver any now-contiguous frames; returns true when complete.
    fn drain(&mut self) -> bool {
        while let Some(bits) = self.pending.remove(&self.next_seq) {
            // a closed consumer just discards remaining output
            let _ = self.out.send(bits);
            self.next_seq += 1;
        }
        self.total_frames == Some(self.next_seq)
    }
}

/// Run the reassembly loop (one thread). Sessions close (dropping their
/// output sender, which ends the consumer's iterator) once all frames
/// are delivered.
pub fn run_reassembly(rx: Receiver<Msg>) {
    let mut sessions: HashMap<u64, SessionState> = HashMap::new();
    for msg in rx {
        match msg {
            Msg::Open { session, out } => {
                sessions.insert(
                    session,
                    SessionState { out, next_seq: 0, pending: BTreeMap::new(), total_frames: None },
                );
            }
            Msg::Finish { session, total_frames } => {
                if let Some(st) = sessions.get_mut(&session) {
                    st.total_frames = Some(total_frames);
                    if st.drain() {
                        sessions.remove(&session);
                    }
                }
            }
            Msg::Decoded(df) => {
                if let Some(st) = sessions.get_mut(&df.session) {
                    st.pending.insert(df.seq, df.bits);
                    if st.drain() {
                        sessions.remove(&df.session);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn decoded(session: u64, seq: u64, tag: u8) -> Msg {
        Msg::Decoded(DecodedFrame { session, seq, bits: vec![tag], t_enq: Instant::now() })
    }

    #[test]
    fn reorders_and_closes() {
        let (tx, rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(16);
        let h = std::thread::spawn(move || run_reassembly(rx));
        tx.send(Msg::Open { session: 1, out: out_tx }).unwrap();
        tx.send(decoded(1, 2, 2)).unwrap();
        tx.send(decoded(1, 0, 0)).unwrap();
        tx.send(decoded(1, 1, 1)).unwrap();
        tx.send(Msg::Finish { session: 1, total_frames: 3 }).unwrap();
        let got: Vec<Vec<u8>> = out_rx.iter().collect(); // ends when sender drops
        assert_eq!(got, vec![vec![0], vec![1], vec![2]]);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn interleaved_sessions_stay_separate() {
        let (tx, rx) = mpsc::channel();
        let (o1_tx, o1_rx) = mpsc::sync_channel(16);
        let (o2_tx, o2_rx) = mpsc::sync_channel(16);
        let h = std::thread::spawn(move || run_reassembly(rx));
        tx.send(Msg::Open { session: 1, out: o1_tx }).unwrap();
        tx.send(Msg::Open { session: 2, out: o2_tx }).unwrap();
        tx.send(decoded(2, 0, 20)).unwrap();
        tx.send(decoded(1, 1, 11)).unwrap();
        tx.send(decoded(1, 0, 10)).unwrap();
        tx.send(decoded(2, 1, 21)).unwrap();
        tx.send(Msg::Finish { session: 1, total_frames: 2 }).unwrap();
        tx.send(Msg::Finish { session: 2, total_frames: 2 }).unwrap();
        assert_eq!(o1_rx.iter().collect::<Vec<_>>(), vec![vec![10], vec![11]]);
        assert_eq!(o2_rx.iter().collect::<Vec<_>>(), vec![vec![20], vec![21]]);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn dropped_consumer_does_not_wedge() {
        let (tx, rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(1);
        let h = std::thread::spawn(move || run_reassembly(rx));
        tx.send(Msg::Open { session: 1, out: out_tx }).unwrap();
        drop(out_rx); // consumer went away
        tx.send(decoded(1, 0, 0)).unwrap();
        tx.send(Msg::Finish { session: 1, total_frames: 1 }).unwrap();
        drop(tx);
        h.join().unwrap(); // must terminate
    }
}
