//! Per-session in-order reassembly: decoded frames arrive out of order
//! from the worker pool — and, with a sharded coordinator, from frames
//! decoded on different engine shards in any interleaving — yet each
//! session's payload bits are delivered to its consumer strictly in
//! sequence.
//!
//! This stage is what makes shard routing and work-stealing invisible
//! to sessions: frames are buffered per session keyed by their sequence
//! number and released only when contiguous, so the delivery order is a
//! pure function of the framing, never of scheduling. A session's
//! output channel closes once `total_frames` (announced by
//! `Session::finish`) have been delivered, which terminates the
//! consumer-side iterator.
//!
//! Reassembly is also where shard faults become visible to consumers:
//! when the supervisor catches a shard panic it [`Msg::Poison`]s every
//! session whose frames were in flight on that shard. Poisoning first
//! delivers whatever contiguous prefix is already buffered (the gapless
//! invariant: a consumer never sees bits with a hole before them), then
//! sends exactly one `Err` and closes the session's channel. Later
//! frames of a poisoned session are ignored like any unknown session's.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::fault::{self, FaultMap};

use super::{DecodedFrame, Metrics};

/// Control + data messages for the reassembly thread.
pub enum Msg {
    Open { session: u64, out: SyncSender<Result<Vec<u8>>> },
    /// Total frames the session will produce (sent at session finish).
    Finish { session: u64, total_frames: u64 },
    Decoded(DecodedFrame),
    /// A fault took out this session's in-flight frames: deliver the
    /// contiguous prefix, then exactly one typed error, and close.
    Poison { session: u64, error: Error },
}

struct SessionState {
    out: SyncSender<Result<Vec<u8>>>,
    next_seq: u64,
    pending: BTreeMap<u64, Vec<u8>>,
    total_frames: Option<u64>,
}

impl SessionState {
    /// Deliver any now-contiguous frames; returns true when complete.
    fn drain(&mut self) -> bool {
        while let Some(bits) = self.pending.remove(&self.next_seq) {
            // a closed consumer just discards remaining output
            let _ = self.out.send(Ok(bits));
            self.next_seq += 1;
        }
        self.total_frames == Some(self.next_seq)
    }
}

/// Run the reassembly loop (one thread). Sessions close (dropping their
/// output sender, which ends the consumer's iterator) once all frames
/// are delivered — or once poisoned, after the gapless prefix plus one
/// typed error.
pub fn run_reassembly(rx: Receiver<Msg>, metrics: Arc<Metrics>, faults: Arc<FaultMap>) {
    let mut sessions: HashMap<u64, SessionState> = HashMap::new();
    let poison = |sessions: &mut HashMap<u64, SessionState>, session: u64, error: Error| {
        if let Some(mut st) = sessions.remove(&session) {
            st.drain(); // gapless prefix first, then the one error
            let _ = st.out.send(Err(error));
            metrics.sessions_poisoned.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    };
    for msg in rx {
        match msg {
            Msg::Open { session, out } => {
                sessions.insert(
                    session,
                    SessionState { out, next_seq: 0, pending: BTreeMap::new(), total_frames: None },
                );
            }
            Msg::Finish { session, total_frames } => {
                if let Some(st) = sessions.get_mut(&session) {
                    st.total_frames = Some(total_frames);
                    if st.drain() {
                        sessions.remove(&session);
                    }
                }
            }
            Msg::Decoded(df) => {
                if faults.fire(fault::site::REASSEMBLY_DELIVER) {
                    poison(
                        &mut sessions,
                        df.session,
                        Error::pipeline("failpoint reassembly.deliver fired: delivery dropped"),
                    );
                    continue;
                }
                if let Some(st) = sessions.get_mut(&df.session) {
                    st.pending.insert(df.seq, df.bits);
                    if st.drain() {
                        sessions.remove(&df.session);
                    }
                }
            }
            Msg::Poison { session, error } => poison(&mut sessions, session, error),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn decoded(session: u64, seq: u64, tag: u8) -> Msg {
        Msg::Decoded(DecodedFrame { session, seq, bits: vec![tag], t_enq: Instant::now() })
    }

    fn spawn_reassembly(
        rx: Receiver<Msg>,
    ) -> (Arc<Metrics>, std::thread::JoinHandle<()>) {
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let h = std::thread::spawn(move || run_reassembly(rx, m, Arc::new(FaultMap::default())));
        (metrics, h)
    }

    #[test]
    fn reorders_and_closes() {
        let (tx, rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(16);
        let (_m, h) = spawn_reassembly(rx);
        tx.send(Msg::Open { session: 1, out: out_tx }).unwrap();
        tx.send(decoded(1, 2, 2)).unwrap();
        tx.send(decoded(1, 0, 0)).unwrap();
        tx.send(decoded(1, 1, 1)).unwrap();
        tx.send(Msg::Finish { session: 1, total_frames: 3 }).unwrap();
        // ends when the sender drops; no errors on the clean path
        let got: Vec<Vec<u8>> = out_rx.iter().map(|c| c.unwrap()).collect();
        assert_eq!(got, vec![vec![0], vec![1], vec![2]]);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn interleaved_sessions_stay_separate() {
        let (tx, rx) = mpsc::channel();
        let (o1_tx, o1_rx) = mpsc::sync_channel(16);
        let (o2_tx, o2_rx) = mpsc::sync_channel(16);
        let (_m, h) = spawn_reassembly(rx);
        tx.send(Msg::Open { session: 1, out: o1_tx }).unwrap();
        tx.send(Msg::Open { session: 2, out: o2_tx }).unwrap();
        tx.send(decoded(2, 0, 20)).unwrap();
        tx.send(decoded(1, 1, 11)).unwrap();
        tx.send(decoded(1, 0, 10)).unwrap();
        tx.send(decoded(2, 1, 21)).unwrap();
        tx.send(Msg::Finish { session: 1, total_frames: 2 }).unwrap();
        tx.send(Msg::Finish { session: 2, total_frames: 2 }).unwrap();
        let drain = |rx: Receiver<Result<Vec<u8>>>| -> Vec<Vec<u8>> {
            rx.iter().map(|c| c.unwrap()).collect()
        };
        assert_eq!(drain(o1_rx), vec![vec![10], vec![11]]);
        assert_eq!(drain(o2_rx), vec![vec![20], vec![21]]);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn dropped_consumer_does_not_wedge() {
        let (tx, rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(1);
        let (_m, h) = spawn_reassembly(rx);
        tx.send(Msg::Open { session: 1, out: out_tx }).unwrap();
        drop(out_rx); // consumer went away
        tx.send(decoded(1, 0, 0)).unwrap();
        tx.send(Msg::Finish { session: 1, total_frames: 1 }).unwrap();
        drop(tx);
        h.join().unwrap(); // must terminate
    }

    #[test]
    fn poison_delivers_gapless_prefix_then_one_error() {
        let (tx, rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(16);
        let (metrics, h) = spawn_reassembly(rx);
        tx.send(Msg::Open { session: 1, out: out_tx }).unwrap();
        tx.send(decoded(1, 0, 0)).unwrap();
        tx.send(decoded(1, 2, 2)).unwrap(); // seq 1 missing: must never surface
        tx.send(Msg::Poison {
            session: 1,
            error: Error::pipeline("shard-restart: shard 0 panicked"),
        })
        .unwrap();
        // a frame arriving after the poison is ignored, not delivered
        tx.send(decoded(1, 1, 1)).unwrap();
        drop(tx);
        h.join().unwrap();
        let got: Vec<Result<Vec<u8>>> = out_rx.iter().collect();
        assert_eq!(got.len(), 2, "prefix then exactly one error: {got:?}");
        assert_eq!(got[0], Ok(vec![0]));
        let e = got[1].clone().unwrap_err();
        assert!(e.is_retryable(), "{e}");
        assert_eq!(
            metrics.sessions_poisoned.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn poison_of_unknown_or_closed_session_is_ignored() {
        let (tx, rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::sync_channel(16);
        let (metrics, h) = spawn_reassembly(rx);
        tx.send(Msg::Open { session: 1, out: out_tx }).unwrap();
        tx.send(decoded(1, 0, 0)).unwrap();
        tx.send(Msg::Finish { session: 1, total_frames: 1 }).unwrap();
        // session 1 completed above; poisons for it and for a session
        // that never existed must both be no-ops
        tx.send(Msg::Poison { session: 1, error: Error::pipeline("late") }).unwrap();
        tx.send(Msg::Poison { session: 99, error: Error::pipeline("ghost") }).unwrap();
        drop(tx);
        h.join().unwrap();
        let got: Vec<Result<Vec<u8>>> = out_rx.iter().collect();
        assert_eq!(got, vec![Ok(vec![0])]);
        assert_eq!(metrics.sessions_poisoned.load(std::sync::atomic::Ordering::Relaxed), 0);
    }
}
