//! Minimal CLI argument parsing (offline image: no clap). Flags are
//! `--key value` pairs plus positional words; subcommands dispatch in
//! `main.rs`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand, positionals and `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(), // boolean flag
                };
                a.flags.insert(key.to_string(), value);
            } else if a.command.is_empty() {
                a.command = arg.clone();
            } else {
                a.positional.push(arg.clone());
            }
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on unknown flags (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k}; known flags: {}",
                      known.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(" "));
            }
        }
        Ok(())
    }
}

/// Build a `BackendSpec` from the common `--backend/--artifacts/--variant`
/// flag triple used by several subcommands.
pub fn backend_from_flags(backend: &str, artifacts: &str, variant: &str,
                          stages: usize) -> Result<crate::coordinator::BackendSpec> {
    use crate::channel::quantize::ChannelPrecision;
    use crate::coordinator::BackendSpec;
    use crate::util::half::HalfKind;
    use crate::viterbi::AccPrecision;
    let cpu = |scheme: &str, acc: AccPrecision, chan: ChannelPrecision| BackendSpec::CpuPacked {
        code: "ccsds".into(),
        scheme: scheme.into(),
        stages,
        acc,
        chan,
        renorm_every: 16,
    };
    Ok(match backend {
        "artifact" | "pjrt" => BackendSpec::artifact(artifacts, variant),
        "scalar" => crate::coordinator::BackendSpec::Scalar { code: "ccsds".into(), stages },
        "cpu-radix2" => cpu("radix2", AccPrecision::Single, ChannelPrecision::Single),
        "cpu-radix4" => cpu("radix4", AccPrecision::Single, ChannelPrecision::Single),
        "cpu-radix4-noperm" => cpu("radix4_noperm", AccPrecision::Single, ChannelPrecision::Single),
        "cpu-radix4-half" => cpu("radix4", AccPrecision::Half(HalfKind::Bf16),
                                  ChannelPrecision::Single),
        "cpu-radix4-half-f16" => cpu("radix4", AccPrecision::Half(HalfKind::F16),
                                      ChannelPrecision::Single),
        other => bail!(
            "unknown backend {other:?}; known: artifact scalar cpu-radix2 cpu-radix4 \
             cpu-radix4-noperm cpu-radix4-half cpu-radix4-half-f16"
        ),
    })
}

/// Print top-level usage.
pub fn print_usage() {
    println!(
        "tcvd — tensor-formulated parallel Viterbi decoder

USAGE: tcvd <command> [--flag value ...]

COMMANDS
  info       platform, artifact manifest, registered codes
  selftest   encode/corrupt/decode round trip on every backend
  encode     --code ccsds --bits N [--in file] [--out file]
  decode     --in llr.f32le [--backend artifact|cpu-radix4|scalar] [--out bits]
  ber        --snr 0:6:1 [--errors 100] [--max-bits N] [--backend ...] [--hard]
  serve      --sessions 8 --bits 65536 --snr 5 [--backend ...] [--json]

Run `make artifacts` first to build the AOT decoder artifacts."
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("ber --snr 0:8:0.5 --bits 100000 --hard");
        assert_eq!(a.command, "ber");
        assert_eq!(a.get("snr"), Some("0:8:0.5"));
        assert_eq!(a.get_usize("bits", 0).unwrap(), 100_000);
        assert!(a.get_bool("hard"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn check_known_catches_typos() {
        let a = parse("serve --sesions 4");
        assert!(a.check_known(&["sessions"]).is_err());
        let b = parse("serve --sessions 4");
        assert!(b.check_known(&["sessions"]).is_ok());
    }
}
