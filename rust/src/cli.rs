//! Minimal CLI argument parsing (offline image: no clap), plus a
//! declarative flag-spec layer.
//!
//! Flags are `--key value` pairs and boolean `--switch`es. Each
//! subcommand in `main.rs` declares a [`CommandSpec`] — largely
//! generated from the `api::DecoderBuilder` option set
//! (`api::builder_flags`), so a new builder option (e.g. `--shards`)
//! appears on every pipeline-constructing subcommand with its default
//! rendered into the help text. Specs reject unknown flags (typos fail
//! instead of being silently ignored) and render the per-subcommand
//! `--help` text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: subcommand, positionals and `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(), // boolean flag
                };
                a.flags.insert(key.to_string(), value);
            } else if a.command.is_empty() {
                a.command = arg.clone();
            } else {
                a.positional.push(arg.clone());
            }
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{key} expects a number, got {v:?}"))),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on unknown flags (catches typos). `--help` is always known.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if k == "help" {
                continue;
            }
            if !known.contains(&k.as_str()) {
                return Err(Error::config(format!(
                    "unknown flag --{k}; known flags: {}",
                    known.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(" ")
                )));
            }
        }
        Ok(())
    }
}

/// One `--flag` a subcommand accepts.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// Value placeholder for help text; empty for boolean switches.
    pub value: &'static str,
    /// One-line description (typically embeds the default).
    pub help: String,
}

impl FlagSpec {
    pub fn new(name: &'static str, value: &'static str, help: impl Into<String>) -> FlagSpec {
        FlagSpec { name, value, help: help.into() }
    }
}

/// A subcommand's declared interface: summary + accepted flags.
#[derive(Clone, Debug)]
pub struct CommandSpec {
    pub name: &'static str,
    pub summary: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl CommandSpec {
    pub fn new(name: &'static str, summary: &'static str, flags: Vec<FlagSpec>) -> CommandSpec {
        CommandSpec { name, summary, flags }
    }

    /// Reject flags this subcommand does not declare.
    pub fn check(&self, args: &Args) -> Result<()> {
        let known: Vec<&str> = self.flags.iter().map(|f| f.name).collect();
        args.check_known(&known)
            .map_err(|e| e.context(format!("tcvd {} (see `tcvd {} --help`)", self.name, self.name)))
    }

    /// Render `tcvd <cmd> --help`.
    pub fn usage(&self) -> String {
        let mut s = format!(
            "tcvd {} — {}\n\nUSAGE: tcvd {} [--flag value ...]\n",
            self.name, self.summary, self.name
        );
        if !self.flags.is_empty() {
            s.push_str("\nFLAGS\n");
            for f in &self.flags {
                let lhs = if f.value.is_empty() {
                    format!("--{}", f.name)
                } else {
                    format!("--{} <{}>", f.name, f.value)
                };
                s.push_str(&format!("  {lhs:<26} {}\n", f.help));
            }
        }
        s
    }
}

/// Print top-level usage from the command table.
pub fn print_usage(specs: &[CommandSpec]) {
    println!(
        "tcvd — tensor-formulated parallel Viterbi decoder\n\n\
         USAGE: tcvd <command> [--flag value ...]\n\
         \x20      tcvd <command> --help\n\nCOMMANDS"
    );
    for sp in specs {
        println!("  {:<10} {}", sp.name, sp.summary);
    }
    println!("\nRun `make artifacts` first to build the AOT decoder artifacts.");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("ber --snr 0:8:0.5 --bits 100000 --hard");
        assert_eq!(a.command, "ber");
        assert_eq!(a.get("snr"), Some("0:8:0.5"));
        assert_eq!(a.get_usize("bits", 0).unwrap(), 100_000);
        assert!(a.get_bool("hard"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        let e = a.get_usize("n", 1).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn check_known_catches_typos() {
        let a = parse("serve --sesions 4");
        assert!(a.check_known(&["sessions"]).is_err());
        let b = parse("serve --sessions 4");
        assert!(b.check_known(&["sessions"]).is_ok());
    }

    #[test]
    fn help_flag_is_always_known() {
        let a = parse("serve --help");
        assert!(a.check_known(&[]).is_ok());
    }

    #[test]
    fn command_spec_checks_and_renders() {
        let spec = CommandSpec::new(
            "demo",
            "demo command",
            vec![
                FlagSpec::new("bits", "N", "payload bits (default 1024)"),
                FlagSpec::new("hard", "", "hard-decision inputs"),
            ],
        );
        assert!(spec.check(&parse("demo --bits 5")).is_ok());
        let e = spec.check(&parse("demo --bots 5")).unwrap_err();
        assert!(e.to_string().contains("unknown flag --bots"), "{e}");
        let u = spec.usage();
        assert!(u.contains("--bits <N>"));
        assert!(u.contains("--hard"));
    }
}
