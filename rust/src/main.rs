//! `tcvd` — tensor-formulated parallel Viterbi decoder (launcher).
//!
//! Subcommands:
//! * `info`      — platform, artifact manifest, registered codes
//! * `selftest`  — encode/corrupt/decode round trip on every backend
//! * `encode`    — encode random or file bits, write coded bits
//! * `decode`    — decode an LLR stream (f32 little-endian file)
//! * `ber`       — Eb/N0 sweep (Fig-13-style), JSON + table output
//! * `serve`     — run the streaming coordinator under a synthetic
//!                 multi-session SDR workload, report throughput/latency

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use tcvd::ber::{measure_ber, sweep, BerSetup};
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::cli::{backend_from_flags, print_usage, Args};
use tcvd::coding::{registry, Encoder, Trellis};
use tcvd::config::Config;
use tcvd::coordinator::server::CoordinatorConfig;
use tcvd::coordinator::{BackendSpec, Coordinator};
use tcvd::runtime::{client, Manifest};
use tcvd::util::rng::Rng;
use tcvd::viterbi::tiled::TileConfig;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "info" => cmd_info(&args),
        "selftest" => cmd_selftest(&args),
        "encode" => cmd_encode(&args),
        "decode" => cmd_decode(&args),
        "ber" => cmd_ber(&args),
        "serve" => cmd_serve(&args),
        "" | "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    args.check_known(&["artifacts"])?;
    let c = client::cpu_client()?;
    println!("{}", client::platform_summary(&c));
    println!("\nregistered codes:");
    for sc in registry::STANDARD_CODES {
        println!("  {:8} {}", sc.name, sc.description);
    }
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("\nartifacts in {}:", dir.display());
            for a in &m.artifacts {
                println!(
                    "  {:50} scheme={:14} Q={:<4} batch={:3} stages={}",
                    a.name, a.scheme, a.ops_per_stage, a.batch, a.stages_per_frame
                );
            }
        }
        Err(e) => println!("\n(no artifacts: {e})"),
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    args.check_known(&["artifacts", "bits", "snr", "seed"])?;
    let n_bits = args.get_usize("bits", 4096)?;
    let snr = args.get_f64("snr", 5.0)?;
    let seed = args.get_u64("seed", 7)?;
    let code = registry::paper_code();
    let mut enc = Encoder::new(code.clone());
    let mut payload = Rng::new(seed).bits(n_bits - 6);
    payload.extend_from_slice(&[0; 6]);
    let coded = enc.encode(&payload);
    let tx = bpsk::modulate(&coded);
    let mut ch = AwgnChannel::new(snr, code.rate(), seed ^ 0xA5A5);
    let rx = ch.transmit(&tx);
    let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();

    let dir = args.get_or("artifacts", "artifacts");
    // the b64_s48 artifact decodes 96-stage frames: 64 payload + 16/16
    let tile_cpu = TileConfig { payload: 64, head: 32, tail: 32 };
    let tile_pjrt = TileConfig { payload: 64, head: 16, tail: 16 };
    let backends: Vec<(&str, TileConfig, BackendSpec)> = vec![
        ("scalar", tile_cpu,
         BackendSpec::Scalar { code: "ccsds".into(), stages: tile_cpu.frame_stages() }),
        ("cpu-radix2", tile_cpu,
         backend_from_flags("cpu-radix2", &dir, "", tile_cpu.frame_stages())?),
        ("cpu-radix4", tile_cpu,
         backend_from_flags("cpu-radix4", &dir, "", tile_cpu.frame_stages())?),
        ("pjrt-artifact", tile_pjrt,
         BackendSpec::artifact(dir.clone(), "radix4_jnp_acc-single_ch-single_b64_s48")),
    ];
    for (name, tile, spec) in backends {
        let coord = match Coordinator::start(CoordinatorConfig {
            backend: spec,
            tile,
            max_batch: 64,
            batch_deadline: Duration::from_micros(200),
            workers: 2,
            queue_depth: 256,
        }) {
            Ok(c) => c,
            Err(e) => {
                println!("{name:14} SKIP ({e})");
                continue;
            }
        };
        let out = coord.decode_stream_blocking(&llr, true)?;
        let errors = out.iter().zip(&payload).filter(|(a, b)| a != b).count();
        let snap = coord.metrics();
        println!(
            "{name:14} errors={errors:4}/{n_bits}  frames={} mean_batch={:.1} p99={:.0}us",
            snap.frames_out, snap.mean_batch, snap.latency_p99_us
        );
        coord.shutdown()?;
    }
    Ok(())
}

fn cmd_encode(args: &Args) -> Result<()> {
    args.check_known(&["code", "bits", "seed", "out", "in"])?;
    let code = registry::lookup(&args.get_or("code", "ccsds"))?;
    let mut enc = Encoder::new(code);
    let payload: Vec<u8> = match args.get("in") {
        Some(path) => std::fs::read(path)
            .with_context(|| format!("reading {path}"))?
            .iter()
            .flat_map(|b| (0..8).map(move |i| (b >> i) & 1))
            .collect(),
        None => Rng::new(args.get_u64("seed", 1)?).bits(args.get_usize("bits", 1024)?),
    };
    let (coded, n_in) = enc.encode_flushed(&payload);
    match args.get("out") {
        Some(path) => {
            let packed = tcvd::util::bitvec::BitVec::from_bits(&coded);
            let bytes: Vec<u8> = packed.words().iter().flat_map(|w| w.to_le_bytes()).collect();
            std::fs::write(path, bytes)?;
            println!("encoded {} info bits -> {} coded bits -> {path}", n_in, coded.len());
        }
        None => println!(
            "encoded {} info bits -> {} coded bits (use --out to save)",
            n_in,
            coded.len()
        ),
    }
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    args.check_known(&["in", "out", "artifacts", "variant", "payload", "head", "tail",
                       "backend", "workers", "batch-deadline-us", "config"])?;
    let cfg = match args.get("config") {
        Some(p) => Config::from_file(std::path::Path::new(p))?,
        None => Config::default(),
    };
    let path = args.get("in").context("--in <llr.f32le> is required")?;
    let raw = std::fs::read(path)?;
    anyhow::ensure!(raw.len() % 4 == 0, "LLR file must be f32 little-endian");
    let llr: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();

    let tile = TileConfig {
        payload: args.get_usize("payload", cfg.tile.payload)?,
        head: args.get_usize("head", cfg.tile.head)?,
        tail: args.get_usize("tail", cfg.tile.tail)?,
    };
    let backend = backend_from_flags(
        &args.get_or("backend", "artifact"),
        &args.get_or("artifacts", &cfg.artifacts_dir),
        &args.get_or("variant", &cfg.variant),
        tile.frame_stages(),
    )?;
    let coord = Coordinator::start(CoordinatorConfig {
        backend,
        tile,
        max_batch: cfg.max_batch,
        batch_deadline: Duration::from_micros(
            args.get_u64("batch-deadline-us", cfg.batch_deadline_us)?,
        ),
        workers: args.get_usize("workers", cfg.workers)?,
        queue_depth: cfg.queue_depth,
    })?;
    let bits = coord.decode_stream_blocking(&llr, false)?;
    let snap = coord.metrics();
    if let Some(p) = args.get("out") {
        let packed = tcvd::util::bitvec::BitVec::from_bits(&bits);
        let bytes: Vec<u8> = packed.words().iter().flat_map(|w| w.to_le_bytes()).collect();
        std::fs::write(p, bytes)?;
    }
    println!(
        "decoded {} bits in {:.3}s ({:.2} Mb/s info) frames={} mean_batch={:.1}",
        bits.len(),
        snap.elapsed_s,
        snap.throughput_bps / 1e6,
        snap.frames_out,
        snap.mean_batch
    );
    coord.shutdown()?;
    Ok(())
}

fn cmd_ber(args: &Args) -> Result<()> {
    args.check_known(&["snr", "errors", "max-bits", "backend", "artifacts", "variant",
                       "payload", "head", "tail", "hard", "exact-llr", "out", "seed"])?;
    let snrs = sweep::parse_range(&args.get_or("snr", "0:6:1"))?;
    let tile = TileConfig {
        payload: args.get_usize("payload", 64)?,
        head: args.get_usize("head", 32)?,
        tail: args.get_usize("tail", 32)?,
    };
    let setup = BerSetup {
        tile,
        target_errors: args.get_usize("errors", 100)?,
        max_bits: args.get_usize("max-bits", 1_000_000)?,
        bits_per_round: 8192,
        hard_decision: args.get_bool("hard"),
        exact_llr: args.get_bool("exact-llr"),
        seed: args.get_u64("seed", 0x7C5D)?,
    };
    let backend = backend_from_flags(
        &args.get_or("backend", "cpu-radix4"),
        &args.get_or("artifacts", "artifacts"),
        &args.get_or("variant", "radix4_jnp_acc-single_ch-single_b64_s48"),
        tile.frame_stages(),
    )?;
    let mut dec = backend.build()?;
    let trellis = Trellis::new(registry::paper_code());
    println!("{:>8} {:>12} {:>12} {:>10}", "Eb/N0", "bits", "errors", "BER");
    let mut points = Vec::new();
    for &db in &snrs {
        let p = measure_ber(dec.as_mut(), &trellis, db, &setup)?;
        println!(
            "{:8.2} {:12} {:12} {:10.3e}{}",
            db,
            p.bits,
            p.errors,
            p.ber(),
            if p.reliable() { "" } else { "  (unreliable)" }
        );
        points.push(p);
    }
    if let Some(out) = args.get("out") {
        let j = sweep::curves_json(&[(dec.label(), points)]);
        std::fs::write(out, j.to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&["sessions", "bits", "snr", "backend", "artifacts", "variant",
                       "payload", "head", "tail", "workers", "max-batch",
                       "batch-deadline-us", "seed", "json"])?;
    let sessions = args.get_usize("sessions", 8)?;
    let bits_per_session = args.get_usize("bits", 65536)?;
    let snr = args.get_f64("snr", 5.0)?;
    let tile = TileConfig {
        payload: args.get_usize("payload", 64)?,
        head: args.get_usize("head", 16)?,
        tail: args.get_usize("tail", 16)?,
    };
    let backend = backend_from_flags(
        &args.get_or("backend", "artifact"),
        &args.get_or("artifacts", "artifacts"),
        &args.get_or("variant", "radix4_jnp_acc-single_ch-single_b64_s48"),
        tile.frame_stages(),
    )?;
    let coord = Coordinator::start(CoordinatorConfig {
        backend,
        tile,
        max_batch: args.get_usize("max-batch", 64)?,
        batch_deadline: Duration::from_micros(args.get_u64("batch-deadline-us", 2000)?),
        workers: args.get_usize("workers", 2)?,
        queue_depth: 1024,
    })?;

    let seed0 = args.get_u64("seed", 99)?;
    let code = registry::paper_code();
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for s in 0..sessions {
            let coord = &coord;
            let code = code.clone();
            joins.push(scope.spawn(move || -> Result<(usize, usize)> {
                let mut rng = Rng::new(seed0 + s as u64);
                let mut enc = Encoder::new(code.clone());
                let mut payload = rng.bits(bits_per_session - 6);
                payload.extend_from_slice(&[0; 6]);
                let coded = enc.encode(&payload);
                let tx = bpsk::modulate(&coded);
                let mut ch = AwgnChannel::new(snr, code.rate(), seed0 ^ ((s as u64) << 8));
                let rx = ch.transmit(&tx);
                let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();
                let (mut h, out) = coord.open_session()?;
                for chunk in llr.chunks(2048) {
                    h.push(chunk)?; // SDR-sized chunks, backpressured
                }
                h.finish(true)?;
                let mut decoded = Vec::new();
                for c in out {
                    decoded.extend_from_slice(&c);
                }
                let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
                Ok((decoded.len(), errors))
            }));
        }
        let mut total_bits = 0usize;
        let mut total_errors = 0usize;
        for j in joins {
            let (b, e) = j.join().expect("session thread panicked")?;
            total_bits += b;
            total_errors += e;
        }
        let snap = coord.metrics();
        println!(
            "sessions={sessions} decoded={total_bits} bits errors={total_errors} (BER {:.2e})",
            total_errors as f64 / total_bits.max(1) as f64
        );
        println!(
            "throughput={:.3} Mb/s  execs={} mean_batch={:.1} p50={:.0}us p99={:.0}us",
            snap.throughput_bps / 1e6,
            snap.execs,
            snap.mean_batch,
            snap.latency_p50_us,
            snap.latency_p99_us
        );
        if args.get_bool("json") {
            println!("{}", snap.to_json().to_string_pretty());
        }
        Ok(())
    })?;
    coord.shutdown()?;
    Ok(())
}
