//! `tcvd` — tensor-formulated parallel Viterbi decoder (launcher).
//!
//! Subcommands (each supports `--help`):
//! * `info`      — platform, artifact manifest, registered codes
//! * `selftest`  — encode/corrupt/decode round trip on every backend
//! * `encode`    — encode random or file bits, write coded bits
//! * `decode`    — decode an LLR stream (f32 little-endian file)
//! * `ber`       — Eb/N0 sweep (Fig-13-style), JSON + table output
//! * `serve`     — serve the coordinator over TCP/UDP sockets
//!                 (`--listen`/`--udp`; see `docs/NETWORKING.md`), or —
//!                 with no listen address — run the legacy synthetic
//!                 multi-session SDR workload and report metrics
//! * `metrics`   — fetch a metrics snapshot from a running server
//!
//! Every pipeline is constructed through `tcvd::api::DecoderBuilder`
//! (TOML config via `--config`, then `--flag` overrides); all errors
//! are the typed `tcvd::Error`.

use std::path::PathBuf;

use tcvd::api::{self, DecoderBuilder};
use tcvd::ber::{measure_ber, sweep, BerSetup};
use tcvd::channel::{awgn::AwgnChannel, bpsk};
use tcvd::cli::{print_usage, Args, CommandSpec, FlagSpec};
use tcvd::coding::{registry, Encoder, TerminationMode};
use tcvd::defaults;
use tcvd::config::Config;
use tcvd::error::{Error, Result, ResultExt};
use tcvd::net::NetConfig;
use tcvd::runtime::{client, Manifest};
use tcvd::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// The declared interface of every subcommand: pipeline-constructing
/// commands inherit the builder's option set from `api::builder_flags`.
fn command_specs() -> Vec<CommandSpec> {
    let artifacts_flag = || {
        FlagSpec::new(
            "artifacts",
            "DIR",
            format!("artifact directory (default {:?})", defaults::ARTIFACTS_DIR),
        )
    };
    vec![
        CommandSpec::new(
            "info",
            "platform, artifact manifest, registered codes",
            vec![artifacts_flag()],
        ),
        CommandSpec::new(
            "selftest",
            "encode/corrupt/decode round trip on every backend and termination mode",
            vec![
                artifacts_flag(),
                FlagSpec::new("bits", "N", "payload bits (default 4096)"),
                FlagSpec::new("snr", "DB", "Eb/N0 in dB (default 5.0)"),
                FlagSpec::new("seed", "N", "PRNG seed (default 7)"),
            ],
        ),
        CommandSpec::new(
            "encode",
            "encode random or file bits, write coded bits",
            vec![
                FlagSpec::new(
                    "code",
                    "NAME",
                    format!("standard code (default {:?})", defaults::CODE),
                ),
                FlagSpec::new("bits", "N", "random payload bits (default 1024)"),
                FlagSpec::new("seed", "N", "PRNG seed for random payload (default 1)"),
                FlagSpec::new("in", "PATH", "read payload bits from file instead"),
                FlagSpec::new("out", "PATH", "write packed coded bits here"),
                FlagSpec::new(
                    "termination",
                    "MODE",
                    format!(
                        "block termination, one of: {} (default {:?})",
                        TerminationMode::NAMES.join(" "),
                        defaults::TERMINATION.as_str()
                    ),
                ),
            ],
        ),
        CommandSpec::new("decode", "decode an LLR stream (f32 little-endian file)", {
            let mut f = api::builder_flags();
            f.push(FlagSpec::new("in", "PATH", "LLR input file, f32 little-endian (required)"));
            f.push(FlagSpec::new("out", "PATH", "write packed decoded bits here"));
            f
        }),
        CommandSpec::new("ber", "Eb/N0 sweep (Fig-13-style), JSON + table output", {
            // one-shot decode path: serving-only knobs would be no-ops
            let mut f: Vec<FlagSpec> = api::builder_flags()
                .into_iter()
                .filter(|fl| {
                    !matches!(
                        fl.name,
                        "workers" | "max-batch" | "batch-deadline-us" | "queue-depth" | "shards"
                    )
                })
                .collect();
            f.push(FlagSpec::new("snr", "A:B:STEP", "Eb/N0 sweep in dB (default 0:6:1)"));
            f.push(FlagSpec::new("errors", "N", "target bit errors per point (default 100)"));
            f.push(FlagSpec::new("max-bits", "N", "bit cap per point (default 1000000)"));
            f.push(FlagSpec::new("hard", "", "hard-decision (+-1) inputs"));
            f.push(FlagSpec::new("exact-llr", "", "exact LLRs 2y/sigma^2 instead of raw symbols"));
            f.push(FlagSpec::new("seed", "N", "measurement seed (default 0x7C5D)"));
            f.push(FlagSpec::new("out", "PATH", "write the sweep as JSON here"));
            f
        }),
        CommandSpec::new("serve", "serve over TCP/UDP sockets, or run the synthetic workload", {
            let mut f = api::builder_flags();
            f.push(FlagSpec::new(
                "listen",
                "ADDR",
                "TCP listen address (host:port; port 0 = OS-assigned). \
                 Enables socket serving",
            ));
            f.push(FlagSpec::new("udp", "ADDR", "UDP bind address (one datagram = one block)"));
            f.push(FlagSpec::new(
                "max-sessions",
                "N",
                format!("concurrent-session cap (default {})", defaults::NET_MAX_SESSIONS),
            ));
            f.push(FlagSpec::new(
                "idle-timeout-ms",
                "MS",
                format!("idle session eviction (default {})", defaults::NET_IDLE_TIMEOUT_MS),
            ));
            f.push(FlagSpec::new(
                "shed-queue-depth",
                "N",
                "shed admissions at this summed shard queue depth (default: queue-depth)",
            ));
            f.push(FlagSpec::new(
                "write-high-water",
                "BYTES",
                format!(
                    "per-connection outbound buffer bound (default {})",
                    defaults::NET_WRITE_HIGH_WATER
                ),
            ));
            f.push(FlagSpec::new(
                "crc",
                "",
                "require a CRC32 on every DATA frame (clients may also offer one per session)",
            ));
            f.push(FlagSpec::new(
                "poller",
                "KIND",
                format!(
                    "reactor readiness backend: auto | poll | epoll (default {:?})",
                    defaults::NET_POLLER
                ),
            ));
            f.push(FlagSpec::new(
                "udp-batch",
                "N",
                format!(
                    "UDP reply datagrams per batched flush, 1 disables (default {})",
                    defaults::NET_UDP_BATCH
                ),
            ));
            f.push(FlagSpec::new(
                "duration-s",
                "S",
                "serve for S seconds then print metrics and exit (default: run until killed)",
            ));
            f.push(FlagSpec::new("sessions", "N", "synthetic mode: concurrent sessions (default 8)"));
            f.push(FlagSpec::new(
                "bits",
                "N",
                "synthetic mode: payload bits per session (default 65536)",
            ));
            f.push(FlagSpec::new("snr", "DB", "synthetic mode: Eb/N0 in dB (default 5.0)"));
            f.push(FlagSpec::new("seed", "N", "synthetic mode: workload seed (default 99)"));
            f.push(FlagSpec::new("json", "", "also print metrics as JSON"));
            f
        }),
        CommandSpec::new(
            "metrics",
            "fetch a metrics snapshot (JSON) from a running tcvd server",
            vec![FlagSpec::new("connect", "ADDR", "server TCP address (required)")],
        ),
    ]
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let specs = command_specs();
    if matches!(args.command.as_str(), "" | "help") {
        print_usage(&specs);
        return Ok(());
    }
    let Some(spec) = specs.iter().find(|s| s.name == args.command) else {
        print_usage(&specs);
        return Err(Error::config(format!("unknown subcommand {:?}", args.command)));
    };
    if args.get_bool("help") {
        print!("{}", spec.usage());
        return Ok(());
    }
    spec.check(&args)?;
    match spec.name {
        "info" => cmd_info(&args),
        "selftest" => cmd_selftest(&args),
        "encode" => cmd_encode(&args),
        "decode" => cmd_decode(&args),
        "ber" => cmd_ber(&args),
        "serve" => cmd_serve(&args),
        "metrics" => cmd_metrics(&args),
        _ => unreachable!("spec table covers dispatch"),
    }
}

/// `--config tcvd.toml` first, then individual `--flag` overrides.
fn builder_from_args(args: &Args) -> Result<DecoderBuilder> {
    let b = match args.get("config") {
        Some(p) => DecoderBuilder::from_toml_file(std::path::Path::new(p))?,
        None => DecoderBuilder::new(),
    };
    b.apply_flags(args)
}

fn cmd_info(args: &Args) -> Result<()> {
    match client::cpu_client() {
        Ok(c) => println!("{}", client::platform_summary(&c)),
        Err(e) => println!("(no PJRT client: {e})"),
    }
    println!("\nregistered codes:");
    for sc in registry::STANDARD_CODES {
        println!("  {:8} {}", sc.name, sc.description);
    }
    let dir = PathBuf::from(args.get_or("artifacts", defaults::ARTIFACTS_DIR));
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("\nartifacts in {}:", dir.display());
            for a in &m.artifacts {
                println!(
                    "  {:50} scheme={:14} Q={:<4} batch={:3} stages={}",
                    a.name, a.scheme, a.ops_per_stage, a.batch, a.stages_per_frame
                );
            }
        }
        Err(e) => println!("\n(no artifacts: {e})"),
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let n_bits = args.get_usize("bits", 4096)?;
    let snr = args.get_f64("snr", 5.0)?;
    let seed = args.get_u64("seed", 7)?;
    let code = registry::paper_code();
    let dir = args.get_or("artifacts", defaults::ARTIFACTS_DIR);

    // one row per (backend, termination mode): every mode replays the
    // same transmit chain with its own termination (flushed blocks
    // carry the k-1 flush stages inside the same stage budget, so all
    // three streams span n_bits trellis stages and tile identically)
    let modes =
        [TerminationMode::Flushed, TerminationMode::TailBiting, TerminationMode::Truncated];
    for mode in modes {
        let flush = mode.flush_stages(code.k());
        let data = Rng::new(seed).bits(n_bits - flush);
        let mut enc = Encoder::new(code.clone());
        let (coded, n_stages) = enc.encode_terminated(&data, mode);
        debug_assert_eq!(n_stages, n_bits);
        let tx = bpsk::modulate(&coded);
        let mut ch = AwgnChannel::new(snr, code.rate(), seed ^ 0xA5A5);
        let rx = ch.transmit(&tx);
        let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();

        // CPU backends use the generous 64+32/32 tile; the artifact
        // default tile (64+16/16) matches the b64_s48 frame. The
        // tensor-emulation and artifact rows only run under the
        // default (flushed) workload to keep the table compact.
        let mut builders: Vec<(&str, DecoderBuilder)> = vec![
            ("scalar", DecoderBuilder::new().backend_name("scalar")?.tile(defaults::CPU_TILE)),
            ("compact", DecoderBuilder::new().backend_name("compact")?.tile(defaults::CPU_TILE)),
            ("simd", DecoderBuilder::new().backend_name("simd")?.tile(defaults::CPU_TILE)),
            (
                "simd-r2",
                DecoderBuilder::new().backend_name("simd")?.radix(2).tile(defaults::CPU_TILE),
            ),
        ];
        if mode == TerminationMode::Flushed {
            builders.push((
                "cpu-radix2",
                DecoderBuilder::new().backend_name("cpu-radix2")?.tile(defaults::CPU_TILE),
            ));
            builders.push((
                "cpu-radix4",
                DecoderBuilder::new().backend_name("cpu-radix4")?.tile(defaults::CPU_TILE),
            ));
            builders.push(("pjrt-artifact", DecoderBuilder::new().artifacts_dir(&dir)));
        }
        for (name, builder) in builders {
            let label = format!("{name}/{mode}");
            // two shards: exercises the sharded dispatcher without
            // paying for a full per-core fleet of artifact compilations
            let builder = builder
                .termination(mode)
                .max_batch(64)
                .batch_deadline_us(200)
                .workers(2)
                .queue_depth(256)
                .shards(2);
            let coord = match builder.serve() {
                Ok(c) => c,
                Err(e) => {
                    println!("{label:26} SKIP ({e})");
                    continue;
                }
            };
            // per-row SKIP on decode errors too (e.g. --bits not a
            // whole number of tail-biting payload tiles), so one bad
            // row never aborts the rest of the table
            let out = match coord.decode_stream_blocking(&llr) {
                Ok(out) => out,
                Err(e) => {
                    println!("{label:26} SKIP ({e})");
                    coord.shutdown()?;
                    continue;
                }
            };
            let errors = out.iter().zip(&data).filter(|(a, b)| a != b).count();
            let snap = coord.metrics();
            println!(
                "{label:26} errors={errors:4}/{}  frames={} mean_batch={:.1} p99={:.0}us",
                data.len(),
                snap.frames_out,
                snap.mean_batch,
                snap.latency_p99_us
            );
            coord.shutdown()?;
        }
    }
    Ok(())
}

fn cmd_encode(args: &Args) -> Result<()> {
    let code = registry::lookup(&args.get_or("code", defaults::CODE))
        .map_err(|e| Error::config(e))?;
    let mut enc = Encoder::new(code);
    let payload: Vec<u8> = match args.get("in") {
        Some(path) => std::fs::read(path)
            .or_config(format!("reading {path}"))?
            .iter()
            .flat_map(|b| (0..8).map(move |i| (b >> i) & 1))
            .collect(),
        None => Rng::new(args.get_u64("seed", 1)?).bits(args.get_usize("bits", 1024)?),
    };
    let mode =
        TerminationMode::parse_named(&args.get_or("termination", defaults::TERMINATION.as_str()))?;
    let (coded, n_in) = enc.encode_terminated(&payload, mode);
    match args.get("out") {
        Some(path) => {
            let packed = tcvd::util::bitvec::BitVec::from_bits(&coded);
            let bytes: Vec<u8> = packed.words().iter().flat_map(|w| w.to_le_bytes()).collect();
            std::fs::write(path, bytes).or_pipeline(format!("writing {path}"))?;
            println!(
                "encoded {} info bits ({mode}, {} trellis stages) -> {} coded bits -> {path}",
                payload.len(),
                n_in,
                coded.len()
            );
        }
        None => println!(
            "encoded {} info bits ({mode}, {} trellis stages) -> {} coded bits (use --out to save)",
            payload.len(),
            n_in,
            coded.len()
        ),
    }
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    let builder = builder_from_args(args)?;
    let path = args.get("in").ok_or_else(|| Error::config("--in <llr.f32le> is required"))?;
    let raw = std::fs::read(path).or_config(format!("reading {path}"))?;
    if raw.len() % 4 != 0 {
        return Err(Error::config("LLR file must be f32 little-endian"));
    }
    let llr: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();

    let coord = builder.serve()?;
    let bits = coord.decode_stream_blocking(&llr)?;
    let snap = coord.metrics();
    if let Some(p) = args.get("out") {
        let packed = tcvd::util::bitvec::BitVec::from_bits(&bits);
        let bytes: Vec<u8> = packed.words().iter().flat_map(|w| w.to_le_bytes()).collect();
        std::fs::write(p, bytes).or_pipeline(format!("writing {p}"))?;
    }
    println!(
        "decoded {} bits in {:.3}s ({:.2} Mb/s info) frames={} mean_batch={:.1}",
        bits.len(),
        snap.elapsed_s,
        snap.throughput_bps / 1e6,
        snap.frames_out,
        snap.mean_batch
    );
    coord.shutdown()?;
    Ok(())
}

fn cmd_ber(args: &Args) -> Result<()> {
    let snrs = sweep::parse_range(&args.get_or("snr", "0:6:1"))?;
    // ber defaults to the CPU radix-4 backend with the generous tile;
    // an explicit --config replaces those defaults wholesale
    let base = match args.get("config") {
        Some(p) => DecoderBuilder::from_toml_file(std::path::Path::new(p))?,
        None => DecoderBuilder::new().backend_name("cpu-radix4")?.tile(defaults::CPU_TILE),
    };
    let builder = base.apply_flags(args)?;
    let setup = BerSetup {
        tile: builder.tile_config(),
        termination: builder.termination_mode(),
        target_errors: args.get_usize("errors", 100)?,
        max_bits: args.get_usize("max-bits", 1_000_000)?,
        bits_per_round: 8192,
        hard_decision: args.get_bool("hard"),
        exact_llr: args.get_bool("exact-llr"),
        seed: args.get_u64("seed", 0x7C5D)?,
    };
    let mut dec = builder.build()?;
    let trellis = dec.trellis().clone();
    println!("{:>8} {:>12} {:>12} {:>10}", "Eb/N0", "bits", "errors", "BER");
    let mut points = Vec::new();
    for &db in &snrs {
        let p = measure_ber(dec.as_frame_decoder(), &trellis, db, &setup)?;
        println!(
            "{:8.2} {:12} {:12} {:10.3e}{}",
            db,
            p.bits,
            p.errors,
            p.ber(),
            if p.reliable() { "" } else { "  (unreliable)" }
        );
        points.push(p);
    }
    if let Some(out) = args.get("out") {
        let j = sweep::curves_json(&[(dec.label(), points)]);
        std::fs::write(out, j.to_string_pretty()).or_pipeline(format!("writing {out}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    let addr =
        args.get("connect").ok_or_else(|| Error::config("--connect <host:port> is required"))?;
    println!("{}", tcvd::net::fetch_metrics(addr)?);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // the [net] section needs the raw config, not just the builder
    let cfg = match args.get("config") {
        Some(p) => Some(Config::from_file(std::path::Path::new(p))?),
        None => None,
    };
    let builder = match &cfg {
        Some(c) => DecoderBuilder::from_config(c)?,
        None => DecoderBuilder::new(),
    }
    .apply_flags(args)?;
    let tcp = args
        .get("listen")
        .map(str::to_string)
        .or_else(|| cfg.as_ref().and_then(|c| c.net_listen.clone()));
    let udp = args
        .get("udp")
        .map(str::to_string)
        .or_else(|| cfg.as_ref().and_then(|c| c.net_udp.clone()));
    if tcp.is_some() || udp.is_some() {
        let mut net = cfg.as_ref().map(NetConfig::from_config).unwrap_or_default();
        net.max_sessions = args.get_usize("max-sessions", net.max_sessions)?;
        net.idle_timeout = std::time::Duration::from_millis(
            args.get_u64("idle-timeout-ms", net.idle_timeout.as_millis() as u64)?,
        );
        if let Some(v) = args.get("shed-queue-depth") {
            let v = v.to_string();
            net.shed_queue_depth =
                Some(v.parse().or_config(format!("--shed-queue-depth {v:?}"))?);
        }
        net.write_high_water = args.get_usize("write-high-water", net.write_high_water)?;
        net.crc = net.crc || args.get_bool("crc");
        if let Some(v) = args.get("poller") {
            net.poller = tcvd::net::PollerKind::parse(v).ok_or_else(|| {
                Error::config(format!("--poller must be \"auto\", \"poll\" or \"epoll\" (got {v:?})"))
            })?;
        }
        net.udp_batch = args.get_usize("udp-batch", net.udp_batch)?;
        if net.udp_batch == 0 {
            return Err(Error::config("--udp-batch must be positive"));
        }
        if net.max_sessions == 0 {
            return Err(Error::config("--max-sessions must be positive"));
        }
        if net.idle_timeout.is_zero() {
            return Err(Error::config("--idle-timeout-ms must be positive"));
        }
        if net.write_high_water == 0 {
            return Err(Error::config("--write-high-water must be positive"));
        }
        return cmd_serve_sockets(args, builder, tcp.as_deref(), udp.as_deref(), net);
    }
    cmd_serve_synthetic(args, builder)
}

/// Socket serving mode: bind, announce the bound addresses (parsed by
/// scripts and the CI smoke stage), serve until `--duration-s` elapses
/// or the process is killed.
fn cmd_serve_sockets(
    args: &Args,
    builder: DecoderBuilder,
    tcp: Option<&str>,
    udp: Option<&str>,
    net: NetConfig,
) -> Result<()> {
    let server = tcvd::net::Server::start(builder, tcp, udp, net)?;
    if let Some(a) = server.tcp_addr() {
        println!("tcvd serve: listening tcp={a}");
    }
    if let Some(a) = server.udp_addr() {
        println!("tcvd serve: listening udp={a}");
    }
    let duration = args.get_f64("duration-s", 0.0)?;
    if duration > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(duration));
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let snap = server.metrics();
    println!(
        "sessions: accepted={} evicted={} shed={} blocks_shed={} handshake_rejects={}",
        snap.net.sessions_accepted,
        snap.net.sessions_evicted,
        snap.net.sessions_shed,
        snap.net.blocks_shed,
        snap.net.handshake_rejects
    );
    println!(
        "wire: in={}B out={}B  blocks={} p50={:.0}us p99={:.0}us",
        snap.net.bytes_in,
        snap.net.bytes_out,
        snap.net.blocks,
        snap.net.block_p50_us,
        snap.net.block_p99_us
    );
    if args.get_bool("json") {
        println!("{}", snap.to_json().to_string_pretty());
    }
    server.shutdown()?;
    Ok(())
}

/// Legacy synthetic mode: in-process multi-session SDR workload.
fn cmd_serve_synthetic(args: &Args, builder: DecoderBuilder) -> Result<()> {
    let sessions = args.get_usize("sessions", 8)?;
    let bits_per_session = args.get_usize("bits", 65536)?;
    let snr = args.get_f64("snr", 5.0)?;
    let coord = builder.serve()?;

    let seed0 = args.get_u64("seed", 99)?;
    let code = registry::paper_code();
    let mode = coord.termination();
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for s in 0..sessions {
            let coord = &coord;
            let code = code.clone();
            joins.push(scope.spawn(move || -> Result<(usize, usize)> {
                let mut rng = Rng::new(seed0 + s as u64);
                let mut enc = Encoder::new(code.clone());
                // the synthetic workload matches the pipeline's
                // termination mode (flushed blocks spend k-1 of the
                // per-session stage budget on the flush)
                let payload = rng.bits(bits_per_session - mode.flush_stages(code.k()));
                let (coded, _) = enc.encode_terminated(&payload, mode);
                let tx = bpsk::modulate(&coded);
                let mut ch = AwgnChannel::new(snr, code.rate(), seed0 ^ ((s as u64) << 8));
                let rx = ch.transmit(&tx);
                let llr: Vec<f32> = rx.iter().map(|&x| x as f32).collect();
                let mut session = coord.open_session()?;
                for chunk in llr.chunks(2048) {
                    session.push(chunk)?; // SDR-sized chunks, backpressured
                }
                let decoded = session.finish_and_collect()?;
                let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
                Ok((decoded.len(), errors))
            }));
        }
        let mut total_bits = 0usize;
        let mut total_errors = 0usize;
        for j in joins {
            let (b, e) = j.join().expect("session thread panicked")?;
            total_bits += b;
            total_errors += e;
        }
        let snap = coord.metrics();
        println!(
            "sessions={sessions} decoded={total_bits} bits errors={total_errors} (BER {:.2e})",
            total_errors as f64 / total_bits.max(1) as f64
        );
        println!(
            "throughput={:.3} Mb/s  execs={} mean_batch={:.1} p50={:.0}us p99={:.0}us",
            snap.throughput_bps / 1e6,
            snap.execs,
            snap.mean_batch,
            snap.latency_p50_us,
            snap.latency_p99_us
        );
        for (i, sh) in snap.shards.iter().enumerate() {
            println!(
                "shard {i}: frames={} execs={} steals={} queue_depth={}",
                sh.frames, sh.execs, sh.steals, sh.queue_depth
            );
        }
        if args.get_bool("json") {
            println!("{}", snap.to_json().to_string_pretty());
        }
        Ok(())
    })?;
    coord.shutdown()?;
    Ok(())
}
