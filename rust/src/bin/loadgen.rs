//! `loadgen` — loopback load/soak harness for a running `tcvd serve`
//! instance.
//!
//! Drives N concurrent worker threads against the server — one fresh
//! TCP connection per block (session churn), or one pipelined
//! ack-windowed UDP flow per worker — and verifies every decoded block
//! **bit-identical** against an in-process one-shot decoder oracle
//! built from the same parameters.
//! The builder flags must therefore describe the same pipeline the
//! server runs — a mismatch is rejected at the HELLO handshake.
//!
//! Exits non-zero when any block mismatches, fails, or an optional
//! latency/throughput bound (`--max-p99-ms` / `--min-mbps`) is missed,
//! so it slots directly into CI as a smoke stage:
//!
//! ```text
//! tcvd serve --listen 127.0.0.1:0 --backend simd &
//! loadgen --connect <addr> --sessions 32 --smoke
//! ```

use tcvd::api::{self, DecoderBuilder};
use tcvd::cli::{Args, CommandSpec, FlagSpec};
use tcvd::defaults;
use tcvd::error::{Error, Result};
use tcvd::net::loadgen::{run, LoadgenOptions, Transport};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run_cli(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// The single-command interface (reuses the builder's flag vocabulary
/// so the pipeline description matches `tcvd serve`'s).
fn spec() -> CommandSpec {
    let mut f = api::builder_flags();
    f.push(FlagSpec::new(
        "connect",
        "ADDR",
        "server address, host:port (required; TCP, or the UDP bind address with --udp)",
    ));
    f.push(FlagSpec::new("udp", "", "drive the UDP transport (one datagram = one block)"));
    f.push(FlagSpec::new("sessions", "N", "concurrent worker sessions (default 8)"));
    f.push(FlagSpec::new("blocks", "N", "blocks per session (default 4)"));
    f.push(FlagSpec::new("crc", "", "TCP: offer a CRC32 on every DATA frame"));
    f.push(FlagSpec::new(
        "window",
        "N",
        format!("UDP: pipelined ack-window size (default {})", defaults::NET_UDP_WINDOW),
    ));
    f.push(FlagSpec::new(
        "block-stages",
        "N",
        "trellis stages per block, multiple of the tile payload (default 256)",
    ));
    f.push(FlagSpec::new("snr", "DB", "workload Eb/N0 in dB (default 5.0)"));
    f.push(FlagSpec::new("seed", "N", "workload seed (default 1)"));
    f.push(FlagSpec::new(
        "max-retries",
        "N",
        "give up on a block after this many shed-retries (default 200)",
    ));
    f.push(FlagSpec::new("smoke", "", "CI preset: 2 blocks/session of one tile payload each"));
    f.push(FlagSpec::new("max-p99-ms", "MS", "fail if p99 block latency exceeds this"));
    f.push(FlagSpec::new("min-mbps", "MBPS", "fail if aggregate throughput is under this"));
    f.push(FlagSpec::new("json", "", "print the report as JSON"));
    CommandSpec::new("loadgen", "loopback load/soak harness for tcvd serve", f)
}

fn run_cli(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let spec = spec();
    if args.get_bool("help") || args.command == "help" {
        print!("{}", spec.usage());
        return Ok(());
    }
    if !args.command.is_empty() || !args.positional.is_empty() {
        return Err(Error::config("loadgen takes flags only (see `loadgen --help`)"));
    }
    spec.check(&args)?;
    let Some(addr) = args.get("connect") else {
        return Err(Error::config("--connect <ADDR> is required (the server's address)"));
    };

    // Pipeline description: SIMD backend + the CPU tile by default —
    // the cheapest always-available config, mirrored by the CI serve
    // invocation — overridable by --config and the builder flags.
    let builder = match args.get("config") {
        Some(p) => DecoderBuilder::from_toml_file(std::path::Path::new(p))?,
        None => DecoderBuilder::new()
            .backend_name("simd")?
            .tile_dims(defaults::CPU_TILE.payload, defaults::CPU_TILE.head, defaults::CPU_TILE.tail),
    }
    .apply_flags(&args)?;

    let mut opts = LoadgenOptions {
        sessions: args.get_usize("sessions", 8)?,
        blocks_per_session: args.get_usize("blocks", 4)?,
        block_stages: args.get_usize("block-stages", 256)?,
        ebn0_db: args.get_f64("snr", 5.0)?,
        seed: args.get_u64("seed", 1)?,
        transport: if args.get_bool("udp") { Transport::Udp } else { Transport::Tcp },
        max_retries: args.get_usize("max-retries", 200)?,
        crc: args.get_bool("crc"),
        udp_window: args.get_usize("window", defaults::NET_UDP_WINDOW)?,
    };
    if args.get_bool("smoke") {
        // small + fast, still churning every session through the
        // handshake / decode / teardown lifecycle
        opts.blocks_per_session = args.get_usize("blocks", 2)?;
        opts.block_stages = args.get_usize("block-stages", builder.tile_config().payload)?;
    }
    let max_p99_ms = match args.get("max-p99-ms") {
        Some(_) => Some(args.get_f64("max-p99-ms", 0.0)?),
        None => None,
    };
    let min_mbps = match args.get("min-mbps") {
        Some(_) => Some(args.get_f64("min-mbps", 0.0)?),
        None => None,
    };

    println!(
        "loadgen: {} x {} blocks of {} stages over {} to {}",
        opts.sessions,
        opts.blocks_per_session,
        opts.block_stages,
        opts.transport.name(),
        addr
    );
    let report = run(addr, &builder, &opts)?;
    println!(
        "loadgen: {} blocks verified, {} shed-retries, {} failures, {} mismatches, \
         {} worker panics",
        report.blocks, report.shed_retries, report.failures, report.mismatches,
        report.worker_panics
    );
    println!(
        "loadgen: {:.3} Mb/s aggregate over {:.3} s; latency p50 {:.3} ms, p99 {:.3} ms",
        report.aggregate_mbps, report.elapsed_s, report.p50_ms, report.p99_ms
    );
    if args.get_bool("json") {
        println!("{}", report.to_json().to_string_pretty());
    }
    report.check(max_p99_ms, min_mbps)
}
