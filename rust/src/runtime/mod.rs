//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client. The
//! only Python involvement ended at `make artifacts` — this module is the
//! entire model-execution path of the Rust binary.

pub mod client;
pub mod manifest;
pub mod literals;
pub mod executor;

pub use executor::{Artifact, ArtifactDecoder};
pub use manifest::{ArtifactMeta, Manifest};
