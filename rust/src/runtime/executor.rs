//! Artifact execution: compile the HLO text once, then run batched
//! forward passes. The forward pass (tensor-formulated ACS) runs inside
//! XLA; traceback runs here in Rust (paper §V-A: traceback cannot be a
//! matmul).

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::coding::trellis::Trellis;
use crate::coding::Code;
use crate::viterbi::types::{FrameDecoder, FrameJob, RawFrame, Survivors, NEG};

use super::literals::{literal_f32, to_f32_vec, to_i32_vec};
use super::manifest::{ArtifactMeta, Manifest};

/// A compiled decoder artifact. NOT `Send`: PJRT executables live on the
/// thread that owns the client — the coordinator funnels all executions
/// through one engine thread (which is also how the paper serializes
/// kernel launches on a CUDA stream).
pub struct Artifact {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Result of one batched forward pass.
///
/// `phi` is step-major flat — index `(t * batch + b) * n_states + s` —
/// matching the artifact's 1-D output contract (see `aot.py`).
#[derive(Clone, Debug)]
pub struct ForwardOut {
    /// Left-local selections (0..gamma), step-major flat.
    pub phi: Vec<i32>,
    /// Final path metrics \[b]\[state] flattened.
    pub lam: Vec<f32>,
}

impl Artifact {
    /// Load + compile one artifact (HLO text -> PJRT executable).
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest, meta: &ArtifactMeta)
                -> Result<Artifact> {
        let path = manifest.hlo_path(meta);
        let exe = compile_hlo(client, &path)?;
        Ok(Artifact { meta: meta.clone(), exe })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Reconstruct the `Code` this artifact was compiled for.
    pub fn code(&self) -> Result<Code> {
        let octal: Vec<&str> = self.meta.polys_octal.iter().map(|s| s.as_str()).collect();
        Code::from_octal(self.meta.k, &octal)
    }

    /// One batched forward pass. `llr` is `[batch, n_steps, width]` flat,
    /// `lam0` is `[batch, n_states]` flat.
    pub fn forward(&self, llr: &[f32], lam0: &[f32]) -> Result<ForwardOut> {
        let m = &self.meta;
        ensure!(llr.len() == m.llr_len(), "llr: got {}, want {}", llr.len(), m.llr_len());
        ensure!(lam0.len() == m.lam_len(), "lam0: got {}, want {}", lam0.len(), m.lam_len());
        let llr_lit = literal_f32(
            llr,
            &[m.batch as i64, m.n_steps as i64, m.width as i64],
        )?;
        let lam_lit = literal_f32(lam0, &[m.batch as i64, m.n_states as i64])?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[llr_lit, lam_lit])
            .context("executing artifact")?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (phi, lam)
        let (phi_lit, lam_out) = result.to_tuple2().context("unpacking output tuple")?;
        let phi = to_i32_vec(&phi_lit)?;
        let lam = to_f32_vec(&lam_out)?;
        ensure!(phi.len() == m.phi_len(), "phi size {} != {}", phi.len(), m.phi_len());
        ensure!(lam.len() == m.lam_len(), "lam size {} != {}", lam.len(), m.lam_len());
        Ok(ForwardOut { phi, lam })
    }
}

/// Compile an HLO text file on the given client.
pub fn compile_hlo(client: &xla::PjRtClient, path: &Path)
                   -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
}

/// `FrameDecoder` over an artifact: batches jobs into full executions
/// (padding the tail batch) and runs traceback per frame.
pub struct ArtifactDecoder {
    artifact: Arc<Artifact>,
    trellis: Arc<Trellis>,
}

impl ArtifactDecoder {
    pub fn new(artifact: Arc<Artifact>, trellis: Arc<Trellis>) -> Self {
        ArtifactDecoder { artifact, trellis }
    }

    /// Build the flat lam0 for a batch of jobs (NEG ramp for known-start).
    pub fn lam0_for(jobs: &[FrameJob], batch: usize, s_count: usize) -> Vec<f32> {
        let mut lam0 = vec![0f32; batch * s_count];
        for (b, job) in jobs.iter().enumerate() {
            if let Some(s) = job.start_state {
                let row = &mut lam0[b * s_count..(b + 1) * s_count];
                row.fill(NEG);
                row[s as usize] = 0.0;
            }
        }
        lam0
    }
}

impl FrameDecoder for ArtifactDecoder {
    fn frame_stages(&self) -> usize {
        self.artifact.meta().stages_per_frame
    }

    fn max_batch(&self) -> usize {
        self.artifact.meta().batch
    }

    fn trellis(&self) -> &Arc<Trellis> {
        &self.trellis
    }

    fn forward_batch(&mut self, jobs: &[FrameJob]) -> Vec<RawFrame> {
        let m = self.artifact.meta().clone();
        assert!(jobs.len() <= m.batch, "got {} jobs, artifact batch {}", jobs.len(), m.batch);
        let frame_llr = m.n_steps * m.width;
        let mut llr = vec![0f32; m.llr_len()];
        for (b, job) in jobs.iter().enumerate() {
            assert_eq!(job.llr.len(), frame_llr, "frame llr length mismatch");
            llr[b * frame_llr..(b + 1) * frame_llr].copy_from_slice(&job.llr);
        }
        let lam0 = Self::lam0_for(jobs, m.batch, m.n_states);
        let out = self.artifact.forward(&llr, &lam0).expect("artifact forward");
        let s_count = m.n_states;
        jobs.iter()
            .enumerate()
            .map(|(b, _)| {
                // de-interleave the step-major flat phi for this frame
                let mut phi = Vec::with_capacity(m.n_steps * s_count);
                for t in 0..m.n_steps {
                    let base = (t * m.batch + b) * s_count;
                    phi.extend(out.phi[base..base + s_count].iter().map(|&v| v as u8));
                }
                RawFrame {
                    surv: Survivors::Radix { rho: m.rho, phi },
                    lam: out.lam[b * s_count..(b + 1) * s_count].to_vec(),
                }
            })
            .collect()
    }

    fn label(&self) -> String {
        format!("pjrt:{}", self.artifact.meta().name)
    }
}
