//! PJRT client construction. One CPU client per process; executables are
//! compiled once and cached by the `Artifact` layer.

use anyhow::{Context, Result};

/// Create the PJRT CPU client (the paper's GPU context analog).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}

/// Human-readable platform summary for `tcvd info`.
pub fn platform_summary(client: &xla::PjRtClient) -> String {
    format!(
        "platform={} version={} devices={}",
        client.platform_name(),
        client.platform_version(),
        client.device_count()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_comes_up() {
        let c = cpu_client().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert!(c.device_count() >= 1);
        let s = platform_summary(&c);
        assert!(s.contains("platform=cpu"));
    }
}
