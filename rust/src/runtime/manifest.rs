//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime (shapes, dtypes, scheme parameters per artifact).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Metadata for one AOT-compiled decoder variant.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub path: String,
    pub scheme: String,
    pub impl_: String,
    pub acc: String,
    pub chan: String,
    pub batch: usize,
    pub n_steps: usize,
    pub rho: u32,
    pub gamma: usize,
    pub width: usize,
    pub n_ops: usize,
    pub ops_per_stage: f64,
    pub renorm_every: usize,
    pub k: u32,
    pub polys_octal: Vec<String>,
    pub n_states: usize,
    pub stages_per_frame: usize,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        Ok(ArtifactMeta {
            name: j.get("name")?.as_str()?.to_string(),
            path: j.get("path")?.as_str()?.to_string(),
            scheme: j.get("scheme")?.as_str()?.to_string(),
            impl_: j.get("impl")?.as_str()?.to_string(),
            acc: j.get("acc")?.as_str()?.to_string(),
            chan: j.get("chan")?.as_str()?.to_string(),
            batch: j.get("batch")?.as_usize()?,
            n_steps: j.get("n_steps")?.as_usize()?,
            rho: j.get("rho")?.as_usize()? as u32,
            gamma: j.get("gamma")?.as_usize()?,
            width: j.get("width")?.as_usize()?,
            n_ops: j.get("n_ops")?.as_usize()?,
            ops_per_stage: j.get("ops_per_stage")?.as_f64()?,
            renorm_every: j.get("renorm_every")?.as_usize()?,
            k: j.get("k")?.as_usize()? as u32,
            polys_octal: j
                .get("polys_octal")?
                .as_arr()?
                .iter()
                .map(|p| Ok(p.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            n_states: j.get("n_states")?.as_usize()?,
            stages_per_frame: j.get("stages_per_frame")?.as_usize()?,
        })
    }

    /// Expected flat input sizes.
    pub fn llr_len(&self) -> usize {
        self.batch * self.n_steps * self.width
    }

    pub fn lam_len(&self) -> usize {
        self.batch * self.n_states
    }

    pub fn phi_len(&self) -> usize {
        self.batch * self.n_steps * self.n_states
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts` first)", mpath.display()))?;
        let j = Json::parse(&text)?;
        let artifacts = j
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        if artifacts.is_empty() {
            bail!("manifest {} lists no artifacts", mpath.display());
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find the unique artifact whose name contains `pattern` (exact name
    /// match wins if several contain it).
    pub fn find(&self, pattern: &str) -> Result<&ArtifactMeta> {
        if let Some(m) = self.artifacts.iter().find(|a| a.name == pattern) {
            return Ok(m);
        }
        let hits: Vec<&ArtifactMeta> =
            self.artifacts.iter().filter(|a| a.name.contains(pattern)).collect();
        match hits.len() {
            0 => bail!(
                "no artifact matches {pattern:?}; available: {}",
                self.artifacts.iter().map(|a| a.name.as_str()).collect::<Vec<_>>().join(", ")
            ),
            1 => Ok(hits[0]),
            _ => bail!(
                "pattern {pattern:?} is ambiguous: {}",
                hits.iter().map(|a| a.name.as_str()).collect::<Vec<_>>().join(", ")
            ),
        }
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> Manifest {
        let entry = |name: &str| ArtifactMeta {
            name: name.to_string(),
            path: format!("{name}.hlo.txt"),
            scheme: "radix4".into(),
            impl_: "jnp".into(),
            acc: "single".into(),
            chan: "single".into(),
            batch: 8,
            n_steps: 32,
            rho: 2,
            gamma: 4,
            width: 4,
            n_ops: 1,
            ops_per_stage: 0.5,
            renorm_every: 16,
            k: 7,
            polys_octal: vec!["171".into(), "133".into()],
            n_states: 64,
            stages_per_frame: 64,
        };
        Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts: vec![entry("radix4_a"), entry("radix4_b")],
        }
    }

    #[test]
    fn find_exact_beats_substring() {
        let m = fake_manifest();
        assert_eq!(m.find("radix4_a").unwrap().name, "radix4_a");
    }

    #[test]
    fn find_ambiguous_errors() {
        let m = fake_manifest();
        assert!(m.find("radix4").is_err());
        assert!(m.find("nothing").is_err());
    }

    #[test]
    fn sizes() {
        let m = fake_manifest();
        let a = &m.artifacts[0];
        assert_eq!(a.llr_len(), 8 * 32 * 4);
        assert_eq!(a.lam_len(), 8 * 64);
        assert_eq!(a.phi_len(), 8 * 32 * 64);
    }
}
