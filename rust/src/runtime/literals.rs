//! Literal packing helpers: flat Rust buffers <-> shaped XLA literals.

use anyhow::{ensure, Context, Result};

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    ensure!(
        expect as usize == data.len(),
        "literal shape {dims:?} needs {expect} elements, got {}",
        data.len()
    );
    xla::Literal::vec1(data).reshape(dims).context("reshaping literal")
}

/// Extract a flat f32 vector from a literal (any shape).
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("extracting f32 literal")
}

/// Extract a flat i32 vector from a literal (any shape).
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("extracting i32 literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let lit = literal_f32(&data, &[2, 3, 4]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }
}
