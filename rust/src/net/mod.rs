//! `tcvd::net` — the socket serving front-end: the sharded
//! [`Coordinator`] exposed over TCP and UDP with session lifecycle,
//! admission control and load-shedding. `std::net` only (the repo is
//! offline): a readiness-driven reactor multiplexes every TCP
//! connection on one thread ([`reactor`] wraps `poll(2)` or Linux
//! `epoll` without dependencies — `net.poller` selects), and a
//! single-threaded UDP datagram loop serves block traffic with
//! `sendmmsg`-style reply batching ([`udp_batch`], `net.udp_batch`) —
//! the server's thread count is fixed no matter how many connections
//! are live.
//!
//! * **TCP** ([`tcp`]): one connection = one streaming [`Session`],
//!   driven as a nonblocking state machine with per-connection
//!   outbound buffering and a write high-water mark for slow readers.
//!   The length-prefixed framing, the HELLO handshake (code /
//!   backend / termination / tile, lowered through
//!   [`DecoderBuilder`]'s own name parsers) and the optional DATA
//!   CRC32 (negotiated in HELLO/ACK) live in [`protocol`].
//! * **UDP** ([`udp`]): one datagram = one self-contained block; a
//!   flow (peer address + flow id) is the session-lifetime unit, built
//!   for tail-biting block traffic.
//! * **Lifecycle** ([`session_table`]): a hard cap on concurrent
//!   sessions, idle eviction with configurable timeouts, and explicit
//!   load-shedding (typed REJECT frames / SHED replies) once the shard
//!   queues saturate — counted in [`Metrics`](crate::coordinator::Metrics)
//!   and exported through the metrics endpoint.
//! * **Load harness** ([`loadgen`]): churns N concurrent loopback
//!   sessions and asserts bit-identity against the one-shot
//!   [`Decoder`](crate::Decoder) oracle.
//!
//! Wire format tables, the session state machine and the
//! eviction/shedding model are documented in `docs/NETWORKING.md`.

pub mod loadgen;
pub mod protocol;
pub mod reactor;
pub mod session_table;
pub mod tcp;
pub mod udp;
pub mod udp_batch;

use std::net::{SocketAddr, TcpListener, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{BackendKind, DecoderBuilder, TerminationMode};
use crate::config::Config;
use crate::coordinator::{Coordinator, Metrics, MetricsSnapshot};
use crate::defaults;
use crate::error::{Error, Result, ResultExt};
use crate::fault::{self, FaultMap};

pub use protocol::{Ack, Hello, PROTO_VERSION};
pub use reactor::PollerKind;
pub use session_table::{FlowTouch, SessionTable};
pub use tcp::{fetch_metrics, TcpClient};
pub use udp::{DatagramSocket, UdpClient, UdpPipelineOptions, UdpRun, UdpRunStats};

/// Tunables of the socket front-end (the `[net]` TOML section /
/// `tcvd serve` flags; defaults from [`crate::defaults`]).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Hard cap on concurrent sessions (TCP connections + UDP flows).
    pub max_sessions: usize,
    /// Idle eviction timeout (TCP read timeout / UDP flow sweep age).
    pub idle_timeout: Duration,
    /// Shed new sessions (and UDP blocks) once the summed shard queue
    /// depth reaches this; `None` uses the pipeline's `queue_depth`.
    pub shed_queue_depth: Option<usize>,
    /// Upper bound on one TCP wire frame's payload, bytes.
    pub max_frame_bytes: usize,
    /// Per-connection outbound buffer high-water mark, bytes: once a
    /// slow reader lets this many bytes pile up, the reactor stops
    /// draining that session's decoded output (the bounded session
    /// channel then backpressures the pipeline).
    pub write_high_water: usize,
    /// Require a CRC32 on every DATA frame, even from clients that did
    /// not offer one in their HELLO (the ACK tells them).
    pub crc: bool,
    /// Readiness backend of the TCP reactor (`"auto"` resolves to
    /// `epoll` on Linux, `poll(2)` elsewhere; see
    /// [`reactor::PollerKind`]).
    pub poller: PollerKind,
    /// UDP reply batching factor: replies accumulate up to this many
    /// datagrams before one batched flush (1 disables batching; the
    /// batch always flushes once the socket has no pending datagrams).
    pub udp_batch: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_sessions: defaults::NET_MAX_SESSIONS,
            idle_timeout: Duration::from_millis(defaults::NET_IDLE_TIMEOUT_MS),
            shed_queue_depth: None,
            max_frame_bytes: defaults::NET_MAX_FRAME_BYTES,
            write_high_water: defaults::NET_WRITE_HIGH_WATER,
            crc: false,
            poller: PollerKind::Auto,
            udp_batch: defaults::NET_UDP_BATCH,
        }
    }
}

impl NetConfig {
    /// Read the `[net]` keys of a parsed [`Config`].
    pub fn from_config(cfg: &Config) -> NetConfig {
        NetConfig {
            max_sessions: cfg.net_max_sessions,
            idle_timeout: Duration::from_millis(cfg.net_idle_timeout_ms),
            shed_queue_depth: cfg.net_shed_queue_depth,
            max_frame_bytes: defaults::NET_MAX_FRAME_BYTES,
            write_high_water: cfg.net_write_high_water,
            crc: cfg.net_crc,
            poller: PollerKind::parse(&cfg.net_poller).unwrap_or(PollerKind::Auto),
            udp_batch: cfg.net_udp_batch,
        }
    }
}

/// The session contract one server serves: every TCP handshake must
/// name exactly this code/backend/termination/tile (lowered through
/// the same [`DecoderBuilder`] parsers the CLI uses), so a client
/// never silently decodes against a pipeline with different framing.
#[derive(Clone, Debug)]
pub struct Contract {
    code: String,
    backend: BackendKind,
    termination: TerminationMode,
    payload: usize,
    head: usize,
    tail: usize,
}

impl Contract {
    /// The contract of the pipeline `builder` describes.
    pub fn of_builder(builder: &DecoderBuilder) -> Contract {
        let tile = builder.tile_config();
        Contract {
            code: builder.code_name().to_string(),
            backend: builder.backend_kind().clone(),
            termination: builder.termination_mode(),
            payload: tile.payload,
            head: tile.head,
            tail: tile.tail,
        }
    }

    /// The HELLO a client of this contract sends (no feature flags —
    /// callers set e.g. [`protocol::flags::DATA_CRC`] before encoding).
    pub fn hello(&self) -> Hello {
        Hello {
            version: PROTO_VERSION,
            flags: 0,
            code: self.code.clone(),
            backend: self.backend.name(),
            termination: self.termination.as_str().to_string(),
            payload_stages: self.payload as u32,
            head_stages: self.head as u32,
            tail_stages: self.tail as u32,
        }
    }

    /// Validate a client HELLO against this contract. The names are
    /// lowered through the builder facade's parsers (unknown names are
    /// the same typed config errors the CLI reports), then compared
    /// against the served pipeline.
    pub fn check_hello(&self, hello: &Hello) -> Result<()> {
        if hello.version != PROTO_VERSION {
            return Err(Error::net(format!(
                "protocol version {} not supported (server speaks {PROTO_VERSION})",
                hello.version
            )));
        }
        let asked = DecoderBuilder::new()
            .code(&hello.code)
            .backend_name(&hello.backend)?
            .termination_name(&hello.termination)?;
        if hello.code != self.code {
            return Err(Error::net(format!(
                "code mismatch: client asked for {:?}, server runs {:?}",
                hello.code, self.code
            )));
        }
        if *asked.backend_kind() != self.backend {
            return Err(Error::net(format!(
                "backend mismatch: client asked for {:?}, server runs {:?}",
                hello.backend,
                self.backend.name()
            )));
        }
        if asked.termination_mode() != self.termination {
            return Err(Error::net(format!(
                "termination mismatch: client asked for {}, server runs {}",
                hello.termination, self.termination
            )));
        }
        let (p, h, t) =
            (hello.payload_stages as usize, hello.head_stages as usize, hello.tail_stages as usize);
        if (p, h, t) != (self.payload, self.head, self.tail) {
            return Err(Error::net(format!(
                "tile mismatch: client framed {p}+{h}/{t}, server runs {}+{}/{}",
                self.payload, self.head, self.tail
            )));
        }
        Ok(())
    }
}

/// Shared state of one running server (the reactor and UDP loops hold
/// an `Arc` each).
pub(crate) struct ServerCtx {
    pub coord: Coordinator,
    pub metrics: Arc<Metrics>,
    pub contract: Contract,
    pub net: NetConfig,
    pub table: SessionTable,
    /// Resolved queue-saturation threshold (see
    /// [`NetConfig::shed_queue_depth`]).
    pub shed_queue_depth: usize,
    /// The pipeline's failpoint map, shared so the `net.shed` site can
    /// force load-shedding deterministically in chaos tests.
    pub faults: Arc<FaultMap>,
    pub shutdown: AtomicBool,
}

impl ServerCtx {
    /// Admission signal: shed when the shard queues are saturated (or
    /// the `net.shed` failpoint forces it).
    pub fn queues_saturated(&self) -> bool {
        self.faults.fire(fault::site::NET_SHED)
            || self.metrics.queue_depth_total() >= self.shed_queue_depth as u64
    }
}

/// A running socket front-end over one [`Coordinator`]. Construct with
/// [`Server::start`]; the OS-assigned addresses are readable via
/// [`tcp_addr`](Server::tcp_addr) / [`udp_addr`](Server::udp_addr)
/// (bind to port 0 for loopback tests).
pub struct Server {
    ctx: Arc<ServerCtx>,
    tcp_addr: Option<SocketAddr>,
    udp_addr: Option<SocketAddr>,
    threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start the pipeline `builder` describes and serve it on the given
    /// listen addresses (at least one of `tcp`/`udp`; `"127.0.0.1:0"`
    /// binds an OS-assigned loopback port).
    pub fn start(
        builder: DecoderBuilder,
        tcp: Option<&str>,
        udp: Option<&str>,
        net: NetConfig,
    ) -> Result<Server> {
        if tcp.is_none() && udp.is_none() {
            return Err(Error::config("Server::start needs a TCP or UDP listen address"));
        }
        let contract = Contract::of_builder(&builder);
        let shed_queue_depth =
            net.shed_queue_depth.unwrap_or(builder.to_coordinator_config().queue_depth);
        let coord = builder.serve()?;
        let metrics = coord.metrics_hub();
        let faults = coord.faults();
        let table = SessionTable::with_faults(net.max_sessions, net.idle_timeout, faults.clone());
        let listener = match tcp {
            Some(addr) => {
                Some(TcpListener::bind(addr).or_net(format!("binding tcp listener on {addr}"))?)
            }
            None => None,
        };
        let socket = match udp {
            Some(addr) => {
                Some(UdpSocket::bind(addr).or_net(format!("binding udp socket on {addr}"))?)
            }
            None => None,
        };
        let tcp_addr = match &listener {
            Some(l) => Some(l.local_addr().or_net("reading tcp listener address")?),
            None => None,
        };
        let udp_addr = match &socket {
            Some(s) => Some(s.local_addr().or_net("reading udp socket address")?),
            None => None,
        };
        let ctx = Arc::new(ServerCtx {
            coord,
            metrics,
            contract,
            net,
            table,
            shed_queue_depth,
            faults,
            shutdown: AtomicBool::new(false),
        });
        let mut threads = Vec::new();
        if let Some(listener) = listener {
            let ctx2 = ctx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tcvd-net-reactor".into())
                    .spawn(move || tcp::run_reactor(listener, ctx2))
                    .or_net("spawning tcp reactor")?,
            );
        }
        if let Some(socket) = socket {
            let ctx2 = ctx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tcvd-net-udp".into())
                    .spawn(move || udp::run_udp(socket, ctx2))
                    .or_net("spawning udp loop")?,
            );
        }
        Ok(Server { ctx, tcp_addr, udp_addr, threads })
    }

    /// The bound TCP listen address, if TCP serving is enabled.
    pub fn tcp_addr(&self) -> Option<SocketAddr> {
        self.tcp_addr
    }

    /// The bound UDP address, if UDP serving is enabled.
    pub fn udp_addr(&self) -> Option<SocketAddr> {
        self.udp_addr
    }

    /// Point-in-time pipeline + net metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.ctx.metrics.snapshot()
    }

    /// Stop the transport loops (the reactor notices the flag within
    /// one poll tick, abandons live connections and exits), then shut
    /// the pipeline down.
    pub fn shutdown(self) -> Result<()> {
        let Server { ctx, threads, .. } = self;
        ctx.shutdown.store(true, Ordering::SeqCst);
        for t in threads {
            t.join().map_err(|_| Error::net("transport thread panicked"))?;
        }
        match Arc::try_unwrap(ctx) {
            Ok(ctx) => ctx.coord.shutdown(),
            // should be unreachable once both loops joined; dropping
            // our Arc still lets the pipeline unwind
            Err(_) => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cpu_builder() -> DecoderBuilder {
        DecoderBuilder::new().backend_name("scalar").unwrap().tile_dims(16, 8, 8)
    }

    #[test]
    fn contract_accepts_its_own_hello() {
        let b = cpu_builder();
        let c = Contract::of_builder(&b);
        c.check_hello(&c.hello()).unwrap();
    }

    #[test]
    fn contract_rejects_mismatches() {
        let c = Contract::of_builder(&cpu_builder());
        let mut h = c.hello();
        h.backend = "simd".into();
        assert!(c.check_hello(&h).is_err());
        let mut h = c.hello();
        h.termination = "tail-biting".into();
        assert!(c.check_hello(&h).is_err());
        let mut h = c.hello();
        h.payload_stages = 64;
        assert!(c.check_hello(&h).is_err());
        let mut h = c.hello();
        h.version = 99;
        assert!(c.check_hello(&h).is_err());
        // unknown names are typed config errors from the builder parsers
        let mut h = c.hello();
        h.backend = "quantum".into();
        let e = c.check_hello(&h).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn server_needs_an_address() {
        let e = Server::start(cpu_builder(), None, None, NetConfig::default()).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }
}
