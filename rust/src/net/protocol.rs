//! Wire protocol of the socket serving front-end: length-prefixed
//! binary frames over TCP, fixed-header datagrams over UDP. The full
//! format tables live in `docs/NETWORKING.md`; this module is the only
//! place bytes are encoded or decoded, so the tables and the code stay
//! reviewable side by side.
//!
//! TCP frame layout (all integers little-endian):
//!
//! ```text
//! [ kind: u8 ][ len: u32 ][ payload: len bytes ]
//! ```
//!
//! UDP request: `[ flow: u64 ][ seq: u32 ][ llr: len/4 f32 ]`;
//! UDP reply: `[ flow: u64 ][ seq: u32 ][ status: u8 ][ bits... ]`.

use std::io::{Read, Write};
use std::sync::OnceLock;

use crate::error::{Error, Result};

/// Protocol version carried in the HELLO frame. Version 2 added the
/// `flags` field to HELLO and ACK (optional per-frame DATA CRC).
pub const PROTO_VERSION: u16 = 2;

/// HELLO/ACK feature flag bits. A client *offers* flags in its HELLO;
/// the server echoes the flags *in effect* in the ACK (it may switch a
/// flag on that the client did not offer, e.g. when `net.crc` makes
/// checksums mandatory server-side), and both ends honor the ACK.
pub mod flags {
    /// Every DATA payload is prefixed with a CRC32 of the LLR bytes.
    pub const DATA_CRC: u16 = 1 << 0;
}

/// TCP frame kinds. Client-to-server kinds have the high bit clear,
/// server-to-client kinds have it set.
pub mod kind {
    /// Client handshake: version + code/backend/termination/tile.
    pub const HELLO: u8 = 0x01;
    /// Raw little-endian f32 LLRs appended to the session stream.
    pub const DATA: u8 = 0x02;
    /// End of stream: flush the framer and close the session output.
    pub const FINISH: u8 = 0x03;
    /// Request a metrics snapshot (valid before or during a session).
    pub const METRICS_REQ: u8 = 0x04;
    /// Server accepts the session: session id + frame geometry.
    pub const ACK: u8 = 0x81;
    /// In-order decoded payload bits (one byte per bit).
    pub const BITS: u8 = 0x82;
    /// All decoded bits delivered; the stream completed cleanly.
    pub const END: u8 = 0x83;
    /// Admission rejected: reason byte + human-readable detail.
    pub const REJECT: u8 = 0x84;
    /// Session-fatal error: typed `tcvd::Error` text; the server
    /// closes the connection after sending this.
    pub const ERROR: u8 = 0x85;
    /// Metrics snapshot reply: JSON text.
    pub const METRICS: u8 = 0x86;
}

/// Reject reasons (first payload byte of a REJECT frame).
pub mod reject {
    /// The concurrent-session cap is reached.
    pub const SESSION_CAP: u8 = 1;
    /// The shard queues are saturated (load shed).
    pub const QUEUE_SATURATED: u8 = 2;
    /// Handshake parameters do not match the served pipeline.
    pub const CONFIG: u8 = 3;
    /// A DATA frame's CRC32 did not match its payload (negotiated via
    /// [`flags::DATA_CRC`](super::flags::DATA_CRC)); the session is
    /// evicted after this reject.
    pub const CRC_MISMATCH: u8 = 4;
    /// The session's engine shard panicked mid-decode and is being
    /// restarted by its supervisor — transient: a retried session is
    /// expected to succeed. Shed-aware clients treat this like a load
    /// shed (the reason token contains the crate-wide `shard-restart`
    /// retryable marker — see `docs/RELIABILITY.md`).
    pub const SHARD_RESTART: u8 = 5;
}

/// Human-readable token for a reject reason byte (stable strings —
/// clients and tests match on them).
pub fn reject_reason_name(reason: u8) -> &'static str {
    match reason {
        reject::SESSION_CAP => "session-cap",
        reject::QUEUE_SATURATED => "queue-saturated",
        reject::CONFIG => "config",
        reject::CRC_MISMATCH => "crc-mismatch",
        reject::SHARD_RESTART => "shard-restart",
        _ => "unknown",
    }
}

/// Is `k` a frame kind this protocol version defines (either
/// direction)?
pub fn check_kind(k: u8) -> Result<()> {
    match k {
        kind::HELLO | kind::DATA | kind::FINISH | kind::METRICS_REQ | kind::ACK | kind::BITS
        | kind::END | kind::REJECT | kind::ERROR | kind::METRICS => Ok(()),
        other => Err(Error::net(format!("unknown frame kind {other:#04x}"))),
    }
}

/// UDP reply status bytes.
pub mod udp_status {
    pub const OK: u8 = 0;
    pub const SHED: u8 = 1;
    pub const ERR: u8 = 2;
}

/// Fixed UDP header length: flow (8) + seq (4).
pub const UDP_HEADER: usize = 12;

/// TCP frame header length: kind (1) + len (4).
pub const FRAME_HEADER: usize = 5;

/// Outcome of one blocking frame read.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete frame: kind + payload.
    Frame(u8, Vec<u8>),
    /// Orderly EOF at a frame boundary (peer closed the connection).
    Eof,
    /// The socket read timeout elapsed (idle connection). A timeout
    /// mid-frame also lands here; either way the connection is no
    /// longer framable and must be closed.
    TimedOut,
}

/// Write one frame: `kind | len | payload` as a single `write_all`.
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> Result<()> {
    let mut buf = Vec::with_capacity(FRAME_HEADER + payload.len());
    buf.push(kind);
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf).map_err(io_err("writing frame"))?;
    w.flush().map_err(io_err("flushing frame"))?;
    Ok(())
}

/// Total wire bytes of a frame with `payload_len` payload bytes.
pub fn frame_wire_bytes(payload_len: usize) -> u64 {
    (FRAME_HEADER + payload_len) as u64
}

fn io_err(ctx: &'static str) -> impl Fn(std::io::Error) -> Error {
    move |e| Error::net(format!("{ctx}: {e}"))
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Blocking read of one frame. Distinguishes orderly EOF and read
/// timeouts from hard I/O errors; enforces `max_len` on the length
/// prefix before allocating.
pub fn read_frame(r: &mut impl Read, max_len: usize) -> Result<ReadOutcome> {
    let mut header = [0u8; FRAME_HEADER];
    // first byte separately: EOF here is an orderly close
    match r.read(&mut header[..1]) {
        Ok(0) => return Ok(ReadOutcome::Eof),
        Ok(_) => {}
        Err(e) if is_timeout(&e) => return Ok(ReadOutcome::TimedOut),
        Err(e) => return Err(Error::net(format!("reading frame header: {e}"))),
    }
    match r.read_exact(&mut header[1..]) {
        Ok(()) => {}
        Err(e) if is_timeout(&e) => return Ok(ReadOutcome::TimedOut),
        Err(e) => return Err(Error::net(format!("reading frame header: {e}"))),
    }
    let kind = header[0];
    let len = u32::from_le_bytes([header[1], header[2], header[3], header[4]]) as usize;
    if len > max_len {
        return Err(Error::net(format!(
            "frame of {len} bytes exceeds the {max_len}-byte limit"
        )));
    }
    let mut payload = vec![0u8; len];
    match r.read_exact(&mut payload) {
        Ok(()) => Ok(ReadOutcome::Frame(kind, payload)),
        Err(e) if is_timeout(&e) => Ok(ReadOutcome::TimedOut),
        Err(e) => Err(Error::net(format!("reading {len}-byte frame payload: {e}"))),
    }
}

fn push_str8(buf: &mut Vec<u8>, s: &str) -> Result<()> {
    if s.len() > u8::MAX as usize {
        return Err(Error::net(format!("string field too long ({} bytes)", s.len())));
    }
    buf.push(s.len() as u8);
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

fn take_str8<'a>(b: &mut &'a [u8]) -> Result<&'a str> {
    let (&len, rest) = b.split_first().ok_or_else(|| Error::net("truncated string field"))?;
    let len = len as usize;
    if rest.len() < len {
        return Err(Error::net("truncated string field"));
    }
    let (s, rest) = rest.split_at(len);
    *b = rest;
    std::str::from_utf8(s).map_err(|_| Error::net("string field is not UTF-8"))
}

fn take_u32(b: &mut &[u8]) -> Result<u32> {
    if b.len() < 4 {
        return Err(Error::net("truncated integer field"));
    }
    let (x, rest) = b.split_at(4);
    *b = rest;
    Ok(u32::from_le_bytes([x[0], x[1], x[2], x[3]]))
}

fn take_u64(b: &mut &[u8]) -> Result<u64> {
    if b.len() < 8 {
        return Err(Error::net("truncated integer field"));
    }
    let (x, rest) = b.split_at(8);
    *b = rest;
    Ok(u64::from_le_bytes([x[0], x[1], x[2], x[3], x[4], x[5], x[6], x[7]]))
}

/// HELLO payload: the session contract the client asks for. The server
/// lowers the names through `DecoderBuilder`'s own parsers and rejects
/// (REJECT/`config`) anything the served pipeline does not match.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hello {
    pub version: u16,
    /// Feature flags the client offers ([`flags`]).
    pub flags: u16,
    pub code: String,
    pub backend: String,
    pub termination: String,
    pub payload_stages: u32,
    pub head_stages: u32,
    pub tail_stages: u32,
}

impl Hello {
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&self.version.to_le_bytes());
        buf.extend_from_slice(&self.flags.to_le_bytes());
        push_str8(&mut buf, &self.code)?;
        push_str8(&mut buf, &self.backend)?;
        push_str8(&mut buf, &self.termination)?;
        buf.extend_from_slice(&self.payload_stages.to_le_bytes());
        buf.extend_from_slice(&self.head_stages.to_le_bytes());
        buf.extend_from_slice(&self.tail_stages.to_le_bytes());
        Ok(buf)
    }

    pub fn decode(mut b: &[u8]) -> Result<Hello> {
        if b.len() < 4 {
            return Err(Error::net("truncated HELLO"));
        }
        let version = u16::from_le_bytes([b[0], b[1]]);
        let flags = u16::from_le_bytes([b[2], b[3]]);
        b = &b[4..];
        let code = take_str8(&mut b)?.to_string();
        let backend = take_str8(&mut b)?.to_string();
        let termination = take_str8(&mut b)?.to_string();
        let payload_stages = take_u32(&mut b)?;
        let head_stages = take_u32(&mut b)?;
        let tail_stages = take_u32(&mut b)?;
        if !b.is_empty() {
            return Err(Error::net("trailing bytes in HELLO"));
        }
        Ok(Hello {
            version,
            flags,
            code,
            backend,
            termination,
            payload_stages,
            head_stages,
            tail_stages,
        })
    }
}

/// ACK payload: session id + the pipeline's frame geometry (so clients
/// can sanity-check their chunking).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ack {
    pub session: u64,
    pub frame_stages: u32,
    pub beta: u32,
    /// Feature flags in effect for the session ([`flags`]) — the
    /// server's decision, which both ends honor from here on.
    pub flags: u16,
}

impl Ack {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(18);
        buf.extend_from_slice(&self.session.to_le_bytes());
        buf.extend_from_slice(&self.frame_stages.to_le_bytes());
        buf.extend_from_slice(&self.beta.to_le_bytes());
        buf.extend_from_slice(&self.flags.to_le_bytes());
        buf
    }

    pub fn decode(mut b: &[u8]) -> Result<Ack> {
        let session = take_u64(&mut b)?;
        let frame_stages = take_u32(&mut b)?;
        let beta = take_u32(&mut b)?;
        if b.len() < 2 {
            return Err(Error::net("truncated ACK"));
        }
        let flags = u16::from_le_bytes([b[0], b[1]]);
        b = &b[2..];
        if !b.is_empty() {
            return Err(Error::net("trailing bytes in ACK"));
        }
        Ok(Ack { session, frame_stages, beta, flags })
    }
}

/// REJECT payload: reason byte + UTF-8 detail.
pub fn encode_reject(reason: u8, detail: &str) -> Vec<u8> {
    let mut buf = Vec::with_capacity(1 + detail.len());
    buf.push(reason);
    buf.extend_from_slice(detail.as_bytes());
    buf
}

/// Decode a REJECT payload into `(reason, detail)`.
pub fn decode_reject(b: &[u8]) -> Result<(u8, String)> {
    let (&reason, rest) = b.split_first().ok_or_else(|| Error::net("empty REJECT"))?;
    Ok((reason, String::from_utf8_lossy(rest).into_owned()))
}

/// Encode an LLR slice as little-endian f32 bytes (DATA payload).
pub fn encode_llrs(llr: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(llr.len() * 4);
    for &x in llr {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    buf
}

/// Decode a DATA payload back into LLRs.
pub fn decode_llrs(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(Error::net(format!("LLR payload of {} bytes is not f32-aligned", b.len())));
    }
    Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// IEEE CRC32 (the zlib/PNG/Ethernet polynomial, reflected). Table is
/// built once; check value: `crc32(b"123456789") == 0xCBF43926`.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Encode a DATA payload: raw LLR bytes, prefixed with their CRC32 when
/// the session negotiated [`flags::DATA_CRC`].
pub fn encode_data_payload(llr: &[f32], crc: bool) -> Vec<u8> {
    let body = encode_llrs(llr);
    if !crc {
        return body;
    }
    let mut buf = Vec::with_capacity(4 + body.len());
    buf.extend_from_slice(&crc32(&body).to_le_bytes());
    buf.extend_from_slice(&body);
    buf
}

/// Decode a DATA payload, verifying the CRC32 prefix when the session
/// negotiated one. A checksum failure is a typed error whose message
/// carries the stable `crc-mismatch` token (see
/// [`is_crc_mismatch`]) — the server answers it with
/// `REJECT crc-mismatch` and evicts the session.
pub fn decode_data_payload(b: &[u8], crc: bool) -> Result<Vec<f32>> {
    if !crc {
        return decode_llrs(b);
    }
    if b.len() < 4 {
        return Err(Error::net(format!("DATA frame of {} bytes is too short for its crc32", b.len())));
    }
    let (head, body) = b.split_at(4);
    let want = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
    let got = crc32(body);
    if got != want {
        return Err(Error::net(format!(
            "crc-mismatch on DATA frame: header {want:#010x}, payload {got:#010x}"
        )));
    }
    decode_llrs(body)
}

/// Whether a decode error is a DATA CRC failure (vs. e.g. a framing or
/// alignment error) — decides REJECT `crc-mismatch` over a plain ERROR.
pub fn is_crc_mismatch(e: &Error) -> bool {
    matches!(e, Error::Net(m) if m.contains("crc-mismatch"))
}

/// Incremental frame parser for the nonblocking read path: feed it
/// whatever bytes `read` produced, take complete frames out. Length
/// prefixes are bounded and kinds validated before the payload is
/// materialized, so a malformed peer is rejected with a typed error no
/// matter how its bytes are sliced across reads.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    pub fn new() -> FrameBuf {
        FrameBuf::default()
    }

    /// Append raw wire bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True when no partial frame is buffered — an EOF here is an
    /// orderly close, an EOF elsewhere is a truncated frame.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Take the next complete frame, if one is buffered. `Ok(None)`
    /// means "need more bytes"; errors (unknown kind, oversize length
    /// prefix) poison the connection and are typed.
    pub fn next_frame(&mut self, max_len: usize) -> Result<Option<(u8, Vec<u8>)>> {
        if self.buf.len() < FRAME_HEADER {
            return Ok(None);
        }
        let kind = self.buf[0];
        check_kind(kind)?;
        let len =
            u32::from_le_bytes([self.buf[1], self.buf[2], self.buf[3], self.buf[4]]) as usize;
        if len > max_len {
            return Err(Error::net(format!(
                "frame of {len} bytes exceeds the {max_len}-byte limit"
            )));
        }
        if self.buf.len() < FRAME_HEADER + len {
            return Ok(None);
        }
        let payload = self.buf[FRAME_HEADER..FRAME_HEADER + len].to_vec();
        self.buf.drain(..FRAME_HEADER + len);
        Ok(Some((kind, payload)))
    }
}

/// One UDP request datagram: a whole block of LLRs for flow `flow`.
#[derive(Clone, Debug, PartialEq)]
pub struct UdpBlock {
    pub flow: u64,
    pub seq: u32,
    pub llr: Vec<f32>,
}

impl UdpBlock {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(UDP_HEADER + self.llr.len() * 4);
        buf.extend_from_slice(&self.flow.to_le_bytes());
        buf.extend_from_slice(&self.seq.to_le_bytes());
        buf.extend_from_slice(&encode_llrs(&self.llr));
        buf
    }

    pub fn decode(mut b: &[u8]) -> Result<UdpBlock> {
        let flow = take_u64(&mut b)?;
        let seq = take_u32(&mut b)?;
        let llr = decode_llrs(b)?;
        Ok(UdpBlock { flow, seq, llr })
    }
}

/// One UDP reply datagram: echoed flow/seq + status + decoded bits
/// (`status == OK`) or a UTF-8 error detail (`status == ERR`).
#[derive(Clone, Debug, PartialEq)]
pub struct UdpReply {
    pub flow: u64,
    pub seq: u32,
    pub status: u8,
    pub body: Vec<u8>,
}

impl UdpReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(UDP_HEADER + 1 + self.body.len());
        buf.extend_from_slice(&self.flow.to_le_bytes());
        buf.extend_from_slice(&self.seq.to_le_bytes());
        buf.push(self.status);
        buf.extend_from_slice(&self.body);
        buf
    }

    pub fn decode(mut b: &[u8]) -> Result<UdpReply> {
        let flow = take_u64(&mut b)?;
        let seq = take_u32(&mut b)?;
        let (&status, body) = b.split_first().ok_or_else(|| Error::net("truncated UDP reply"))?;
        Ok(UdpReply { flow, seq, status, body: body.to_vec() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let mut wire = Vec::new();
        write_frame(&mut wire, kind::DATA, &[1, 2, 3]).unwrap();
        write_frame(&mut wire, kind::FINISH, &[]).unwrap();
        let mut r = Cursor::new(wire);
        match read_frame(&mut r, 1024).unwrap() {
            ReadOutcome::Frame(k, p) => {
                assert_eq!(k, kind::DATA);
                assert_eq!(p, vec![1, 2, 3]);
            }
            other => panic!("{other:?}"),
        }
        match read_frame(&mut r, 1024).unwrap() {
            ReadOutcome::Frame(k, p) => {
                assert_eq!(k, kind::FINISH);
                assert!(p.is_empty());
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(read_frame(&mut r, 1024).unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn oversized_frame_rejected_before_allocation() {
        let mut wire = Vec::new();
        wire.push(kind::DATA);
        wire.extend_from_slice(&u32::MAX.to_le_bytes());
        let e = read_frame(&mut Cursor::new(wire), 1 << 20).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "{e}");
        assert!(e.to_string().contains("exceeds"), "{e}");
    }

    #[test]
    fn truncated_frame_is_hard_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, kind::BITS, &[9; 10]).unwrap();
        wire.truncate(wire.len() - 3);
        let e = read_frame(&mut Cursor::new(wire), 1024).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "{e}");
    }

    #[test]
    fn hello_roundtrip() {
        let h = Hello {
            version: PROTO_VERSION,
            flags: flags::DATA_CRC,
            code: "ccsds".into(),
            backend: "simd".into(),
            termination: "tail-biting".into(),
            payload_stages: 64,
            head_stages: 32,
            tail_stages: 32,
        };
        assert_eq!(Hello::decode(&h.encode().unwrap()).unwrap(), h);
        assert!(Hello::decode(&[1]).is_err());
        let mut long = h.encode().unwrap();
        long.push(0);
        assert!(Hello::decode(&long).is_err());
    }

    #[test]
    fn ack_and_reject_roundtrip() {
        let a = Ack { session: 7, frame_stages: 96, beta: 2, flags: flags::DATA_CRC };
        assert_eq!(Ack::decode(&a.encode()).unwrap(), a);
        let (reason, detail) =
            decode_reject(&encode_reject(reject::SESSION_CAP, "cap 2 reached")).unwrap();
        assert_eq!(reason, reject::SESSION_CAP);
        assert_eq!(reject_reason_name(reason), "session-cap");
        assert_eq!(detail, "cap 2 reached");
    }

    #[test]
    fn llr_roundtrip_and_alignment() {
        let llr = vec![1.5f32, -0.25, 3.0];
        assert_eq!(decode_llrs(&encode_llrs(&llr)).unwrap(), llr);
        assert!(decode_llrs(&[0, 1, 2]).is_err());
    }

    #[test]
    fn crc32_check_vector() {
        // the standard CRC-32/ISO-HDLC check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn data_payload_crc_roundtrip_and_mismatch() {
        let llr = vec![0.5f32, -2.0, 1.25];
        // without crc: plain LLR bytes
        assert_eq!(decode_data_payload(&encode_data_payload(&llr, false), false).unwrap(), llr);
        // with crc: prefixed, verified
        let mut wire = encode_data_payload(&llr, true);
        assert_eq!(wire.len(), 4 + llr.len() * 4);
        assert_eq!(decode_data_payload(&wire, true).unwrap(), llr);
        // flip a payload bit: typed crc-mismatch, not a panic
        wire[6] ^= 0x01;
        let e = decode_data_payload(&wire, true).unwrap_err();
        assert!(is_crc_mismatch(&e), "{e}");
        assert!(!is_crc_mismatch(&Error::net("LLR payload of 3 bytes is not f32-aligned")));
    }

    #[test]
    fn frame_buf_reassembles_dribbled_bytes() {
        let mut wire = Vec::new();
        write_frame(&mut wire, kind::DATA, &[1, 2, 3, 4]).unwrap();
        write_frame(&mut wire, kind::FINISH, &[]).unwrap();
        let mut fb = FrameBuf::new();
        let mut frames = Vec::new();
        for &b in &wire {
            fb.extend(&[b]); // one byte at a time
            while let Some(f) = fb.next_frame(1024).unwrap() {
                frames.push(f);
            }
        }
        assert_eq!(frames, vec![(kind::DATA, vec![1, 2, 3, 4]), (kind::FINISH, vec![])]);
        assert!(fb.is_empty());
    }

    #[test]
    fn frame_buf_rejects_unknown_kind_and_oversize() {
        let mut fb = FrameBuf::new();
        fb.extend(&[0x7F, 0, 0, 0, 0]);
        let e = fb.next_frame(1024).unwrap_err();
        assert!(matches!(e, Error::Net(_)), "{e}");
        assert!(e.to_string().contains("unknown frame kind"), "{e}");

        let mut fb = FrameBuf::new();
        fb.extend(&[kind::DATA]);
        fb.extend(&u32::MAX.to_le_bytes());
        let e = fb.next_frame(1 << 20).unwrap_err();
        assert!(e.to_string().contains("exceeds"), "{e}");
    }

    #[test]
    fn udp_roundtrip() {
        let b = UdpBlock { flow: 42, seq: 3, llr: vec![0.5, -1.0] };
        assert_eq!(UdpBlock::decode(&b.encode()).unwrap(), b);
        let r = UdpReply { flow: 42, seq: 3, status: udp_status::OK, body: vec![1, 0, 1] };
        assert_eq!(UdpReply::decode(&r.encode()).unwrap(), r);
        assert!(UdpBlock::decode(&[0; 5]).is_err());
    }
}
