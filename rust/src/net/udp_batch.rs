//! Server-side UDP reply batching: `sendmmsg`-style syscall
//! aggregation for the datagram serving loop.
//!
//! [`ReplyBatch`] accumulates encoded reply datagrams and flushes them
//! through one batched send syscall ([`DatagramTx::send_batch`])
//! whenever the batch fills (`net.udp_batch` datagrams) or the serving
//! loop drains the socket (no further request datagram is immediately
//! pending), so an isolated reply is never delayed behind a timer.
//!
//! The batched syscall is gated at *runtime*, the same way the SIMD
//! ACS kernel gates AVX2 dispatch: the first `send_batch` that reports
//! the syscall unavailable latches the batch into per-datagram
//! [`DatagramTx::send_one`] fallback for the rest of the server's
//! life, and every datagram sent that way bumps
//! `net.udp_send_fallbacks`. Successful batches bump
//! `net.udp_batched_sends` (one per syscall) and
//! `net.udp_batch_datagrams` (one per datagram), so the observed
//! aggregation ratio is `udp_batch_datagrams / udp_batched_sends`.
//!
//! Replies on UDP are best-effort (the stop-and-wait / windowed client
//! retransmits on silence), so transient send errors drop the affected
//! datagrams without counting their bytes — mirroring what the
//! pre-batching loop did with a failed `send_to`.

use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::Ordering;

use crate::coordinator::NetStats;

/// Datagram sink a [`ReplyBatch`] flushes into. The real
/// implementation is [`SysTx`] (a `UdpSocket` with a Linux `sendmmsg`
/// fast path); tests substitute deterministic shims to pin the exact
/// syscall/counter sequence.
pub trait DatagramTx {
    /// Send a prefix of `msgs` in one batched syscall and return how
    /// many datagrams it covered.
    ///
    /// `Err` means the batched syscall is *unavailable on this system*
    /// (e.g. `ENOSYS`) and latches the caller into the
    /// [`send_one`](DatagramTx::send_one) fallback. A transient send
    /// failure is not an `Err`: best-effort delivery drops the
    /// remaining datagrams by returning `Ok(0)`.
    fn send_batch(&self, msgs: &[(SocketAddr, Vec<u8>)]) -> std::io::Result<usize>;

    /// Send one datagram (the unbatched path).
    fn send_one(&self, peer: SocketAddr, buf: &[u8]) -> std::io::Result<()>;
}

/// Accumulates encoded reply datagrams and flushes them in batches of
/// up to `cap` through a [`DatagramTx`]. `cap <= 1` disables batching
/// entirely: every push sends immediately and no batching counters
/// move, so `net.udp_batch = 1` reproduces the pre-batching server
/// byte-for-byte.
pub struct ReplyBatch<'a, T: DatagramTx> {
    tx: &'a T,
    stats: &'a NetStats,
    cap: usize,
    pending: Vec<(SocketAddr, Vec<u8>)>,
    /// Latched runtime gate: flips false on the first `send_batch`
    /// that reports the syscall unavailable, never flips back.
    available: bool,
}

impl<'a, T: DatagramTx> ReplyBatch<'a, T> {
    pub fn new(tx: &'a T, cap: usize, stats: &'a NetStats) -> Self {
        ReplyBatch { tx, stats, cap, pending: Vec::with_capacity(cap.max(1)), available: true }
    }

    /// Datagrams waiting for the next flush.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Queue one encoded reply; sends immediately when batching is
    /// disabled (`cap <= 1`) or the batched syscall has latched
    /// unavailable, and flushes when the batch fills.
    pub fn push(&mut self, peer: SocketAddr, wire: Vec<u8>) {
        if self.cap <= 1 || !self.available {
            self.send_single(peer, &wire);
            return;
        }
        self.pending.push((peer, wire));
        if self.pending.len() >= self.cap {
            self.flush();
        }
    }

    /// Send everything pending. Called by the serving loop whenever
    /// the socket has no further datagram to drain (and on shutdown),
    /// so batching adds at most one socket-drain check of latency.
    pub fn flush(&mut self) {
        let mut off = 0;
        while off < self.pending.len() {
            match self.tx.send_batch(&self.pending[off..]) {
                Ok(0) => {
                    // transient send failure: best-effort drop of the
                    // remainder, bytes uncounted (matches a failed
                    // send_to on the unbatched path)
                    break;
                }
                Ok(n) => {
                    let n = n.min(self.pending.len() - off);
                    self.stats.udp_batched_sends.fetch_add(1, Ordering::Relaxed);
                    self.stats.udp_batch_datagrams.fetch_add(n as u64, Ordering::Relaxed);
                    let bytes: u64 =
                        self.pending[off..off + n].iter().map(|(_, w)| w.len() as u64).sum();
                    self.stats.bytes_out.fetch_add(bytes, Ordering::Relaxed);
                    off += n;
                }
                Err(_) => {
                    // syscall unavailable on this system: latch the
                    // per-datagram fallback and drain what's left
                    self.available = false;
                    let rest: Vec<_> = self.pending.drain(off..).collect();
                    for (peer, wire) in rest {
                        self.send_single(peer, &wire);
                    }
                    break;
                }
            }
        }
        self.pending.clear();
    }

    fn send_single(&self, peer: SocketAddr, wire: &[u8]) {
        if self.tx.send_one(peer, wire).is_ok() {
            self.stats.bytes_out.fetch_add(wire.len() as u64, Ordering::Relaxed);
            // only a *latched* single is a fallback; cap <= 1 is
            // batching deliberately disabled, not degraded
            if self.cap > 1 && !self.available {
                self.stats.udp_send_fallbacks.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// The real transport: replies go out over the server's `UdpSocket`,
/// batched through raw dependency-free `sendmmsg(2)` bindings on
/// Linux. Elsewhere `send_batch` reports unavailable on first use and
/// the batch latches into plain `send_to`.
pub struct SysTx<'a>(pub &'a UdpSocket);

impl DatagramTx for SysTx<'_> {
    #[cfg(target_os = "linux")]
    fn send_batch(&self, msgs: &[(SocketAddr, Vec<u8>)]) -> std::io::Result<usize> {
        mmsg::send_batch(self.0, msgs)
    }

    #[cfg(not(target_os = "linux"))]
    fn send_batch(&self, _msgs: &[(SocketAddr, Vec<u8>)]) -> std::io::Result<usize> {
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "sendmmsg is only bound on linux",
        ))
    }

    fn send_one(&self, peer: SocketAddr, buf: &[u8]) -> std::io::Result<()> {
        self.0.send_to(buf, peer).map(|_| ())
    }
}

/// Raw `sendmmsg(2)` bindings (no libc crate), mirroring the style of
/// the `poll`/`epoll` bindings in `net::reactor`.
#[cfg(target_os = "linux")]
mod mmsg {
    use std::net::{SocketAddr, UdpSocket};
    use std::os::raw::{c_int, c_uint, c_void};
    use std::os::unix::io::AsRawFd;

    const AF_INET: u16 = 2;
    const AF_INET6: u16 = 10;
    const ENOSYS: i32 = 38;
    const EOPNOTSUPP: i32 = 95;
    const EINTR: i32 = 4;

    /// Widest sockaddr we emit (`sockaddr_in6` is 28 bytes).
    const SOCKADDR_MAX: usize = 28;

    #[repr(C)]
    struct IoVec {
        base: *const u8,
        len: usize,
    }

    /// `struct msghdr` (linux UAPI layout; `repr(C)` reproduces the
    /// pointer-alignment padding after `msg_namelen`).
    #[repr(C)]
    struct MsgHdr {
        msg_name: *mut c_void,
        msg_namelen: u32,
        msg_iov: *mut IoVec,
        msg_iovlen: usize,
        msg_control: *mut c_void,
        msg_controllen: usize,
        msg_flags: c_int,
    }

    #[repr(C)]
    struct MmsgHdr {
        msg_hdr: MsgHdr,
        msg_len: c_uint,
    }

    extern "C" {
        fn sendmmsg(sockfd: c_int, msgvec: *mut MmsgHdr, vlen: c_uint, flags: c_int) -> c_int;
    }

    /// Serialize `addr` into `buf` with the kernel's `sockaddr_in` /
    /// `sockaddr_in6` layout; returns the address length.
    fn encode_sockaddr(addr: &SocketAddr, buf: &mut [u8; SOCKADDR_MAX]) -> u32 {
        buf.fill(0);
        match addr {
            SocketAddr::V4(a) => {
                buf[..2].copy_from_slice(&AF_INET.to_ne_bytes());
                buf[2..4].copy_from_slice(&a.port().to_be_bytes());
                buf[4..8].copy_from_slice(&a.ip().octets());
                16
            }
            SocketAddr::V6(a) => {
                buf[..2].copy_from_slice(&AF_INET6.to_ne_bytes());
                buf[2..4].copy_from_slice(&a.port().to_be_bytes());
                buf[4..8].copy_from_slice(&a.flowinfo().to_be_bytes());
                buf[8..24].copy_from_slice(&a.ip().octets());
                buf[24..28].copy_from_slice(&a.scope_id().to_ne_bytes());
                28
            }
        }
    }

    pub fn send_batch(socket: &UdpSocket, msgs: &[(SocketAddr, Vec<u8>)]) -> std::io::Result<usize> {
        // every pointer below targets these three flat arrays, which
        // outlive the syscall
        let mut addrs = vec![[0u8; SOCKADDR_MAX]; msgs.len()];
        let mut iovs = Vec::with_capacity(msgs.len());
        let mut hdrs = Vec::with_capacity(msgs.len());
        for (i, (peer, wire)) in msgs.iter().enumerate() {
            let namelen = encode_sockaddr(peer, &mut addrs[i]);
            iovs.push(IoVec { base: wire.as_ptr(), len: wire.len() });
            hdrs.push(MmsgHdr {
                msg_hdr: MsgHdr {
                    msg_name: addrs[i].as_mut_ptr() as *mut c_void,
                    msg_namelen: namelen,
                    msg_iov: std::ptr::null_mut(), // patched below
                    msg_iovlen: 1,
                    msg_control: std::ptr::null_mut(),
                    msg_controllen: 0,
                    msg_flags: 0,
                },
                msg_len: 0,
            });
        }
        for (hdr, iov) in hdrs.iter_mut().zip(iovs.iter_mut()) {
            hdr.msg_hdr.msg_iov = iov as *mut IoVec;
        }
        loop {
            let n = unsafe {
                sendmmsg(socket.as_raw_fd(), hdrs.as_mut_ptr(), hdrs.len() as c_uint, 0)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = std::io::Error::last_os_error();
            return match err.raw_os_error() {
                Some(EINTR) => continue,
                // unavailable: latch the per-datagram fallback
                Some(ENOSYS) | Some(EOPNOTSUPP) => Err(err),
                // transient: best-effort drop (caller stops the flush)
                _ => Ok(0),
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::sync::atomic::Ordering;

    fn peer(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    /// What the shim does on the next `send_batch` call.
    #[derive(Clone, Copy)]
    enum Step {
        /// Accept up to this many datagrams.
        Accept(usize),
        /// Report the syscall unavailable.
        Unavailable,
        /// Report a transient failure (`Ok(0)`).
        Transient,
    }

    /// Deterministic [`DatagramTx`]: scripted `send_batch` outcomes,
    /// records every syscall so tests pin the exact sequence.
    #[derive(Default)]
    struct ShimTx {
        script: RefCell<Vec<Step>>,
        /// Sizes handed to each `send_batch` call.
        batch_calls: RefCell<Vec<usize>>,
        /// Byte lengths sent through `send_one`.
        singles: RefCell<Vec<usize>>,
    }

    impl ShimTx {
        fn scripted(steps: &[Step]) -> ShimTx {
            let shim = ShimTx::default();
            *shim.script.borrow_mut() = steps.to_vec();
            shim
        }
    }

    impl DatagramTx for ShimTx {
        fn send_batch(&self, msgs: &[(SocketAddr, Vec<u8>)]) -> std::io::Result<usize> {
            self.batch_calls.borrow_mut().push(msgs.len());
            let step = {
                let mut s = self.script.borrow_mut();
                if s.is_empty() { Step::Accept(msgs.len()) } else { s.remove(0) }
            };
            match step {
                Step::Accept(n) => Ok(n.min(msgs.len())),
                Step::Transient => Ok(0),
                Step::Unavailable => Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "sendmmsg: ENOSYS",
                )),
            }
        }

        fn send_one(&self, _peer: SocketAddr, buf: &[u8]) -> std::io::Result<()> {
            self.singles.borrow_mut().push(buf.len());
            Ok(())
        }
    }

    fn counters(stats: &NetStats) -> (u64, u64, u64, u64) {
        (
            stats.udp_batched_sends.load(Ordering::Relaxed),
            stats.udp_batch_datagrams.load(Ordering::Relaxed),
            stats.udp_send_fallbacks.load(Ordering::Relaxed),
            stats.bytes_out.load(Ordering::Relaxed),
        )
    }

    #[test]
    fn full_batch_flushes_in_one_syscall() {
        let tx = ShimTx::default();
        let stats = NetStats::default();
        let mut batch = ReplyBatch::new(&tx, 4, &stats);
        for i in 0..4 {
            batch.push(peer(9000 + i), vec![0u8; 10 + i as usize]);
        }
        // filling the batch flushed it without waiting for a tick
        assert!(batch.is_empty());
        assert_eq!(*tx.batch_calls.borrow(), vec![4]);
        assert!(tx.singles.borrow().is_empty());
        assert_eq!(counters(&stats), (1, 4, 0, 10 + 11 + 12 + 13));
    }

    #[test]
    fn drain_flush_sends_a_partial_batch() {
        let tx = ShimTx::default();
        let stats = NetStats::default();
        let mut batch = ReplyBatch::new(&tx, 8, &stats);
        batch.push(peer(9000), vec![0u8; 7]);
        batch.push(peer(9001), vec![0u8; 9]);
        assert_eq!(batch.len(), 2, "below cap: nothing sent yet");
        assert_eq!(counters(&stats), (0, 0, 0, 0));
        batch.flush(); // the serving loop drained the socket
        assert!(batch.is_empty());
        assert_eq!(*tx.batch_calls.borrow(), vec![2]);
        assert_eq!(counters(&stats), (1, 2, 0, 16));
        batch.flush(); // empty flush is a no-op, not a zero-size syscall
        assert_eq!(*tx.batch_calls.borrow(), vec![2]);
    }

    #[test]
    fn partial_kernel_accept_retries_the_remainder() {
        let tx = ShimTx::scripted(&[Step::Accept(3), Step::Accept(2)]);
        let stats = NetStats::default();
        let mut batch = ReplyBatch::new(&tx, 5, &stats);
        for i in 0..5 {
            batch.push(peer(9000 + i), vec![0u8; 4]);
        }
        // 5 datagrams over two syscalls (kernel accepted 3, then 2)
        assert_eq!(*tx.batch_calls.borrow(), vec![5, 2]);
        assert_eq!(counters(&stats), (2, 5, 0, 20));
    }

    #[test]
    fn cap_one_disables_batching_and_counters() {
        let tx = ShimTx::default();
        let stats = NetStats::default();
        let mut batch = ReplyBatch::new(&tx, 1, &stats);
        batch.push(peer(9000), vec![0u8; 5]);
        batch.push(peer(9001), vec![0u8; 6]);
        // straight through send_one, never buffered, no batch syscalls,
        // and no fallback counters — cap 1 is "disabled", not "degraded"
        assert!(batch.is_empty());
        assert!(tx.batch_calls.borrow().is_empty());
        assert_eq!(*tx.singles.borrow(), vec![5, 6]);
        assert_eq!(counters(&stats), (0, 0, 0, 11));
    }

    #[test]
    fn unavailable_syscall_latches_single_datagram_fallback() {
        let tx = ShimTx::scripted(&[Step::Unavailable]);
        let stats = NetStats::default();
        let mut batch = ReplyBatch::new(&tx, 4, &stats);
        batch.push(peer(9000), vec![0u8; 3]);
        batch.push(peer(9001), vec![0u8; 5]);
        batch.flush();
        // the probe syscall failed; both datagrams fell back to singles
        assert_eq!(*tx.batch_calls.borrow(), vec![2]);
        assert_eq!(*tx.singles.borrow(), vec![3, 5]);
        assert_eq!(counters(&stats), (0, 0, 2, 8));
        // latched: later pushes go straight to send_one without
        // re-probing the syscall
        batch.push(peer(9002), vec![0u8; 7]);
        assert!(batch.is_empty());
        assert_eq!(*tx.batch_calls.borrow(), vec![2], "no second probe");
        assert_eq!(*tx.singles.borrow(), vec![3, 5, 7]);
        assert_eq!(counters(&stats), (0, 0, 3, 15));
    }

    #[test]
    fn transient_failure_drops_without_latching() {
        let tx = ShimTx::scripted(&[Step::Transient]);
        let stats = NetStats::default();
        let mut batch = ReplyBatch::new(&tx, 4, &stats);
        batch.push(peer(9000), vec![0u8; 3]);
        batch.flush();
        // best-effort drop: nothing counted, nothing resent
        assert_eq!(*tx.batch_calls.borrow(), vec![1]);
        assert!(tx.singles.borrow().is_empty());
        assert_eq!(counters(&stats), (0, 0, 0, 0));
        // not latched: the next flush probes the batched syscall again
        batch.push(peer(9001), vec![0u8; 4]);
        batch.flush();
        assert_eq!(*tx.batch_calls.borrow(), vec![1, 1]);
        assert_eq!(counters(&stats), (1, 1, 0, 4));
    }
}
