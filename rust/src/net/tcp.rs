//! TCP transport of the socket front-end: one connection is one
//! streaming [`Session`](crate::coordinator::Session).
//!
//! Server side: a single readiness-driven reactor thread
//! (`tcvd-net-reactor`) owns the listener and every connection —
//! nonblocking sockets multiplexed over the dependency-free readiness
//! wrappers in [`super::reactor`] (`poll(2)`, or the Linux `epoll`
//! kernel-event backend; `net.poller`). Each connection is a small state
//! machine (handshake → streaming → draining → closing) built on the
//! incremental [`FrameBuf`] parser, so partial reads and 1-byte writes
//! from a peer are business as usual. Decoded BITS frames are written
//! back through a per-connection outbound buffer with a backpressure
//! high-water mark (`net.write_high_water`): when a slow reader lets
//! the buffer fill, the reactor stops draining that session's pipeline
//! output (the bounded session channel then backpressures the shards)
//! instead of buffering without bound — no writer thread per session,
//! no unbounded memory. The thread count is fixed no matter how many
//! connections are live.
//!
//! Every connection path — clean END, dirty disconnect, protocol
//! error, CRC mismatch, idle eviction — closes the pipeline session
//! exactly once (`SessionHandle::close_dispatched` is idempotent), so
//! the reassembler never leaks state and `Coordinator::shutdown` never
//! hangs on an abandoned session.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::DecoderBuilder;
use crate::coordinator::{poller_code, SessionHandle};
use crate::defaults;
use crate::error::{Error, Result, ResultExt};

use super::protocol::{
    decode_data_payload, decode_reject, encode_data_payload, encode_reject, flags,
    frame_wire_bytes, is_crc_mismatch, kind, read_frame, reject, reject_reason_name, write_frame,
    Ack, FrameBuf, Hello, ReadOutcome,
};
use super::reactor::{listener_fd, stream_fd, PollSet, READ, WRITE};
use super::{Contract, ServerCtx};

/// How long a client waits for a server frame before giving up.
const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Stop consuming DATA frames from a connection once this many framed
/// jobs are waiting on the pipeline (read interest resumes when the
/// shard queues accept them).
const PENDING_FRAMES_MAX: usize = 64;

/// Per-connection outbound buffer: a queue of wire segments flushed as
/// far as the socket accepts, tolerating partial writes. Small control
/// frames (ACK, END, errors) coalesce into a shared tail segment so
/// they cost one `write` together; decoded BITS payloads are *moved*
/// in as their own segments ([`push_frame_owned`](Self::push_frame_owned))
/// — the reassembler's output `Vec` becomes the wire buffer directly,
/// with no intermediate copy.
#[derive(Default)]
struct OutBuf {
    segs: std::collections::VecDeque<Vec<u8>>,
    /// Bytes of the front segment already written to the socket.
    pos: usize,
    /// Total unwritten bytes across all segments.
    len: usize,
    /// Whether the tail segment is a coalescing buffer small frames may
    /// append to (false when the tail is a moved payload segment).
    tail_coalesces: bool,
}

impl OutBuf {
    fn len(&self) -> usize {
        self.len
    }

    fn coalescing_tail(&mut self) -> &mut Vec<u8> {
        if !self.tail_coalesces {
            self.segs.push_back(Vec::new());
            self.tail_coalesces = true;
        }
        self.segs.back_mut().expect("coalescing tail exists")
    }

    /// Append a frame by copy (control frames: payloads are tiny).
    fn push_frame(&mut self, frame_kind: u8, payload: &[u8]) {
        self.len += 5 + payload.len();
        let tail = self.coalescing_tail();
        tail.push(frame_kind);
        tail.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        tail.extend_from_slice(payload);
    }

    /// Append a frame moving `payload` in as its own segment: the
    /// zero-copy BITS path (only the 5-byte header is materialized).
    fn push_frame_owned(&mut self, frame_kind: u8, payload: Vec<u8>) {
        self.len += 5 + payload.len();
        let tail = self.coalescing_tail();
        tail.push(frame_kind);
        tail.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        if !payload.is_empty() {
            self.segs.push_back(payload);
            self.tail_coalesces = false;
        }
    }

    /// The next contiguous run of unwritten bytes (one segment's worth).
    fn pending(&self) -> &[u8] {
        match self.segs.front() {
            Some(s) => &s[self.pos..],
            None => &[],
        }
    }

    fn consume(&mut self, n: usize) {
        debug_assert!(n <= self.len);
        self.len -= n;
        self.pos += n;
        while let Some(front) = self.segs.front() {
            if self.pos < front.len() {
                break;
            }
            self.pos -= front.len();
            self.segs.pop_front();
        }
        if self.segs.is_empty() {
            self.pos = 0;
            self.tail_coalesces = false;
        }
    }

    fn clear(&mut self) {
        self.segs.clear();
        self.pos = 0;
        self.len = 0;
        self.tail_coalesces = false;
    }
}

/// Connection lifecycle. Counter discipline matches the blocking
/// implementation this replaced: `sessions_evicted` increments exactly
/// once per dirty close of an *admitted* session, never for handshake
/// failures or clean ENDs.
enum Phase {
    /// Pre-session: waiting for HELLO (METRICS_REQ answered inline).
    Handshake,
    /// Session open: DATA/FINISH/METRICS_REQ frames drive the pipeline.
    Streaming,
    /// FINISH accepted: dispatch the tail, drain the decoded output,
    /// then END.
    Draining,
    /// Flush the outbound buffer, then close the socket.
    Closing,
}

/// The pipeline half of an admitted connection.
struct SessionIo {
    handle: SessionHandle,
    rx: Option<Receiver<Result<Vec<u8>>>>,
    t_finish: Option<Instant>,
}

struct Conn {
    sock: TcpStream,
    inbuf: FrameBuf,
    outbuf: OutBuf,
    phase: Phase,
    session: Option<SessionIo>,
    /// DATA frames carry a CRC32 prefix (decided at ACK time).
    crc: bool,
    /// Whether this connection holds a session-table slot.
    admitted: bool,
    eof: bool,
    write_dead: bool,
    last_read: Instant,
    last_write: Instant,
    done: bool,
}

impl Conn {
    fn new(sock: TcpStream) -> Conn {
        let now = Instant::now();
        Conn {
            sock,
            inbuf: FrameBuf::new(),
            outbuf: OutBuf::default(),
            phase: Phase::Handshake,
            session: None,
            crc: false,
            admitted: false,
            eof: false,
            write_dead: false,
            last_read: now,
            last_write: now,
            done: false,
        }
    }

    /// Poll interest this tick: read while the state machine consumes
    /// input (and the pipeline is keeping up), write while bytes wait.
    fn interest(&self) -> u8 {
        let mut i = 0;
        if self.wants_read() {
            i |= READ;
        }
        if self.outbuf.len() > 0 && !self.write_dead {
            i |= WRITE;
        }
        i
    }

    fn wants_read(&self) -> bool {
        match self.phase {
            Phase::Handshake => true,
            Phase::Streaming => self
                .session
                .as_ref()
                .map_or(true, |s| s.handle.pending_frames() < PENDING_FRAMES_MAX),
            Phase::Draining | Phase::Closing => false,
        }
    }

    /// Progress is gated on the pipeline rather than the socket — poll
    /// with a short timeout so completion is not tick-quantized.
    fn wants_fast_tick(&self) -> bool {
        match self.phase {
            Phase::Streaming | Phase::Draining => self.session.as_ref().is_some_and(|s| {
                s.handle.pending_frames() > 0 || (s.handle.framing_done() && s.rx.is_some())
            }),
            _ => false,
        }
    }

    fn queue_frame(&mut self, ctx: &ServerCtx, frame_kind: u8, payload: &[u8]) {
        if self.write_dead {
            return;
        }
        self.outbuf.push_frame(frame_kind, payload);
        ctx.metrics.net.write_buf_hwm.fetch_max(self.outbuf.len() as u64, Ordering::Relaxed);
    }

    fn queue_error(&mut self, ctx: &ServerCtx, e: &Error) {
        let text = e.to_string();
        self.queue_frame(ctx, kind::ERROR, text.as_bytes());
    }

    fn queue_metrics(&mut self, ctx: &ServerCtx) {
        let snap = ctx.metrics.snapshot().to_json().to_string_pretty();
        self.queue_frame(ctx, kind::METRICS, snap.as_bytes());
    }

    /// Dirty close of an admitted session: close the pipeline session
    /// at its dispatched prefix (idempotent), drop the output receiver
    /// (the reassembler ignores sends to a dropped receiver), count the
    /// eviction exactly once, optionally queue a final frame, and move
    /// to Closing.
    fn dirty_close(&mut self, ctx: &ServerCtx, last_frame: Option<(u8, Vec<u8>)>) {
        if let Some(mut s) = self.session.take() {
            s.handle.close_dispatched();
            ctx.metrics.net.sessions_evicted.fetch_add(1, Ordering::Relaxed);
        }
        if let Some((k, p)) = last_frame {
            self.queue_frame(ctx, k, &p);
        }
        self.phase = Phase::Closing;
    }

    /// Read whatever the socket has, without blocking.
    fn read_some(&mut self, ctx: &ServerCtx, scratch: &mut [u8]) {
        loop {
            match self.sock.read(scratch) {
                Ok(0) => {
                    self.eof = true;
                    return;
                }
                Ok(n) => {
                    self.inbuf.extend(&scratch[..n]);
                    self.last_read = Instant::now();
                    // bound per-tick intake: one oversize frame's worth
                    if self.inbuf.buffered() > ctx.net.max_frame_bytes + scratch.len() {
                        return;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    // hard read error: same terminal treatment as EOF
                    self.eof = true;
                    return;
                }
            }
        }
    }

    /// Consume complete frames from the input buffer, per phase.
    fn process_frames(&mut self, ctx: &Arc<ServerCtx>) {
        loop {
            if !matches!(self.phase, Phase::Handshake | Phase::Streaming) {
                return;
            }
            if matches!(self.phase, Phase::Streaming)
                && self.session.as_ref().is_some_and(|s| {
                    s.handle.pending_frames() >= PENDING_FRAMES_MAX
                })
            {
                return; // pipeline backpressure: leave frames buffered
            }
            let (k, p) = match self.inbuf.next_frame(ctx.net.max_frame_bytes) {
                Ok(Some(frame)) => frame,
                Ok(None) => return,
                Err(e) => {
                    // unframable input: typed error, then close (a
                    // pre-session connection closes without counters,
                    // an admitted one is a dirty disconnect)
                    match self.phase {
                        Phase::Handshake => {
                            self.queue_error(ctx, &e);
                            self.phase = Phase::Closing;
                        }
                        _ => self.dirty_close(
                            ctx,
                            Some((kind::ERROR, e.to_string().into_bytes())),
                        ),
                    }
                    return;
                }
            };
            ctx.metrics.net.bytes_in.fetch_add(frame_wire_bytes(p.len()), Ordering::Relaxed);
            match self.phase {
                Phase::Handshake => self.handshake_frame(ctx, k, &p),
                Phase::Streaming => self.session_frame(ctx, k, &p),
                _ => unreachable!("checked above"),
            }
        }
    }

    /// One pre-session frame: METRICS_REQ is answered sessionless, a
    /// HELLO runs contract + admission checks in the same order as the
    /// blocking server (config mismatch, then queue saturation, then
    /// the session cap).
    fn handshake_frame(&mut self, ctx: &Arc<ServerCtx>, k: u8, p: &[u8]) {
        match k {
            kind::METRICS_REQ => self.queue_metrics(ctx),
            kind::HELLO => {
                let hello = match Hello::decode(p) {
                    Ok(h) => h,
                    Err(e) => {
                        self.queue_error(ctx, &e);
                        self.phase = Phase::Closing;
                        return;
                    }
                };
                if let Err(e) = ctx.contract.check_hello(&hello) {
                    ctx.metrics.net.handshake_rejects.fetch_add(1, Ordering::Relaxed);
                    let rej = encode_reject(reject::CONFIG, e.message());
                    self.queue_frame(ctx, kind::REJECT, &rej);
                    self.phase = Phase::Closing;
                    return;
                }
                // admission: saturation before the cap, so a saturated
                // server sheds deterministically even with free slots
                if ctx.queues_saturated() {
                    ctx.metrics.net.sessions_shed.fetch_add(1, Ordering::Relaxed);
                    let detail =
                        format!("shard queues at depth {}", ctx.metrics.queue_depth_total());
                    let rej = encode_reject(reject::QUEUE_SATURATED, &detail);
                    self.queue_frame(ctx, kind::REJECT, &rej);
                    self.phase = Phase::Closing;
                    return;
                }
                if !ctx.table.admit_tcp() {
                    ctx.metrics.net.sessions_shed.fetch_add(1, Ordering::Relaxed);
                    let detail = format!("session cap {} reached", ctx.net.max_sessions);
                    let rej = encode_reject(reject::SESSION_CAP, &detail);
                    self.queue_frame(ctx, kind::REJECT, &rej);
                    self.phase = Phase::Closing;
                    return;
                }
                self.admitted = true;
                let session = match ctx.coord.open_session() {
                    Ok(s) => s,
                    Err(e) => {
                        ctx.table.release_tcp();
                        self.admitted = false;
                        self.queue_error(ctx, &e);
                        self.phase = Phase::Closing;
                        return;
                    }
                };
                ctx.metrics.net.sessions_accepted.fetch_add(1, Ordering::Relaxed);
                // CRC is in effect when the client offers it or the
                // server demands it; the ACK records the decision
                self.crc = hello.flags & flags::DATA_CRC != 0 || ctx.net.crc;
                let ack = Ack {
                    session: session.id(),
                    frame_stages: ctx.coord.tile().frame_stages() as u32,
                    beta: ctx.coord.trellis().code().beta() as u32,
                    flags: if self.crc { flags::DATA_CRC } else { 0 },
                };
                let (handle, rx) = session.split();
                self.session = Some(SessionIo { handle, rx: Some(rx), t_finish: None });
                self.queue_frame(ctx, kind::ACK, &ack.encode());
                self.phase = Phase::Streaming;
            }
            other => {
                self.queue_error(
                    ctx,
                    &Error::net(format!("expected HELLO, got frame kind {other:#04x}")),
                );
                self.phase = Phase::Closing;
            }
        }
    }

    /// One in-session frame.
    fn session_frame(&mut self, ctx: &ServerCtx, k: u8, p: &[u8]) {
        match k {
            kind::DATA => {
                let llr = match decode_data_payload(p, self.crc) {
                    Ok(llr) => llr,
                    Err(e) => {
                        let frame = if is_crc_mismatch(&e) {
                            (kind::REJECT, encode_reject(reject::CRC_MISMATCH, e.message()))
                        } else {
                            (kind::ERROR, e.to_string().into_bytes())
                        };
                        self.dirty_close(ctx, Some(frame));
                        return;
                    }
                };
                let s = self.session.as_mut().expect("streaming implies session");
                if let Err(e) = s.handle.frame_chunk(&llr) {
                    self.dirty_close(ctx, Some((kind::ERROR, e.to_string().into_bytes())));
                }
            }
            kind::FINISH => {
                let s = self.session.as_mut().expect("streaming implies session");
                s.t_finish = Some(Instant::now());
                match s.handle.frame_finish() {
                    Ok(()) => self.phase = Phase::Draining,
                    Err(e) => {
                        // the framer rejected the stream shape (e.g. a
                        // partial tail-biting tile); frame_finish
                        // already closed the pipeline session
                        self.dirty_close(ctx, Some((kind::ERROR, e.to_string().into_bytes())));
                    }
                }
            }
            kind::METRICS_REQ => self.queue_metrics(ctx),
            other => {
                let e = Error::net(format!("unexpected frame kind {other:#04x} in session"));
                self.dirty_close(ctx, Some((kind::ERROR, e.to_string().into_bytes())));
            }
        }
    }

    /// Drive the pipeline half: dispatch framed jobs, close the session
    /// once the tail is dispatched, move decoded output into the
    /// outbound buffer up to the high-water mark, send END when the
    /// output stream completes.
    fn pump_session(&mut self, ctx: &ServerCtx) {
        let Some(mut s) = self.session.take() else { return };
        if let Err(e) = s.handle.try_dispatch() {
            // pipeline shut down under the session
            s.handle.close_dispatched();
            ctx.metrics.net.sessions_evicted.fetch_add(1, Ordering::Relaxed);
            self.queue_error(ctx, &e);
            self.phase = Phase::Closing;
            return;
        }
        if matches!(self.phase, Phase::Draining)
            && s.handle.framing_done()
            && s.handle.pending_frames() == 0
        {
            s.handle.close_dispatched(); // idempotent
        }
        let mut completed = false;
        loop {
            if self.outbuf.len() >= ctx.net.write_high_water {
                // backpressure: a slow reader stops the drain here; the
                // bounded session channel then holds the pipeline
                // instead of this buffer growing
                break;
            }
            let polled = match s.rx.as_ref() {
                Some(rx) => rx.try_recv(),
                None => break,
            };
            match polled {
                Ok(Ok(chunk)) => {
                    // zero-copy: the decoded chunk becomes an outbound
                    // segment as-is (header-only materialization)
                    self.outbuf.push_frame_owned(kind::BITS, chunk);
                    ctx.metrics
                        .net
                        .write_buf_hwm
                        .fetch_max(self.outbuf.len() as u64, Ordering::Relaxed);
                }
                Ok(Err(e)) => {
                    // the session was poisoned by a pipeline fault (its
                    // shard panicked mid-decode). A retryable fault is
                    // surfaced as a REJECT the shed-aware clients retry
                    // against the restarted shard; anything else is a
                    // terminal ERROR. Either way the error is the last
                    // thing on the wire and the session closes dirty.
                    s.handle.close_dispatched();
                    ctx.metrics.net.sessions_evicted.fetch_add(1, Ordering::Relaxed);
                    if e.is_retryable() {
                        ctx.metrics.net.sessions_shed.fetch_add(1, Ordering::Relaxed);
                        let rej = encode_reject(reject::SHARD_RESTART, e.message());
                        self.queue_frame(ctx, kind::REJECT, &rej);
                    } else {
                        self.queue_error(ctx, &e);
                    }
                    self.phase = Phase::Closing;
                    return;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    s.rx = None;
                    completed = true;
                    break;
                }
            }
        }
        if completed && matches!(self.phase, Phase::Draining) {
            // all decoded bits are at least in the outbound buffer:
            // record the FINISH → last-byte latency and close cleanly
            if let Some(t) = s.t_finish {
                ctx.metrics.record_net_block(t.elapsed());
            }
            self.session = None; // close_dispatched already ran
            self.queue_frame(ctx, kind::END, &[]);
            self.phase = Phase::Closing;
            return;
        }
        self.session = Some(s);
    }

    /// Write as much of the outbound buffer as the socket accepts.
    fn flush(&mut self, ctx: &ServerCtx) {
        if self.write_dead {
            self.outbuf.clear();
            return;
        }
        while self.outbuf.len() > 0 {
            match self.sock.write(self.outbuf.pending()) {
                Ok(0) => {
                    self.write_dead = true;
                    break;
                }
                Ok(n) => {
                    self.outbuf.consume(n);
                    ctx.metrics.net.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
                    self.last_write = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.write_dead = true;
                    break;
                }
            }
        }
        if self.write_dead {
            self.outbuf.clear();
        }
    }

    /// End-of-tick transitions: EOF handling, idle eviction, close
    /// completion.
    fn finalize(&mut self, ctx: &ServerCtx) {
        let idle = ctx.table.idle_timeout();
        match self.phase {
            Phase::Handshake => {
                // silence or disconnect before a session existed:
                // nothing to evict, nothing to count
                if self.eof || self.write_dead || self.last_read.elapsed() > idle {
                    self.phase = Phase::Closing;
                }
            }
            Phase::Streaming => {
                if self.eof || self.write_dead {
                    self.dirty_close(ctx, None);
                } else if self.last_read.elapsed() > idle {
                    let e = Error::net(format!("session evicted: idle for {idle:?}"));
                    self.dirty_close(ctx, Some((kind::ERROR, e.to_string().into_bytes())));
                }
            }
            Phase::Draining => {
                // reads are ignored while draining (matching the old
                // writer-drain behavior), but a reader that stops
                // accepting bytes for a whole idle timeout is evicted
                // rather than wedging the session
                if self.write_dead {
                    self.dirty_close(ctx, None);
                } else if self.outbuf.len() > 0 && self.last_write.elapsed() > idle {
                    self.dirty_close(ctx, None);
                }
            }
            Phase::Closing => {
                // a peer that never drains the final frames does not
                // pin the slot forever
                if self.outbuf.len() == 0 || self.write_dead || self.last_write.elapsed() > idle {
                    self.done = true;
                }
            }
        }
    }

    fn drive(&mut self, ctx: &Arc<ServerCtx>, ready: u8, scratch: &mut [u8]) {
        if ready & READ != 0 && self.wants_read() {
            self.read_some(ctx, scratch);
        }
        self.process_frames(ctx);
        self.pump_session(ctx);
        self.flush(ctx);
        self.finalize(ctx);
    }

    /// Server shutdown: close the pipeline session (no eviction
    /// counter — the server is going away, the session did nothing
    /// wrong) and release resources.
    fn abandon(&mut self) {
        if let Some(mut s) = self.session.take() {
            s.handle.close_dispatched();
        }
    }
}

/// Re-issue `listen(2)` on the bound listener to widen the accept
/// backlog to the session cap. std's `TcpListener::bind` hardcodes a
/// backlog of 128, which a few-thousand-session connect burst
/// overflows — the dropped SYNs stall ~1 s per retransmit before the
/// reactor ever sees them. POSIX allows `listen` on an
/// already-listening socket to update the backlog; the kernel clamps
/// the value to `net.core.somaxconn`. Errors are ignored: the default
/// backlog still serves correctly, just with retransmit stalls under
/// bursts.
#[cfg(unix)]
fn widen_listen_backlog(listener: &TcpListener, backlog: usize) {
    extern "C" {
        fn listen(fd: i32, backlog: i32) -> i32;
    }
    let clamped = backlog.min(i32::MAX as usize) as i32;
    unsafe {
        let _ = listen(listener_fd(listener), clamped);
    }
}

#[cfg(not(unix))]
fn widen_listen_backlog(_listener: &TcpListener, _backlog: usize) {}

/// The reactor loop (one thread per server, regardless of connection
/// count). Exits when the shutdown flag is set — the poll timeout
/// doubles as the shutdown check interval.
pub(crate) fn run_reactor(listener: TcpListener, ctx: Arc<ServerCtx>) {
    let _ = listener.set_nonblocking(true);
    widen_listen_backlog(&listener, ctx.net.max_sessions);
    let idle = ctx.table.idle_timeout();
    let tick = (idle / 4).clamp(Duration::from_millis(5), Duration::from_millis(50));
    let fast = Duration::from_millis(1);
    let mut conns: Vec<Conn> = Vec::new();
    let mut tokens: Vec<usize> = Vec::new();
    let mut set = PollSet::with_poller(ctx.net.poller);
    let code = match set.kind() {
        "epoll" => poller_code::EPOLL,
        "fallback" => poller_code::FALLBACK,
        _ => poller_code::POLL,
    };
    ctx.metrics.net.poller.store(code, Ordering::Relaxed);
    let mut scratch = vec![0u8; 64 * 1024];

    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            break;
        }
        set.clear();
        let ltok = set.register(listener_fd(&listener), READ);
        tokens.clear();
        for c in &conns {
            tokens.push(set.register(stream_fd(&c.sock), c.interest()));
        }
        ctx.metrics.net.reactor_fds.store(set.len() as u64, Ordering::Relaxed);
        let timeout = if conns.iter().any(Conn::wants_fast_tick) { fast } else { tick };
        let n_ready = set.poll(timeout);
        ctx.metrics.net.reactor_wakeups.fetch_add(1, Ordering::Relaxed);
        ctx.metrics.net.reactor_ready_events.fetch_add(n_ready as u64, Ordering::Relaxed);

        if set.readiness(ltok) & READ != 0 {
            loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nodelay(true);
                        if stream.set_nonblocking(true).is_ok() {
                            conns.push(Conn::new(stream));
                            tokens.push(usize::MAX); // not polled this tick
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => {
                        // transient accept failure (ECONNABORTED, EMFILE,
                        // ...): count it and retry next tick
                        note_accept_error(&e, &ctx.metrics.net);
                        break;
                    }
                }
            }
        }
        for (i, c) in conns.iter_mut().enumerate() {
            let ready = match tokens.get(i) {
                Some(&t) if t != usize::MAX => set.readiness(t),
                _ => 0,
            };
            c.drive(&ctx, ready, &mut scratch);
        }
        conns.retain_mut(|c| {
            if c.done {
                if c.admitted {
                    ctx.table.release_tcp();
                }
                false
            } else {
                true
            }
        });
    }
    // shutdown: close every live session so the coordinator can join
    for c in &mut conns {
        c.abandon();
        if c.admitted {
            ctx.table.release_tcp();
        }
    }
    ctx.metrics.net.reactor_fds.store(0, Ordering::Relaxed);
}

/// Count one failed `accept(2)` in `net.accept_errors`. `WouldBlock`
/// is the normal "backlog drained" signal of a nonblocking listener,
/// never an error; everything else is transient but observable.
fn note_accept_error(e: &std::io::Error, net: &crate::coordinator::NetStats) {
    if e.kind() != std::io::ErrorKind::WouldBlock {
        net.accept_errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// A connected TCP decode session. `connect` performs the HELLO/ACK
/// handshake from the builder's parameters; [`push`](TcpClient::push)
/// streams LLR chunks; [`finish`](TcpClient::finish) flushes the
/// stream and collects every decoded payload bit.
pub struct TcpClient {
    stream: TcpStream,
    ack: Ack,
    crc: bool,
}

impl TcpClient {
    /// Connect and handshake. The HELLO carries the builder's
    /// code/backend/termination/tile; a server running anything else
    /// rejects the session (the reject reason and detail land in the
    /// returned [`Error::Net`]).
    pub fn connect(addr: impl ToSocketAddrs, builder: &DecoderBuilder) -> Result<TcpClient> {
        Self::connect_opts(addr, builder, false)
    }

    /// [`connect`](Self::connect), optionally offering a CRC32 on every
    /// DATA frame. The server's ACK decides whether checksums are in
    /// effect (it may switch them on even when not offered, when run
    /// with `net.crc = true`); the client honors the ACK either way.
    pub fn connect_opts(
        addr: impl ToSocketAddrs,
        builder: &DecoderBuilder,
        crc: bool,
    ) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).or_net("connecting to tcvd server")?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT)).or_net("setting read timeout")?;
        let mut hello = Contract::of_builder(builder).hello();
        if crc {
            hello.flags |= flags::DATA_CRC;
        }
        write_frame(&mut (&stream), kind::HELLO, &hello.encode()?)?;
        match read_frame(&mut (&stream), defaults::NET_MAX_FRAME_BYTES)? {
            ReadOutcome::Frame(kind::ACK, p) => {
                let ack = Ack::decode(&p)?;
                let crc = ack.flags & flags::DATA_CRC != 0;
                Ok(TcpClient { stream, ack, crc })
            }
            ReadOutcome::Frame(kind::REJECT, p) => {
                let (reason, detail) = decode_reject(&p)?;
                Err(Error::net(format!(
                    "session rejected ({}): {detail}",
                    reject_reason_name(reason)
                )))
            }
            ReadOutcome::Frame(kind::ERROR, p) => {
                Err(Error::net(format!("server error: {}", String::from_utf8_lossy(&p))))
            }
            ReadOutcome::Frame(k, _) => {
                Err(Error::net(format!("unexpected frame kind {k:#04x} in handshake")))
            }
            ReadOutcome::Eof => Err(Error::net("server closed the connection during handshake")),
            ReadOutcome::TimedOut => Err(Error::net("timed out waiting for the handshake reply")),
        }
    }

    /// The server's ACK: session id + frame geometry + feature flags.
    pub fn ack(&self) -> Ack {
        self.ack
    }

    /// Whether DATA frames on this session carry a CRC32 (the server's
    /// ACK decision).
    pub fn crc(&self) -> bool {
        self.crc
    }

    /// Stream one LLR chunk (length must be a multiple of beta, like
    /// [`Session::push`](crate::coordinator::Session::push)).
    pub fn push(&mut self, llr: &[f32]) -> Result<()> {
        write_frame(&mut (&self.stream), kind::DATA, &encode_data_payload(llr, self.crc))
    }

    /// End the stream and collect every decoded payload bit (one byte
    /// per bit, in order). Consumes the client; the server closes the
    /// connection after its END frame.
    pub fn finish(self) -> Result<Vec<u8>> {
        self.finish_timed().map(|(bits, _)| bits)
    }

    /// [`finish`](Self::finish), also reporting the FINISH → last-byte
    /// latency: the wall time from the FINISH frame hitting the wire to
    /// the END frame (i.e. the server-side decode + drain, excluding
    /// this client's connect and push cadence). This is the per-block
    /// latency quantity the loadgen harness samples.
    pub fn finish_timed(self) -> Result<(Vec<u8>, Duration)> {
        write_frame(&mut (&self.stream), kind::FINISH, &[])?;
        let t0 = Instant::now();
        let mut bits = Vec::new();
        loop {
            match read_frame(&mut (&self.stream), defaults::NET_MAX_FRAME_BYTES)? {
                ReadOutcome::Frame(kind::BITS, p) => bits.extend_from_slice(&p),
                ReadOutcome::Frame(kind::END, _) => return Ok((bits, t0.elapsed())),
                ReadOutcome::Frame(kind::ERROR, p) => {
                    return Err(Error::net(format!(
                        "server error: {}",
                        String::from_utf8_lossy(&p)
                    )))
                }
                ReadOutcome::Frame(kind::REJECT, p) => {
                    let (reason, detail) = decode_reject(&p)?;
                    return Err(Error::net(format!(
                        "session rejected ({}): {detail}",
                        reject_reason_name(reason)
                    )));
                }
                ReadOutcome::Frame(k, _) => {
                    return Err(Error::net(format!("unexpected frame kind {k:#04x} in stream")))
                }
                ReadOutcome::Eof => {
                    return Err(Error::net("connection closed before the END frame"))
                }
                ReadOutcome::TimedOut => {
                    return Err(Error::net("timed out waiting for decoded bits"))
                }
            }
        }
    }

    /// Fetch a metrics snapshot over this session's connection.
    pub fn metrics_json(&mut self) -> Result<String> {
        write_frame(&mut (&self.stream), kind::METRICS_REQ, &[])?;
        loop {
            match read_frame(&mut (&self.stream), defaults::NET_MAX_FRAME_BYTES)? {
                // in-flight decoded bits may interleave ahead of the
                // metrics reply: losing them would corrupt the stream,
                // so metrics_json is only valid before the first push
                // or after finish on a fresh connection
                ReadOutcome::Frame(kind::METRICS, p) => {
                    return String::from_utf8(p).or_net("metrics reply is not UTF-8")
                }
                ReadOutcome::Frame(kind::ERROR, p) => {
                    return Err(Error::net(format!(
                        "server error: {}",
                        String::from_utf8_lossy(&p)
                    )))
                }
                ReadOutcome::Frame(k, _) => {
                    return Err(Error::net(format!(
                        "unexpected frame kind {k:#04x} awaiting metrics"
                    )))
                }
                ReadOutcome::Eof => return Err(Error::net("connection closed awaiting metrics")),
                ReadOutcome::TimedOut => return Err(Error::net("timed out awaiting metrics")),
            }
        }
    }
}

/// One-shot metrics fetch: connect, METRICS_REQ, parse nothing — the
/// raw JSON text is returned (the `tcvd metrics` peer command).
pub fn fetch_metrics(addr: impl ToSocketAddrs) -> Result<String> {
    let stream = TcpStream::connect(addr).or_net("connecting to tcvd server")?;
    stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT)).or_net("setting read timeout")?;
    write_frame(&mut (&stream), kind::METRICS_REQ, &[])?;
    match read_frame(&mut (&stream), defaults::NET_MAX_FRAME_BYTES)? {
        ReadOutcome::Frame(kind::METRICS, p) => {
            String::from_utf8(p).or_net("metrics reply is not UTF-8")
        }
        ReadOutcome::Frame(k, _) => {
            Err(Error::net(format!("unexpected frame kind {k:#04x} awaiting metrics")))
        }
        ReadOutcome::Eof => Err(Error::net("connection closed awaiting metrics")),
        ReadOutcome::TimedOut => Err(Error::net("timed out awaiting metrics")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NetStats;

    #[test]
    fn accept_errors_count_real_failures_only() {
        let net = NetStats::default();
        note_accept_error(&std::io::Error::from(std::io::ErrorKind::WouldBlock), &net);
        assert_eq!(net.accept_errors.load(Ordering::Relaxed), 0, "WouldBlock is not an error");
        note_accept_error(&std::io::Error::from(std::io::ErrorKind::ConnectionAborted), &net);
        note_accept_error(&std::io::Error::from(std::io::ErrorKind::Other), &net);
        assert_eq!(net.accept_errors.load(Ordering::Relaxed), 2);
    }

    /// Reference flat encoding of one wire frame.
    fn flat_frame(frame_kind: u8, payload: &[u8]) -> Vec<u8> {
        let mut wire = vec![frame_kind];
        wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        wire.extend_from_slice(payload);
        wire
    }

    /// Drain an [`OutBuf`] through its `pending`/`consume` contract in
    /// `step`-sized nibbles (1 = worst-case partial writes), returning
    /// the byte stream a socket would have seen.
    fn drain_outbuf(buf: &mut OutBuf, step: usize) -> Vec<u8> {
        let mut seen = Vec::new();
        while buf.len() > 0 {
            let chunk = buf.pending();
            assert!(!chunk.is_empty(), "len says {} but pending is empty", buf.len());
            let n = chunk.len().min(step);
            seen.extend_from_slice(&chunk[..n]);
            buf.consume(n);
        }
        assert!(buf.pending().is_empty());
        seen
    }

    #[test]
    fn outbuf_control_frames_coalesce_into_one_segment() {
        let mut buf = OutBuf::default();
        buf.push_frame(kind::ACK, b"ack-payload");
        buf.push_frame(kind::END, &[]);
        buf.push_frame(kind::REJECT, b"why");
        // one coalesced segment: the three control frames cost a single
        // socket write
        let mut want = flat_frame(kind::ACK, b"ack-payload");
        want.extend(flat_frame(kind::END, &[]));
        want.extend(flat_frame(kind::REJECT, b"why"));
        assert_eq!(buf.len(), want.len());
        assert_eq!(buf.pending(), &want[..], "all three frames share one contiguous segment");
        buf.consume(want.len());
        assert_eq!(buf.len(), 0);
    }

    #[test]
    fn outbuf_owned_push_is_wire_identical_to_copied_push() {
        let payload: Vec<u8> = (0u8..=255).cycle().take(700).collect();
        let mut copied = OutBuf::default();
        copied.push_frame(kind::ACK, b"pre");
        copied.push_frame(kind::BITS, &payload);
        copied.push_frame(kind::END, &[]);
        let mut owned = OutBuf::default();
        owned.push_frame(kind::ACK, b"pre");
        owned.push_frame_owned(kind::BITS, payload.clone());
        owned.push_frame(kind::END, &[]);
        assert_eq!(owned.len(), copied.len());
        // byte-for-byte identical under every flush granularity
        for step in [1, 5, 64, 4096] {
            let mut c = OutBuf::default();
            c.push_frame(kind::ACK, b"pre");
            c.push_frame(kind::BITS, &payload);
            c.push_frame(kind::END, &[]);
            let mut o = OutBuf::default();
            o.push_frame(kind::ACK, b"pre");
            o.push_frame_owned(kind::BITS, payload.clone());
            o.push_frame(kind::END, &[]);
            assert_eq!(drain_outbuf(&mut o, step), drain_outbuf(&mut c, step), "step={step}");
        }
    }

    #[test]
    fn outbuf_owned_payload_is_moved_not_copied() {
        let payload: Vec<u8> = vec![0xAB; 512];
        let payload_ptr = payload.as_ptr();
        let mut buf = OutBuf::default();
        buf.push_frame_owned(kind::BITS, payload);
        // consume exactly the 5-byte header: the next pending slice must
        // be the original allocation, not a copy
        buf.consume(5);
        assert_eq!(buf.pending().len(), 512);
        assert!(
            std::ptr::eq(buf.pending().as_ptr(), payload_ptr),
            "BITS payload was copied instead of moved"
        );
    }

    #[test]
    fn outbuf_partial_consume_across_segment_boundaries() {
        let mut buf = OutBuf::default();
        buf.push_frame(kind::ACK, b"aa");
        buf.push_frame_owned(kind::BITS, vec![1, 2, 3, 4, 5, 6, 7]);
        buf.push_frame_owned(kind::BITS, vec![8, 9]);
        buf.push_frame(kind::END, &[]);
        let mut want = flat_frame(kind::ACK, b"aa");
        want.extend(flat_frame(kind::BITS, &[1, 2, 3, 4, 5, 6, 7]));
        want.extend(flat_frame(kind::BITS, &[8, 9]));
        want.extend(flat_frame(kind::END, &[]));
        assert_eq!(drain_outbuf(&mut buf, 3), want, "3-byte nibbles straddle every boundary");
        // after a full drain the buffer coalesces fresh frames again
        buf.push_frame(kind::END, &[]);
        assert_eq!(buf.pending(), &flat_frame(kind::END, &[])[..]);
        buf.clear();
        assert_eq!(buf.len(), 0);
        assert!(buf.pending().is_empty());
    }
}
